"""Paper Table I: accuracy of the detection system (160 pos / 134 neg).

Reproduces the full train->extract->classify chain on the synthetic
INRIA/MIT stand-in (see DESIGN.md §8.1) with the paper's split sizes and
reports the same three rows PER NUMERICS MODE: the fp32 chain (paper's
Matlab software role) and the fixed-point chain (the hardware datapath:
integer CORDIC, int16 histograms, int8 descriptors -- DESIGN.md §12).
Paper values: 83.75 % / 85.07 % / 84.35 %.

Each mode trains its own SVM on its own descriptors (the paper trains on
the datapath it deploys); the gate (`--check`) enforces total accuracy
>= 0.80 for every mode and |fixed - fp32| <= 1.5 points -- the fixed
chain must not cost detection quality.

Results land in BENCH_detect.json under the "accuracy" key through the
shared merge-update writer (bench_io.py), flat scalars only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

try:                                   # package-style (python -m benchmarks.run)
    from benchmarks.bench_io import update_bench
except ImportError:                    # direct: python benchmarks/bench_accuracy.py
    from bench_io import update_bench

from repro.configs import hog_svm
from repro.core.hog import HOGConfig, PAPER_HOG, hog_descriptor
from repro.core.svm import SVMTrainConfig, accuracy_table, train_svm
from repro.data.synth_pedestrian import PedestrianDataConfig, make_dataset

PAPER = {"with_person_acc": 0.8375, "without_person_acc": 0.8507,
         "total_acc": 0.8435}

#: numerics modes Table I is reproduced for. fp32 = the software oracle
#: chain; fixed = the quantized datapath (the paper's actual hardware).
MODES: Dict[str, HOGConfig] = {
    "fp32": PAPER_HOG,
    "fixed": hog_svm.QUANT,
}

#: CI gate thresholds (--check): every mode's total accuracy, and the
#: fixed-vs-fp32 total-accuracy gap in accuracy points
MIN_TOTAL_ACC = 0.80
MAX_FIXED_GAP_PTS = 1.5


def _extract(x: np.ndarray, cfg: HOGConfig) -> np.ndarray:
    return np.asarray(hog_descriptor(jnp.asarray(x), cfg))


def run(fast: bool = False,
        data_cfg: Optional[PedestrianDataConfig] = None,
        modes: Sequence[str] = tuple(MODES),
        train_cfg: SVMTrainConfig = SVMTrainConfig(steps=4000,
                                                   neg_weight=3.0),
        ) -> Dict[str, float]:
    """Table I per numerics mode. Returns a FLAT metrics dict
    (mode-prefixed scalar keys) and writes it to BENCH_detect.json
    under "accuracy".

    fast=True shrinks only the TRAIN split sizes of `data_cfg` via
    dataclasses.replace -- any other non-default dataset field (noise,
    contrast, seed, ...) the caller configured is preserved. (The old
    code rebuilt PedestrianDataConfig(n_pos=..., n_neg=...) from
    scratch, silently resetting every other field to its default.)
    """
    cfg = data_cfg if data_cfg is not None else PedestrianDataConfig()
    if fast:
        # 2400/1650 is the smallest split where BOTH numerics modes
        # clear the 0.80 gate with the seeded Pegasos run (800/550, the
        # old shrink, lands ~0.72 -- under the gate, not a regression)
        cfg = dataclasses.replace(cfg, n_pos=2400, n_neg=1650)

    x_tr, y_tr, x_te, y_te = make_dataset(cfg)
    y_trj, y_tej = jnp.asarray(y_tr), jnp.asarray(y_te)

    metrics: Dict[str, float] = {
        "fast": bool(fast), "n_train": int(len(y_tr)),
        "n_test": int(len(y_te)),
    }
    print("# Table I -- accuracy (ours vs paper), per numerics mode")
    for mode in modes:
        hog_cfg = MODES[mode]
        t0 = time.time()
        f_tr = _extract(x_tr, hog_cfg)
        f_te = _extract(x_te, hog_cfg)
        t_extract = time.time() - t0

        t0 = time.time()
        params, _ = train_svm(jnp.asarray(f_tr), y_trj, train_cfg)
        t_train = time.time() - t0

        acc = accuracy_table(params, jnp.asarray(f_te), y_tej)
        for key in ("with_person_acc", "without_person_acc", "total_acc"):
            metrics[f"{mode}_{key}"] = float(acc[key])
            print(f"table1/{mode}/{key},{acc[key]:.4f},"
                  f"paper={PAPER[key]:.4f}")
        metrics[f"{mode}_train_s"] = float(t_train)
        metrics[f"{mode}_extract_s"] = float(t_extract)
        metrics[f"{mode}_gap_vs_paper_pts"] = \
            (float(acc["total_acc"]) - PAPER["total_acc"]) * 100.0
        print(f"table1/{mode}/train_time_s,{t_train:.1f},paper=298.3")

    if "fp32" in modes and "fixed" in modes:
        gap = (metrics["fixed_total_acc"] - metrics["fp32_total_acc"]) * 100
        metrics["fixed_vs_fp32_gap_pts"] = float(gap)
        print(f"table1/fixed_vs_fp32_gap_pts,{gap:+.2f},gate<= "
              f"{MAX_FIXED_GAP_PTS}")

    update_bench(accuracy=metrics)
    return metrics


def check(metrics: Dict[str, float],
          modes: Sequence[str] = tuple(MODES)) -> int:
    """CI gate: 0 iff every mode's total accuracy clears MIN_TOTAL_ACC
    and the fixed-vs-fp32 gap is within MAX_FIXED_GAP_PTS points."""
    failures = []
    for mode in modes:
        total = metrics.get(f"{mode}_total_acc")
        if total is None or total < MIN_TOTAL_ACC:
            failures.append(f"{mode}_total_acc={total} < {MIN_TOTAL_ACC}")
    gap = metrics.get("fixed_vs_fp32_gap_pts")
    if gap is not None and abs(gap) > MAX_FIXED_GAP_PTS:
        failures.append(
            f"|fixed_vs_fp32_gap_pts|={abs(gap):.2f} > {MAX_FIXED_GAP_PTS}")
    for f in failures:
        print(f"accuracy-gate/FAIL,{f}")
    if not failures:
        print("accuracy-gate/ok,all thresholds cleared")
    return 1 if failures else 0


def format_table(metrics: Dict[str, float],
                 modes: Sequence[str] = tuple(MODES)) -> str:
    """The Table I artifact (plain text) the CI lane uploads."""
    rows = [("row", *modes, "paper")]
    for key, paper in (("with_person_acc", PAPER["with_person_acc"]),
                       ("without_person_acc", PAPER["without_person_acc"]),
                       ("total_acc", PAPER["total_acc"])):
        rows.append((key,
                     *(f"{metrics.get(f'{m}_{key}', float('nan')):.4f}"
                       for m in modes),
                     f"{paper:.4f}"))
    rows.append(("train_s",
                 *(f"{metrics.get(f'{m}_train_s', float('nan')):.1f}"
                   for m in modes), "298.3"))
    if "fixed_vs_fp32_gap_pts" in metrics:
        rows.append(("fixed_vs_fp32_gap_pts",
                     *([f"{metrics['fixed_vs_fp32_gap_pts']:+.2f}"]
                       + [""] * (len(modes) - 1)),
                     f"<={MAX_FIXED_GAP_PTS}"))
    widths = [max(len(str(r[i])) for r in rows) for i in range(len(rows[0]))]
    return "\n".join(
        "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
        for r in rows) + "\n"


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shrink the train split (800/550) for CI")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every mode's total accuracy >= "
                         f"{MIN_TOTAL_ACC} and the fixed-vs-fp32 gap is "
                         f"within {MAX_FIXED_GAP_PTS} points")
    ap.add_argument("--table", type=str, default=None, metavar="PATH",
                    help="also write the Table I text artifact here")
    ap.add_argument("--modes", type=str, default=",".join(MODES),
                    help="comma-separated subset of: " + ",".join(MODES))
    a = ap.parse_args(argv)
    modes = tuple(m for m in a.modes.split(",") if m)
    unknown = [m for m in modes if m not in MODES]
    if unknown:
        ap.error(f"unknown modes {unknown}; available: {sorted(MODES)}")
    metrics = run(fast=a.fast, modes=modes)
    if a.table:
        import pathlib
        pathlib.Path(a.table).write_text(format_table(metrics, modes))
        print(f"table1/artifact,{a.table},written")
    return check(metrics, modes) if a.check else 0


if __name__ == "__main__":
    raise SystemExit(main())
