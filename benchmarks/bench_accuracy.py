"""Paper Table I: accuracy of the detection system (160 pos / 134 neg).

Reproduces the full train->extract->classify chain on the synthetic
INRIA/MIT stand-in (see DESIGN.md §8.1) with the paper's split sizes and
reports the same three rows. Paper values: 83.75 % / 85.07 % / 84.35 %.
"""
from __future__ import annotations

import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.core.svm import SVMTrainConfig, accuracy_table, train_svm
from repro.data.synth_pedestrian import PedestrianDataConfig, make_dataset

PAPER = {"with_person_acc": 0.8375, "without_person_acc": 0.8507,
         "total_acc": 0.8435}


def run(fast: bool = False) -> Dict[str, float]:
    cfg = PedestrianDataConfig()
    if fast:
        cfg = PedestrianDataConfig(n_pos=800, n_neg=550)
    t0 = time.time()
    x_tr, y_tr, x_te, y_te = make_dataset(cfg)
    f_tr = np.asarray(hog_descriptor(jnp.asarray(x_tr), PAPER_HOG))
    f_te = np.asarray(hog_descriptor(jnp.asarray(x_te), PAPER_HOG))
    t_extract = time.time() - t0

    t0 = time.time()
    params, losses = train_svm(
        jnp.asarray(f_tr), jnp.asarray(y_tr),
        SVMTrainConfig(steps=4000, neg_weight=6.0))
    t_train = time.time() - t0

    acc = accuracy_table(params, jnp.asarray(f_te), jnp.asarray(y_te))
    rows = [
        ("with_person", acc["with_person_acc"], PAPER["with_person_acc"]),
        ("without_person", acc["without_person_acc"],
         PAPER["without_person_acc"]),
        ("total", acc["total_acc"], PAPER["total_acc"]),
    ]
    print("# Table I -- accuracy (ours vs paper)")
    for name, ours, paper in rows:
        print(f"table1/{name},{ours:.4f},paper={paper:.4f}")
    print(f"table1/train_time_s,{t_train:.1f},paper=298.3")
    print(f"table1/extract_time_s,{t_extract:.1f},n={len(y_tr)}")
    return {"acc": acc, "train_s": t_train}


if __name__ == "__main__":
    run()
