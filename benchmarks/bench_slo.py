"""SLO harness: the serving contract as a CI gate (DESIGN.md §15).

The paper's pitch is a latency CONTRACT -- 0.757 ms/frame at 50 MHz,
not a mean it sometimes hits -- and this repro's serving story should
be held to the same standard. `run_slo` replays seeded golden clips
(data/synth_pedestrian.make_clip: constant-velocity pedestrians over
static clutter) through the real DetectionService and records

    p50/p99 ms/frame   client-observed sojourn latency (submit ->
                       future resolution through the microbatcher),
                       best-of-rounds so one noisy CI neighbour does
                       not fail the lane
    miss rate          ground-truth pedestrians with no detection
                       within the +-32 px corner criterion
                       (launch/detect.py's recall rule), over every
                       clip frame -- the accuracy half of the SLO

into BENCH_detect.json under "slo". `--check` re-measures and gates
BOTH against the committed baseline: p99 host-normalized by the
calibration mini-pipeline (bench_timing._calibration_fn, recorded next
to the baseline -- a slower CI runner scales the limit instead of
failing it), miss rate with a small absolute slack (accuracy does not
host-normalize). A missing baseline is a SKIP, not a failure, same as
bench_timing --check.

`--metrics PATH` streams the service's structured events (obs/metrics)
to a JSONL artifact the CI lane uploads -- every gated number ships
with the event stream that produced it.

Usage:
    python benchmarks/bench_slo.py [--fast]            # record baseline
    python benchmarks/bench_slo.py --check [--fast]    # CI gate
    python benchmarks/bench_slo.py --check --metrics slo_metrics.jsonl
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro import platform  # noqa: E402  (applies REPRO_* at import)

platform.hermetic_autotune()   # probe live, don't inherit a stale cache

import numpy as np             # noqa: E402

try:                                   # package-style
    from benchmarks.bench_io import update_bench as _update_bench
    from benchmarks.bench_timing import _calibration_fn
except ImportError:                    # direct: python benchmarks/bench_slo.py
    from bench_io import update_bench as _update_bench
    from bench_timing import _calibration_fn

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_detect.json"

#: corner-match radius of the recall criterion (launch/detect.py)
MATCH_PX = 32

#: --check tolerances: p99 is wall-time on shared CI runners even after
#: host normalization (the service path adds queueing the calibration
#: pipeline cannot see), so the latency gate is generous; the miss-rate
#: slack absorbs SVM training noise on the fast split
P99_TOLERANCE = 0.50
MISS_RATE_SLACK = 0.05


def _golden_clips(fast: bool):
    """Seeded clips the SLO replays -- two traffic shapes: a busy
    240x320 street and a sparser 256x384 one. REPRO_SEED shifts the
    whole suite for replay experiments (default 0 = the committed
    baseline's clips)."""
    from repro.data.synth_pedestrian import ClipConfig, make_clip
    seed = platform.default_seed()
    rng = np.random.default_rng(seed)
    n = 6 if fast else 12
    clips = [make_clip(rng, ClipConfig(n_frames=n, h=240, w=320,
                                       n_people=2)),
             make_clip(rng, ClipConfig(n_frames=n, h=256, w=384,
                                       n_people=1, n_distractors=5))]
    return clips, seed


def _train_session(fast: bool):
    from repro.api import DetectionSession, PipelineConfig
    from repro.core.detector import DetectorConfig
    from repro.core.svm import SVMTrainConfig
    cfg = PipelineConfig(
        detector=DetectorConfig(score_threshold=0.5),
        train=SVMTrainConfig(steps=1200 if fast else 2500,
                             neg_weight=6.0))
    rng = np.random.default_rng(platform.default_seed())
    n_pos, n_neg = (500, 350) if fast else (1500, 1000)
    return DetectionSession.train(cfg, n_pos=n_pos, n_neg=n_neg, rng=rng)


def _matched(dets, box) -> bool:
    y0, x0 = box[0], box[1]
    return any(abs(d["box"][0] - y0) < MATCH_PX
               and abs(d["box"][1] - x0) < MATCH_PX for d in dets)


def _measure_round(service, clips):
    """One replay of every clip through the service, frame by frame
    (client-observed sojourn: submit -> result). Returns (latencies_ms,
    truth_total, truth_missed)."""
    lat, total, missed = [], 0, 0
    for frames, truths in clips:
        for t in range(len(frames)):
            t0 = time.perf_counter()
            r = service.detect_frames([np.asarray(frames[t])],
                                      timeout=120)[0]
            lat.append((time.perf_counter() - t0) * 1e3)
            dets = r.get("detections", [])
            for person in truths[t]:
                total += 1
                missed += not _matched(dets, person["box"])
    return lat, total, missed


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def run_slo(fast: bool = False, metrics_path: str = "",
            write: bool = True) -> dict:
    """Measure the serving SLO numbers; write BENCH "slo" when asked."""
    from repro.obs import MetricsConfig

    clips, seed = _golden_clips(fast)
    n_frames = sum(len(f) for f, _ in clips)
    print(f"# SLO replay -- {len(clips)} golden clips, {n_frames} "
          f"frames, seed {seed}")
    session = _train_session(fast)

    opts = {}
    if metrics_path:
        opts["metrics"] = MetricsConfig(jsonl_path=metrics_path, ring=64)
    service = session.serve(frame_batch=1, **opts).start()
    try:
        # round 0 pays every per-bucket compile; it never scores
        _measure_round(service, clips)
        rounds = 2 if fast else 3
        best = None
        total = missed = 0
        for i in range(rounds):
            lat, total, missed = _measure_round(service, clips)
            row = {"p50_ms": _pct(lat, 50), "p99_ms": _pct(lat, 99),
                   "mean_ms": float(np.mean(lat))}
            print(f"slo/round{i},p50 {row['p50_ms']:.2f} ms,"
                  f"p99 {row['p99_ms']:.2f} ms")
            if best is None or row["p99_ms"] < best["p99_ms"]:
                best = row
        svc_stats = {"frames": service.stats["frames"],
                     "batches": service.stats["frame_batches"],
                     "answers": service.stats["frame_answers"]}
    finally:
        service.stop()

    miss_rate = missed / max(1, total)
    calib = _calibration_fn()
    calib()                                       # compile
    best_c = np.inf
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(5):
            calib()
        best_c = min(best_c, (time.perf_counter() - t0) / 5)
    calib_ms = best_c * 1e3

    row = {
        "p50_ms": round(best["p50_ms"], 3),
        "p99_ms": round(best["p99_ms"], 3),
        "mean_ms": round(best["mean_ms"], 3),
        "miss_rate": round(miss_rate, 4),
        "truth_boxes": total,
        "missed": missed,
        "frames_per_round": n_frames,
        "rounds": rounds,
        "fast": fast,
        "seed": seed,
        "calibration_ms": round(calib_ms, 3),
        "platform": platform.describe(),
    }
    print(f"slo/p50_ms,{row['p50_ms']:.2f}")
    print(f"slo/p99_ms,{row['p99_ms']:.2f},best of {rounds} rounds")
    print(f"slo/miss_rate,{miss_rate:.4f},{missed}/{total} truth boxes")
    print(f"slo/calibration_ms,{calib_ms:.3f}")
    if metrics_path:
        print(f"slo/metrics,{metrics_path}")
    if write:
        _update_bench(slo=row)
        print(f"slo/WROTE,{BENCH_JSON}")
    row["service"] = svc_stats
    return row


def run_check(fast: bool = True, metrics_path: str = "") -> int:
    """Gate p99 ms/frame AND miss rate against the committed "slo"
    baseline. Exit 1 on breach; a missing baseline SKIPs (exit 0) so a
    branch that resets BENCH_detect.json does not turn CI red without
    an actual regression. Never writes the json."""
    if not BENCH_JSON.exists():
        print("slo-check/SKIP,no BENCH_detect.json baseline")
        return 0
    base = json.loads(BENCH_JSON.read_text()).get("slo")
    if not base:
        print("slo-check/SKIP,no slo section in BENCH_detect.json "
              "(run benchmarks/bench_slo.py to record one)")
        return 0

    now = run_slo(fast=fast, metrics_path=metrics_path, write=False)

    calib_base = base.get("calibration_ms")
    scale = (now["calibration_ms"] / calib_base) if calib_base else 1.0
    p99_limit = base["p99_ms"] * scale * (1.0 + P99_TOLERANCE)
    miss_limit = base["miss_rate"] + MISS_RATE_SLACK

    p99_ok = now["p99_ms"] <= p99_limit
    miss_ok = now["miss_rate"] <= miss_limit
    print(f"slo-check/baseline,p99 {base['p99_ms']:.2f} ms,"
          f"miss {base['miss_rate']:.4f},calib "
          f"{calib_base and f'{calib_base:.3f}'} ms")
    print(f"slo-check/host_scale,{scale:.3f},"
          f"calib now {now['calibration_ms']:.3f} ms")
    print(f"slo-check/p99,{now['p99_ms']:.2f},limit {p99_limit:.2f} "
          f"(+{P99_TOLERANCE:.0%} host-normalized),"
          f"{'PASS' if p99_ok else 'FAIL'}")
    print(f"slo-check/miss_rate,{now['miss_rate']:.4f},"
          f"limit {miss_limit:.4f} (+{MISS_RATE_SLACK} abs),"
          f"{'PASS' if miss_ok else 'FAIL'}")
    verdict = "PASS" if (p99_ok and miss_ok) else "FAIL"
    print(f"slo-check/{verdict},p99 + miss-rate SLO")
    return 0 if verdict == "PASS" else 1


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", action="store_true",
                    help="smaller train split, fewer clips/rounds "
                         "(the CI lane's mode)")
    ap.add_argument("--check", action="store_true",
                    help="gate p99 + miss-rate vs the committed BENCH "
                         "slo baseline instead of recording one")
    ap.add_argument("--metrics", metavar="PATH", default="",
                    help="stream service events to this JSONL file "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args(argv)
    if args.check:
        return run_check(fast=args.fast, metrics_path=args.metrics)
    run_slo(fast=args.fast, metrics_path=args.metrics)
    return 0


if __name__ == "__main__":
    sys.exit(main())
