"""Framework throughput benchmarks: train-step tokens/s and decode
steps/s for a small config on the host (the large-scale numbers are
dry-run roofline territory -- see bench_roofline.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import decode_step, init_cache, prefill
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def run(fast: bool = False):
    cfg = get_config("qwen3-14b", smoke=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    B, S = 8, 128
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    step = jax.jit(make_train_step(cfg, OptConfig()))
    state, _ = step(state, batch)           # compile
    iters = 5 if fast else 10
    t0 = time.perf_counter()
    for _ in range(iters):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / iters
    print(f"train/smoke_tokens_per_s,{B*S/dt:.0f},B={B} S={S}")
    print(f"train/smoke_step_ms,{dt*1e3:.1f},")

    # decode throughput
    params = state["params"]
    pre = {"tokens": jnp.ones((B, 16), jnp.int32)}
    logits, cache = prefill(params, pre, cfg, max_len=64)
    dstep = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache = dstep(params, tok, cache)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, cache = dstep(params, tok, cache)
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / iters
    print(f"serve/smoke_decode_tokens_per_s,{B/dt:.0f},B={B}")
    return dt


if __name__ == "__main__":
    run()
