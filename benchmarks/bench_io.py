"""Shared BENCH_detect.json merge-update writer.

Both bench entry points (bench_timing.py, bench_accuracy.py) record
into the same BENCH_detect.json; each section must preserve the others'
rows, so every writer goes through update_bench (read -> dict.update ->
atomic-enough single write). Kept dependency-free so scripts can run
directly (`python benchmarks/bench_accuracy.py`) or as a package module
(`python -m benchmarks.run`) -- hence the dual-import dance at the use
sites.
"""
from __future__ import annotations

import json
import pathlib

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_detect.json"


def update_bench(**updates) -> None:
    """Merge-update BENCH_detect.json, preserving other sections. Every
    write also refreshes the top-level "platform" stamp
    (repro.platform.describe()) so the recorded numbers are always
    attributable to the environment that measured them; best-effort --
    a jax-free caller still gets its section written."""
    data = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else {}
    data.update(updates)
    try:
        from repro import platform
        data["platform"] = platform.describe()
    except Exception:
        pass
    BENCH_JSON.write_text(json.dumps(data, indent=2) + "\n")
