"""Per-stage kernel decomposition (the paper's 108-cycle cell pipeline /
47-cycle normalizer, as per-stage wall times) + staged-vs-fused HBM
traffic accounting for the Pallas kernels.

CPU wall times use the jnp reference path (XLA-fused -- what the fused
Pallas kernel mirrors structurally); the Pallas kernels themselves are
validated in interpret mode (tests/) and targeted at TPU, so we report
their ANALYTIC per-window HBM bytes, which is the term that determines
TPU latency (the HOG chain is memory-bound: ~0.02 flops/byte).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hog as H


def _time(fn, *args, iters=20):
    for _ in range(3):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(fast: bool = False):
    rng = np.random.default_rng(0)
    B = 64 if fast else 256
    gray = jnp.asarray(
        rng.integers(0, 256, (B, 130, 66)).astype(np.float32))
    cfg = H.PAPER_HOG

    grad = jax.jit(lambda g: H.gradients(g))
    fx, fy = grad(gray)
    magbin = jax.jit(lambda a, b: H.mag_bin_sector(a, b))
    mag, bi = magbin(fx, fy)
    cell = jax.jit(lambda m, b: H.cell_histograms(m, b, cfg))
    hist = cell(mag, bi)
    bnorm = jax.jit(lambda h: H.block_normalize(h, cfg))

    stages = [
        ("gradient", _time(grad, gray)),
        ("mag_bin_sector", _time(magbin, fx, fy)),
        ("mag_bin_cordic",
         _time(jax.jit(lambda a, b: H.mag_bin_cordic(a, b)), fx, fy)),
        ("cell_hist", _time(cell, mag, bi)),
        ("block_norm", _time(bnorm, hist)),
    ]
    print("# per-stage times (us/window) -- the 108-cycle/47-cycle "
          "pipeline decomposition")
    for name, t in stages:
        print(f"kernels/{name}_us_per_window,{t/B*1e6:.2f},B={B}")

    # staged vs fused HBM traffic per window (drives TPU latency)
    in_b = 130 * 66 * 4
    mag_b = 128 * 64 * 4 * 2          # mag + bin int32
    hist_b = 16 * 8 * 9 * 4
    desc_b = 3780 * 4
    staged = (in_b + mag_b) + (mag_b + hist_b) + (hist_b + desc_b)
    fused = in_b + desc_b
    print(f"kernels/staged_hbm_bytes_per_window,{staged},3 pallas_calls")
    print(f"kernels/fused_hbm_bytes_per_window,{fused},1 pallas_call")
    print(f"kernels/fused_traffic_reduction,{staged/fused:.2f},x")
    # v5e roofline latency of the fused kernel per 256-window batch
    t_mem = fused * 256 / 819e9
    print(f"kernels/fused_tpu_roofline_us_per_256batch,{t_mem*1e6:.1f},"
          f"memory-bound")
    return stages


if __name__ == "__main__":
    run()
