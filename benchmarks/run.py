"""Benchmark runner: one section per paper table + framework benches.
Prints ``name,value,derived`` CSV rows. ``--fast`` trims sizes for CI.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="accuracy|timing|kernels|roofline|train")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_kernels, bench_roofline,
                            bench_timing, bench_train)
    benches = {
        "accuracy": lambda: bench_accuracy.run(fast=args.fast),
        "timing": lambda: bench_timing.run(fast=args.fast),
        "kernels": lambda: bench_kernels.run(fast=args.fast),
        "train": lambda: bench_train.run(fast=args.fast),
        "roofline": lambda: bench_roofline.run(),
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n== bench:{name} ==", flush=True)
        try:
            fn()
        except Exception as e:  # report, keep going
            print(f"{name}/FAILED,{e!r},", file=sys.stderr)
            print(f"{name}/FAILED,{e!r},")
        print(f"{name}/bench_wall_s,{time.time()-t0:.1f},")


if __name__ == '__main__':
    main()
