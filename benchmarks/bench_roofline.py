"""§Roofline: render the per-(arch x shape x mesh) table from the dry-run
results JSON (results/dryrun.json, produced by launch/dryrun.py).

Per cell: the three terms (s), dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
ratio, MFU at roofline, and per-device memory. This file does not compile
anything -- it reads the dry-run artifact, so it stays fast in CI.
"""
from __future__ import annotations

import json
import os

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run(path: str = DEFAULT):
    if not os.path.exists(path):
        print(f"roofline/skip,0,no dryrun results at {path}")
        return
    with open(path) as f:
        rows = json.load(f)
    print("# §Roofline -- arch,shape,mesh,t_compute_s,t_memory_s,t_coll_s,"
          "bottleneck,useful_flops_frac,mfu,peak_GiB")
    n_ok = 0
    for key, r in sorted(rows.items()):
        if r.get("status") == "skip":
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},SKIP,"
                  f"{r['reason']}")
            continue
        if r.get("status") != "ok":
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},ERROR,"
                  f"{r.get('error', '?')}")
            continue
        n_ok += 1
        peak = r["mem"]["peak_bytes"] / 2 ** 30
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
              f"{r['t_coll_s']:.4g},{r['bottleneck']},"
              f"{r['useful_flops_frac']:.3f},{r['mfu']:.4f},{peak:.2f}")
    print(f"roofline/cells_ok,{n_ok},")


if __name__ == "__main__":
    run()
