"""Paper Table II: detection timing, software vs co-processor path.

The paper: Matlab 41 ms/window vs ModelSim hardware 0.757 ms at 50 MHz
(54x). The TPU analogue measured here:

  software path    -- per-window (batch=1) jit'd jnp pipeline on CPU
                      (the "Matlab" role: one window at a time)
  co-processor path -- batched pipeline, per-window time amortized over
                      a 256-window batch (the TPU dataflow role)
  dense-scene path  -- score_map conv: per-WINDOW time when windows
                      overlap in a scene (beyond-paper, §Perf)
  TPU roofline      -- derived per-window latency from the dry-run
                      (bytes/819GBps vs flops/197TFLOPs), reported by
                      benchmarks/bench_roofline.py from dryrun.json

Timing on this container is CPU wall time -- the RATIO between the
software and batched paths is the reproduction target, not the absolute
numbers (the paper's own 54x compares two implementations on different
substrates as well).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.core.pipeline import classify_windows
from repro.core.svm import init_svm
from repro.core.detector import DetectorConfig, FrameDetector, score_map


BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_detect.json"


def _update_bench(**updates):
    """Merge-update BENCH_detect.json so independent bench entry points
    (full detect sweep, session_overhead) preserve each other's rows."""
    data = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() else {}
    data.update(updates)
    BENCH_JSON.write_text(json.dumps(data, indent=2) + "\n")


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(fast: bool = False):
    rng = np.random.default_rng(0)
    svm = init_svm(3780)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    win1 = jnp.asarray(rng.integers(0, 256, (1, 130, 66, 3)).astype(np.uint8))
    B = 64 if fast else 256
    winB = jnp.asarray(rng.integers(0, 256, (B, 130, 66, 3)).astype(np.uint8))

    f1 = jax.jit(lambda w: classify_windows(svm, w)["score"])
    t_sw = _time(f1, win1)                      # per window, batch=1
    t_batch = _time(f1, winB) / B               # amortized per window

    fx = jax.jit(lambda w: hog_descriptor(w, PAPER_HOG))
    t_extract1 = _time(fx, win1)
    t_extractB = _time(fx, winB) / B

    # dense scene: 320x240 -> ~600 window positions in one conv
    scene = jnp.asarray(rng.integers(0, 256, (320, 240)).astype(np.float32))
    sm = jax.jit(lambda s: score_map(s, svm["w"], svm["b"], PAPER_HOG))
    smap = sm(scene)
    n_windows = smap.shape[0] * smap.shape[1]
    t_scene = _time(sm, scene) / n_windows

    print("# Table II -- timing per window (CPU host; ratios are the "
          "reproduction target)")
    print(f"table2/attracting_software_ms,{t_extract1*1e3:.3f},paper=16")
    print(f"table2/attracting_batched_ms,{t_extractB*1e3:.3f},paper=0.411")
    print(f"table2/detecting_software_ms,{t_sw*1e3:.3f},paper=41")
    print(f"table2/detecting_batched_ms,{t_batch*1e3:.3f},paper=0.757")
    # NOTE: on this 1-core CPU host, batching cannot beat batch=1 (no
    # parallel hardware -- the paper's 54x IS its hardware parallelism).
    # The two host-measurable analogues of the paper's speedup are:
    #   * dense-scene amortization (one conv scores ~500 windows), and
    #   * the TPU roofline latency from the dry-run (60.5 ns/window,
    #     bench_roofline.py / EXPERIMENTS.md §Roofline).
    print(f"table2/speedup_batched_cpu_host,{t_sw/t_batch:.1f},"
          f"paper=54 (needs parallel hw; see dense_scene + roofline)")
    print(f"table2/detecting_dense_scene_ms,{t_scene*1e3:.4f},"
          f"windows={n_windows}")
    print(f"table2/speedup_dense_scene,{t_sw/t_scene:.1f},"
          f"beyond-paper analogue of the 54x")
    print(f"table2/tpu_roofline_ns_per_window,60.5,"
          f"vs paper 757000 ns (dryrun hog cell)")

    det = run_detect(fast=fast)
    ses = run_session_overhead(fast=fast)
    return {"speedup": t_sw / t_scene, "detect": det,
            "session_overhead": ses}


# ----------------------------------------------------------- batched video
# Frames/s of detect_batch (the vmapped/scanned per-bucket program, one
# dispatch + one host sync per batch) vs the same frames through
# sequential detect() calls. The acceptance target: batched B>=4 at
# 640x480 beats 4x sequential.

def run_detect_batch(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 480, 640
    batches = [4] if fast else [4, 8]
    det = FrameDetector(svm, DetectorConfig(scales=(1.0, 0.8, 0.64)))
    frames = [rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
              for _ in range(max(batches))]
    det(frames[0])                                   # compile single
    results = {}
    rounds = 3 if fast else 7
    print("# batched video path -- detect_batch vs sequential detect()")
    for B in batches:
        det.detect_batch(frames[:B])                 # compile (bucket, B)
        # alternate the two paths and keep each one's BEST round: the
        # host is shared/noisy and the signal is ~10%, so paired
        # min-of-k over >=1s samples is what makes the comparison
        # reproducible (reps stretches small-B rounds to B*reps >= 16
        # frames per sample)
        reps = max(1, 16 // B)
        t_seq, t_bat = np.inf, np.inf
        for _ in range(rounds):
            t0 = time.perf_counter()
            for _ in range(reps):
                for f in frames[:B]:
                    det(f)
            t_seq = min(t_seq, (time.perf_counter() - t0) / (B * reps))
            t0 = time.perf_counter()
            for _ in range(reps):
                det.detect_batch(frames[:B])
            t_bat = min(t_bat, (time.perf_counter() - t0) / (B * reps))
        results[f"B{B}"] = {
            "batch": B,
            "seq_ms_per_frame": t_seq * 1e3,
            "seq_fps": 1.0 / t_seq,
            "batch_ms_per_frame": t_bat * 1e3,
            "batch_fps": 1.0 / t_bat,
            "speedup_batch_vs_seq": t_seq / t_bat,
        }
        print(f"detect_batch/{w}x{h}_B{B}_seq_fps,{1/t_seq:.2f},"
              f"{t_seq*1e3:.1f} ms/frame")
        print(f"detect_batch/{w}x{h}_B{B}_batch_fps,{1/t_bat:.2f},"
              f"{t_bat*1e3:.1f} ms/frame")
        print(f"detect_batch/{w}x{h}_B{B}_speedup,{t_seq/t_bat:.3f},"
              f"batched vs sequential")
    return results


# ----------------------------------------------------------- multi-scale
# Dense device-resident detection vs. the per-window-recompute baseline
# (slice every window position at 8-px stride per pyramid scale, HOG each
# window independently). This is the beyond-paper detection hot path the
# refactor targets; BENCH_detect.json records the trajectory.

def _per_window_recompute(frame: np.ndarray, svm, per_scale,
                          batch: int = 512) -> int:
    """The naive baseline: re-extract HOG for every window of every
    pyramid scale independently (no dense sharing). `per_scale` is the
    detector program's own (scale, PH, PW) geometry (FrameProgram.per_scale),
    so both paths score exactly the same window positions. Returns #windows."""
    fn = jax.jit(lambda x: classify_windows(svm, x)["score"])
    n_windows = 0
    hcfg = PAPER_HOG
    h, w = frame.shape[:2]
    for s, ph, pw in per_scale:
        g = np.asarray(jax.image.resize(jnp.asarray(frame, jnp.float32),
                                        (int(h * s), int(w * s), 3),
                                        "linear"))
        wins = np.empty((ph * pw, hcfg.window_h, hcfg.window_w, 3),
                        np.float32)
        for i in range(ph):
            for j in range(pw):
                wins[i * pw + j] = g[i * 8:i * 8 + hcfg.window_h,
                                     j * 8:j * 8 + hcfg.window_w]
        for k in range(0, len(wins), batch):
            chunk = wins[k:k + batch]
            if len(chunk) < batch:            # pad to the compiled batch
                chunk = np.concatenate(
                    [chunk, np.zeros((batch - len(chunk),) + chunk.shape[1:],
                                     np.float32)])
            jax.block_until_ready(fn(jnp.asarray(chunk)))
        n_windows += ph * pw
    return n_windows


def run_detect(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    sizes = [(480, 640)] if fast else [(480, 640), (720, 1280)]
    scales = (1.0, 0.8, 0.64)
    results = {}
    print("# multi-scale detection -- dense device-resident vs "
          "per-window recompute")
    for (h, w) in sizes:
        frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
        det = FrameDetector(svm, DetectorConfig(scales=scales,
                                                score_threshold=0.0))
        prog, ph_pad, pw_pad = det.program_for(h, w)  # shared geometry
        n_windows = prog.n_positions
        # the program geometry is in padded-frame coords; give the
        # baseline the identically padded frame so both paths score
        # exactly the same window positions
        frame_padded = np.pad(frame, ((0, ph_pad - h), (0, pw_pad - w),
                                      (0, 0)), mode="edge")

        det(frame)                                   # compile warmup
        iters = 3 if fast else 5
        t0 = time.perf_counter()
        for _ in range(iters):
            det(frame)
        t_dense = (time.perf_counter() - t0) / iters

        t0 = time.perf_counter()
        _per_window_recompute(frame_padded, svm, prog.per_scale)  # + compile
        t_base_c = time.perf_counter() - t0
        t0 = time.perf_counter()
        _per_window_recompute(frame_padded, svm, prog.per_scale)
        t_base = time.perf_counter() - t0

        key = f"{w}x{h}"
        results[key] = {
            "n_windows": int(n_windows),
            "dense_ms_per_frame": t_dense * 1e3,
            "dense_windows_per_s": n_windows / t_dense,
            "per_window_ms_per_frame": t_base * 1e3,
            "per_window_windows_per_s": n_windows / t_base,
            "speedup_dense_vs_per_window": t_base / t_dense,
        }
        print(f"detect/{key}_windows,{n_windows},per frame x{len(scales)} "
              f"scales")
        print(f"detect/{key}_dense_ms,{t_dense*1e3:.1f},"
              f"{n_windows/t_dense:,.0f} windows/s")
        print(f"detect/{key}_per_window_ms,{t_base*1e3:.1f},"
              f"{n_windows/t_base:,.0f} windows/s "
              f"(compile pass {t_base_c*1e3:.0f} ms)")
        print(f"detect/{key}_speedup,{t_base/t_dense:.1f},"
              f"dense vs per-window recompute")

    batched = run_detect_batch(fast=fast)
    _update_bench(host="cpu", scales=list(scales), backend="ref",
                  results=results, batched={"640x480": batched})
    print(f"detect/json,{BENCH_JSON.name},written")
    return results


# ------------------------------------------------------ session overhead
# The api facade (repro.api.DetectionSession) must be free: same frame,
# same compiled program, once through the raw FrameDetector legacy call
# and once through session.detect(...).to_list(). Acceptance: <= 5%
# steady-state per-frame overhead (ISSUE 3). Paired min-of-k timing, as
# in run_detect_batch, because the host is shared/noisy.

def run_session_overhead(fast: bool = False) -> dict:
    from repro.api import DetectionSession, PipelineConfig

    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 480, 640
    cfg = DetectorConfig(scales=(1.0, 0.8, 0.64))
    det = FrameDetector(svm, cfg)
    ses = DetectionSession(svm, PipelineConfig(detector=cfg))
    frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
    det(frame)                                   # compile (shared cache)
    ses.detect(frame).to_list()

    def _raw():
        det(frame)

    def _api():
        ses.detect(frame).to_list()

    rounds, iters = (4, 4) if fast else (8, 8)
    t_raw, t_ses = np.inf, np.inf
    for r in range(rounds):
        # alternate which path goes first so ordering bias cancels
        order = (_raw, _api) if r % 2 == 0 else (_api, _raw)
        for fn in order:
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            t = (time.perf_counter() - t0) / iters
            if fn is _raw:
                t_raw = min(t_raw, t)
            else:
                t_ses = min(t_ses, t)

    overhead = (t_ses - t_raw) / t_raw * 100.0
    row = {"frame": f"{w}x{h}",
           "raw_ms_per_frame": t_raw * 1e3,
           "session_ms_per_frame": t_ses * 1e3,
           "overhead_pct": overhead}
    print("# api facade -- DetectionSession vs raw FrameDetector")
    print(f"session/{w}x{h}_raw_ms,{t_raw*1e3:.2f},FrameDetector() "
          f"per frame")
    print(f"session/{w}x{h}_session_ms,{t_ses*1e3:.2f},"
          f"DetectionSession.detect().to_list() per frame")
    print(f"session/{w}x{h}_overhead_pct,{overhead:.2f},"
          f"acceptance <= 5%")
    _update_bench(session_overhead=row)
    return row


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--session-only", action="store_true",
                    help="measure + record only the session_overhead row")
    a = ap.parse_args()
    if a.session_only:
        run_session_overhead(fast=a.fast)
    else:
        run(fast=a.fast)
