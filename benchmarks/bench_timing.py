"""Paper Table II: detection timing, software vs co-processor path.

The paper: Matlab 41 ms/window vs ModelSim hardware 0.757 ms at 50 MHz
(54x). The TPU analogue measured here:

  software path    -- per-window (batch=1) jit'd jnp pipeline on CPU
                      (the "Matlab" role: one window at a time)
  co-processor path -- batched pipeline, per-window time amortized over
                      a 256-window batch (the TPU dataflow role)
  dense-scene path  -- score_map conv: per-WINDOW time when windows
                      overlap in a scene (beyond-paper, §Perf)
  TPU roofline      -- derived per-window latency from the dry-run
                      (bytes/819GBps vs flops/197TFLOPs), reported by
                      benchmarks/bench_roofline.py from dryrun.json

Timing on this container is CPU wall time -- the RATIO between the
software and batched paths is the reproduction target, not the absolute
numbers (the paper's own 54x compares two implementations on different
substrates as well).
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro import platform  # noqa: E402  (applies REPRO_* at import)

if "--sharded" in sys.argv or "--uhd" in sys.argv:
    # the sharded/uhd sections need multiple devices; forcing host
    # devices must happen BEFORE jax first initializes (the same seam
    # launch/dryrun.py uses). An operator-provided count in XLA_FLAGS
    # wins -- force_host_devices merges, never clobbers.
    platform.force_host_devices(8)
# probe the batch schedules live: a stale disk-cached autotune decision
# would make the recorded probe_ms tables lies about THIS run
platform.hermetic_autotune()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import PAPER_HOG, grayscale, hog_descriptor
from repro.core.pipeline import classify_windows
from repro.core.svm import init_svm
from repro.core.detector import (DetectorConfig, FrameDetector,
                                 autotune_report, nms_keep, score_blocks,
                                 score_map, _resize_weights, _single_fn)
from repro.core.stages import dense_blocks


BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_detect.json"

#: PR-1 dense baseline this PR's kernel-grade hot path is measured
#: against (BENCH_detect.json "results" row before the PR-4 overhaul)
PR1_DENSE_BASELINE_MS = 67.44


try:                                   # package-style (python -m benchmarks.run)
    from benchmarks.bench_io import update_bench as _update_bench
except ImportError:                    # direct: python benchmarks/bench_timing.py
    from bench_io import update_bench as _update_bench


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _dist_ms(samples_s) -> dict:
    """min/p50/p99 over per-iteration wall-time samples, in ms. Min
    stays the headline for SPEEDUP ratios (least host noise); p50/p99
    are the latency-SLO view -- a path whose min looks fine but whose
    p99 grew is a regression min-of-k cannot see."""
    ts = np.asarray(sorted(samples_s), np.float64)
    return {"min_ms": float(ts[0] * 1e3),
            "p50_ms": float(np.percentile(ts, 50) * 1e3),
            "p99_ms": float(np.percentile(ts, 99) * 1e3),
            "samples": int(len(ts))}


def _time_dist(fn, iters=10, warmup=2) -> dict:
    """Per-iteration timing distribution of a nullary fn (fn must block
    on its own result)."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return _dist_ms(samples)


def run(fast: bool = False):
    rng = np.random.default_rng(0)
    svm = init_svm(3780)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    win1 = jnp.asarray(rng.integers(0, 256, (1, 130, 66, 3)).astype(np.uint8))
    B = 64 if fast else 256
    winB = jnp.asarray(rng.integers(0, 256, (B, 130, 66, 3)).astype(np.uint8))

    f1 = jax.jit(lambda w: classify_windows(svm, w)["score"])
    t_sw = _time(f1, win1)                      # per window, batch=1
    t_batch = _time(f1, winB) / B               # amortized per window

    fx = jax.jit(lambda w: hog_descriptor(w, PAPER_HOG))
    t_extract1 = _time(fx, win1)
    t_extractB = _time(fx, winB) / B

    # dense scene: 320x240 -> ~600 window positions in one conv
    scene = jnp.asarray(rng.integers(0, 256, (320, 240)).astype(np.float32))
    sm = jax.jit(lambda s: score_map(s, svm["w"], svm["b"], PAPER_HOG))
    smap = sm(scene)
    n_windows = smap.shape[0] * smap.shape[1]
    t_scene = _time(sm, scene) / n_windows

    print("# Table II -- timing per window (CPU host; ratios are the "
          "reproduction target)")
    print(f"table2/attracting_software_ms,{t_extract1*1e3:.3f},paper=16")
    print(f"table2/attracting_batched_ms,{t_extractB*1e3:.3f},paper=0.411")
    print(f"table2/detecting_software_ms,{t_sw*1e3:.3f},paper=41")
    print(f"table2/detecting_batched_ms,{t_batch*1e3:.3f},paper=0.757")
    # NOTE: on this 1-core CPU host, batching cannot beat batch=1 (no
    # parallel hardware -- the paper's 54x IS its hardware parallelism).
    # The two host-measurable analogues of the paper's speedup are:
    #   * dense-scene amortization (one conv scores ~500 windows), and
    #   * the TPU roofline latency from the dry-run (60.5 ns/window,
    #     bench_roofline.py / EXPERIMENTS.md §Roofline).
    print(f"table2/speedup_batched_cpu_host,{t_sw/t_batch:.1f},"
          f"paper=54 (needs parallel hw; see dense_scene + roofline)")
    print(f"table2/detecting_dense_scene_ms,{t_scene*1e3:.4f},"
          f"windows={n_windows}")
    print(f"table2/speedup_dense_scene,{t_sw/t_scene:.1f},"
          f"beyond-paper analogue of the 54x")
    print(f"table2/tpu_roofline_ns_per_window,60.5,"
          f"vs paper 757000 ns (dryrun hog cell)")

    det = run_detect(fast=fast)
    breakdown = run_stage_breakdown(fast=fast)
    ses = run_session_overhead(fast=fast)
    # the sharded section only means something with >1 device (use
    # --sharded to self-force 8 host devices before jax init)
    shd = run_sharded(fast=fast) if jax.device_count() > 1 else None
    return {"speedup": t_sw / t_scene, "detect": det,
            "stage_breakdown": breakdown, "session_overhead": ses,
            "sharded": shd}


# ----------------------------------------------------------- batched video
# Frames/s of detect_batch (the vmapped/scanned per-bucket program, one
# dispatch + one host sync per batch) vs the same frames through
# sequential detect() calls. The acceptance target: batched B>=4 at
# 640x480 beats 4x sequential.

def run_detect_batch(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 480, 640
    batches = [4] if fast else [4, 8]
    det = FrameDetector(svm, DetectorConfig(scales=(1.0, 0.8, 0.64)))
    frames = [rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
              for _ in range(max(batches))]
    det(frames[0])                                   # compile single
    results = {}
    rounds = 3 if fast else 7
    print("# batched video path -- detect_batch vs sequential detect()")
    for B in batches:
        det.detect_batch(frames[:B])                 # compile (bucket, B)
        # alternate the two paths and keep each one's BEST round: the
        # host is shared/noisy and the signal is ~10%, so paired
        # min-of-k over >=1s samples is what makes the comparison
        # reproducible (reps stretches small-B rounds to B*reps >= 16
        # frames per sample)
        reps = max(1, 16 // B)
        seq_s, bat_s = [], []
        for _ in range(rounds):
            t0 = time.perf_counter()
            for _ in range(reps):
                for f in frames[:B]:
                    det(f)
            seq_s.append((time.perf_counter() - t0) / (B * reps))
            t0 = time.perf_counter()
            for _ in range(reps):
                det.detect_batch(frames[:B])
            bat_s.append((time.perf_counter() - t0) / (B * reps))
        t_seq, t_bat = min(seq_s), min(bat_s)
        results[f"B{B}"] = {
            "batch": B,
            "seq_ms_per_frame": t_seq * 1e3,
            "seq_fps": 1.0 / t_seq,
            "batch_ms_per_frame": t_bat * 1e3,
            "batch_fps": 1.0 / t_bat,
            "speedup_batch_vs_seq": t_seq / t_bat,
            "seq_dist": _dist_ms(seq_s),
            "batch_dist": _dist_ms(bat_s),
        }
        print(f"detect_batch/{w}x{h}_B{B}_seq_fps,{1/t_seq:.2f},"
              f"{t_seq*1e3:.1f} ms/frame")
        print(f"detect_batch/{w}x{h}_B{B}_batch_fps,{1/t_bat:.2f},"
              f"{t_bat*1e3:.1f} ms/frame")
        print(f"detect_batch/{w}x{h}_B{B}_speedup,{t_seq/t_bat:.3f},"
              f"batched vs sequential")
    return results


# ----------------------------------------------------------- multi-scale
# Dense device-resident detection vs. the per-window-recompute baseline
# (slice every window position at 8-px stride per pyramid scale, HOG each
# window independently). This is the beyond-paper detection hot path the
# refactor targets; BENCH_detect.json records the trajectory.

def _per_window_recompute(frame: np.ndarray, svm, per_scale,
                          batch: int = 512) -> int:
    """The naive baseline: re-extract HOG for every window of every
    pyramid scale independently (no dense sharing). `per_scale` is the
    detector program's own (scale, PH, PW) geometry (FrameProgram.per_scale),
    so both paths score exactly the same window positions. Returns #windows."""
    fn = jax.jit(lambda x: classify_windows(svm, x)["score"])
    n_windows = 0
    hcfg = PAPER_HOG
    h, w = frame.shape[:2]
    for s, ph, pw in per_scale:
        g = np.asarray(jax.image.resize(jnp.asarray(frame, jnp.float32),
                                        (int(h * s), int(w * s), 3),
                                        "linear"))
        wins = np.empty((ph * pw, hcfg.window_h, hcfg.window_w, 3),
                        np.float32)
        for i in range(ph):
            for j in range(pw):
                wins[i * pw + j] = g[i * 8:i * 8 + hcfg.window_h,
                                     j * 8:j * 8 + hcfg.window_w]
        for k in range(0, len(wins), batch):
            chunk = wins[k:k + batch]
            if len(chunk) < batch:            # pad to the compiled batch
                chunk = np.concatenate(
                    [chunk, np.zeros((batch - len(chunk),) + chunk.shape[1:],
                                     np.float32)])
            jax.block_until_ready(fn(jnp.asarray(chunk)))
        n_windows += ph * pw
    return n_windows


def run_detect(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    sizes = [(480, 640)] if fast else [(480, 640), (720, 1280)]
    scales = (1.0, 0.8, 0.64)
    results = {}
    print("# multi-scale detection -- dense device-resident vs "
          "per-window recompute")
    for (h, w) in sizes:
        frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
        det = FrameDetector(svm, DetectorConfig(scales=scales,
                                                score_threshold=0.0))
        prog, ph_pad, pw_pad = det.program_for(h, w)  # shared geometry
        n_windows = prog.n_positions
        # the program geometry is in padded-frame coords; give the
        # baseline the identically padded frame so both paths score
        # exactly the same window positions
        frame_padded = np.pad(frame, ((0, ph_pad - h), (0, pw_pad - w),
                                      (0, 0)), mode="edge")

        det(frame)                                   # compile warmup
        iters = 3 if fast else 5
        dense_s = []
        for _ in range(iters):
            t0 = time.perf_counter()
            det(frame)
            dense_s.append(time.perf_counter() - t0)
        t_dense = float(np.mean(dense_s))            # mean, as before

        t0 = time.perf_counter()
        _per_window_recompute(frame_padded, svm, prog.per_scale)  # + compile
        t_base_c = time.perf_counter() - t0
        t0 = time.perf_counter()
        _per_window_recompute(frame_padded, svm, prog.per_scale)
        t_base = time.perf_counter() - t0

        key = f"{w}x{h}"
        results[key] = {
            "n_windows": int(n_windows),
            "dense_ms_per_frame": t_dense * 1e3,
            "dense_dist": _dist_ms(dense_s),
            "dense_windows_per_s": n_windows / t_dense,
            "per_window_ms_per_frame": t_base * 1e3,
            "per_window_windows_per_s": n_windows / t_base,
            "speedup_dense_vs_per_window": t_base / t_dense,
        }
        print(f"detect/{key}_windows,{n_windows},per frame x{len(scales)} "
              f"scales")
        print(f"detect/{key}_dense_ms,{t_dense*1e3:.1f},"
              f"{n_windows/t_dense:,.0f} windows/s")
        print(f"detect/{key}_per_window_ms,{t_base*1e3:.1f},"
              f"{n_windows/t_base:,.0f} windows/s "
              f"(compile pass {t_base_c*1e3:.0f} ms)")
        print(f"detect/{key}_speedup,{t_base/t_dense:.1f},"
              f"dense vs per-window recompute")

    batched = run_detect_batch(fast=fast)
    # the autotuned scan-vs-vmap schedules the batched rows ran under
    batched["schedule"] = autotune_report()
    _update_bench(host="cpu", scales=list(scales), backend="ref",
                  results=results, batched={"640x480": batched})
    print(f"detect/json,{BENCH_JSON.name},written")
    return results


# ------------------------------------------------------ per-stage profile
# Where the dense frame budget goes: grayscale+pad / pyramid resize /
# dense HOG stages / matmul scoring / top-k+NMS, each stage timed as its
# own jitted program on the same per-bucket geometry the production
# program uses. The section is written to BENCH_detect.json ("pr4") and
# is what `--check` gates CI perf regressions against.

def _calibration_fn():
    """Jitted MINIATURE of the gated pipeline (resize matmul -> dense
    HOG stages -> matmul scoring on a 242x322 scene) -- the host-speed
    yardstick so --check can compare a measurement from THIS machine
    against a baseline committed from another one. A bare matmul would
    only track MXU/BLAS speed; the dense budget is dominated by the
    memory-/vector-bound stage chain, so the yardstick runs the same
    mix."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(242, 322)).astype(np.float32) * 40)
    wv = jnp.asarray(rng.normal(size=3780).astype(np.float32) * 0.02)
    wy = jnp.asarray(_resize_weights(242, 194))
    wx = jnp.asarray(_resize_weights(322, 258))

    def mini(x):
        small = (wy @ x) @ wx.T
        return [score_blocks(dense_blocks(s, PAPER_HOG, "ref"),
                             wv, jnp.float32(0.0), PAPER_HOG)
                for s in (x, small)]

    f = jax.jit(mini)
    return lambda: jax.block_until_ready(f(g))


def _measure_dense_and_calib(det: FrameDetector, frame: np.ndarray,
                             rounds: int = 5, iters: int = 5):
    """(dense ms/frame, calibration ms), both min-of-rounds and measured
    in ALTERNATING rounds so the pair sees the same host contention --
    a calibration taken minutes apart from the dense measurement on a
    shared host skews the --check normalization by whatever the load
    did in between."""
    calib = _calibration_fn()
    det(frame)                                   # compile both
    calib()
    best_d, best_c = np.inf, np.inf
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(iters):
            det(frame)
        best_d = min(best_d, (time.perf_counter() - t0) / iters)
        t0 = time.perf_counter()
        for _ in range(iters):
            calib()
        best_c = min(best_c, (time.perf_counter() - t0) / iters)
    return best_d * 1e3, best_c * 1e3


def run_stage_breakdown(fast: bool = False) -> dict:
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 480, 640
    cfg = DetectorConfig(scales=(1.0, 0.8, 0.64))
    det = FrameDetector(svm, cfg)
    prog, ph, pw = det.program_for(h, w)
    frame = jnp.asarray(rng.integers(0, 256, (h, w, 3)).astype(np.uint8))
    hcfg = cfg.hog
    iters = 10 if fast else 20

    # stage 0: grayscale + edge pad to the bucket
    g_fn = jax.jit(lambda f: jnp.pad(grayscale(f),
                                     ((0, ph - h), (0, pw - w)),
                                     mode="edge"))
    gray = g_fn(frame)
    t_gray = _time(g_fn, frame, iters=iters)

    # stage 1: pyramid resize (matmul form, exact production weights)
    shapes = [(int(ph * s), int(pw * s)) for s, _, _ in prog.per_scale]
    mats = [(jnp.asarray(_resize_weights(ph, sh)),
             jnp.asarray(_resize_weights(pw, sw)))
            for (sh, sw) in shapes if (sh, sw) != (ph, pw)]
    r_fn = jax.jit(lambda g: [(wy @ g) @ wx.T for wy, wx in mats])
    pyramid = [gray] + list(r_fn(gray))
    t_resize = _time(r_fn, gray, iters=iters)

    # stage 2: dense HOG stages (grad -> mag/bin -> hist -> block norm)
    s_fn = jax.jit(lambda gs: [dense_blocks(g, hcfg, cfg.backend)
                               for g in gs])
    blocks = s_fn(pyramid)
    t_stages = _time(s_fn, pyramid, iters=iters)

    # stage 3: dense SVM scoring (blocked matmul + shifted collate)
    c_fn = jax.jit(lambda bls: [score_blocks(bl, svm["w"], svm["b"], hcfg)
                                for bl in bls])
    smaps = c_fn(blocks)
    t_score = _time(c_fn, blocks, iters=iters)

    # stage 4: threshold mask + device top-k + vectorized NMS
    boxes_dev = jnp.asarray(prog.boxes)
    k = prog.k

    def tail(sms, hw):
        scores = jnp.concatenate([s.reshape(-1) for s in sms])
        inside = (boxes_dev[:, 2] <= hw[0] + 1e-4) \
            & (boxes_dev[:, 3] <= hw[1] + 1e-4)
        valid = inside & (scores > cfg.score_threshold)
        top, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
        return top, idx, nms_keep(boxes_dev[idx], top, cfg.nms_iou)

    t_fn = jax.jit(tail)
    hw_v = jnp.asarray([h, w], jnp.float32)
    t_tail = _time(t_fn, smaps, hw_v, iters=iters)

    # the fused production program end to end (device-resident: timed
    # with block_until_ready on the raw tensors, so a host round-trip
    # sneaking into the program would show up as a gap vs the stage sum).
    # On accelerators the program DONATES its frame argument, so each
    # timed call gets a fresh copy -- same freshness contract detect_raw
    # provides (the copy is inside the timing, as in production)
    from repro.core.detector import _donate
    fn = _single_fn(h, w, ph, pw, cfg)
    mk = (lambda f: jnp.array(f, copy=True)) if _donate() \
        else (lambda f: f)
    t_prog = _time(lambda f: fn(mk(f), svm["w"], svm["b"], hw_v), frame,
                   iters=iters)

    dense_ms, calib_ms = _measure_dense_and_calib(
        det, np.asarray(frame), rounds=3 if fast else 5)
    stage_ms = {
        "grayscale_pad": t_gray * 1e3,
        "pyramid_resize": t_resize * 1e3,
        "dense_stages": t_stages * 1e3,
        "score": t_score * 1e3,
        "topk_nms": t_tail * 1e3,
    }
    row = {
        "dense_ms_per_frame": dense_ms,
        "device_program_ms": t_prog * 1e3,
        "stage_ms": stage_ms,
        "stage_sum_ms": sum(stage_ms.values()),
        "baseline_pr1_dense_ms": PR1_DENSE_BASELINE_MS,
        "speedup_vs_pr1": PR1_DENSE_BASELINE_MS / dense_ms,
    }
    print("# per-stage dense profile -- 640x480, production geometry")
    for kk, v in stage_ms.items():
        print(f"stage/{kk}_ms,{v:.2f}")
    print(f"stage/sum_ms,{row['stage_sum_ms']:.2f},"
          f"program {t_prog*1e3:.2f} ms (fusion closes the gap)")
    print(f"stage/dense_ms_per_frame,{dense_ms:.2f},"
          f"{PR1_DENSE_BASELINE_MS / dense_ms:.2f}x vs PR-1 "
          f"{PR1_DENSE_BASELINE_MS} ms")
    _update_bench(pr4={"host": "cpu", "640x480": row,
                       "calibration_ms": calib_ms})
    return row


# ---------------------------------------------------- CI regression gate

def run_check(tolerance: float = 0.15, fast: bool = True) -> int:
    """Fail (exit 1) when the dense 640x480 ms/frame regresses more than
    `tolerance` vs the committed BENCH_detect.json "pr4" baseline.

    Host-speed differences (CI runners vs the machine that committed
    the baseline) are normalized out with the calibration
    mini-pipeline recorded next to the baseline. Never writes the
    json.
    """
    # a missing baseline is a SKIP, not a failure: exit 0 so a branch
    # that resets BENCH_detect.json does not turn CI red without any
    # actual regression
    if not BENCH_JSON.exists():
        print("check/SKIP,no BENCH_detect.json baseline")
        return 0
    data = json.loads(BENCH_JSON.read_text())
    base = data.get("pr4", {}).get("640x480")
    calib_base = data.get("pr4", {}).get("calibration_ms")
    if not base:
        print("check/SKIP,no pr4 section in BENCH_detect.json "
              "(run benchmarks/bench_timing.py to record one)")
        return 0
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    det = FrameDetector(svm, DetectorConfig(scales=(1.0, 0.8, 0.64)))
    frame = rng.integers(0, 256, (480, 640, 3)).astype(np.uint8)
    now_ms, calib_now = _measure_dense_and_calib(
        det, frame, rounds=3 if fast else 5)
    scale = (calib_now / calib_base) if calib_base else 1.0
    limit = base["dense_ms_per_frame"] * scale * (1.0 + tolerance)
    verdict = "PASS" if now_ms <= limit else "FAIL"
    print(f"check/baseline_ms,{base['dense_ms_per_frame']:.2f},"
          f"calib {calib_base and f'{calib_base:.3f}'} ms")
    print(f"check/host_scale,{scale:.3f},calib now {calib_now:.3f} ms")
    print(f"check/current_ms,{now_ms:.2f},limit {limit:.2f} "
          f"(+{tolerance:.0%})")
    print(f"check/{verdict},dense 640x480 ms/frame")
    return 0 if verdict == "PASS" else 1


# --------------------------------------------------------- sharded batch
# Multi-device data parallelism over detect_batch: the frame batch laid
# over the 'data' mesh axis, B/n_devices frames per device, vs the same
# batch on one device. Run under forced host devices
# (`--sharded` self-forces XLA_FLAGS=--xla_force_host_platform_device_count=8
# before jax init) this measures dispatch/SPMD overhead, not speedup --
# forced host devices share one CPU; on real multi-chip hosts the same
# section measures the actual scaling. Doubles as the CI correctness
# smoke: sharded results must stay byte-identical to single-device for
# divisible AND non-divisible batch sizes, and every autotune entry must
# carry its mesh dimension.

def run_sharded(fast: bool = False) -> dict:
    from repro.core.detector import _resolve_dp  # resolved device count

    n_dev = jax.device_count()
    if n_dev < 2:
        # a 1-device "sharded" run would compare the unsharded path to
        # itself and report a vacuous PASS -- fail loudly instead (the
        # --sharded flag self-forces 8 host devices, so landing here
        # means an operator-set XLA_FLAGS pinned the count to 1)
        print(f"sharded/FAIL,needs >= 2 devices, found {n_dev} "
              f"(--sharded forces XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8)")
        return {"ok": False, "n_devices": n_dev}
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = (240, 320) if fast else (480, 640)
    scales = (1.0, 0.8, 0.64)
    dp = n_dev
    B = 2 * dp
    frames = np.stack([rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
                       for _ in range(B)])
    single = FrameDetector(svm, DetectorConfig(
        scales=scales, batch_chunk=0, data_parallel=1))
    shard = FrameDetector(svm, DetectorConfig(
        scales=scales, batch_chunk=0, data_parallel=0))
    assert _resolve_dp(shard.cfg) == dp

    print(f"# sharded detect_batch -- {w}x{h} B={B} over {dp} device(s)")
    # correctness first: byte-identical to the single-device path, for a
    # divisible batch and a non-divisible one (exercises pad-and-mask).
    # The gate pins the SAME explicit schedule on both sides (chunk=1):
    # letting each side autotune independently would conflate sharding
    # equivalence with scan-vs-vmap schedule numerics (only guaranteed
    # to 1e-5 across schedules) and could flake the CI lane.
    single_pin = FrameDetector(svm, DetectorConfig(
        scales=scales, batch_chunk=1, data_parallel=1))
    shard_pin = FrameDetector(svm, DetectorConfig(
        scales=scales, batch_chunk=1, data_parallel=0))
    want = single_pin.detect_batch(frames)
    identical = shard_pin.detect_batch(frames) == want
    nd = B - 1
    identical_nd = (shard_pin.detect_batch(frames[:nd])
                    == single_pin.detect_batch(frames[:nd]))
    # the autotuned pair is what the timing below runs; probing it here
    # also populates the mesh-tagged schedule entries for the BENCH row
    single.detect_batch_raw(frames).block_until_ready()
    shard.detect_batch_raw(frames).block_until_ready()
    rep = autotune_report()
    mesh_tagged = bool(rep) and all("mesh=data:" in k for k in rep)
    print(f"sharded/identical_divisible,{identical},B={B}")
    print(f"sharded/identical_nondivisible,{identical_nd},B={nd}")
    print(f"sharded/autotune_mesh_tagged,{mesh_tagged},"
          f"{len(rep)} schedule entries")

    # paired min-of-k timing (same protocol as run_detect_batch)
    rounds = 3 if fast else 7
    single_s, shard_s = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        single.detect_batch_raw(frames).block_until_ready()
        single_s.append((time.perf_counter() - t0) / B)
        t0 = time.perf_counter()
        shard.detect_batch_raw(frames).block_until_ready()
        shard_s.append((time.perf_counter() - t0) / B)
    t_single, t_shard = min(single_s), min(shard_s)
    row = {
        "host": "cpu-forced",
        "n_devices": n_dev,
        "data_parallel": dp,
        "frame": f"{w}x{h}",
        "B": B,
        "single_ms_per_frame": t_single * 1e3,
        "sharded_ms_per_frame": t_shard * 1e3,
        "single_dist": _dist_ms(single_s),
        "sharded_dist": _dist_ms(shard_s),
        "speedup_sharded_vs_single": t_single / t_shard,
        "identical_divisible": bool(identical),
        "identical_nondivisible": bool(identical_nd),
        "schedule": {k: v for k, v in rep.items()
                     if f"mesh=data:{dp}" in k},
    }
    print(f"sharded/{w}x{h}_B{B}_single_ms,{t_single*1e3:.1f},per frame")
    print(f"sharded/{w}x{h}_B{B}_sharded_ms,{t_shard*1e3:.1f},"
          f"per frame over {dp} device(s)")
    print(f"sharded/{w}x{h}_B{B}_speedup,{t_single/t_shard:.3f},"
          f"forced host devices share one CPU -- overhead bound, "
          f"not scaling")
    _update_bench(sharded=row)
    ok = identical and identical_nd and mesh_tagged
    print(f"sharded/{'PASS' if ok else 'FAIL'},byte-identical + "
          f"mesh-tagged autotune")
    row["ok"] = bool(ok)
    return row


# ------------------------------------------------------------ UHD tiled
# Single-frame 3840x2160 latency: the untiled program on one device vs
# the intra-frame tiled path (row-slab and scale-group) with every
# forced host device on the 'tile' mesh axis. Forced host devices share
# one CPU, so the tiled speedup here comes from the work the tiled
# path's banded pyramid resize removes (O(taps) per pixel vs the dense
# matmul's O(src)) -- the decomposition itself is overhead-bound on this
# host and becomes real scaling on multi-chip hosts, exactly as in the
# sharded section. Doubles as the CI identity smoke at full UHD: tiled
# must stay box-identical to untiled per resize mode (exit 1 otherwise).

def run_uhd(fast: bool = False) -> dict:
    from repro.core.detector import _resolve_fp

    n_dev = jax.device_count()
    if n_dev < 2:
        print(f"uhd/FAIL,needs >= 2 devices, found {n_dev} "
              f"(--uhd forces XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8)")
        return {"ok": False, "n_devices": n_dev}
    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 2160, 3840
    frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
    base = dict(scales=(1.0, 0.8, 0.64), score_threshold=0.5)
    single = FrameDetector(svm, DetectorConfig(**base))
    single_banded = FrameDetector(svm, DetectorConfig(
        **base, pyramid_resize="banded"))
    tiled = FrameDetector(svm, DetectorConfig(
        **base, pyramid_resize="banded", frame_parallel=0,
        tile_mode="slab"))
    tiled_scale = FrameDetector(svm, DetectorConfig(
        **base, pyramid_resize="banded", frame_parallel=0,
        tile_mode="scale"))
    tiled_mm = FrameDetector(svm, DetectorConfig(
        **base, frame_parallel=0, tile_mode="slab"))
    fp = _resolve_fp(tiled.cfg)
    prog = tiled.program_for(h, w)[0]
    print(f"# uhd single-frame -- {w}x{h}, untiled vs {fp}-tile "
          f"intra-frame parallel ({prog.n_positions} windows, "
          f"k={prog.k})")

    # identity gates: tiled vs untiled within each resize mode (both
    # modes are self-consistent; comparing across modes would conflate
    # tiling with resize accumulation-order numerics)
    want_banded = single_banded(frame)
    ident_slab = tiled(frame) == want_banded
    ident_scale = tiled_scale(frame) == want_banded
    ident_mm = tiled_mm(frame) == single(frame)
    print(f"uhd/identical_slab,{ident_slab},banded resize, {fp} tiles")
    print(f"uhd/identical_scale,{ident_scale},banded resize, {fp} tiles")
    print(f"uhd/identical_matmul,{ident_mm},matmul resize, {fp} tiles")

    iters = 3 if fast else 7

    def bench(det):
        return _time_dist(
            lambda: det.detect_raw(frame).block_until_ready(),
            iters=iters, warmup=1)

    d_single = bench(single)
    d_single_banded = bench(single_banded)
    d_tiled = bench(tiled)
    d_tiled_scale = bench(tiled_scale)
    # headline: untiled default vs the best tile mode ON THIS HOST. The
    # forced mesh shares one core, so slab's halo overlap (~40% extra
    # HOG rows across 8 tiles) is paid serially here; scale groups have
    # no halo. On genuinely parallel devices slab balances better --
    # both modes are recorded so either claim stays auditable.
    best_ms = min(d_tiled["min_ms"], d_tiled_scale["min_ms"])
    best_mode = ("slab" if d_tiled["min_ms"] <= d_tiled_scale["min_ms"]
                 else "scale")
    speedup = d_single["min_ms"] / best_ms
    row = {
        "host": "cpu-forced",
        "n_devices": n_dev,
        "frame_parallel": fp,
        "frame": f"{w}x{h}",
        "n_windows": int(prog.n_positions),
        "k": int(prog.k),
        "single_ms": d_single["min_ms"],
        "single_dist": d_single,
        "single_banded_ms": d_single_banded["min_ms"],
        "single_banded_dist": d_single_banded,
        "tiled_slab_ms": d_tiled["min_ms"],
        "tiled_slab_dist": d_tiled,
        "tiled_scale_ms": d_tiled_scale["min_ms"],
        "tiled_scale_dist": d_tiled_scale,
        "speedup_tiled_vs_single": speedup,
        "speedup_tile_mode": best_mode,
        "identical_slab": bool(ident_slab),
        "identical_scale": bool(ident_scale),
        "identical_matmul": bool(ident_mm),
    }
    print(f"uhd/{w}x{h}_single_ms,{d_single['min_ms']:.1f},"
          f"p50 {d_single['p50_ms']:.1f} p99 {d_single['p99_ms']:.1f}")
    print(f"uhd/{w}x{h}_single_banded_ms,{d_single_banded['min_ms']:.1f},"
          f"p50 {d_single_banded['p50_ms']:.1f} "
          f"p99 {d_single_banded['p99_ms']:.1f}")
    print(f"uhd/{w}x{h}_tiled_slab_ms,{d_tiled['min_ms']:.1f},"
          f"p50 {d_tiled['p50_ms']:.1f} p99 {d_tiled['p99_ms']:.1f} "
          f"over {fp} tiles")
    print(f"uhd/{w}x{h}_tiled_scale_ms,{d_tiled_scale['min_ms']:.1f},"
          f"p50 {d_tiled_scale['p50_ms']:.1f} "
          f"p99 {d_tiled_scale['p99_ms']:.1f}")
    print(f"uhd/{w}x{h}_speedup,{speedup:.2f},tiled(banded {best_mode}) "
          f"vs untiled default -- acceptance >= 1.5")
    _update_bench(uhd=row)
    ok = bool(ident_slab and ident_scale and ident_mm and speedup >= 1.5)
    print(f"uhd/{'PASS' if ok else 'FAIL'},box-identical per resize mode "
          f"and >= 1.5x tiled speedup")
    row["ok"] = ok
    return row


# ------------------------------------------------------ session overhead
# The api facade (repro.api.DetectionSession) must be free: same frame,
# same compiled program, once through the raw FrameDetector legacy call
# and once through session.detect(...).to_list(). Acceptance: <= 5%
# steady-state per-frame overhead (ISSUE 3). Paired min-of-k timing, as
# in run_detect_batch, because the host is shared/noisy.

def run_session_overhead(fast: bool = False) -> dict:
    from repro.api import DetectionSession, PipelineConfig

    rng = np.random.default_rng(0)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    h, w = 480, 640
    cfg = DetectorConfig(scales=(1.0, 0.8, 0.64))
    det = FrameDetector(svm, cfg)
    ses = DetectionSession(svm, PipelineConfig(detector=cfg))
    frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
    det(frame)                                   # compile (shared cache)
    ses.detect(frame).to_list()

    def _raw():
        det(frame)

    def _api():
        ses.detect(frame).to_list()

    rounds, iters = (4, 4) if fast else (8, 8)
    t_raw, t_ses = np.inf, np.inf
    for r in range(rounds):
        # alternate which path goes first so ordering bias cancels
        order = (_raw, _api) if r % 2 == 0 else (_api, _raw)
        for fn in order:
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            t = (time.perf_counter() - t0) / iters
            if fn is _raw:
                t_raw = min(t_raw, t)
            else:
                t_ses = min(t_ses, t)

    overhead = (t_ses - t_raw) / t_raw * 100.0
    row = {"frame": f"{w}x{h}",
           "raw_ms_per_frame": t_raw * 1e3,
           "session_ms_per_frame": t_ses * 1e3,
           "overhead_pct": overhead}
    print("# api facade -- DetectionSession vs raw FrameDetector")
    print(f"session/{w}x{h}_raw_ms,{t_raw*1e3:.2f},FrameDetector() "
          f"per frame")
    print(f"session/{w}x{h}_session_ms,{t_ses*1e3:.2f},"
          f"DetectionSession.detect().to_list() per frame")
    print(f"session/{w}x{h}_overhead_pct,{overhead:.2f},"
          f"acceptance <= 5%")
    _update_bench(session_overhead=row)
    return row


# ---------------------------------------------------- fixed-point numerics
# The quant section (DESIGN.md §12): is int8 scoring actually faster than
# the bf16 MXU path, and what does the fixed datapath cost end to end?
# Scoring is measured as the (M, 36) @ (36, 105) contribution matmul at
# the dense-grid M of a 640x480 frame and of a UHD frame, int8 (exact
# int32 accumulation + rank-1 rescale) vs bf16 (f32 accumulation) --
# both as the jitted XLA form the ref backend runs, host-honest on CPU.
# End-to-end compares the quant preset against the perf preset (same
# fused dense backend, autotuned schedule) in ms/frame.

def run_quant(fast: bool = False) -> dict:
    import dataclasses

    from repro.api.config import presets
    from repro.core import quant
    from repro.core.hog import HOGConfig
    from repro.core.stages import dense_blocks as _dense

    rng = np.random.default_rng(0)
    row = {"host": "cpu", "scoring": {}, "e2e": {}}
    print("# quant -- int8 fixed-point datapath vs the float chain")

    # -------------------------- scoring: int8 vs bf16 contribution matmul
    bh_bw, bd = 105, 36
    wt = rng.normal(0, 0.05, size=(bd, bh_bw)).astype(np.float32)
    wq, s_cols = quant.quantize_weight_columns(jnp.asarray(wt))
    sizes = {"640x480": 58 * 78, "3840x2160": 268 * 478}
    iters = 5 if fast else 20
    for key, m_rows in sizes.items():
        v = rng.random(size=(m_rows, bd)).astype(np.float32)
        q, s_rows = quant.quantize_blocks(jnp.asarray(v))
        q, s_rows = jax.block_until_ready((q, s_rows))
        flat16 = jnp.asarray(v).astype(jnp.bfloat16)
        wt16 = jnp.asarray(wt).astype(jnp.bfloat16)

        @jax.jit
        def _score_bf16(x, w):
            return jax.lax.dot_general(
                x, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)

        @jax.jit
        def _score_int8(xq, wq8, sr, sc):
            ci = jax.lax.dot_general(
                xq, wq8, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            return quant.rescale_scores(ci, sr, sc)

        t_bf16 = _time(_score_bf16, flat16, wt16, iters=iters)
        t_int8 = _time(_score_int8, q, wq, s_rows, s_cols, iters=iters)
        row["scoring"][key] = {
            "m_rows": int(m_rows),
            "bf16_ms": t_bf16 * 1e3, "int8_ms": t_int8 * 1e3,
            "int8_vs_bf16_speedup": t_bf16 / t_int8,
        }
        print(f"quant/score_{key},bf16={t_bf16*1e3:.3f}ms,"
              f"int8={t_int8*1e3:.3f}ms,x{t_bf16/t_int8:.2f}")

    # ------------------------------- agreement: fixed chain ref vs fused
    cfg_fixed = HOGConfig(mode="cordic", numerics="fixed")
    scene = rng.integers(0, 256, size=(240, 320)).astype(np.float32)
    br = _dense(scene, cfg_fixed, backend="ref")
    bf = _dense(scene, cfg_fixed, backend="fused")
    agree = float(jnp.max(jnp.abs(br - bf)))
    row["ref_vs_fused_max_abs"] = agree
    ok = agree < 1e-5
    print(f"quant/ref_vs_fused_max_abs,{agree:.2e},gate<1e-5")

    # ----------------------------------- end to end: quant vs perf preset
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    e2e_sizes = [(480, 640)] if fast else [(480, 640), (2160, 3840)]
    e2e_iters = 3 if fast else 5
    for (h, w) in e2e_sizes:
        frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
        key = f"{w}x{h}"
        sub = {}
        for name in ("quant", "perf"):
            det = FrameDetector(svm, presets(name).detector)
            det(frame)                               # compile warmup
            sub[name] = _time_dist(lambda d=det: d(frame),
                                   iters=e2e_iters, warmup=1)
        sub["quant_vs_perf"] = sub["perf"]["min_ms"] / sub["quant"]["min_ms"]
        row["e2e"][key] = sub
        print(f"quant/e2e_{key},quant={sub['quant']['min_ms']:.1f}ms,"
              f"perf={sub['perf']['min_ms']:.1f}ms,"
              f"x{sub['quant_vs_perf']:.2f}")

    row["ok"] = bool(ok)
    _update_bench(quant=row)
    print(f"quant/json,{BENCH_JSON.name},written")
    return row


# ------------------------------------------------- multi-head stacking
# K classifiers in ONE compiled program (DESIGN.md §13): the dense
# contribution matmul widens from (M, 36) @ (36, 105) to
# (M, 36) @ (36, 105*K). On the MXU that widening is near-free (same M
# rows, same reduction dim); on this CPU host the scoring FLOPs scale
# with K, so the gate bounds the MARGINAL cost: each extra head must
# add < 15% of a full single-head pass at 640x480 (the naive
# alternative -- one program per class -- adds 100% per head; measured
# here the widened matmul adds ~9%). Plus the correctness gate: head 0
# of the stack byte-identical to the single-head program (scores /
# index / keep / n_valid arrays).

def run_multiclass(fast: bool = False) -> dict:
    K = 4
    rng = np.random.default_rng(0)
    h, w = 480, 640
    cfg = DetectorConfig(scales=(1.0, 0.8, 0.64))
    F = cfg.hog.n_features
    ws = rng.normal(0, 0.01, size=(K, F)).astype(np.float32)
    bs = rng.normal(0, 0.1, size=(K,)).astype(np.float32)
    det1 = FrameDetector({"w": ws[0], "b": bs[0]}, cfg)
    detK = FrameDetector({"w": ws, "b": bs}, cfg,
                         classes=tuple(f"head{k}" for k in range(K)))
    frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)

    r1 = det1.detect_raw(frame)                  # compiles both programs
    rK = detK.detect_raw(frame)
    same = all(bool(jnp.array_equal(a, b)) for a, b in [
        (r1._scores, rK._scores[0]), (r1._index, rK._index[0]),
        (r1._keep, rK._keep[0]), (r1._n_valid, rK._n_valid[0])])

    iters = 4 if fast else 10
    t1 = _time_dist(lambda: det1.detect_raw(frame).block_until_ready(),
                    iters=iters, warmup=1)
    tK = _time_dist(lambda: detK.detect_raw(frame).block_until_ready(),
                    iters=iters, warmup=1)
    overhead = (tK["min_ms"] - t1["min_ms"]) / t1["min_ms"]
    per_head = overhead / (K - 1)
    ok = same and per_head < 0.15
    row = {"frame": f"{w}x{h}", "heads": K,
           "single_head": t1, "stacked": tK,
           "overhead_pct": overhead * 100.0,
           "per_head_overhead_pct": per_head * 100.0,
           "head0_byte_identical": bool(same), "ok": bool(ok)}
    print(f"# multi-head -- K={K} stacked heads vs one (one widened matmul)")
    print(f"multiclass/{w}x{h}_single_ms,{t1['min_ms']:.2f},1 head")
    print(f"multiclass/{w}x{h}_stacked_ms,{tK['min_ms']:.2f},{K} heads")
    print(f"multiclass/{w}x{h}_overhead_pct,{overhead*100:.2f},"
          f"{K-1} extra heads")
    print(f"multiclass/{w}x{h}_per_head_overhead_pct,{per_head*100:.2f},"
          f"gate<15% (naive one-program-per-class = 100)")
    print(f"multiclass/head0_byte_identical,{same},gate=True")
    _update_bench(multiclass=row)
    print(f"multiclass/json,{BENCH_JSON.name},written")
    return row


# --------------------------------------------------- two-stage cascade
# The coarse-reject scheduler (core/cascade.py) on the traffic shape it
# is built for: 640x480 frames where pedestrians cluster, individually
# visible, in one corner of an otherwise empty frame, mixed 1:1 with
# fully empty frames. Both heads train with hard-negative bootstrapping
# (data/mining.py) -- the synthetic domain's dense score field is
# meaningless without it -- then both stage thresholds are CALIBRATED
# on held-out scenes, the way a deployment sets them on validation
# traffic: the fine gate clears the empty-scene score ceiling (so
# full-pass detections are pedestrian neighbourhoods, not background
# noise), and the coarse gate sits as high as empty-scene quiet allows
# while staying under every calibration pedestrian's coarse score.
# The coarse stage sweeps ONE scale (0.5: the 66x34 head sees exactly
# the 130x66 pedestrians this traffic contains) -- on the CPU host each
# extra pyramid level costs ~2ms of op-dispatch regardless of its pixel
# count, so the single-scale sweep is what makes the coarse stage pay
# for itself; general traffic with unknown person sizes would keep the
# multi-scale default. Region crops run with a score-hysteresis band
# (CascadeConfig.fine_hysteresis) to absorb crop-grid resampling
# jitter. Gates: the cascade retains >= 99% of the full dense pass's
# detections (matched by IoU >= 0.5, same class, or by covering the
# same ground-truth pedestrian) AND runs >= 1.5x faster over the mix.

def run_cascade(fast: bool = False) -> dict:
    import dataclasses

    from repro.api import DetectionSession, presets
    from repro.core.cascade import CascadeDetector, coarse_detector
    from repro.data.synth_pedestrian import make_scene

    rng = np.random.default_rng(0)
    h, w = 480, 640
    n_pos, n_neg = (800, 550) if fast else (1200, 800)
    cfg = presets("cascade")
    sess = DetectionSession.train(cfg, n_pos=n_pos, n_neg=n_neg, rng=rng,
                                  hard_negative_rounds=2,
                                  mine_scenes=10 if fast else 16)
    coarse_svm = sess.cascade(rng=rng).coarse.svm     # train coarse once

    def _iou(a, b):
        y0, x0 = max(a[0], b[0]), max(a[1], b[1])
        y1, x1 = min(a[2], b[2]), min(a[3], b[3])
        inter = max(0.0, y1 - y0) * max(0.0, x1 - x0)
        ua = ((a[2] - a[0]) * (a[3] - a[1])
              + (b[2] - b[0]) * (b[3] - b[1]) - inter)
        return inter / (ua + 1e-9)

    def _clustered(r):
        # clustered but individually visible: rejection-sample until
        # the pasted pedestrians do not overlap each other
        for _ in range(50):
            s, t = make_scene(r, h, w, n_people=2, region=(0, 0, 320, 320))
            bs = [(y, x, y + hh, x + ww) for y, x, hh, ww in t]
            if all(_iou(a, b) < 0.05
                   for i, a in enumerate(bs) for b in bs[i + 1:]):
                return s, t
        return s, t

    base_ccfg = dataclasses.replace(cfg.cascade, coarse_scales=(0.5,),
                                    margin=96, max_regions=2,
                                    fine_hysteresis=1.5)

    # ------------- threshold calibration on held-out validation scenes
    loose_f = FrameDetector(sess.svm, dataclasses.replace(
        cfg.detector, score_threshold=0.0))
    loose_c = coarse_detector(coarse_svm, cfg.detector,
                              dataclasses.replace(base_ccfg,
                                                  coarse_threshold=-2.0))
    cal = np.random.default_rng(5000)
    f_ceiling = c_ceiling = 0.0
    for _ in range(4):
        scene, _ = make_scene(cal, h, w, n_people=0)
        f_ceiling = max([f_ceiling] + [d["score"] for d in
                                       loose_f.detect_raw(scene).to_list()])
        c_ceiling = max([c_ceiling] + [d["score"] for d in
                                       loose_c.detect_raw(scene).to_list()])
    person_c = []                  # coarse score at each calibration person
    for _ in range(3):
        scene, truth = _clustered(cal)
        hits = loose_c.detect_raw(scene).to_list()
        for (ty, tx, th_, tw) in truth:
            t = (ty, tx, ty + th_, tx + tw)
            person_c.append(max(
                (d["score"] for d in hits if _iou(d["box"], t) > 0.1),
                default=-2.0))
    fthr = f_ceiling + 1.0
    cthr = min(c_ceiling + 0.25, min(person_c) - 0.1)
    det_cfg = dataclasses.replace(cfg.detector, score_threshold=float(fthr))
    ccfg = dataclasses.replace(base_ccfg, coarse_threshold=float(cthr))
    fine = FrameDetector(sess.svm, det_cfg)
    casc = CascadeDetector(fine, coarse_detector(coarse_svm, det_cfg, ccfg),
                           ccfg)
    print(f"cascade/calibrated,fine_thr={fthr:.2f},coarse_thr={cthr:.2f}")

    n_clustered = 3 if fast else 6
    pairs = [_clustered(rng) for _ in range(n_clustered)]
    pairs += [make_scene(rng, h, w, n_people=0)
              for _ in range(n_clustered)]  # empty serving traffic
    scenes = [p[0] for p in pairs]

    # correctness pass (doubles as compile warmup for every region
    # bucket the deterministic cascade will hit again under timing).
    # The gate covers TRUE detections -- full-pass detections that match
    # a ground-truth pedestrian (IoU >= 0.4), the same universe the
    # core/cascade.py retention unit test uses: one counts as retained
    # when a cascade detection matches it directly (IoU >= 0.5, same
    # class) OR reports the same ground-truth pedestrian (region-local
    # NMS may keep a slightly shifted box for the same object). The
    # synthetic domain's paste-edge halo detections (no ground-truth
    # match) are tracked separately as fp_detections/fp_kept.
    kept = total = fp_kept = fp_total = 0
    for scene, truth in pairs:
        full = fine.detect_raw(scene).to_list()
        cd = casc.detect(scene)
        tboxes = [(ty, tx, ty + hh, tx + ww) for ty, tx, hh, ww in truth]

        def _gt(d):
            return max(range(len(tboxes)), default=None,
                       key=lambda i: _iou(d["box"], tboxes[i])) \
                if any(_iou(d["box"], tb) >= 0.4 for tb in tboxes) else None

        for f in full:
            same_box = any(_iou(f["box"], c["box"]) >= 0.5
                           and f.get("class_id") == c.get("class_id")
                           for c in cd)
            gt = _gt(f)
            same_person = gt is not None and any(
                _gt(c) == gt and f.get("class_id") == c.get("class_id")
                for c in cd)
            got = bool(same_box or same_person)
            if gt is not None:
                total += 1
                kept += got
                if not got:
                    print(f"cascade/lost,"
                          f"{[round(v, 1) for v in f['box']]},"
                          f"score={f['score']:.1f}")
            else:
                fp_total += 1
                fp_kept += got
    retention = kept / total if total else 0.0
    area_frac = casc.stats["region_area_frac"] / max(1, casc.stats["frames"])

    def _full():
        for scene in scenes:
            fine.detect_raw(scene).to_list()

    def _casc():
        for scene in scenes:
            casc.detect(scene)

    iters = 2 if fast else 4
    t_full = _time_dist(_full, iters=iters, warmup=1)
    t_casc = _time_dist(_casc, iters=iters, warmup=0)
    speedup = t_full["min_ms"] / t_casc["min_ms"]
    ok = total > 0 and retention >= 0.99 and speedup >= 1.5
    n = len(scenes)
    row = {"frame": f"{w}x{h}", "scenes": n,
           "clustered": n_clustered, "empty": n - n_clustered,
           "train": {"n_pos": n_pos, "n_neg": n_neg},
           "calibrated": {"fine_threshold": float(fthr),
                          "coarse_threshold": float(cthr)},
           "full_ms_per_frame": t_full["min_ms"] / n,
           "cascade_ms_per_frame": t_casc["min_ms"] / n,
           "speedup": speedup, "retention": retention,
           "detections_full": int(total), "detections_kept": int(kept),
           "fp_detections": int(fp_total), "fp_kept": int(fp_kept),
           "region_area_frac": area_frac, "ok": bool(ok)}
    print("# cascade -- coarse reject + fine-on-regions vs full dense pass")
    print(f"cascade/{w}x{h}_full_ms,{t_full['min_ms']/n:.1f},"
          f"dense per frame over {n}-frame mix")
    print(f"cascade/{w}x{h}_cascade_ms,{t_casc['min_ms']/n:.1f},"
          f"two-stage per frame")
    print(f"cascade/{w}x{h}_speedup,{speedup:.2f},gate>=1.5")
    print(f"cascade/retention,{retention:.3f},{kept}/{total} "
          f"truth-matched,gate>=0.99")
    print(f"cascade/fp_retained,{fp_kept}/{fp_total},"
          f"paste-edge halos (informational)")
    print(f"cascade/region_area_frac,{area_frac:.3f},"
          f"fine-stage pixel fraction")
    _update_bench(cascade=row)
    print(f"cascade/json,{BENCH_JSON.name},written")
    return row


def run_resilience(fast: bool = False) -> dict:
    """Chaos benchmark: the supervised engine under the standard fault
    schedule (serve/faults.py chaos_specs -- worker kill, device loss,
    latency spikes) vs an unperturbed run on the SAME frames.

    Records wall-clock overhead of surviving the faults, restart and
    retry counts, and the liveness gate the chaos-smoke CI lane
    enforces: every future resolves, detections are byte-identical to
    the clean run, and stop() returns. Exits 1 on any liveness miss.
    """
    from repro.serve.engine import DetectionService
    from repro.serve.faults import FaultInjector, chaos_specs

    n = 10 if fast else 24
    h, w = 160, 128
    rng = np.random.default_rng(11)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    det = DetectorConfig(score_threshold=-10.0, scales=(1.0,))
    frames = [rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
              for _ in range(n)]

    def _run(faults):
        svc = DetectionService(svm, detector=det, frame_batch=1,
                               max_wait_ms=1.0, faults=faults).start()
        t0 = time.perf_counter()
        res = svc.detect_frames(frames, timeout=180)
        wall = time.perf_counter() - t0
        stats = dict(svc.stats)
        svc.stop()
        return res, wall, stats

    _run(None)                       # warm the compiled program
    clean, t_clean, _ = _run(None)
    inj = FaultInjector(chaos_specs(), seed=0)
    chaos, t_chaos, stats = _run(inj)

    resolved = len(chaos) == n and all(isinstance(r, dict) for r in chaos)
    identical = all(c.get("detections") == r.get("detections")
                    for c, r in zip(chaos, clean))
    ok = (resolved and identical and stats["restarts"] >= 1
          and stats["frame_answers"] == n)
    row = {"frame": f"{w}x{h}", "frames": n,
           "clean_ms_per_frame": t_clean * 1e3 / n,
           "chaos_ms_per_frame": t_chaos * 1e3 / n,
           "chaos_overhead_x": t_chaos / max(t_clean, 1e-9),
           "fired": [list(f) for f in inj.fired],
           "restarts": stats["restarts"], "retries": stats["retries"],
           "worker_failures": stats["worker_failures"],
           "deadline_shed": stats["deadline_shed"],
           "latency_ms": stats["latency_ms"],
           "breaker": stats["breaker"], "ok": bool(ok)}
    print("# resilience -- supervised engine under the chaos schedule")
    print(f"resilience/clean_ms,{t_clean*1e3/n:.1f},per frame, no faults")
    print(f"resilience/chaos_ms,{t_chaos*1e3/n:.1f},per frame under "
          f"kill+device-loss+latency")
    print(f"resilience/overhead,{t_chaos/max(t_clean,1e-9):.2f}x,"
          f"restarts={stats['restarts']} retries={stats['retries']}")
    print(f"resilience/identical,{identical},chaos vs clean detections,"
          f"gate=True")
    print(f"resilience/resolved,{resolved},all {n} futures,gate=True")
    _update_bench(resilience=row)
    print(f"resilience/json,{BENCH_JSON.name},written")
    return row


if __name__ == "__main__":
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--session-only", action="store_true",
                    help="measure + record only the session_overhead row")
    ap.add_argument("--breakdown-only", action="store_true",
                    help="measure + record only the per-stage pr4 row")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: fail if dense 640x480 ms/frame "
                         "regressed vs the committed pr4 baseline "
                         "(never writes BENCH_detect.json)")
    ap.add_argument("--sharded", action="store_true",
                    help="measure + record the multi-device sharded "
                         "section (forces 8 host devices via XLA_FLAGS "
                         "unless already set); exits 1 when sharded "
                         "results are not byte-identical to the "
                         "single-device path")
    ap.add_argument("--uhd", action="store_true",
                    help="measure + record the 3840x2160 intra-frame "
                         "tiled section (forces 8 host devices via "
                         "XLA_FLAGS unless already set); exits 1 when "
                         "tiled results are not box-identical to the "
                         "untiled path")
    ap.add_argument("--quant", action="store_true",
                    help="measure + record the fixed-point numerics "
                         "section (int8-vs-bf16 scoring, quant-vs-perf "
                         "e2e ms/frame); exits 1 when the fixed chain's "
                         "ref and fused backends disagree")
    ap.add_argument("--multiclass", action="store_true",
                    help="measure + record the K=4 stacked-heads "
                         "section; exits 1 when the stacking overhead "
                         "tops 15%% or head 0 of the stack is not "
                         "byte-identical to the single-head program")
    ap.add_argument("--cascade", action="store_true",
                    help="measure + record the two-stage cascade "
                         "section (retention + speedup vs the full "
                         "dense pass on the synthetic clustered/empty "
                         "mix); exits 1 when retention < 0.99 or "
                         "speedup < 1.5")
    ap.add_argument("--resilience", action="store_true",
                    help="measure + record the chaos section (clean vs "
                         "fault-injected serving on the same frames); "
                         "exits 1 when a future fails to resolve or "
                         "chaos detections differ from the clean run")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="--check: allowed regression fraction "
                         "(default 0.15 = 15%%)")
    a = ap.parse_args()
    if a.resilience:
        sys.exit(0 if run_resilience(fast=a.fast)["ok"] else 1)
    elif a.multiclass:
        sys.exit(0 if run_multiclass(fast=a.fast)["ok"] else 1)
    elif a.cascade:
        sys.exit(0 if run_cascade(fast=a.fast)["ok"] else 1)
    elif a.quant:
        sys.exit(0 if run_quant(fast=a.fast)["ok"] else 1)
    elif a.uhd:
        sys.exit(0 if run_uhd(fast=a.fast)["ok"] else 1)
    elif a.sharded:
        sys.exit(0 if run_sharded(fast=a.fast)["ok"] else 1)
    elif a.check:
        sys.exit(run_check(tolerance=a.tolerance, fast=a.fast))
    elif a.session_only:
        run_session_overhead(fast=a.fast)
    elif a.breakdown_only:
        run_stage_breakdown(fast=a.fast)
    else:
        run(fast=a.fast)
