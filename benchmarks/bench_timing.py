"""Paper Table II: detection timing, software vs co-processor path.

The paper: Matlab 41 ms/window vs ModelSim hardware 0.757 ms at 50 MHz
(54x). The TPU analogue measured here:

  software path    -- per-window (batch=1) jit'd jnp pipeline on CPU
                      (the "Matlab" role: one window at a time)
  co-processor path -- batched pipeline, per-window time amortized over
                      a 256-window batch (the TPU dataflow role)
  dense-scene path  -- score_map conv: per-WINDOW time when windows
                      overlap in a scene (beyond-paper, §Perf)
  TPU roofline      -- derived per-window latency from the dry-run
                      (bytes/819GBps vs flops/197TFLOPs), reported by
                      benchmarks/bench_roofline.py from dryrun.json

Timing on this container is CPU wall time -- the RATIO between the
software and batched paths is the reproduction target, not the absolute
numbers (the paper's own 54x compares two implementations on different
substrates as well).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.core.pipeline import classify_windows
from repro.core.svm import init_svm
from repro.core.detector import score_map


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(fast: bool = False):
    rng = np.random.default_rng(0)
    svm = init_svm(3780)
    svm = {"w": jnp.asarray(rng.normal(size=3780).astype(np.float32)) * .01,
           "b": jnp.float32(0.0)}
    win1 = jnp.asarray(rng.integers(0, 256, (1, 130, 66, 3)).astype(np.uint8))
    B = 64 if fast else 256
    winB = jnp.asarray(rng.integers(0, 256, (B, 130, 66, 3)).astype(np.uint8))

    f1 = jax.jit(lambda w: classify_windows(svm, w)["score"])
    t_sw = _time(f1, win1)                      # per window, batch=1
    t_batch = _time(f1, winB) / B               # amortized per window

    fx = jax.jit(lambda w: hog_descriptor(w, PAPER_HOG))
    t_extract1 = _time(fx, win1)
    t_extractB = _time(fx, winB) / B

    # dense scene: 320x240 -> ~600 window positions in one conv
    scene = jnp.asarray(rng.integers(0, 256, (320, 240)).astype(np.float32))
    sm = jax.jit(lambda s: score_map(s, svm["w"], svm["b"], PAPER_HOG))
    smap = sm(scene)
    n_windows = smap.shape[0] * smap.shape[1]
    t_scene = _time(sm, scene) / n_windows

    print("# Table II -- timing per window (CPU host; ratios are the "
          "reproduction target)")
    print(f"table2/attracting_software_ms,{t_extract1*1e3:.3f},paper=16")
    print(f"table2/attracting_batched_ms,{t_extractB*1e3:.3f},paper=0.411")
    print(f"table2/detecting_software_ms,{t_sw*1e3:.3f},paper=41")
    print(f"table2/detecting_batched_ms,{t_batch*1e3:.3f},paper=0.757")
    # NOTE: on this 1-core CPU host, batching cannot beat batch=1 (no
    # parallel hardware -- the paper's 54x IS its hardware parallelism).
    # The two host-measurable analogues of the paper's speedup are:
    #   * dense-scene amortization (one conv scores ~500 windows), and
    #   * the TPU roofline latency from the dry-run (60.5 ns/window,
    #     bench_roofline.py / EXPERIMENTS.md §Roofline).
    print(f"table2/speedup_batched_cpu_host,{t_sw/t_batch:.1f},"
          f"paper=54 (needs parallel hw; see dense_scene + roofline)")
    print(f"table2/detecting_dense_scene_ms,{t_scene*1e3:.4f},"
          f"windows={n_windows}")
    print(f"table2/speedup_dense_scene,{t_sw/t_scene:.1f},"
          f"beyond-paper analogue of the 54x")
    print(f"table2/tpu_roofline_ns_per_window,60.5,"
          f"vs paper 757000 ns (dryrun hog cell)")
    return {"speedup": t_sw / t_scene}


if __name__ == "__main__":
    run()
