"""End-to-end LM training driver: data pipeline -> sharded train step ->
async checkpointing -> resume. Runs a ~5M-param model for a few hundred
steps on CPU by default; --size 100m selects the ~100M config the
deliverable names (sized for real hardware; same code path).

Demonstrates the fault-tolerance loop: kill it mid-run and re-launch --
it resumes from the latest atomic checkpoint.

Usage: PYTHONPATH=src python examples/train_lm.py [--steps 300]
           [--size tiny|100m] [--ckpt /tmp/repro_ckpt] [--ddp]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.lm_data import LMDataConfig, batches
from repro.models.configs import ModelConfig
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

SIZES = {
    # ~5M params: CPU-friendly demo
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=4,
                        d_model=256, n_heads=4, n_kv_heads=2, d_ff=683,
                        vocab=512, rope_theta=1e4),
    # ~100M params: the deliverable config (run on real hardware)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab=50304, rope_theta=1e4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="tiny", choices=list(SIZES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = SIZES[args.size]
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")
    opt = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt))  # m/v share zero consts

    mgr = CheckpointManager(args.ckpt, keep=2)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        print(f"resuming from checkpoint step {latest}")
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state = mgr.restore(latest, target)
        start = latest

    data = batches(LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                batch=args.batch))
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        b = next(data)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            tps = args.batch * args.seq * 20 / (time.time() - t0)
            print(f"step {step+1:4d}  loss {losses[-1]:.3f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"{tps:,.0f} tok/s")
            t0 = time.time()
        if (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, state)
    mgr.wait()
    first = np.mean(losses[:20]) if len(losses) > 20 else losses[0]
    last = np.mean(losses[-20:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first else 'check config'})")


if __name__ == "__main__":
    main()
