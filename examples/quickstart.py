"""Quickstart: the paper's full system end-to-end in ~2 minutes on CPU.

1. synthesize the pedestrian dataset (paper split sizes),
2. extract HOG descriptors (130x66 -> 3780 features, eqs. 1-5),
3. train the linear SVM in-framework (replacing the paper's Matlab step),
4. evaluate Table I accuracy,
5. run the multi-scale sliding-window detector on a scene through the
   unified api (`repro.api.DetectionSession` -- the paper's one-command
   co-processor interface; "future development" §VI),
6. the multi-workload layer (DESIGN.md §13): named SVM heads stacked
   into ONE widened scoring matmul (`HeadRegistry`, per-class NMS and
   thresholds, `detect(classes=...)`), and the two-stage cascade --
   a half-resolution coarse head rejects empty neighbourhoods so the
   dense chain only runs on promoted crops (`session.cascade()`),
7. resilient serving (DESIGN.md §14): injected latency spikes push the
   service's rolling p99 over the degradation line, responses report
   `degraded_mode` per frame, and the hysteresis ladder climbs back to
   the full pipeline once the overload clears -- while the whole
   episode streams off-process as structured JSONL events
   (`repro.obs.metrics`, DESIGN.md §15) you can `tail -f` live.

The same session serves every other path too:

    session.detect_batch(frames)    # stacked frames, one device step
    session.stream(clip)            # batched detection + IoU tracking
    session.serve().start()         # micro-batching DetectionService

and `presets("paper" | "faithful" | "perf")` swaps the whole numerics /
precision / serving tree in one argument (see DESIGN.md §8). For big
frames, `presets("uhd")` adds intra-frame parallelism: the pyramid is
tiled over every spare device (`detector.frame_parallel`), with the
banded O(taps) pyramid resize and an overlap-exact merge + NMS, so a
3840x2160 frame's latency drops while staying box-identical to the
untiled path (DESIGN.md §11); frames below `frame_parallel_min_area`
keep routing to the untiled program. `presets("quant")` switches the
whole chain to the paper's actual hardware datapath -- integer CORDIC
gradients, int16 cell histograms, int8 block descriptors and
int8xint8->int32 scoring (DESIGN.md §12) -- within 1.5 accuracy points
of fp32 on Table I and byte-identical under data- and tile-sharding.

Usage:  PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.api import DetectionSession, HeadRegistry, PipelineConfig
from repro.core import (DetectorConfig, PAPER_HOG, accuracy_table,
                        hog_descriptor, train_svm)
from repro.core.svm import SVMTrainConfig
from repro.data.synth_pedestrian import (PedestrianDataConfig, make_dataset,
                                         make_scene)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller train split (accuracy lands lower than the paper band; full run matches)")
    args = ap.parse_args()

    dcfg = (PedestrianDataConfig(n_pos=800, n_neg=550) if args.fast
            else PedestrianDataConfig())
    print(f"[1/7] generating {dcfg.n_pos}+{dcfg.n_neg} train windows ...")
    x_tr, y_tr, x_te, y_te = make_dataset(dcfg)

    print("[2/7] extracting HOG descriptors (mode=sector, TPU-native) ...")
    t0 = time.time()
    f_tr = hog_descriptor(jnp.asarray(x_tr), PAPER_HOG)
    f_te = hog_descriptor(jnp.asarray(x_te), PAPER_HOG)
    print(f"      {f_tr.shape[0]} x {f_tr.shape[1]} features "
          f"in {time.time()-t0:.1f}s")

    print("[3/7] training linear SVM (Pegasos, class-weighted) ...")
    params, losses = train_svm(f_tr, jnp.asarray(y_tr),
                               SVMTrainConfig(steps=4000, neg_weight=6.0))
    print(f"      final hinge loss {float(losses[-1]):.4f}")

    print("[4/7] Table I evaluation (paper: 84.35 %) ...")
    acc = accuracy_table(params, f_te, jnp.asarray(y_te))
    print(f"      with person    {acc['with_person_acc']*100:.2f}%  "
          f"(paper 83.75%)")
    print(f"      without person {acc['without_person_acc']*100:.2f}%  "
          f"(paper 85.07%)")
    print(f"      total          {acc['total_acc']*100:.2f}%  "
          f"(paper 84.35%)")

    print("[5/7] multi-scale detection on a 320x240 scene "
          "(DetectionSession) ...")
    session = DetectionSession(params, PipelineConfig(
        detector=DetectorConfig(score_threshold=0.5)))
    rng = np.random.default_rng(7)
    scene, true_boxes = make_scene(rng, 320, 240, n_people=2)
    result = session.detect(scene)           # typed, device-resident
    dets = result.to_list()                  # legacy dict contract
    print(f"      true boxes: {true_boxes}")
    for d in dets[:5]:
        y0, x0, y1, x1 = d["box"]
        print(f"      det: ({y0:.0f},{x0:.0f})-({y1:.0f},{x1:.0f}) "
              f"score={d['score']:.2f} scale={d['scale']}")
    if not dets:
        print("      (no detections above threshold)")
    if result.saturated:
        # with max_detections=0 (the default) K scales with the window
        # grid, so this only fires on an explicit, too-small override
        print("      (top-k saturated: raise detector.max_detections)")

    print("[6/7] multi-head registry + two-stage cascade "
          "(DESIGN.md §13) ...")
    # K named heads -> ONE widened (36, 105*K) scoring matmul. The
    # second head reuses the pedestrian params under a stricter gate --
    # a stand-in for a separately trained class (vehicle, custom).
    registry = HeadRegistry()
    registry.add("pedestrian", params, threshold=3.0)
    registry.add("pedestrian_strict", params, threshold=6.0)
    multi = DetectionSession(registry, session.config)
    # a sparser 480x640 scene: people confined to one corner, so the
    # cascade has background to reject
    sparse, _ = make_scene(rng, 480, 640, n_people=2,
                           region=(0, 0, 260, 260))
    for d in multi.detect(sparse).to_list()[:4]:
        print(f"      {d['label']:<18} (class {d['class_id']}) "
              f"score={d['score']:.2f}")
    # cascade: the 66x34 coarse head sweeps the frame at a loose
    # threshold; only its hit neighbourhoods run the dense chain
    coarse_svm = None
    if args.fast:                # smaller coarse training split
        from repro.core.cascade import train_coarse_head
        coarse_svm, _ = train_coarse_head(
            multi.config.hog, SVMTrainConfig(steps=1500),
            n_pos=300, n_neg=220, rng=rng, mine_scenes=4)
    casc = multi.cascade(coarse_svm=coarse_svm, rng=rng)
    cdets = casc.detect(sparse)
    frac = casc.stats["region_area_frac"] / casc.stats["frames"]
    print(f"      cascade: {len(cdets)} detections, fine stage ran on "
          f"{frac*100:.0f}% of the frame's pixels")

    print("[7/7] graceful degradation under synthetic overload "
          "(DESIGN.md §14) ...")
    # a resilient service: rolling-p99 latency drives the degradation
    # ladder (full -> reduced pyramid here; with a cascade handle the
    # rungs are full -> cascade -> coarse). The FaultInjector's latency
    # spikes stand in for an overloaded device; every response reports
    # the rung that served it, and the ladder climbs back once p99
    # recovers -- with hysteresis, so it doesn't flap. Small frames +
    # pre-warmed programs keep the demo's latencies about compute, not
    # compiles.
    from repro.core.cascade import reduced_detector
    from repro.core.detector import FrameDetector
    from repro.serve.faults import FaultInjector, FaultSpec
    from repro.serve.resilience import ResilienceConfig
    small, _ = make_scene(rng, 160, 128, n_people=1)
    warm = FrameDetector(params, DetectorConfig(score_threshold=0.5,
                                                scales=(1.0, 0.8)))
    warm.detect_raw(small)
    reduced_detector(warm).detect_raw(small)
    inj = FaultInjector((FaultSpec("latency", at_batches=(2, 3, 4, 5),
                                   latency_ms=120.0),), seed=0)
    # every service event (batches, rung transitions, sheds, restarts)
    # streams to a JSONL file as it happens -- telemetry that survives
    # the process (DESIGN.md §15)
    import os
    import tempfile
    from repro.obs import JsonlSink, MetricsConfig
    mpath = os.path.join(tempfile.mkdtemp(prefix="repro-quickstart-"),
                         "metrics.jsonl")
    print(f"      events -> {mpath}  (live: tail -f {mpath})")
    svc = session.serve(
        frame_detector=warm, frame_batch=1, faults=inj,
        metrics=MetricsConfig(jsonl_path=mpath, ring=64),
        resilience=ResilienceConfig(degrade_p99_ms=80.0,
                                    recover_p99_ms=30.0,
                                    recover_dwell=2,
                                    latency_window=4)).start()
    rungs = []
    for _ in range(14):
        r = svc.detect_frames([small], timeout=120)[0]
        rungs.append(r["degraded_mode"])
    s = svc.stats
    svc.stop()
    episode = " ".join(f"{r}x{n}" for r, n in
                       [(r, rungs.count(r)) for r in dict.fromkeys(rungs)])
    print(f"      degraded_mode per frame: {episode}")
    print(f"      p50={s['latency_ms']['p50']:.0f}ms "
          f"p99={s['latency_ms']['p99']:.0f}ms "
          f"degraded={s['frames_degraded']} frames, "
          f"ladder transitions={s['ladder']['transitions']}, "
          f"final rung={s['degraded_mode']}")
    events = JsonlSink.read(mpath)
    kinds = {}
    for e in events:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    print(f"      {len(events)} events exported: "
          + " ".join(f"{k}x{n}" for k, n in sorted(kinds.items())))
    for t in (e for e in events if e["kind"] == "rung_transition"):
        print(f"      t={t['t_ms']:7.1f}ms  {t['rung_from']} -> "
              f"{t['rung_to']}  ({t['direction']}, p99="
              f"{t['p99_ms']:.0f}ms, queue={t['queue_depth']})")


if __name__ == "__main__":
    main()
