"""Full-frame detection as a SERVICE: a camera-style stream of frames
through DetectionService.submit_frame -- pyramid, dense HOG, top-k and
NMS all device-resident, one compiled program per frame-shape bucket
(core/detector.py). The first frame pays compilation; every later frame
of the same shape reuses the program.

Usage: PYTHONPATH=src python examples/detect_frames.py [--frames 8]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.detector import DetectorConfig
from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.core.svm import SVMTrainConfig, train_svm
from repro.data.synth_pedestrian import (PedestrianDataConfig, make_scene,
                                         make_windows)
from repro.serve.engine import DetectionService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    print("training a quick SVM ...")
    x, y = make_windows(500, 350, PedestrianDataConfig(), rng)
    f = hog_descriptor(jnp.asarray(x), PAPER_HOG)
    svm, _ = train_svm(f, jnp.asarray(y),
                       SVMTrainConfig(steps=1500, neg_weight=6.0))

    service = DetectionService(
        svm, detector=DetectorConfig(score_threshold=0.5)).start()

    print(f"streaming {args.frames} 320x240 frames ...")
    frames, truths = [], []
    for _ in range(args.frames):
        scene, truth = make_scene(rng, 320, 240, n_people=2)
        frames.append(scene)
        truths.append(truth)

    t0 = time.time()
    results = service.detect_frames(frames)
    wall = time.time() - t0

    hits = 0
    for r, truth in zip(results, truths):
        for (ty, tx, _, _) in truth:
            hits += any(abs(d["box"][0] - ty) < 32
                        and abs(d["box"][1] - tx) < 32
                        for d in r["detections"])
    per_frame = [r["ms"] for r in results]
    print(f"wall            {wall:.2f}s for {args.frames} frames")
    print(f"frame latency   first={per_frame[0]:.0f} ms (compile), "
          f"steady={np.mean(per_frame[1:]):.0f} ms")
    print(f"service stats   frames={service.stats['frames']} "
          f"mean_ms={service.stats['frame_ms']:.0f} "
          f"boxes={service.stats['frame_boxes']}")
    print(f"recall          {hits}/{2 * args.frames}")
    service.stop()


if __name__ == "__main__":
    main()
