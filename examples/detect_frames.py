"""Full-frame detection as a SERVICE and as a tracked STREAM, all from
one `repro.api.DetectionSession`.

Phase 1 -- service: `session.serve()` starts the micro-batching
DetectionService on the session's own compiled programs (pyramid, dense
HOG, top-k and NMS device-resident, one program per frame-shape
bucket). The first frame pays compilation; same-shape requests coalesce
into one batched device step. Results carry per-frame latency and the
top-k `saturated` flag.

Phase 2 -- stream: a synthetic video clip (constant-velocity
pedestrians) through `session.stream` -- the batched device path + IoU
tracker -- after an explicit `session.warmup` of every (batch, shape)
the clip will hit, so the timed region measures steady-state
throughput. Reports track-id stability.

Usage: PYTHONPATH=src python examples/detect_frames.py [--frames 8]
                                                       [--clip-frames 12]
"""
import argparse
import time

import numpy as np

from repro.api import DetectionSession, PipelineConfig
from repro.core.detector import DetectorConfig
from repro.core.svm import SVMTrainConfig
from repro.core.video import TrackerConfig
from repro.data.synth_pedestrian import ClipConfig, make_clip, make_scene


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--clip-frames", type=int, default=12)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    print("training a quick SVM (DetectionSession.train) ...")
    cfg = PipelineConfig(
        detector=DetectorConfig(score_threshold=0.5),
        train=SVMTrainConfig(steps=1500, neg_weight=6.0))
    session = DetectionSession.train(cfg, n_pos=500, n_neg=350)

    service = session.serve().start()

    print(f"streaming {args.frames} 320x240 frames ...")
    frames, truths = [], []
    for _ in range(args.frames):
        scene, truth = make_scene(rng, 320, 240, n_people=2)
        frames.append(scene)
        truths.append(truth)

    t0 = time.time()
    results = service.detect_frames(frames)
    wall = time.time() - t0

    hits = 0
    for r, truth in zip(results, truths):
        for (ty, tx, _, _) in truth:
            hits += any(abs(d["box"][0] - ty) < 32
                        and abs(d["box"][1] - tx) < 32
                        for d in r["detections"])
    per_frame = [r["ms"] for r in results]
    n_sat = sum(bool(r.get("saturated")) for r in results)
    print(f"wall            {wall:.2f}s for {args.frames} frames")
    print(f"frame latency   first={per_frame[0]:.0f} ms (compile), "
          f"steady={np.mean(per_frame[1:]):.0f} ms")
    print(f"service stats   frames={service.stats['frames']} "
          f"batches={service.stats['frame_batches']} "
          f"occupancy={service.stats['frame_occupancy']:.2f} "
          f"mean_ms={service.stats['frame_ms']:.0f} "
          f"boxes={service.stats['frame_boxes']} "
          f"saturated={n_sat}")
    print(f"recall          {hits}/{2 * args.frames}")
    service.stop()

    # ---- phase 2: batched clip + tracking -------------------------------
    print(f"\nvideo clip: {args.clip_frames} frames, 2 walkers, "
          f"session.stream (batched path + tracker) ...")
    clip, truth = make_clip(rng, ClipConfig(n_frames=args.clip_frames,
                                            h=240, w=320, n_people=2))
    # the quick SVM fires broadly at threshold 0.5; 512 top-k slots keep
    # the candidate tail out of the saturation path
    video = DetectionSession(session.svm, PipelineConfig(
        detector=DetectorConfig(score_threshold=0.5, max_detections=512),
        tracker=TrackerConfig(min_hits=2, max_misses=3)))
    # compile EVERY (bucket, B) the clip will hit -- full chunks and the
    # tail -- so the timed region measures steady-state throughput
    h, w = clip.shape[1], clip.shape[2]
    warm = []
    head = min(8, len(clip))
    warm.append((head, h, w) if head > 1 else (h, w))
    tail = len(clip) % 8
    if tail:
        warm.append((tail, h, w) if tail > 1 else (h, w))
    video.warmup(warm)
    t0 = time.time()
    tracked = [d.to_list() for d in video.stream(list(clip), batch_size=8)]
    wall = time.time() - t0

    track_hits, id_sets = 0, {}
    # min_hits=2 means no track can be emitted on frame 0 -- score
    # recall over the frames where emission is possible
    for dets, gt in zip(tracked[1:], truth[1:]):
        for g in gt:
            ty, tx = g["box"][:2]
            for d in dets:
                if abs(d["box"][0] - ty) < 32 and abs(d["box"][1] - tx) < 32:
                    track_hits += 1
                    id_sets.setdefault(g["id"], set()).add(d["track_id"])
                    break
    print(f"clip throughput {len(clip) / wall:.1f} frames/s "
          f"({wall * 1e3 / len(clip):.0f} ms/frame, batch=8)")
    print(f"track recall    {track_hits}/{2 * (len(clip) - 1)}")
    for pid, ids in sorted(id_sets.items()):
        print(f"walker {pid}       track ids {sorted(ids)} "
              f"({'stable' if len(ids) == 1 else 'fragmented'})")


if __name__ == "__main__":
    main()
