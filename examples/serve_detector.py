"""The co-processor as a SERVICE: batched request queue in front of the
jit'd detection step -- the deployment shape the paper sketches in §VI
(camera -> ARM core -> detection block).

Trains a quick SVM through `repro.api.DetectionSession`, starts the
service with `session.serve()` (one PipelineConfig carries the window
batch + wait deadline), submits 500 async requests, reports latency
percentiles + batch occupancy.

Usage: PYTHONPATH=src python examples/serve_detector.py
"""
import time

import numpy as np

from repro.api import DetectionSession, PipelineConfig, ServiceConfig
from repro.core.svm import SVMTrainConfig
from repro.data.synth_pedestrian import PedestrianDataConfig, make_windows


def main():
    dcfg = PedestrianDataConfig()
    print("training a quick SVM ...")
    cfg = PipelineConfig(
        train=SVMTrainConfig(steps=1500, neg_weight=6.0),
        service=ServiceConfig(window_batch=64, max_wait_ms=4.0))
    session = DetectionSession.train(cfg, n_pos=600, n_neg=400,
                                     data_cfg=dcfg)

    service = session.serve().start()
    print("submitting 500 requests ...")
    # a fresh stream so requests are not the training windows
    x_req, y_req = make_windows(250, 250, dcfg, np.random.default_rng(1))
    lat = []
    correct = 0
    t_all = time.time()
    futs = []
    for i in range(len(y_req)):
        futs.append((time.time(), i, service.submit(x_req[i])))
    for t0, i, fut in futs:
        r = fut.get(timeout=60)
        lat.append(time.time() - t0)
        correct += int(r["human"] == int(y_req[i]))
    wall = time.time() - t_all
    lat_ms = np.sort(np.asarray(lat) * 1e3)
    print(f"throughput      {len(y_req)/wall:,.0f} windows/s")
    print(f"latency p50/p95 {lat_ms[len(lat_ms)//2]:.1f} / "
          f"{lat_ms[int(len(lat_ms)*.95)]:.1f} ms")
    print(f"accuracy        {correct/len(y_req)*100:.1f}%")
    print(f"batch occupancy {service.stats['occupancy']*100:.0f}%  "
          f"({service.stats['batches']} batches)")
    service.stop()


if __name__ == "__main__":
    main()
