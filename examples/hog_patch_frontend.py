"""HOG descriptors as a VLM patch-embedding frontend.

The assignment stubs qwen2-vl's vision encoder; this example shows the
paper's feature extractor IS such a frontend: image patches -> HOG
descriptors (3780-d, contrast-normalized) -> linear projection to
d_model -> prepended to the token stream of the qwen2-vl (smoke)
backbone with M-RoPE (t, h, w) positions. A classical-CV co-processor
feeding a modern multimodal LM -- the paper's §VI pipeline, upgraded.

Usage: PYTHONPATH=src python examples/hog_patch_frontend.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.hog import HOGConfig, hog_descriptor
from repro.models.model import forward, init_params


def hog_patch_embed(image: np.ndarray, patch: int = 66,
                    d_model: int = 64, key=None):
    """Split an image into patches, HOG each, project to d_model."""
    H, W, _ = image.shape
    ph, pw = H // patch, W // patch
    cfg = HOGConfig(window_h=patch, window_w=patch)
    patches = np.stack([
        image[i * patch:(i + 1) * patch, j * patch:(j + 1) * patch]
        for i in range(ph) for j in range(pw)])
    desc = hog_descriptor(jnp.asarray(patches), cfg)     # (P, F)
    proj = jax.random.normal(key, (desc.shape[-1], d_model),
                             jnp.float32) * desc.shape[-1] ** -0.5
    return desc @ proj, (ph, pw)


def main():
    cfg = get_config("qwen2-vl-72b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    image = rng.integers(0, 256, (132, 132, 3)).astype(np.uint8)

    embeds, (ph, pw) = hog_patch_embed(image, patch=66,
                                       d_model=cfg.d_model,
                                       key=jax.random.PRNGKey(1))
    n_img = embeds.shape[0]
    print(f"image 132x132 -> {ph}x{pw} HOG patches -> "
          f"({n_img}, {cfg.d_model}) embeddings")

    text = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    B, S_txt = text.shape
    S = n_img + S_txt

    # M-RoPE positions: image patches get (t=0, h=i, w=j); text gets
    # sequential t after the image block (qwen2-vl scheme)
    pos_img = np.stack([np.zeros(n_img),
                        np.repeat(np.arange(ph), pw),
                        np.tile(np.arange(pw), ph)], -1)
    t0 = max(ph, pw)
    pos_txt = np.stack([np.arange(S_txt) + t0] * 3, -1)
    positions = jnp.asarray(
        np.concatenate([pos_img, pos_txt])[None], jnp.int32)

    # splice image embeddings in place of the first n_img token slots
    tokens = jnp.concatenate(
        [jnp.zeros((1, n_img), jnp.int32), text], axis=1)
    from repro.models.model import embed_tokens, logits_from_hidden, _scan_layers
    x = embed_tokens(params, tokens, cfg)
    x = x.at[:, :n_img].set(embeds[None].astype(cfg.dtype))
    x = _scan_layers(x, params["layers"], cfg, positions, None)
    logits = logits_from_hidden(params, x, cfg)
    print(f"backbone logits: {logits.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))}")
    print("HOG frontend -> M-RoPE VLM backbone: OK")


if __name__ == "__main__":
    main()
