"""Multi-head (stacked-classifier) detection: identity + class axis.

The acceptance bar of the multi-head subsystem (DESIGN.md §13) is
BYTE-identity, not closeness: scoring K stacked heads through the one
widened (BH*BW, 36) @ (36, 105*K) matmul must reproduce each head's
single-head program bit for bit, in every numerics mode --

  * float32 / bf16: the widened matmul only appends columns; each
    column is the same 36-element dot product the single-head program
    computes, and the shifted-add collate runs per head plane in the
    single-head accumulation order;
  * int8 "fixed": quantization scales are per COLUMN
    (quant.quantize_weight_columns), so head k's codes in the widened
    weight matrix equal its single-head codes exactly and the integer
    accumulation is order-free.

K=1 stacked must equal the plain single-head path (the legacy program),
per-class NMS must be class-isolated (head k's keep decisions never see
head j's boxes), and the class axis must thread through Detections,
session subsets, the registry round-trip, and tracker association.
"""
import dataclasses
import os
import tempfile

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.detector import DetectorConfig, FrameDetector, nms_keep
from repro.core.heads import HeadRegistry
from repro.core.hog import HOGConfig

SEED = 7


def _mk_heads(n, f, rng):
    return [{"w": rng.normal(0, 0.05, (f,)).astype(np.float32),
             "b": np.float32(rng.normal() * 0.01)} for _ in range(n)]


def _stack(heads):
    return {"w": np.stack([h["w"] for h in heads]),
            "b": np.asarray([h["b"] for h in heads], np.float32)}


def _frame(rng, h=200, w=160):
    return rng.integers(0, 255, (h, w, 3), np.uint8)


def _raw(det):
    return (np.asarray(det._scores), np.asarray(det._index),
            np.asarray(det._keep), np.asarray(det._n_valid))


MODES = [("float", "f32"), ("float", "bf16"), ("fixed", "f32")]


@pytest.mark.parametrize("numerics,feat", MODES)
def test_stacked_byte_identical_to_per_head(numerics, feat):
    rng = np.random.default_rng(SEED)
    hog = HOGConfig(numerics=numerics, feat_dtype=feat)
    cfg = DetectorConfig(hog=hog, score_threshold=-3.0)
    frame = _frame(rng)
    heads = _mk_heads(3, hog.n_features, rng)
    multi = FrameDetector(_stack(heads), cfg).detect_raw(frame)
    for k, head in enumerate(heads):
        single = FrameDetector(head, cfg).detect_raw(frame)
        s, i, kp, nv = _raw(multi.for_class(k))
        s1, i1, kp1, nv1 = _raw(single)
        assert np.array_equal(s, s1), f"head {k} scores differ ({numerics})"
        assert np.array_equal(i, i1)
        assert np.array_equal(kp, kp1)
        assert int(nv) == int(nv1)


@pytest.mark.parametrize("numerics,feat", MODES)
def test_k1_byte_identical_to_single_head_path(numerics, feat):
    """A one-head stack must reproduce the legacy single-head program
    exactly -- the K=1 detector is the same detector."""
    rng = np.random.default_rng(SEED + 1)
    hog = HOGConfig(numerics=numerics, feat_dtype=feat)
    cfg = DetectorConfig(hog=hog, score_threshold=-3.0)
    frame = _frame(rng)
    head = _mk_heads(1, hog.n_features, rng)[0]
    single = FrameDetector(head, cfg).detect_raw(frame)
    one = FrameDetector(_stack([head]), cfg).detect_raw(frame)
    s, i, kp, nv = _raw(one.for_class(0))
    s1, i1, kp1, nv1 = _raw(single)
    assert np.array_equal(s, s1)
    assert np.array_equal(i, i1)
    assert np.array_equal(kp, kp1)
    assert int(nv) == int(nv1)


def test_batched_multihead_matches_single_frame():
    rng = np.random.default_rng(SEED + 2)
    cfg = DetectorConfig(score_threshold=-3.0)
    heads = _mk_heads(2, cfg.hog.n_features, rng)
    det = FrameDetector(_stack(heads), cfg)
    frames = [_frame(rng), _frame(rng), _frame(rng)]
    batch = det.detect_batch_raw(frames)
    assert batch.batched and batch.classes == ("head0", "head1")
    for i, f in enumerate(frames):
        s, ix, kp, nv = _raw(det.detect_raw(f))
        sb, ixb, kpb, nvb = _raw(batch.frame(i))
        assert np.array_equal(s, sb) and np.array_equal(kp, kpb)
        assert np.array_equal(ix, ixb) and np.array_equal(nv, nvb)


# ---------------------------------------------------- per-class NMS

def _per_class_keep(boxes, scores, thr):
    """Reference: run device NMS independently per class row."""
    import jax.numpy as jnp
    return np.stack([np.asarray(nms_keep(jnp.asarray(boxes[k]),
                                         jnp.asarray(scores[k]), thr))
                     for k in range(boxes.shape[0])])


def check_class_isolation(rng):
    """Identical boxes in two classes: per-class NMS keeps BOTH (no
    cross-class suppression), and each class's keep set equals the
    class-independent reference."""
    import jax
    n, thr = 12, 0.3
    y0 = rng.uniform(0, 100, n)
    x0 = rng.uniform(0, 100, n)
    boxes = np.stack([y0, x0, y0 + rng.uniform(5, 60, n),
                      x0 + rng.uniform(5, 60, n)], -1).astype(np.float32)
    scores = np.sort(rng.uniform(0.1, 5.0, (2, n)).astype(np.float32),
                     axis=1)[:, ::-1].copy()
    stacked_boxes = np.stack([boxes, boxes])
    keep = np.asarray(jax.vmap(nms_keep, in_axes=(0, 0, None))(
        stacked_boxes, scores, thr))
    ref = _per_class_keep(stacked_boxes, scores, thr)
    assert np.array_equal(keep, ref)
    # both classes keep their own top box even though the boxes overlap
    # perfectly across classes
    assert keep[0, 0] and keep[1, 0]


def test_class_isolation_seeded():
    rng = np.random.default_rng(SEED + 3)
    for _ in range(25):
        check_class_isolation(rng)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_class_isolation_hypothesis(seed):
    check_class_isolation(np.random.default_rng(seed))


# ------------------------------------------------- Detections class axis

def test_detections_class_axis_api():
    rng = np.random.default_rng(SEED + 4)
    cfg = DetectorConfig(score_threshold=-3.0)
    heads = _mk_heads(2, cfg.hog.n_features, rng)
    det = FrameDetector(_stack(heads), cfg,
                        classes=("pedestrian", "vehicle"))
    d = det.detect_raw(_frame(rng))
    assert d.classes == ("pedestrian", "vehicle")
    lst = d.to_list()
    assert lst and all({"box", "score", "scale", "class_id",
                        "label"} <= set(e) for e in lst)
    assert {e["label"] for e in lst} <= {"pedestrian", "vehicle"}
    assert all(lst[i]["score"] >= lst[i + 1]["score"]
               for i in range(len(lst) - 1))
    # for_class slices back to the single-head contract
    ped = d.for_class("pedestrian")
    assert ped.classes is None
    assert len(ped.to_list()) == sum(e["class_id"] == 0 for e in lst)
    # saturated keeps the class axis
    assert np.shape(d.saturated) == (2,)
    # stack/frame round-trip with classes
    from repro.api.results import Detections
    b = Detections.stack([d, d])
    assert b.batched and b.batch_size == 2 and b.classes == d.classes
    s, i, kp, nv = _raw(b.frame(1))
    assert np.array_equal(s, np.asarray(d._scores))
    assert np.shape(nv) == (2,)


def test_detections_class_axis_empty():
    from repro.api.results import Detections
    from repro.core.detector import DecodeTables
    t = DecodeTables(np.zeros((0, 4), np.float32),
                     np.zeros((0,), np.float32), 0)
    e = Detections.empty(t, classes=("a", "b"))
    assert e.to_list() == [] and not e.batched
    eb = Detections.empty_batch(t, 3, classes=("a", "b"))
    assert eb.batched and eb.to_list() == [[], [], []]


# ----------------------------------------------------------- registry

def test_registry_stacking_and_thresholds():
    rng = np.random.default_rng(SEED + 5)
    f = 3780
    heads = _mk_heads(3, f, rng)
    reg = HeadRegistry()
    reg.add("ped", heads[0], threshold=0.5)
    reg.add("veh", heads[1])
    reg.add("_coarse", heads[2])           # auxiliary: excluded
    assert reg.names == ("ped", "veh")
    svm, names, thr = reg.stacked()
    assert svm["w"].shape == (2, f) and svm["b"].shape == (2,)
    assert names == ("ped", "veh") and thr == (0.5, None)
    np.testing.assert_array_equal(svm["w"][0], heads[0]["w"])
    # explicit subsets (order = class order) and aux inclusion
    _, names2, _ = reg.stacked(("veh", "ped"))
    assert names2 == ("veh", "ped")
    svm3, _, _ = reg.stacked(("_coarse",))
    np.testing.assert_array_equal(svm3["w"][0], heads[2]["w"])
    with pytest.raises(KeyError):
        reg.stacked(("nope",))
    with pytest.raises(ValueError):
        reg.add("ped", heads[0])           # no silent overwrite
    # mixed geometry only fails at stacking time
    reg.add("_tiny", {"w": np.zeros(756, np.float32), "b": 0.0})
    with pytest.raises(ValueError):
        reg.stacked(("ped", "_tiny"))


def test_registry_checkpoint_round_trip(tmp_path):
    rng = np.random.default_rng(SEED + 6)
    heads = _mk_heads(2, 3780, rng)
    reg = HeadRegistry()
    reg.add("ped", heads[0], threshold=0.25, metadata={"v": 1})
    reg.add("_coarse", {"w": rng.normal(size=756).astype(np.float32),
                        "b": 0.5})
    path = os.path.join(str(tmp_path), "ckpt")
    reg.save(path)
    assert HeadRegistry.is_registry_checkpoint(path)
    back = HeadRegistry.load(path)
    assert back.names == ("ped",) and "_coarse" in back
    assert back.get("ped").threshold == 0.25
    assert back.get("ped").metadata == {"v": 1}
    np.testing.assert_array_equal(back.get("ped").params["w"],
                                  reg.get("ped").params["w"])
    np.testing.assert_array_equal(back.get("_coarse").params["w"],
                                  reg.get("_coarse").params["w"])


def test_session_class_subsets_and_round_trip(tmp_path):
    from repro.api import DetectionSession
    rng = np.random.default_rng(SEED + 7)
    cfg = DetectorConfig(score_threshold=-1.0)
    heads = _mk_heads(2, cfg.hog.n_features, rng)
    reg = HeadRegistry()
    reg.add("a", heads[0])
    reg.add("b", heads[1], threshold=50.0)   # gated far above any score
    from repro.api.config import PipelineConfig
    sess = DetectionSession(reg, PipelineConfig(hog=cfg.hog, detector=cfg))
    frame = _frame(rng)
    both = sess.detect(frame).to_list()
    assert {d["label"] for d in both} == {"a"}, \
        "head b's per-class threshold must gate all its windows"
    only_a = sess.detect(frame, classes="a").to_list()
    assert [d["box"] for d in only_a] == \
        [d["box"] for d in both if d["label"] == "a"]
    # single-head sessions reject class subsets
    single = DetectionSession(heads[0],
                              PipelineConfig(hog=cfg.hog, detector=cfg))
    with pytest.raises(ValueError):
        single.detect(frame, classes="a")
    # session save/load keeps the registry form
    p = os.path.join(str(tmp_path), "s")
    sess.save(p)
    back = DetectionSession.load(p, PipelineConfig(hog=cfg.hog,
                                                   detector=cfg))
    assert back.registry is not None
    assert back.detect(frame).to_list() == both


def test_multihead_rejects_frame_parallel():
    rng = np.random.default_rng(SEED + 8)
    cfg = DetectorConfig(score_threshold=-1.0, frame_parallel=0,
                         frame_parallel_min_area=0)
    heads = _mk_heads(2, cfg.hog.n_features, rng)
    det = FrameDetector(_stack(heads), cfg)
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices to resolve a tile axis")
    with pytest.raises(ValueError, match="frame_parallel"):
        det.detect_raw(_frame(rng))


# ------------------------------------------------ tracker class gating

def _det(box, score, cid=None, label=None):
    d = {"box": box, "score": score, "scale": 1.0}
    if cid is not None:
        d["class_id"] = cid
        d["label"] = label or f"c{cid}"
    return d


def test_tracker_gates_association_on_class():
    from repro.core.video import Tracker
    trk = Tracker()
    box = (10.0, 10.0, 140.0, 76.0)
    near = (12.0, 11.0, 142.0, 77.0)
    out0 = trk.update([_det(box, 1.0, 0)])
    # a perfectly overlapping detection of ANOTHER class must open a
    # new track, not steal the pedestrian's id
    out1 = trk.update([_det(near, 1.0, 1)])
    assert out0[0]["track_id"] != out1[0]["track_id"]
    assert out1[0]["class_id"] == 1
    # ...while the same class keeps matching its track
    out2 = trk.update([_det(near, 1.0, 0), _det(box, 0.9, 1)])
    by_cls = {d["class_id"]: d for d in out2}
    assert by_cls[0]["track_id"] == out0[0]["track_id"]
    assert by_cls[1]["track_id"] == out1[0]["track_id"]
    assert by_cls[0]["hits"] == 2 and by_cls[1]["hits"] == 2


def test_tracker_classless_behavior_unchanged():
    from repro.core.video import Tracker
    trk = Tracker()
    box = (10.0, 10.0, 140.0, 76.0)
    near = (12.0, 11.0, 142.0, 77.0)
    t0 = trk.update([_det(box, 1.0)])
    t1 = trk.update([_det(near, 1.0)])
    assert t0[0]["track_id"] == t1[0]["track_id"]
    assert "class_id" not in t1[0]
