"""SVM training, detector, data pipeline, serving engine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.svm import (SVMTrainConfig, accuracy_table, hinge_loss,
                            init_svm, predict, svm_score, train_svm)
from repro.core.detector import DetectorConfig, _nms, detect, score_map
from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.data.synth_pedestrian import (PedestrianDataConfig, make_dataset,
                                         make_scene, make_windows)

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------- SVM
def test_svm_learns_separable():
    n, f = 512, 64
    w_true = RNG.normal(size=f).astype(np.float32)
    x = RNG.normal(size=(n, f)).astype(np.float32)
    y = (x @ w_true > 0).astype(np.int32)
    params, losses = train_svm(jnp.asarray(x), jnp.asarray(y),
                               SVMTrainConfig(steps=800, lam=1e-5))
    acc = accuracy_table(params, jnp.asarray(x), jnp.asarray(y))
    assert acc["total_acc"] > 0.97
    assert losses[-1] < losses[0]


def test_hinge_loss_zero_for_perfect_margin():
    params = {"w": jnp.asarray([10.0, 0.0]), "b": jnp.asarray(0.0)}
    x = jnp.asarray([[1.0, 0.0], [-1.0, 0.0]])
    y = jnp.asarray([1.0, -1.0])
    loss = hinge_loss(params, x, y, lam=0.0)
    assert float(loss) == 0.0


@settings(max_examples=10, deadline=None)
@given(nw=st.floats(0.5, 8.0))
def test_class_weight_monotone_effect(nw):
    """Higher neg_weight never hurts negative-class accuracy on a
    fixed imbalanced problem (property of weighted hinge)."""
    n, f = 256, 16
    x = RNG.normal(size=(n, f)).astype(np.float32)
    w_true = RNG.normal(size=f).astype(np.float32)
    y = (x @ w_true > -0.8).astype(np.int32)   # imbalanced positives
    p1, _ = train_svm(jnp.asarray(x), jnp.asarray(y),
                      SVMTrainConfig(steps=300, neg_weight=1.0, seed=1))
    p2, _ = train_svm(jnp.asarray(x), jnp.asarray(y),
                      SVMTrainConfig(steps=300, neg_weight=nw, seed=1))
    a1 = accuracy_table(p1, jnp.asarray(x), jnp.asarray(y))
    a2 = accuracy_table(p2, jnp.asarray(x), jnp.asarray(y))
    if nw >= 1.0:
        assert a2["without_person_acc"] >= a1["without_person_acc"] - 0.05


def test_sign_rule_eq7():
    params = {"w": jnp.asarray([1.0]), "b": jnp.asarray(-0.5)}
    x = jnp.asarray([[1.0], [0.0]])
    np.testing.assert_array_equal(np.asarray(predict(params, x)), [1, 0])


# -------------------------------------------------------------- detector
def test_score_map_matches_per_window_scores():
    """Dense conv score map == per-window descriptor @ w (the detector's
    core claim: block norm is window-independent)."""
    gray = jnp.asarray(RNG.integers(0, 256, (200, 150)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    b = jnp.float32(0.1)
    sm = score_map(gray, w, b, PAPER_HOG)
    # check a few window positions at 8px stride
    for (i, j) in [(0, 0), (3, 2), (5, 7)]:
        win = gray[i * 8:i * 8 + 130, j * 8:j * 8 + 66]
        d = hog_descriptor(win[None], PAPER_HOG)[0]
        want = float(d @ w + b)
        np.testing.assert_allclose(float(sm[i, j]), want, rtol=1e-4,
                                   atol=1e-4)


def test_nms_removes_overlaps():
    boxes = np.asarray([[0, 0, 10, 10], [1, 1, 11, 11], [50, 50, 60, 60]],
                       np.float32)
    scores = np.asarray([0.9, 0.8, 0.7])
    keep = _nms(boxes, scores, 0.3)
    assert keep == [0, 2]


def test_detect_finds_planted_person():
    """End-to-end: train a quick SVM, detect a planted pedestrian."""
    rng = np.random.default_rng(3)
    cfg = PedestrianDataConfig(n_pos=400, n_neg=300)
    x, y = make_windows(400, 300, cfg, rng)
    f = hog_descriptor(jnp.asarray(x), PAPER_HOG)
    svm, _ = train_svm(f, jnp.asarray(y),
                       SVMTrainConfig(steps=1200, neg_weight=6.0))
    scene, true_boxes = make_scene(rng, 280, 200, n_people=1)
    dets = detect(scene, svm, DetectorConfig(scales=(1.0,),
                                             score_threshold=0.0))
    assert dets, "no detections at all"
    ty, tx, th, tw = true_boxes[0]
    best = max(dets, key=lambda d: d["score"])
    y0, x0, _, _ = best["box"]
    # top detection within one cell-stride neighborhood of the plant
    assert abs(y0 - ty) <= 24 and abs(x0 - tx) <= 24, (best, true_boxes)


# ------------------------------------------------------------------ data
def test_dataset_shapes_and_split():
    cfg = PedestrianDataConfig(n_pos=20, n_neg=10, n_test_pos=8,
                               n_test_neg=6)
    x_tr, y_tr, x_te, y_te = make_dataset(cfg)
    assert x_tr.shape == (30, 130, 66, 3) and x_tr.dtype == np.uint8
    assert int(y_tr.sum()) == 20
    assert x_te.shape == (14, 130, 66, 3)
    assert int(y_te.sum()) == 8


def test_dataset_deterministic():
    cfg = PedestrianDataConfig(n_pos=5, n_neg=5, n_test_pos=2, n_test_neg=2)
    a = make_dataset(cfg)
    b = make_dataset(cfg)
    for t1, t2 in zip(a, b):
        np.testing.assert_array_equal(t1, t2)


# ----------------------------------------------------------------- serve
def test_detection_service_batches():
    from repro.serve.engine import DetectionService
    svm = init_svm(3780)
    svc = DetectionService(svm, batch_size=8, max_wait_ms=5.0).start()
    wins = [RNG.integers(0, 256, (130, 66, 3)).astype(np.uint8)
            for _ in range(20)]
    res = svc.detect(wins)
    svc.stop()
    assert len(res) == 20
    assert all(r["human"] in (0, 1) for r in res)
    assert svc.stats["requests"] == 20
