"""Test session config.

All process-level environment handling lives in `repro.platform`
(DESIGN.md §15): importing it applies the REPRO_* knobs exactly once,
before jax initializes -- conftest import time is safe. In particular
REPRO_TEST_DEVICES=N forces N host devices (for the sharded /
tiled-UHD suites); the dry-run (launch/dryrun.py) requests its own
512-device mesh through the same seam; benches and default test runs
see 1 device.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import platform  # noqa: E402  (applies REPRO_* at import)

# hermetic autotune: an empty path disables the DISK cache (a stale
# ~/.cache entry from a previous run would short-circuit the probe the
# autotune tests assert on); tests of the disk cache itself monkeypatch
# this to a tmp file. In-memory autotune behavior is unchanged.
platform.hermetic_autotune()
