"""Test session config.

REPRO_TEST_DEVICES=N forces N host devices (for tests/test_distributed.py:
MoE expert-parallel paths, DDP + gradient compression, elastic restore).
Must be set before jax initializes -- conftest import time is safe.
The dry-run (launch/dryrun.py) manages its own 512-device flag; benches
and default test runs see 1 device.
"""
import os

n = os.environ.get("REPRO_TEST_DEVICES")
if n:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}")

# hermetic autotune: an empty path disables the DISK cache (a stale
# ~/.cache entry from a previous run would short-circuit the probe the
# autotune tests assert on); tests of the disk cache itself monkeypatch
# this to a tmp file. In-memory autotune behavior is unchanged.
os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "")
