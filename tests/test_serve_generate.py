"""LM generation loop + training-launcher fault-tolerance integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve.engine import generate


@pytest.mark.parametrize("arch", ["mamba2-130m", "qwen3-14b", "hymba-1.5b"])
def test_generate_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out = generate(params, cfg, prompt, max_new_tokens=6)
    assert out.shape == (2, 14)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
    # prompt is preserved
    np.testing.assert_array_equal(np.asarray(out[:, :8]),
                                  np.asarray(prompt))


def test_generate_greedy_deterministic():
    cfg = get_config("mamba2-130m", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    a = generate(params, cfg, prompt, max_new_tokens=8)
    b = generate(params, cfg, prompt, max_new_tokens=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_launcher_checkpoint_resume(tmp_path):
    """Kill-and-resume: the launcher restarts from the atomic checkpoint."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    rc = main(["--arch", "mamba2-130m", "--steps", "4", "--batch", "2",
               "--seq", "32", "--ckpt", ck, "--ckpt-every", "2"])
    assert rc == 0
    from repro.checkpoint.manager import CheckpointManager
    assert CheckpointManager(ck).latest_step() == 4
    # relaunch with more steps: resumes at 4, runs to 6
    rc = main(["--arch", "mamba2-130m", "--steps", "6", "--batch", "2",
               "--seq", "32", "--ckpt", ck, "--ckpt-every", "2"])
    assert rc == 0
    assert CheckpointManager(ck).latest_step() == 6
