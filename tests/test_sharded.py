"""Multi-device sharded detection + the seam-bugfix regression tests.

Two families share this module because the CI `sharded` lane runs it as
one process:

  * BUGFIX REGRESSIONS (always run, any device count): Tracker default
    configs must not alias across instances, DetectionService futures
    must never hang (worker exception / stop() with a backlog), and the
    mesh builders must reject axis sizes the host cannot satisfy with a
    clear error instead of an opaque reshape crash.
  * SHARDED EQUIVALENCE (self-skip below 2 devices): detect_batch over
    the 'data' mesh must produce byte-identical `Detections.to_list()`
    output vs the single-device path, per backend/numerics mode, for
    divisible AND non-divisible batch sizes (the pad-and-mask path),
    with mesh-tagged autotune entries. The CI lane forces 8 host
    devices via REPRO_TEST_DEVICES=8 (see conftest.py).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.detector import (DetectorConfig, FrameDetector,
                                 autotune_report)
from repro.core.hog import PAPER_HOG
from repro.core.video import Tracker, TrackerConfig
from repro.launch.mesh import make_detection_mesh, make_host_mesh
from repro.serve.engine import DetectionService

multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs forced host devices (REPRO_TEST_DEVICES=8, CI lane "
           "'sharded')")

RNG = np.random.default_rng(11)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}
DET_CFG = DetectorConfig(score_threshold=-10.0, scales=(1.0,))


def _frames(n, h=160, w=128):
    return np.stack([RNG.integers(0, 256, (h, w, 3)).astype(np.uint8)
                     for _ in range(n)])


# ------------------------------------------------- bugfix: tracker config

def test_tracker_default_configs_do_not_alias():
    """Regression: `def __init__(self, cfg=TrackerConfig())` handed every
    Tracker the same config object; now each instance builds its own."""
    a, b = Tracker(), Tracker()
    assert a.cfg == b.cfg
    assert a.cfg is not b.cfg


def test_tracker_config_is_frozen():
    """One caller mutating thresholds must raise, not silently change
    behavior for every tracker sharing the instance."""
    t = Tracker()
    with pytest.raises(dataclasses.FrozenInstanceError):
        t.cfg.iou_match = 0.99


def test_tracker_explicit_config_is_used_verbatim():
    cfg = TrackerConfig(iou_match=0.55, max_misses=4)
    assert Tracker(cfg).cfg is cfg


# ---------------------------------------------- bugfix: service futures

def test_service_stop_with_backlog_answers_errors():
    """Regression: stop() with queued-but-unserved requests left every
    submitter blocked forever in fut.get() (futures have no error path
    of their own). Now the backlog is drained with an error payload."""
    svc = DetectionService(SVM, detector=DET_CFG)       # worker NOT started
    frame = _frames(1)[0]
    futs = [svc.submit_frame(frame) for _ in range(3)]
    wfut = svc.submit(RNG.integers(0, 256, (130, 66, 3)).astype(np.uint8))
    svc.stop()
    for fut in futs:
        res = fut.get(timeout=5)                        # must NOT hang
        assert res["detections"] == [] and "error" in res
        assert "backlog" in res["error"]
    wres = wfut.get(timeout=5)
    assert wres["human"] == -1 and "error" in wres
    # pending slots released: the backpressure bound is whole again
    assert svc._pending_frames == 0


def test_service_worker_exception_restarts_and_serves():
    """Regression (PR 5): an exception escaping the per-request
    containment killed the worker thread silently, hanging every
    in-flight and future request. Since the supervisor (PR 9) a
    TRANSIENT escape is absorbed entirely: the worker restarts,
    `worker_error` keeps the traceback, and the request that was in
    the room when it happened is retried and served normally."""
    svc = DetectionService(SVM, detector=DET_CFG, max_wait_ms=1.0)
    original = svc._serve_frame_batch
    calls = {"n": 0}

    def boom():
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected-worker-bug")
        return original()

    svc._serve_frame_batch = boom
    frame = _frames(1)[0]
    fut = svc.submit_frame(frame)       # queued before the worker runs:
    svc.start()                         # its first serve attempt raises
    res = fut.get(timeout=60)           # must NOT hang
    assert "error" not in res           # retried after the restart
    assert res["detections"]
    assert "injected-worker-bug" in (svc.worker_error or "")
    assert svc.stats["restarts"] >= 1
    assert svc.stats["worker_failures"] >= 1
    # the respawned worker keeps serving
    ok = svc.submit_frame(frame).get(timeout=30)
    assert "error" not in ok
    svc.stop()


def test_service_worker_deterministic_exception_fails_fast():
    """A deterministic failure class (ValueError et al.,
    faults.DETERMINISTIC_TYPES) must NOT be retried: the in-flight
    request is answered immediately with the original traceback."""
    svc = DetectionService(SVM, detector=DET_CFG, max_wait_ms=1.0)

    def boom():
        # simulate the failure arriving mid-batch, with the request
        # already in the worker's hands
        req = svc._next_frame_req()
        if req is not None:
            svc._inflight = [req]
            raise ValueError("deterministic-worker-bug")
        return False

    svc._serve_frame_batch = boom
    fut = svc.submit_frame(_frames(1)[0])
    svc.start()
    res = fut.get(timeout=15)
    assert "error" in res and "deterministic-worker-bug" in res["error"]
    assert "deterministic failure" in res["error"]
    svc.stop()


def test_service_stop_is_idempotent_and_rejects_nothing_silently():
    svc = DetectionService(SVM, detector=DET_CFG).start()
    svc.stop()
    svc.stop()                                          # second stop: no-op
    assert svc._pending_frames == 0


# ------------------------------------------------- bugfix: mesh guards

def test_make_host_mesh_rejects_oversized_model_axis():
    """Regression: model > n_devices made data = n // model == 0 and
    died in a numpy reshape; now a ValueError names the device count."""
    n = len(jax.devices())
    with pytest.raises(ValueError) as ei:
        make_host_mesh(model=n + 1)
    assert str(n) in str(ei.value) and "device" in str(ei.value)
    with pytest.raises(ValueError):
        make_host_mesh(model=0)


def test_make_detection_mesh_guard_and_default():
    n = len(jax.devices())
    with pytest.raises(ValueError) as ei:
        make_detection_mesh(n + 1)
    assert str(n) in str(ei.value)
    mesh = make_detection_mesh()                        # 0 = all devices
    assert mesh.axis_names == ("data",) and mesh.size == n


def test_detector_data_parallel_guard():
    n = len(jax.devices())
    det = FrameDetector(SVM, dataclasses.replace(DET_CFG,
                                                 data_parallel=n + 1))
    with pytest.raises(ValueError) as ei:
        det.detect_batch(_frames(2))
    assert str(n) in str(ei.value)


# --------------------------------------- sharded-vs-single equivalence

def _equiv_case(backend, mode, batch_chunk, n_frames, h=160, w=128):
    hog = dataclasses.replace(PAPER_HOG, mode=mode)
    base = DetectorConfig(hog=hog, score_threshold=-10.0,
                          scales=(1.0, 0.8), backend=backend,
                          batch_chunk=batch_chunk)
    frames = _frames(n_frames, h, w)
    single = FrameDetector(SVM, dataclasses.replace(base, data_parallel=1))
    shard = FrameDetector(SVM, dataclasses.replace(base, data_parallel=0))
    want = single.detect_batch_raw(frames)
    got = shard.detect_batch_raw(frames)
    assert got.batch_size == want.batch_size == n_frames
    assert got.to_list() == want.to_list()              # byte-identical
    assert np.array_equal(np.asarray(got.saturated),
                          np.asarray(want.saturated))


@multi
@pytest.mark.parametrize("backend,mode", [("ref", "ref"),
                                          ("ref", "sector"),
                                          ("ref", "cordic")])
def test_sharded_matches_single_device_divisible(backend, mode):
    """B a multiple of the mesh: every device gets an equal real
    sub-batch; to_list() must match the single-device path byte for
    byte in every numerics mode."""
    _equiv_case(backend, mode, batch_chunk=1, n_frames=jax.device_count())


@multi
@pytest.mark.parametrize("backend,mode", [("ref", "sector")])
def test_sharded_matches_single_device_nondivisible(backend, mode):
    """B NOT a multiple of the mesh exercises pad-and-mask: zero frames
    with an hw=(0,0) mask fill the last shard and are sliced off."""
    _equiv_case(backend, mode, batch_chunk=1,
                n_frames=jax.device_count() + 3)


@multi
def test_sharded_matches_single_device_fixed_numerics():
    """numerics="fixed" (int8 chain) over the data mesh: the integer
    CORDIC / int16 histograms / int8 matmul make every per-window value
    independent of batch placement, so the sharded path must match the
    single-device path byte for byte -- divisible AND pad-and-mask."""
    from repro.configs import hog_svm
    base = DetectorConfig(hog=hog_svm.QUANT, score_threshold=-10.0,
                          scales=(1.0, 0.8), backend="ref", batch_chunk=1)
    for n_frames in (jax.device_count(), jax.device_count() + 3):
        frames = _frames(n_frames)
        single = FrameDetector(SVM, dataclasses.replace(base,
                                                        data_parallel=1))
        shard = FrameDetector(SVM, dataclasses.replace(base,
                                                       data_parallel=0))
        want = single.detect_batch_raw(frames)
        got = shard.detect_batch_raw(frames)
        assert got.to_list() == want.to_list()          # byte-identical


@multi
def test_sharded_matches_single_device_wide_vmap_schedule():
    """Same equivalence under the wide-vmap per-device schedule
    (chunk >= local batch) instead of the frame-by-frame scan."""
    _equiv_case("ref", "sector", batch_chunk=16,
                n_frames=2 * jax.device_count())


@multi
@pytest.mark.slow
def test_sharded_matches_single_device_fused_backend():
    """The dense fused Pallas backend (interpreter on CPU) through the
    sharded program -- small frame, one scale, to bound interpret time."""
    hog = dataclasses.replace(PAPER_HOG, mode="sector")
    base = DetectorConfig(hog=hog, score_threshold=-10.0, scales=(1.0,),
                          backend="fused", batch_chunk=1)
    frames = _frames(jax.device_count(), 160, 96)
    single = FrameDetector(SVM, dataclasses.replace(base, data_parallel=1))
    shard = FrameDetector(SVM, dataclasses.replace(base, data_parallel=0))
    assert (shard.detect_batch_raw(frames).to_list()
            == single.detect_batch_raw(frames).to_list())


@multi
def test_sharded_mixed_true_shapes_one_bucket():
    """Mixed true sizes sharing one padded bucket take the pre-padded
    host path; sharding must agree with single-device there too."""
    fa = RNG.integers(0, 256, (150, 120, 3)).astype(np.uint8)
    fb = RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
    frames = [fa, fb, fa, fb, fa]
    base = DetectorConfig(score_threshold=-10.0, scales=(1.0,),
                          batch_chunk=1)
    single = FrameDetector(SVM, dataclasses.replace(base, data_parallel=1))
    shard = FrameDetector(SVM, dataclasses.replace(base, data_parallel=0))
    assert (shard.detect_batch_raw(frames).to_list()
            == single.detect_batch_raw(frames).to_list())


@multi
def test_autotune_report_carries_mesh_dimension():
    """Every autotune entry is tagged with its mesh layout, and the
    sharded probe keys on the PADDED batch over the real device count
    -- BENCH schedule entries must never be ambiguous about devices."""
    n_dev = jax.device_count()
    det = FrameDetector(SVM, DetectorConfig(
        score_threshold=-10.0, scales=(1.0,), batch_chunk=0,
        data_parallel=0))
    frames = _frames(n_dev + 1)                         # pads to 2 * n_dev
    first = det.detect_batch(frames)
    rep = autotune_report()
    assert rep and all("mesh=data:" in k for k in rep)
    key = [k for k in rep if f"mesh=data:{n_dev}" in k]
    assert key, rep
    # cached decision: the second call must not re-probe
    det.detect_batch(frames)
    assert autotune_report()[key[0]] == rep[key[0]]
    # and the autotuned schedule agrees with an explicit one (score
    # tolerance across schedules, as in the PR-4 autotune test)
    expl = FrameDetector(SVM, DetectorConfig(
        score_threshold=-10.0, scales=(1.0,), batch_chunk=1,
        data_parallel=0))
    want = expl.detect_batch(frames)
    assert len(want) == len(first)
    for fa, fb in zip(want, first):
        assert len(fa) == len(fb)
        for da, db in zip(fa, fb):
            assert abs(da["score"] - db["score"]) < 1e-5


@multi
def test_session_sharded_preset_warmup_and_stats():
    """The api layer end to end: the `sharded` preset resolves to every
    device, warmup compiles the sharded batched program (including a
    non-divisible B), and cache_stats reports the mesh."""
    from repro.api.config import presets
    from repro.api.session import DetectionSession

    n_dev = jax.device_count()
    cfg = presets("sharded").replace(
        detector=dataclasses.replace(presets("sharded").detector,
                                     score_threshold=-10.0,
                                     scales=(1.0,)))
    ses = DetectionSession(SVM, cfg)
    assert ses.data_devices == n_dev
    stats = ses.warmup([(160, 128), (n_dev + 1, 160, 128)])
    assert stats["mesh"] == {"data_parallel": 0, "devices": n_dev,
                             "frame_parallel": 1, "tile_devices": 1}
    # traffic of the warmed shape: no new program compiles
    before = ses.cache_stats()["batch_programs"]["misses"]
    ses.detect_batch(_frames(n_dev + 1))
    assert ses.cache_stats()["batch_programs"]["misses"] == before


@multi
def test_service_coalesces_to_device_target():
    """The microbatcher's per-dispatch target scales with the
    detector's data mesh and the stats break occupancy out per device."""
    n_dev = jax.device_count()
    cfg = dataclasses.replace(DET_CFG, data_parallel=0, batch_chunk=1)
    svc = DetectionService(SVM, detector=cfg, frame_batch=2,
                           max_wait_ms=200.0)
    assert svc.devices == n_dev
    assert svc.frame_target == 2 * n_dev
    assert svc.stats["devices"] == n_dev
    frames = list(_frames(2 * n_dev))
    futs = [svc.submit_frame(f) for f in frames]        # queue, then start
    svc.start()
    try:
        for fut in futs:
            assert "error" not in fut.get(timeout=120)
        assert svc.stats["frames"] == 2 * n_dev
        # one full coalesced dispatch: every device saw frame_batch frames
        if svc.stats["frame_batches"] == 1:
            assert svc.stats["per_device_occupancy"] == [1.0] * n_dev
        assert len(svc.stats["per_device_occupancy"]) == n_dev
        assert sum(svc.stats["device_frames"]) == svc.stats["frames"]
    finally:
        svc.stop()
