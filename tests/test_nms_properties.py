"""Property-based tests for the device NMS primitives.

`matrix_iou` / `nms_keep` (core/detector.py) are the device-side
selection stage every detection result flows through; these tests state
their INVARIANTS rather than example outputs:

  * IoU is symmetric, lands in [0, 1], and is 1 on the diagonal;
  * no two boxes kept by NMS overlap above the suppression threshold;
  * the kept set is invariant under any permutation of the input boxes
    (scores ride along, ties excluded) -- NMS depends on the score
    ORDER, not the storage order;
  * the device `nms_keep` keeps exactly the host greedy `_nms` set.

Each invariant runs twice: a hypothesis-driven version (via the
optional-dependency shim -- skips when hypothesis is absent) and a
seeded multi-trial version that always runs, so CI without hypothesis
still exercises every property.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.detector import _nms, matrix_iou, nms_keep
from repro.core.video import iou_np

IOU_THR = 0.3


def _random_boxes(rng: np.random.Generator, n: int):
    """n boxes with positive area and UNIQUE scores (ties would make
    the permutation property ill-defined)."""
    y0 = rng.uniform(0, 200, n)
    x0 = rng.uniform(0, 200, n)
    boxes = np.stack([y0, x0, y0 + rng.uniform(4, 90, n),
                      x0 + rng.uniform(4, 90, n)], -1).astype(np.float32)
    scores = rng.permutation(n).astype(np.float32) + \
        rng.uniform(0.0, 0.5, n).astype(np.float32)
    return boxes, scores


def _kept_rows(boxes: np.ndarray, scores: np.ndarray,
               thr: float = IOU_THR) -> frozenset:
    """Device-NMS keep set as row identities of the ORIGINAL array."""
    order = np.argsort(-scores)
    mask = np.asarray(nms_keep(jnp.asarray(boxes[order]),
                               jnp.asarray(scores[order]), thr))
    return frozenset(order[np.where(mask)[0]].tolist())


# ------------------------------------------------------------ invariants

def check_iou_properties(boxes: np.ndarray):
    a = jnp.asarray(boxes)
    iou = np.asarray(matrix_iou(a, a))
    assert np.all(iou >= 0.0) and np.all(iou <= 1.0 + 1e-6)
    np.testing.assert_allclose(iou, iou.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diag(iou), 1.0, atol=1e-5)
    # the host twin used by the tracker agrees with the device op
    np.testing.assert_allclose(iou_np(boxes, boxes), iou,
                               rtol=1e-4, atol=1e-5)


def check_no_kept_overlap(boxes: np.ndarray, scores: np.ndarray):
    kept = sorted(_kept_rows(boxes, scores))
    iou = iou_np(boxes[kept], boxes[kept])
    np.fill_diagonal(iou, 0.0)
    assert np.all(iou <= IOU_THR + 1e-5), \
        f"kept boxes {kept} overlap above {IOU_THR}"


def check_permutation_invariant(boxes: np.ndarray, scores: np.ndarray,
                                perm: np.ndarray):
    base = _kept_rows(boxes, scores)
    permuted = _kept_rows(boxes[perm], scores[perm])
    assert {int(perm[i]) for i in permuted} == set(base)


def check_host_device_equivalence(boxes: np.ndarray, scores: np.ndarray):
    assert _kept_rows(boxes, scores) == frozenset(_nms(boxes, scores,
                                                       IOU_THR))


# ----------------------------------------- seeded versions (always run)

@pytest.mark.parametrize("seed", range(8))
def test_iou_properties_seeded(seed):
    rng = np.random.default_rng(seed)
    boxes, _ = _random_boxes(rng, int(rng.integers(1, 120)))
    check_iou_properties(boxes)


@pytest.mark.parametrize("seed", range(8))
def test_nms_no_kept_overlap_seeded(seed):
    rng = np.random.default_rng(100 + seed)
    boxes, scores = _random_boxes(rng, int(rng.integers(1, 150)))
    check_no_kept_overlap(boxes, scores)


@pytest.mark.parametrize("seed", range(8))
def test_nms_permutation_invariant_seeded(seed):
    rng = np.random.default_rng(200 + seed)
    boxes, scores = _random_boxes(rng, int(rng.integers(2, 120)))
    check_permutation_invariant(boxes, scores,
                                rng.permutation(len(boxes)))


@pytest.mark.parametrize("seed", range(8))
def test_nms_host_device_equivalence_seeded(seed):
    rng = np.random.default_rng(300 + seed)
    boxes, scores = _random_boxes(rng, int(rng.integers(1, 150)))
    check_host_device_equivalence(boxes, scores)


# ------------------------------------ hypothesis versions (skip-if-absent)

@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=120),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_iou_properties_hypothesis(n, seed):
    boxes, _ = _random_boxes(np.random.default_rng(seed), n)
    check_iou_properties(boxes)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=150),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_nms_no_kept_overlap_hypothesis(n, seed):
    boxes, scores = _random_boxes(np.random.default_rng(seed), n)
    check_no_kept_overlap(boxes, scores)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=2, max_value=120),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_nms_permutation_invariant_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    boxes, scores = _random_boxes(rng, n)
    check_permutation_invariant(boxes, scores, rng.permutation(n))


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=150),
       st.integers(min_value=0, max_value=2**32 - 1))
def test_nms_host_device_equivalence_hypothesis(n, seed):
    boxes, scores = _random_boxes(np.random.default_rng(seed), n)
    check_host_device_equivalence(boxes, scores)
