"""repro.platform -- the one process-level config seam (DESIGN.md §15).

Pinned here:
  * IDEMPOTENCE: apply() re-entry is a no-op (importing the module in
    five entry points applies the env knobs once), and re-applying
    against an explicit env is safe (every mutation is merge/setdefault).
  * PRECEDENCE: an operator-set XLA_FLAGS always survives --
    force_host_devices and REPRO_* knobs merge/append, never clobber,
    and an operator-set flag of the same name wins outright.
  * RESOLUTION: autotune cache path, deterministic seed, forced-device
    parsing, describe() snapshot keys.
  * THE GREP GATE: no jax-affecting os.environ mutation anywhere in
    src/ or benchmarks/ outside platform.py itself.
"""
import os
import pathlib
import re

import pytest

from repro import platform

FORCE = "xla_force_host_platform_device_count"


# ========================================================== idempotence

def test_apply_ran_at_import():
    # conftest imports repro.platform, so by the time any test runs the
    # process-level application already happened exactly once
    assert platform._APPLIED is not None


def test_apply_reentry_is_noop():
    first = platform.apply()
    assert platform.apply() is first          # same record, no rework
    assert platform.apply() is platform.apply()


def test_apply_twice_on_explicit_env_is_stable():
    env = {"REPRO_TEST_DEVICES": "4", "REPRO_X64": "0",
           "REPRO_XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    a1 = platform.apply(env)
    flags1 = env["XLA_FLAGS"]
    a2 = platform.apply(env)                  # merge/setdefault: no growth
    assert env["XLA_FLAGS"] == flags1
    assert a1 == a2
    assert env["XLA_FLAGS"].count(FORCE) == 1
    assert env["XLA_FLAGS"].count("fast_math") == 1


def test_explicit_env_does_not_touch_process_guard():
    guard = platform._APPLIED
    platform.apply({"REPRO_TEST_DEVICES": "2"})
    assert platform._APPLIED is guard


# =========================================================== precedence

def test_user_xla_flags_survive_force():
    env = {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    n = platform.force_host_devices(8, env)
    assert n == 8
    assert "--xla_cpu_enable_fast_math=false" in env["XLA_FLAGS"]
    assert f"--{FORCE}=8" in env["XLA_FLAGS"]


def test_user_set_device_count_wins():
    env = {"XLA_FLAGS": f"--{FORCE}=2"}
    # the operator pinned 2; a code-requested 8 must NOT override it
    assert platform.force_host_devices(8, env) == 2
    assert env["XLA_FLAGS"] == f"--{FORCE}=2"


def test_repro_test_devices_merges_not_clobbers():
    env = {"XLA_FLAGS": "--xla_dump_to=/tmp/d", "REPRO_TEST_DEVICES": "4"}
    applied = platform.apply(env)
    assert applied["forced_host_devices"] == 4
    assert "--xla_dump_to=/tmp/d" in env["XLA_FLAGS"]


def test_repro_xla_flags_existing_flag_wins():
    env = {"XLA_FLAGS": "--xla_foo=user",
           "REPRO_XLA_FLAGS": "--xla_foo=repro --xla_bar=1"}
    platform.apply(env)
    assert env["XLA_FLAGS"].count("--xla_foo") == 1
    assert "--xla_foo=user" in env["XLA_FLAGS"]   # user's value kept
    assert "--xla_bar=1" in env["XLA_FLAGS"]      # new flag appended


def test_user_jax_enable_x64_wins_over_repro_x64():
    env = {"JAX_ENABLE_X64": "1", "REPRO_X64": "0"}
    applied = platform.apply(env)
    assert env["JAX_ENABLE_X64"] == "1"           # setdefault: user wins
    assert applied["x64"] is True


def test_repro_platform_pin_setdefault():
    env = {"REPRO_PLATFORM": "cpu"}
    assert platform.apply(env)["jax_platforms"] == "cpu"
    env2 = {"REPRO_PLATFORM": "cpu", "JAX_PLATFORMS": "tpu"}
    assert platform.apply(env2)["jax_platforms"] == "tpu"


# ============================================================ resolution

def test_forced_host_devices_parser():
    assert platform.forced_host_devices({"XLA_FLAGS": f"--{FORCE}=8"}) == 8
    assert platform.forced_host_devices({"XLA_FLAGS": ""}) is None
    assert platform.forced_host_devices({}) is None
    assert platform.forced_host_devices(
        {"XLA_FLAGS": f"--{FORCE}=junk"}) is None


def test_autotune_cache_path_resolution(tmp_path):
    assert platform.autotune_cache_path(
        {"REPRO_AUTOTUNE_CACHE": ""}) is None          # "" disables
    p = str(tmp_path / "a.json")
    assert platform.autotune_cache_path(
        {"REPRO_AUTOTUNE_CACHE": p}) == p
    default = platform.autotune_cache_path({})
    assert default.endswith(os.path.join(".cache", "repro",
                                         "autotune.json"))


def test_autotune_cache_module_delegates():
    # core/autotune_cache.cache_path must resolve through the seam
    from repro.core import autotune_cache
    assert autotune_cache.cache_path() == platform.autotune_cache_path()


def test_hermetic_autotune_is_setdefault():
    env = {}
    platform.hermetic_autotune(env)
    assert env["REPRO_AUTOTUNE_CACHE"] == ""
    env = {"REPRO_AUTOTUNE_CACHE": "/keep/me.json"}
    platform.hermetic_autotune(env)
    assert env["REPRO_AUTOTUNE_CACHE"] == "/keep/me.json"


def test_default_seed():
    assert platform.default_seed({}) == 0
    assert platform.default_seed({"REPRO_SEED": "42"}) == 42
    assert platform.default_seed({"REPRO_SEED": "nonsense"}) == 0


def test_describe_snapshot_keys():
    d = platform.describe()
    for key in ("backend", "device_count", "x64", "xla_flags",
                "jax_version", "forced_host_devices", "autotune_cache",
                "seed", "applied", "process_index", "machine"):
        assert key in d, key
    assert d["backend"] in ("cpu", "gpu", "tpu")
    assert d["device_count"] >= 1
    import json
    json.dumps(d)                                  # snapshot is JSON-safe


def test_is_main_single_process():
    assert platform.is_main() is True


# ============================================================= grep gate

def test_no_env_mutation_outside_platform():
    """The repo-wide invariant the refactor exists for: no jax-affecting
    `os.environ[...] =` / setdefault / update mutation in src/ or
    benchmarks/ outside platform.py (reads are fine -- interpretation
    belongs to the seam, but a read-only get cannot clobber operator
    intent)."""
    root = pathlib.Path(__file__).resolve().parent.parent
    mutation = re.compile(
        r"os\.environ\s*\[[^]]+\]\s*=|os\.environ\.setdefault|"
        r"os\.environ\.update|os\.environ\.pop")
    offenders = []
    for sub in ("src", "benchmarks"):
        for py in (root / sub).rglob("*.py"):
            if py.name == "platform.py":
                continue
            for i, line in enumerate(py.read_text().splitlines(), 1):
                if mutation.search(line):
                    offenders.append(f"{py.relative_to(root)}:{i}")
    assert not offenders, (
        "env mutation outside repro.platform: " + ", ".join(offenders))
