"""Metrics export suite (DESIGN.md §15, `repro.obs.metrics`).

Two families, mirroring test_resilience.py:

  * UNIT (no device): the sink zoo -- JSONL round-trip (the schema
    contract: what JsonlSink wrote, JsonlSink.read re-parses to the
    emitted dicts), ring bounds/counts, callback/tee fan-out, Emitter
    stamping + error swallowing, MetricsConfig wiring through
    PipelineConfig JSON.
  * INTEGRATION (device): the acceptance criterion from the issue --
    a chaos run with a JSONL sink emits at least one event per rung
    transition, per restart, and per deadline shed, and the stream
    stays schema-valid end to end.

Chaos fixtures reuse test_resilience.py's tiny-frame setup (160x128,
single scale, threshold -10) so no new programs compile.
"""
import json
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.detector import DetectorConfig
from repro.obs.metrics import (CallbackSink, Emitter, JsonlSink,
                               MetricsConfig, MetricsSink, NullSink,
                               RingSink, TeeSink, make_sink)
from repro.serve.engine import DetectionService
from repro.serve.faults import FaultInjector, FaultSpec
from repro.serve.resilience import ResilienceConfig

RNG = np.random.default_rng(11)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}
DET_CFG = DetectorConfig(score_threshold=-10.0, scales=(1.0,))

#: every event kind the engine can emit (metrics.py module docstring)
KNOWN_KINDS = {"service_start", "batch", "rung_transition",
               "deadline_shed", "worker_failure", "restart",
               "service_stop", "stage_timing"}


def _frames(n, h=160, w=128, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
            for _ in range(n)]


def _service(**kw):
    kw.setdefault("detector", DET_CFG)
    kw.setdefault("frame_batch", 1)
    kw.setdefault("max_wait_ms", 1.0)
    return DetectionService(SVM, **kw)


def _assert_stamped(events):
    """Schema contract shared by every sink: stamped fields present,
    seq unique and gapless, t_ms non-negative, kind known. (File order
    is not asserted: seq is taken under the emitter lock but the write
    happens outside it, so two threads may interleave lines.)"""
    assert events, "no events emitted"
    seqs = sorted(e["seq"] for e in events)
    assert seqs == list(range(seqs[0], seqs[0] + len(seqs)))
    assert all(e["t_ms"] >= 0 for e in events)
    assert {e["kind"] for e in events} <= KNOWN_KINDS


# ================================================================ unit

def test_jsonl_round_trip(tmp_path):
    """THE export contract: what went in comes back out, dict-equal."""
    path = str(tmp_path / "m.jsonl")
    sink = JsonlSink(path)
    em = Emitter(sink, rank0_only=False)
    sent = [("service_start", {"devices": 4, "rungs": ["full", "coarse"]}),
            ("batch", {"n": 2, "ms_per_frame": 1.5, "queue_depth": 0}),
            ("service_stop", {"frames": 2})]
    for kind, payload in sent:
        em.emit(kind, **payload)
    em.close()

    back = JsonlSink.read(path)
    assert len(back) == len(sent)
    _assert_stamped(back)
    for ev, (kind, payload) in zip(back, sent):
        assert ev["kind"] == kind
        assert {k: ev[k] for k in payload} == payload
    # and each line is independently valid JSON (tail -f contract)
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_jsonl_numpy_payloads_stay_valid(tmp_path):
    path = str(tmp_path / "np.jsonl")
    sink = JsonlSink(path)
    sink.emit({"kind": "batch", "seq": 0, "t_ms": 0.0,
               "lat": np.float32(1.5), "n": np.int64(3),
               "occ": np.asarray([0.5, 1.0])})
    sink.close()
    (ev,) = JsonlSink.read(path)
    assert ev["lat"] == 1.5 and ev["n"] == 3 and ev["occ"] == [0.5, 1.0]


def test_jsonl_append_and_close_idempotent(tmp_path):
    path = str(tmp_path / "a.jsonl")
    s1 = JsonlSink(path)
    s1.emit({"kind": "batch", "seq": 0, "t_ms": 0.0})
    s1.close()
    s1.close()                                    # double close: fine
    s1.emit({"kind": "batch", "seq": 9, "t_ms": 0.0})   # after close: dropped
    s2 = JsonlSink(path)                          # append, not truncate
    s2.emit({"kind": "batch", "seq": 1, "t_ms": 0.0})
    s2.close()
    assert [e["seq"] for e in JsonlSink.read(path)] == [0, 1]


def test_ring_sink_bounds_and_counts():
    ring = RingSink(capacity=3)
    for i in range(5):
        ring.emit({"kind": "batch" if i % 2 else "restart", "seq": i})
    evs = ring.events()
    assert len(evs) == 3                          # bounded
    assert [e["seq"] for e in evs] == [2, 3, 4]   # keeps the newest
    assert ring.counts() == {"restart": 2, "batch": 1}
    assert [e["seq"] for e in ring.events(kind="batch")] == [3]


def test_callback_and_tee_fan_out():
    got = []
    ring = RingSink(8)
    tee = TeeSink([CallbackSink(got.append), ring])
    tee.emit({"kind": "batch", "seq": 0})
    tee.close()
    assert got == ring.events() == [{"kind": "batch", "seq": 0}]


def test_sinks_satisfy_protocol():
    for sink in (NullSink(), RingSink(1), CallbackSink(lambda e: None),
                 TeeSink([])):
        assert isinstance(sink, MetricsSink)


def test_emitter_stamps_and_swallows_sink_errors():
    class Boom:
        def emit(self, event):
            raise OSError("disk full")

        def close(self):
            raise OSError("still full")

    em = Emitter(Boom(), rank0_only=False)
    em.emit("batch", n=1)
    em.emit("batch", n=2)
    assert em.dropped == 2                        # serve loop never sees it
    assert "disk full" in em.last_error
    em.close()                                    # close errors swallowed too

    ring = RingSink(8)
    em = Emitter(ring, rank0_only=False)
    em.emit("batch", n=1)
    time.sleep(0.002)
    em.emit("restart", restarts=1)
    _assert_stamped(ring.events())
    assert ring.events()[1]["t_ms"] >= ring.events()[0]["t_ms"]


def test_emitter_null_sink_inactive():
    em = Emitter(NullSink(), rank0_only=False)
    assert not em.active
    em.emit("batch", n=1)                         # cheap no-op
    assert em._seq == 0


def test_emitter_thread_safe_seq():
    ring = RingSink(4096)
    em = Emitter(ring, rank0_only=False)

    def pump():
        for _ in range(200):
            em.emit("batch", n=1)

    ts = [threading.Thread(target=pump) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    seqs = sorted(e["seq"] for e in ring.events())
    assert seqs == list(range(800))               # no duplicate stamps


def test_metrics_config_enabled_and_make_sink(tmp_path):
    assert not MetricsConfig().enabled            # all-default == off
    sink, ring = make_sink(MetricsConfig())
    assert isinstance(sink, NullSink) and ring is None

    cfg = MetricsConfig(jsonl_path=str(tmp_path / "m.jsonl"), ring=16)
    assert cfg.enabled
    sink, ring = make_sink(cfg)
    assert isinstance(sink, TeeSink) and isinstance(ring, RingSink)
    sink.emit({"kind": "batch", "seq": 0, "t_ms": 0.0})
    sink.close()
    assert ring.counts() == {"batch": 1}
    assert len(JsonlSink.read(cfg.jsonl_path)) == 1

    sink, ring = make_sink(MetricsConfig(ring=8))
    assert isinstance(sink, RingSink) and sink is ring


def test_pipeline_config_metrics_round_trip(tmp_path):
    import dataclasses
    from repro.api import PipelineConfig
    mc = MetricsConfig(jsonl_path=str(tmp_path / "m.jsonl"), ring=32,
                       stage_timing=True)
    cfg = PipelineConfig()
    cfg = cfg.replace(service=dataclasses.replace(cfg.service, metrics=mc))
    back = PipelineConfig.from_json(cfg.to_json())
    assert back.service.metrics == mc
    assert back.service.metrics.enabled
    assert back == cfg


# ========================================================= integration

def test_engine_emits_lifecycle_and_batches(tmp_path):
    """Plain run: service_start .. batch* .. service_stop, in order,
    and stats()["metrics"] reconciles with the stream."""
    path = str(tmp_path / "serve.jsonl")
    svc = _service(metrics=MetricsConfig(jsonl_path=path, ring=64))
    svc.start()
    try:
        for r in svc.detect_frames(_frames(4), timeout=120):
            assert "detections" in r
    finally:
        svc.stop()

    events = JsonlSink.read(path)
    _assert_stamped(events)
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "service_start" and kinds[-1] == "service_stop"
    batches = [e for e in events if e["kind"] == "batch"]
    assert sum(b["n"] for b in batches) == 4
    for b in batches:
        assert b["ms_per_frame"] > 0
        assert b["latency_ms"]["p99"] >= 0     # rolling snapshot rides along
        assert 0 < b["occupancy"] <= 1.0
        assert isinstance(b["rung"], str)
    start = events[0]
    assert start["platform"]["device_count"] >= 1
    stop = events[-1]
    assert stop["frames"] == 4

    m = svc.stats["metrics"]
    assert m["enabled"] and m["dropped"] == 0
    assert m["emitted"] == len(events)
    assert m["recent"]["batch"] == len(batches)


def test_metrics_disabled_is_default():
    svc = _service()
    svc.start()
    try:
        svc.detect_frames(_frames(2), timeout=120)
    finally:
        svc.stop()
    assert svc.stats["metrics"] == {"enabled": False, "emitted": 0,
                                    "dropped": 0}


def test_chaos_run_emits_transition_restart_and_shed(tmp_path):
    """The issue's acceptance criterion: a chaos run with the JSONL
    sink enabled emits >= 1 event per rung transition, worker restart,
    and deadline shed -- and the stream re-parses clean."""
    path = str(tmp_path / "chaos.jsonl")
    inj = FaultInjector([
        FaultSpec("latency", at_batches=(2, 3, 4, 5), latency_ms=80.0),
        FaultSpec("kill_worker", at_batches=(8,)),
    ], seed=0)
    svc = _service(
        metrics=MetricsConfig(jsonl_path=path, ring=64),
        faults=inj,
        resilience=ResilienceConfig(degrade_p99_ms=50.0,
                                    recover_p99_ms=20.0,
                                    recover_dwell=2, latency_window=4))

    frames = _frames(14)
    # shed first: submit with an already-hopeless deadline before start
    shed_futs = [svc.submit_frame(f, deadline_ms=1.0) for f in frames[:2]]
    time.sleep(0.05)
    svc.start()
    try:
        for f in frames:
            svc.submit_frame(f).get(timeout=120)
    finally:
        svc.stop()
    for fut in shed_futs:
        assert fut.get(timeout=5).get("deadline_exceeded")

    events = JsonlSink.read(path)
    _assert_stamped(events)
    counts = {}
    for e in events:
        counts[e["kind"]] = counts.get(e["kind"], 0) + 1

    assert counts.get("deadline_shed", 0) >= 1
    assert counts.get("rung_transition", 0) >= 1
    assert counts.get("worker_failure", 0) >= 1
    assert counts.get("restart", 0) >= 1

    trans = [e for e in events if e["kind"] == "rung_transition"]
    assert any(t["direction"] == "degrade" for t in trans)
    for t in trans:
        assert t["rung_from"] != t["rung_to"]
        assert t["direction"] in ("degrade", "recover")
    shed = [e for e in events if e["kind"] == "deadline_shed"][-1]
    assert shed["shed_total"] >= 2     # one event per shed, running total
    fail = [e for e in events if e["kind"] == "worker_failure"][0]
    assert "error" in fail and "breaker" in fail
    rst = [e for e in events if e["kind"] == "restart"][0]
    assert rst["restarts"] >= 1
    stop = [e for e in events if e["kind"] == "service_stop"][0]
    assert stop["restarts"] >= 1 and stop["deadline_shed"] >= 2


def test_stage_timing_events_opt_in(tmp_path):
    path = str(tmp_path / "stage.jsonl")
    svc = _service(metrics=MetricsConfig(jsonl_path=path,
                                         stage_timing=True))
    svc.start()
    try:
        svc.detect_frames(_frames(3), timeout=120)
    finally:
        svc.stop()
    stages = [e for e in JsonlSink.read(path)
              if e["kind"] == "stage_timing"]
    assert stages, "stage_timing=True emitted no stage events"
    for e in stages:
        assert e["queue_ms_mean"] >= 0
        assert e["compute_ms_per_frame"] > 0
