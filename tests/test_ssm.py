"""Mamba-2 SSD: chunked algorithm vs naive sequential recurrence."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import _ssm_p
from repro.models.ssm import ssd_decode, ssd_forward


def _naive_ssd(x, p, cfg):
    """Literal per-step recurrence h_t = exp(dt A) h_{t-1} + dt B x_t."""
    B, S, D = x.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    cache = {"state": jnp.zeros((B, H, N, P), jnp.float32),
             "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.conv_dim),
                               jnp.float32)}
    outs = []
    for t in range(S):
        y, cache = ssd_decode(x[:, t:t + 1], p, cfg, cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), cache


@pytest.mark.parametrize("S", [16, 24])   # chunk-aligned and ragged
def test_chunked_matches_sequential(S):
    cfg = dataclasses.replace(get_config("mamba2-130m", smoke=True),
                              dtype=jnp.float32, ssm_chunk=8)
    p = _ssm_p(jax.random.PRNGKey(0), 0, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk, cache_c = ssd_forward(x, p, cfg)
    y_seq, cache_s = _naive_ssd(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache_c["state"]),
                               np.asarray(cache_s["state"]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache_c["conv"]),
                               np.asarray(cache_s["conv"]),
                               rtol=1e-5, atol=1e-5)


def test_decode_continues_forward():
    """ssd_forward cache -> ssd_decode continuation == one longer forward."""
    cfg = dataclasses.replace(get_config("mamba2-130m", smoke=True),
                              dtype=jnp.float32, ssm_chunk=8)
    p = _ssm_p(jax.random.PRNGKey(0), 0, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, _ = ssd_forward(x, p, cfg)
    y_pre, cache = ssd_forward(x[:, :16], p, cfg)
    y_step, _ = ssd_decode(x[:, 16:17], p, cfg, cache)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, 16]),
                               rtol=2e-3, atol=2e-3)


def test_state_decay_bounded():
    """|state| stays bounded for long inputs (A < 0 guarantees decay)."""
    cfg = dataclasses.replace(get_config("mamba2-130m", smoke=True),
                              dtype=jnp.float32, ssm_chunk=16)
    p = _ssm_p(jax.random.PRNGKey(0), 0, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, cfg.d_model),
                          jnp.float32)
    _, cache = ssd_forward(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(cache["state"])))
    assert float(jnp.max(jnp.abs(cache["state"]))) < 1e4
