"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

RNG = np.random.default_rng(0)


def _qkv(B, H, K, S, hd, dtype=jnp.float32):
    mk = lambda i, sh: jnp.asarray(RNG.normal(size=sh).astype(np.float32),
                                   dtype)
    return (mk(0, (B, H, S, hd)), mk(1, (B, K, S, hd)),
            mk(2, (B, K, S, hd)))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_matches_ref(causal, gqa):
    B, K, S, hd = 2, 2, 64, 16
    q, k, v = _qkv(B, K * gqa, K, S, hd)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(S=st.sampled_from([32, 64, 128]),
       bq=st.sampled_from([16, 32]),
       hd=st.sampled_from([8, 16]))
def test_flash_shape_sweep(S, bq, hd):
    q, k, v = _qkv(1, 2, 2, S, hd)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 4, 2, 64, 16, jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_row_sums_preserved():
    """Softmax rows sum to 1: output of attention over constant V == V."""
    B, H, K, S, hd = 1, 2, 2, 64, 8
    q, k, _ = _qkv(B, H, K, S, hd)
    v = jnp.ones((B, K, S, hd), jnp.float32) * 3.0
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), 3.0, rtol=1e-5)
