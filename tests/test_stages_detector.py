"""Staged-pipeline refactor: dense-vs-windowed equivalence per numerics
mode and per backend, vectorized NMS vs the greedy host reference, input
validation, frame-shape-bucket compile caching, full-frame serving."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.detector import (DetectorConfig, FrameDetector, _frame_program,
                                 _nms, detect, matrix_iou, nms_keep,
                                 scene_blocks, score_map)
from repro.core.hog import HOGConfig, PAPER_HOG, hog_descriptor
from repro.core.pipeline import extract_features
from repro.core.stages import (dense_blocks, validate_window, window_blocks,
                               window_descriptor)
from repro.core.svm import init_svm

RNG = np.random.default_rng(99)


def _scene(h=200, w=150):
    return jnp.asarray(RNG.integers(0, 256, (h, w)).astype(np.float32))


# ------------------------------------------- dense vs windowed, per mode
@pytest.mark.parametrize("mode", ["ref", "cordic", "sector"])
def test_dense_matches_windowed_per_mode(mode):
    """score_map(gray)[i, j] == svm_score(hog(window at (8i, 8j))) for
    every numerics mode -- the window-independence of eq. 5 that makes
    dense detection exact, now guaranteed by the shared stage chain."""
    cfg = dataclasses.replace(PAPER_HOG, mode=mode)
    gray = _scene()
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    b = jnp.float32(0.1)
    sm = score_map(gray, w, b, cfg)
    for (i, j) in [(0, 0), (2, 3), (5, 7)]:
        win = gray[i * 8:i * 8 + 130, j * 8:j * 8 + 66]
        d = hog_descriptor(win[None], cfg)[0]
        want = float(d @ w + b)
        np.testing.assert_allclose(float(sm[i, j]), want,
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------- backends share the stages
@pytest.mark.parametrize("backend", ["kernel", "fused"])
def test_dense_path_runs_on_pallas_backends(backend):
    """The dense layout must run on the Pallas backends too (it could
    not before the staged-pipeline refactor) and agree with ref."""
    gray = _scene()
    ref = dense_blocks(gray, PAPER_HOG, "ref")
    got = dense_blocks(gray, PAPER_HOG, backend)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["ref", "cordic", "sector"])
@pytest.mark.parametrize("backend", ["kernel", "fused"])
def test_dense_grid_kernels_match_ref_per_mode(backend, mode):
    """The DENSE-GRID Pallas kernels (row-slab tiled: dense_grad_hist +
    dense_block_norm for "kernel", dense_fused_hog for "fused") must
    agree with the pure-jnp ref chain per numerics mode, including on
    scenes whose cell grid does not divide the slab height (exercises
    the padded last slab / clamped-gather halo)."""
    cfg = dataclasses.replace(PAPER_HOG, mode=mode)
    for hw in [(200, 150), (146, 210)]:       # 24 and 18 cell rows
        gray = jnp.asarray(RNG.integers(0, 256, hw).astype(np.float32))
        ref = dense_blocks(gray, cfg, "ref")
        got = dense_blocks(gray, cfg, backend)
        assert got.shape == ref.shape
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_dense_grid_kernels_batch_axis():
    """Dense kernels tile (B, H, W) scenes; every batch element must
    match its own single-scene result (grid over (B, slabs))."""
    scenes = jnp.asarray(RNG.integers(0, 256, (3, 146, 150))
                         .astype(np.float32))
    for backend in ("kernel", "fused"):
        got = dense_blocks(scenes, PAPER_HOG, backend)
        for i in range(3):
            np.testing.assert_allclose(
                got[i], dense_blocks(scenes[i], PAPER_HOG, "ref"),
                rtol=1e-5, atol=1e-5)


# ------------------------------------------------- matmul score restructure
def test_score_blocks_matches_conv_reference():
    """The blocked-matmul scorer must reproduce the conv formulation it
    replaced: score[i,j] = <blocks[i:i+15, j:j+7, :], W> + b."""
    from repro.core.detector import score_blocks
    gray = _scene(220, 180)
    blocks = dense_blocks(gray, PAPER_HOG, "ref")
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    b = jnp.float32(0.25)
    wk = w.reshape(15, 7, 36)
    want = jax.lax.conv_general_dilated(
        blocks[None], wk[..., None], (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)[0, :, :, 0] + b
    got = score_blocks(blocks, w, b, PAPER_HOG)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # the Pallas MXU kernel route (kernel/fused backends) agrees too
    got_k = score_blocks(blocks, w, b, PAPER_HOG, use_kernel=True)
    np.testing.assert_allclose(got_k, want, rtol=1e-4, atol=1e-4)


def test_score_blocks_bf16_descriptors_f32_accumulation():
    """perf-preset layout: bf16 block grid in, f32 scores out, close to
    the f32 path within bf16 tolerance."""
    from repro.core.detector import score_blocks
    blocks = dense_blocks(_scene(), PAPER_HOG, "ref")
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    f32 = score_blocks(blocks, w, jnp.float32(0.0), PAPER_HOG)
    bf16 = score_blocks(blocks.astype(jnp.bfloat16), w, jnp.float32(0.0),
                        PAPER_HOG)
    assert bf16.dtype == jnp.float32
    np.testing.assert_allclose(bf16, f32, rtol=0.05, atol=0.05)


def test_scene_blocks_and_score_map_accept_backend():
    gray = _scene()
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    b = jnp.float32(0.0)
    np.testing.assert_allclose(scene_blocks(gray, PAPER_HOG, "kernel"),
                               scene_blocks(gray, PAPER_HOG, "ref"),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(score_map(gray, w, b, PAPER_HOG, "fused"),
                               score_map(gray, w, b, PAPER_HOG, "ref"),
                               rtol=1e-4, atol=1e-4)


def test_window_layout_backends_agree():
    win = jnp.asarray(RNG.integers(0, 256, (3, 130, 66, 3)).astype(np.uint8))
    d_ref = window_descriptor(win, PAPER_HOG, "ref")
    for backend in ("kernel", "fused"):
        np.testing.assert_allclose(window_descriptor(win, PAPER_HOG, backend),
                                   d_ref, rtol=1e-5, atol=1e-5)
    blocks = window_blocks(win, PAPER_HOG, "ref")
    assert blocks.shape == (3, 15, 7, 36)


# ------------------------------------------------------- input validation
def test_small_window_raises():
    small = jnp.zeros((2, 100, 50), jnp.float32)
    with pytest.raises(ValueError, match="smaller than"):
        hog_descriptor(small, PAPER_HOG)
    with pytest.raises(ValueError, match="smaller than"):
        extract_features(jnp.zeros((2, 129, 66, 3), jnp.uint8), PAPER_HOG)
    with pytest.raises(ValueError):
        validate_window(jnp.zeros((130, 65)), PAPER_HOG)
    # >= geometry still fine (top-left crop)
    assert hog_descriptor(jnp.zeros((1, 140, 70)), PAPER_HOG).shape == (1, 3780)


# ------------------------------------------------------------------- NMS
def test_vectorized_nms_matches_greedy_on_random_boxes():
    for trial in range(5):
        rng = np.random.default_rng(trial)
        n = int(rng.integers(5, 220))
        y0 = rng.uniform(0, 300, n)
        x0 = rng.uniform(0, 300, n)
        boxes = np.stack([y0, x0, y0 + rng.uniform(8, 80, n),
                          x0 + rng.uniform(8, 80, n)], -1).astype(np.float32)
        scores = rng.normal(size=n).astype(np.float32)
        want = sorted(_nms(boxes, scores, 0.3))
        order = np.argsort(-scores)
        mask = np.asarray(nms_keep(jnp.asarray(boxes[order]),
                                   jnp.asarray(scores[order]), 0.3))
        got = sorted(order[np.where(mask)[0]].tolist())
        assert got == want, (trial, got, want)


def test_nms_keep_ignores_neg_inf_rows():
    boxes = jnp.asarray([[0, 0, 10, 10], [100, 100, 110, 110],
                         [0, 0, 10, 10]], jnp.float32)
    scores = jnp.asarray([1.0, 0.5, -jnp.inf])
    keep = np.asarray(nms_keep(boxes, scores, 0.3))
    assert keep.tolist() == [True, True, False]


def test_matrix_iou_values():
    a = jnp.asarray([[0, 0, 10, 10]], jnp.float32)
    b = jnp.asarray([[0, 0, 10, 10], [5, 5, 15, 15],
                     [20, 20, 30, 30]], jnp.float32)
    iou = np.asarray(matrix_iou(a, b))[0]
    np.testing.assert_allclose(iou, [1.0, 25 / 175, 0.0], rtol=1e-5)


# ----------------------------------------- device-resident detect() path
def test_detect_no_retrace_across_calls():
    """Same-shape frames must reuse ONE compiled program (the scale loop
    and NMS are inside it; only box decode is host-side)."""
    svm = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    cfg = DetectorConfig(score_threshold=-10.0, scales=(1.0, 0.8))
    det = FrameDetector(svm, cfg)
    f1 = RNG.integers(0, 256, (224, 160, 3)).astype(np.uint8)
    f2 = RNG.integers(0, 256, (224, 160, 3)).astype(np.uint8)
    r1, r2 = det(f1), det(f2)
    assert r1 and r2
    # the fused frame program (grayscale+pad inside) is the hot path now
    from repro.core.detector import _single_fn
    fn = _single_fn(224, 160, 224, 160, cfg)
    assert fn._cache_size() == 1                 # one trace, two frames
    # same bucket -> same cached FrameProgram object
    prog, _, _ = det.program_for(224, 160)
    prog2, _, _ = det.program_for(224, 160)
    assert prog2 is prog


def test_detect_results_sorted_and_decoded():
    svm = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    dets = detect(RNG.integers(0, 256, (224, 160, 3)).astype(np.uint8),
                  svm, DetectorConfig(score_threshold=-10.0, scales=(1.0,)))
    assert dets
    scores = [d["score"] for d in dets]
    assert scores == sorted(scores, reverse=True)
    for d in dets:
        y0, x0, y1, x1 = d["box"]
        assert 0 <= y0 < y1 <= 224 + 1e-3
        assert 0 <= x0 < x1 <= 160 + 1e-3
        assert d["scale"] == 1.0


def test_detect_tiny_frame_returns_empty():
    svm = init_svm(3780)
    assert detect(np.zeros((64, 64, 3), np.uint8), svm) == []


def test_detect_padded_bucket_masks_out_of_frame_boxes():
    """A frame that needs padding must never report a window that lies
    outside the true frame."""
    svm = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    h, w = 150, 100                       # pads to 160 x 128 (bucket 32)
    dets = detect(RNG.integers(0, 256, (h, w, 3)).astype(np.uint8),
                  svm, DetectorConfig(score_threshold=-10.0, scales=(1.0,)))
    assert dets
    for d in dets:
        assert d["box"][2] <= h + 1e-3 and d["box"][3] <= w + 1e-3


# -------------------------------------------------------- full-frame serve
def test_detection_service_full_frames():
    from repro.serve.engine import DetectionService
    svm = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    svc = DetectionService(
        svm, batch_size=8,
        detector=DetectorConfig(score_threshold=-10.0, scales=(1.0,))).start()
    frames = [RNG.integers(0, 256, (224, 160, 3)).astype(np.uint8)
              for _ in range(3)]
    res = svc.detect_frames(frames)
    # window path still works alongside
    wres = svc.detect([RNG.integers(0, 256, (130, 66, 3)).astype(np.uint8)])
    svc.stop()
    assert len(res) == 3
    for r in res:
        assert r["detections"] and r["ms"] > 0
        assert {"box", "score", "scale"} <= set(r["detections"][0])
    assert svc.stats["frames"] == 3
    assert svc.stats["frame_ms"] > 0
    assert wres[0]["human"] in (0, 1)


# ------------------------------------- perf preset vs paper preset boxes
def test_perf_preset_matches_paper_preset_boxes():
    """Golden-style fixture check (fixed seeds): the perf preset (dense
    fused Pallas backend, bf16 descriptors, matmul scoring) must find
    the same boxes as the paper preset (ref backend, f32) with scores
    within bf16 tolerance. Only detections with a clear threshold
    margin are required to match -- bf16 jitter may legitimately move
    a score across the cut."""
    import dataclasses as dc
    from repro.api.config import presets

    rng = np.random.default_rng(42)
    svm = {"w": jnp.asarray((rng.normal(size=3780) * 0.02)
                            .astype(np.float32)),
           "b": jnp.float32(0.0)}
    frame = rng.integers(0, 256, (220, 180, 3)).astype(np.uint8)
    margin, tol = 0.05, 0.05

    def run(preset):
        det_cfg = dc.replace(presets(preset).detector,
                             score_threshold=0.0, scales=(1.0, 0.8))
        return FrameDetector(svm, det_cfg)(frame)

    paper, perf = run("paper"), run("perf")

    def match(src, dst, name):
        for d in src:
            if d["score"] < margin:
                continue
            twins = [e for e in dst
                     if np.allclose(e["box"], d["box"], atol=1.0)]
            assert twins, f"{name}: no box twin for {d}"
            assert min(abs(e["score"] - d["score"])
                       for e in twins) < tol, (d, twins)

    match(paper, perf, "paper->perf")
    match(perf, paper, "perf->paper")


# ------------------------------------------------- batch-chunk autotune
def test_batch_chunk_autotune_resolves_and_matches():
    """batch_chunk=0 must probe scan-vs-vmap at first use, cache the
    decision (visible in autotune_report) and produce results identical
    to an explicitly configured schedule."""
    from repro.core.detector import autotune_report
    svm = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
           "b": jnp.float32(0.0)}
    frames = np.stack([RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
                       for _ in range(3)])
    auto = FrameDetector(svm, DetectorConfig(
        score_threshold=-10.0, scales=(1.0,), batch_chunk=0))
    got = auto.detect_batch(frames)
    # every schedule entry is tagged with its mesh layout (data:1 = the
    # unsharded path) so BENCH entries stay unambiguous about devices
    key = "160x128->160x128 B=3 mesh=data:1 [rgb-uint8]"
    rep = autotune_report()
    assert key in rep and rep[key]["chunk"] in (1, 3)
    assert set(rep[key]["probe_ms"]) == {1, 3}
    # cached: second call must not re-probe (same dict object contents)
    auto.detect_batch(frames)
    assert autotune_report()[key] == rep[key]
    for chunk in (1, 3):
        det = FrameDetector(svm, DetectorConfig(
            score_threshold=-10.0, scales=(1.0,), batch_chunk=chunk))
        want = det.detect_batch(frames)
        assert len(want) == len(got)
        for a, b in zip(want, got):
            assert len(a) == len(b)
            for da, db in zip(a, b):
                assert abs(da["score"] - db["score"]) < 1e-5
