"""Banded sliding-window attention == masked full attention (§Perf)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.attention import attention, banded_attention
from repro.models.model import forward, init_params, layer_segments


def _p(cfg, key):
    from repro.models.model import _attn_p
    return jax.tree.map(lambda x: x, _attn_p(key, 0, cfg))


@pytest.mark.parametrize("n_meta", [0, 8])
def test_banded_matches_masked(n_meta):
    cfg = dataclasses.replace(
        get_config("hymba-1.5b", smoke=True), meta_tokens=n_meta,
        dtype=jnp.float32)
    p = _p(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64 + n_meta
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    w = cfg.sliding_window  # 16 in smoke
    ref = attention(x, p, cfg, pos, window=w, n_meta=n_meta)
    band = banded_attention(x, p, cfg, pos, window=w, n_meta=n_meta)
    np.testing.assert_allclose(np.asarray(band), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_banded_non_divisible_seq():
    cfg = dataclasses.replace(get_config("hymba-1.5b", smoke=True),
                              meta_tokens=0, dtype=jnp.float32)
    p = _p(cfg, jax.random.PRNGKey(0))
    B, S = 1, 53   # not a multiple of window=16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = attention(x, p, cfg, pos, window=cfg.sliding_window)
    band = banded_attention(x, p, cfg, pos, window=cfg.sliding_window)
    np.testing.assert_allclose(np.asarray(band), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_layer_segments():
    cfg = get_config("hymba-1.5b")   # global at 0, 15, 31 of 32
    segs = layer_segments(cfg)
    assert segs[0] == (0, 1, "global")
    assert segs[1] == (1, 15, "window")
    assert segs[2] == (15, 16, "global")
    assert segs[-1] == (31, 32, "global")
    assert sum(b - a for a, b, _ in segs) == cfg.n_layers


def test_banded_forward_matches_baseline_forward():
    """Full hymba smoke forward: banded segmented stack == baseline."""
    from repro.models.moe import ShardingCtx
    cfg = get_config("hymba-1.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, cfg.vocab)}
    base = forward(params, batch, cfg, None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), banded=True)
    band = forward(params, batch, cfg, ctx)
    np.testing.assert_allclose(
        np.asarray(band, np.float32), np.asarray(base, np.float32),
        rtol=3e-2, atol=3e-2)
