"""Resilience + chaos suite (DESIGN.md §14, CI lane `chaos-smoke`).

Two families:

  * UNIT (no device, no jit): the resilience primitives --
    RetryPolicy backoff/jitter, CircuitBreaker with an injected fake
    clock, RollingLatency percentiles, DegradationLadder hysteresis,
    and the FaultInjector's seeded determinism.
  * CHAOS (device, deterministic schedules): the supervised
    DetectionService under injected worker kills, device loss, latency
    spikes, deadlines, and malformed frames. The invariants pinned
    here are liveness invariants: every submitted future resolves
    (result, DeadlineExceeded, or traceback-carrying error), stop()
    under chaos returns within its timeout, stats reconcile
    (frame_answers == accepted submissions), and a forced degradation
    episode reports `degraded_mode` and recovers to the full pipeline
    with byte-identical detections to an unperturbed run.

Frames are small (160x128, single scale, threshold -10) so the whole
file runs on a handful of compiled programs.
"""
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.detector import DetectorConfig, FrameDetector
from repro.serve.engine import (CircuitOpen, DetectionService,
                                ServiceOverloaded, ServiceStopped)
from repro.serve.faults import (DETERMINISTIC_TYPES, DeterministicFault,
                                FaultInjector, FaultSpec, TransientFault,
                                WorkerKilled, chaos_specs, malformed_frame)
from repro.serve.resilience import (CircuitBreaker, DegradationLadder,
                                    ResilienceConfig, RetryPolicy,
                                    RollingLatency)

RNG = np.random.default_rng(11)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}
DET_CFG = DetectorConfig(score_threshold=-10.0, scales=(1.0,))


def _frames(n, h=160, w=128, seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
            for _ in range(n)]


def _service(**kw):
    kw.setdefault("detector", DET_CFG)
    kw.setdefault("frame_batch", 1)
    kw.setdefault("max_wait_ms", 1.0)
    return DetectionService(SVM, **kw)


# ================================================================ unit

def test_retry_policy_caps_and_jitter_determinism():
    p = RetryPolicy(backoff_base_ms=5.0, backoff_cap_ms=40.0, jitter=0.0)
    assert [p.delay_ms(a) for a in (1, 2, 3, 4, 5)] == \
        [5.0, 10.0, 20.0, 40.0, 40.0]
    j = RetryPolicy(jitter=0.5, seed=7)
    a, b = j.delay_ms(3), j.delay_ms(3)
    assert a == b                              # seeded: replayable
    assert j.delay_ms(3) <= 20.0               # jitter only subtracts
    assert j.delay_ms(3) >= 10.0               # and at most `jitter` of it


def test_circuit_breaker_state_machine_fake_clock():
    t = {"now": 0.0}
    br = CircuitBreaker(max_failures=3, reset_after_s=10.0,
                        clock=lambda: t["now"])
    assert br.state == "closed" and br.admit() and br.probe_due()
    br.record_failure(); br.record_failure()
    assert br.state == "closed" and br.admit()     # not consecutive enough
    br.record_failure()
    assert br.state == "open" and not br.admit() and not br.probe_due()
    t["now"] = 9.9
    assert not br.admit()
    t["now"] = 10.0                                # cooled: probe due
    assert br.admit() and br.probe_due()
    assert br.state == "half_open"
    br.record_failure()                            # probe failed: reopen
    assert br.state == "open" and not br.admit()
    t["now"] = 25.0
    assert br.probe_due()
    br.record_success()                            # probe served: close
    assert br.state == "closed" and br.consecutive == 0


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(max_failures=2)
    br.record_failure(); br.record_success(); br.record_failure()
    assert br.state == "closed"                    # never 2 in a row


def test_rolling_latency_window_and_percentiles():
    rl = RollingLatency(window=4)
    assert rl.percentile(99) == 0.0 and len(rl) == 0
    for v in (100.0, 1.0, 2.0, 3.0, 4.0):          # 100 falls out
        rl.add(v)
    assert len(rl) == 4
    assert rl.percentile(50) == 2.5
    assert rl.snapshot()["p99"] <= 4.0


def test_ladder_hysteresis_degrade_fast_recover_slow():
    lad = DegradationLadder(("full", "cascade", "coarse"),
                            degrade_p99_ms=100.0, recover_p99_ms=50.0,
                            recover_dwell=2, min_samples=1)
    assert lad.enabled and lad.rung == "full"
    assert lad.observe(150.0, 0, 8) == "cascade"   # overload: drop a rung
    assert lad.observe(150.0, 0, 8) == "coarse"    # still hot: next rung
    assert lad.observe(150.0, 0, 8) == "coarse"    # floor holds
    assert lad.observe(60.0, 0, 8) == "coarse"     # hysteresis band: hold
    assert lad.observe(40.0, 0, 8) == "coarse"     # healthy 1/2
    assert lad.observe(40.0, 0, 8) == "cascade"    # healthy 2/2: climb one
    assert lad.observe(40.0, 0, 8) == "cascade"    # dwell restarts per rung
    assert lad.observe(40.0, 0, 8) == "full"
    assert lad.transitions == 4


def test_ladder_depth_trigger_and_inert_default():
    lad = DegradationLadder(("full", "reduced"), degrade_depth=10)
    assert lad.observe(0.0, 10, 0) == "reduced"    # depth alone degrades
    inert = DegradationLadder(("full", "reduced"))
    assert not inert.enabled
    assert inert.observe(1e9, 1_000_000, 64) == "full"


def test_ladder_ignores_thin_latency_window():
    lad = DegradationLadder(("full", "reduced"), degrade_p99_ms=10.0,
                            min_samples=4)
    # compile-time spike with 1 sample must not trigger the ladder
    assert lad.observe(5000.0, 0, 1) == "full"
    assert lad.observe(5000.0, 0, 4) == "reduced"


def test_fault_injector_is_deterministic_and_capped():
    mk = lambda: FaultInjector((
        FaultSpec("exception", prob=0.3, max_fires=2),
        FaultSpec("latency", at_batches=(1,), latency_ms=0.0)), seed=42)
    a, b = mk(), mk()
    for inj in (a, b):
        for _ in range(50):
            try:
                inj.before_batch(1)
            except TransientFault:
                pass
    assert a.fired == b.fired                      # seeded: replayable
    assert sum(k == "exception" for _, k in a.fired) == 2   # max_fires
    assert (1, "latency") in a.fired


def test_fault_spec_rejects_unknown_kind_at_construction():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("melt_the_chip")


def test_fault_taxonomy():
    assert issubclass(WorkerKilled, BaseException)
    assert not issubclass(WorkerKilled, Exception)  # sails past except
    assert isinstance(DeterministicFault("x"), DETERMINISTIC_TYPES)
    assert not isinstance(TransientFault("x"), DETERMINISTIC_TYPES)
    assert issubclass(CircuitOpen, ServiceOverloaded)  # caller-compatible


def test_resilience_config_json_roundtrip_via_pipeline():
    from repro.api.config import PipelineConfig, presets
    p = presets("resilient")
    assert p.service.resilience.deadline_ms > 0
    q = PipelineConfig.from_json(p.to_json())
    assert q == p and isinstance(q.service.resilience.retry, RetryPolicy)


# =============================================================== chaos

@pytest.fixture(scope="module")
def serial():
    """Unperturbed per-frame reference detections (and program warmup
    for everything after it)."""
    det = FrameDetector(SVM, DET_CFG)
    frames = _frames(10)
    return frames, [det.detect_raw(f).to_list() for f in frames]


def test_chaos_schedule_liveness_and_identical_results(serial):
    """The acceptance gate: under the standard worker-kill/device-loss/
    latency schedule every future resolves, results are byte-identical
    to the unperturbed run, and stop() returns."""
    frames, ref = serial
    inj = FaultInjector(chaos_specs(), seed=0)
    svc = _service(faults=inj).start()
    res = svc.detect_frames(frames, timeout=120)
    assert [r["detections"] for r in res] == ref
    assert all("error" not in r for r in res)
    assert {k for _, k in inj.fired} == \
        {"kill_worker", "device_loss", "latency"}
    assert svc.stats["restarts"] >= 2 and svc.stats["retries"] >= 2
    assert svc.stats["frame_answers"] == len(frames)
    t0 = time.monotonic()
    svc.stop()
    assert time.monotonic() - t0 < 15


def test_deadline_shed_before_compute(serial):
    """An expired request is answered with the DeadlineExceeded payload
    BEFORE compute: with the worker parked, every queued request's
    budget burns down and none of them reach the detector."""
    frames, _ = serial
    svc = _service()
    futs = [svc.submit_frame(f, deadline_ms=1.0) for f in frames[:4]]
    time.sleep(0.05)                   # budgets expire while queued
    svc.start()
    for f in futs:
        r = f.get(timeout=30)
        assert r.get("deadline_exceeded") is True
        assert "DeadlineExceeded" in r["error"]
    assert svc.stats["deadline_shed"] == 4
    assert svc.stats["frames"] == 0            # nothing was computed
    # an un-deadlined request right after is served normally
    ok = svc.submit_frame(frames[0]).get(timeout=60)
    assert "error" not in ok
    svc.stop()


def test_deadline_default_from_config(serial):
    frames, _ = serial
    svc = _service(resilience=ResilienceConfig(deadline_ms=1.0))
    fut = svc.submit_frame(frames[0])          # inherits the 1 ms budget
    time.sleep(0.05)
    svc.start()
    assert fut.get(timeout=30).get("deadline_exceeded") is True
    svc.stop()


def test_breaker_trips_to_fail_fast_then_recovers(serial):
    """N consecutive worker deaths open the breaker: submission raises
    CircuitOpen, queued work is drained (not parked), and after the
    cooldown a probe worker serves again and closes it."""
    frames, ref = serial
    inj = FaultInjector((FaultSpec("kill_worker", at_batches=(0, 1),
                                   max_fires=2),), seed=0)
    svc = _service(faults=inj,
                   resilience=ResilienceConfig(
                       breaker_failures=2, breaker_reset_s=0.2,
                       retry=RetryPolicy(max_attempts=5,
                                         backoff_base_ms=1.0,
                                         backoff_cap_ms=2.0))).start()
    fut = svc.submit_frame(frames[0])
    deadline = time.monotonic() + 30
    while svc.stats["breaker"]["state"] != "open":
        assert time.monotonic() < deadline, "breaker never opened"
        time.sleep(0.005)
    # open: fail-fast admission ...
    with pytest.raises(CircuitOpen):
        while True:                  # may race the cooldown; bounded
            svc.submit_frame(frames[0])
            assert time.monotonic() < deadline
    # ... and the queued request was answered, not parked
    r = fut.get(timeout=30)
    assert isinstance(r, dict)
    # cooldown elapses -> half-open probe serves -> closed
    time.sleep(0.25)
    ok = svc.detect_frames([frames[0]], timeout=60)[0]
    assert ok["detections"] == ref[0]
    assert svc.stats["breaker"]["state"] == "closed"
    assert svc.stats["restarts"] >= 2
    svc.stop()


def test_degradation_episode_reports_and_recovers(serial):
    """Forced overload degrades to the reduced rung (surfaced per
    response as degraded_mode), and after the spikes stop the ladder
    climbs back to full with byte-identical detections."""
    frames, ref = serial
    inj = FaultInjector((FaultSpec("latency", at_batches=(2, 3, 4, 5),
                                   latency_ms=80.0),), seed=0)
    svc = _service(
        faults=inj,
        resilience=ResilienceConfig(degrade_p99_ms=50.0,
                                    recover_p99_ms=20.0,
                                    recover_dwell=2, latency_window=4))
    svc.start()
    rungs = []
    for f in frames:
        r = svc.detect_frames([f], timeout=60)[0]
        assert "degraded_mode" in r
        rungs.append(r["degraded_mode"])
    assert "reduced" in rungs, f"never degraded: {rungs}"
    assert svc.stats["frames_degraded"] >= 1
    assert svc.stats["ladder"]["transitions"] >= 1
    # spikes over: ladder climbs back and full-pipeline results are
    # byte-identical to the unperturbed reference
    deadline = time.monotonic() + 60
    while svc.stats["degraded_mode"] != "full":
        assert time.monotonic() < deadline, \
            f"never recovered: {svc.stats['ladder']}"
        svc.detect_frames([frames[0]], timeout=60)
    res = svc.detect_frames(frames, timeout=120)
    assert [r["degraded_mode"] for r in res] == ["full"] * len(frames)
    assert [r["detections"] for r in res] == ref
    assert svc.stats["ladder"]["transitions"] >= 2   # down AND back up
    svc.stop()


def test_malformed_frames_do_not_poison_batchmates(serial):
    """Garbage frames riding a batch with good frames get error (or
    empty) payloads; the good frames' results are unaffected."""
    frames, ref = serial
    rng = np.random.default_rng(3)
    bad = [malformed_frame(rng) for _ in range(4)]
    svc = _service(frame_batch=2).start()
    mixed = [frames[0], bad[0], frames[1], bad[1],
             bad[2], frames[2], bad[3], frames[3]]
    res = svc.detect_frames(mixed, timeout=120)
    assert len(res) == len(mixed)              # every future resolved
    assert [res[i]["detections"] for i in (0, 2, 5, 7)] == ref[:4]
    assert svc.stats["frame_answers"] == len(mixed)
    assert svc.stats["restarts"] == 0          # contained, not a death
    svc.stop()
    assert svc._pending_frames == 0


def test_stop_under_chaos_never_hangs_and_stats_reconcile(serial):
    """stop() racing live chaos traffic: returns within its timeout,
    every accepted future resolves, and the books balance."""
    frames, _ = serial
    inj = FaultInjector((
        FaultSpec("kill_worker", prob=0.2, max_fires=3),
        FaultSpec("latency", prob=0.5, latency_ms=20.0)), seed=5)
    svc = _service(faults=inj).start()
    futs, lock = [], threading.Lock()

    def client(seed):
        for f in _frames(6, seed=seed):
            try:
                fut = svc.submit_frame(f, deadline_ms=500.0)
            except (ServiceOverloaded, ServiceStopped):
                continue
            with lock:
                futs.append(fut)

    threads = [threading.Thread(target=client, args=(s,))
               for s in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    t0 = time.monotonic()
    svc.stop()
    assert time.monotonic() - t0 < 15
    for t in threads:
        t.join(timeout=10)
        assert not t.is_alive()
    for fut in futs:
        assert isinstance(fut.get(timeout=5), dict)   # resolved, somehow
    assert svc._pending_frames == 0
    assert svc.stats["frame_answers"] == len(futs)


def test_submit_after_stop_raises_service_stopped(serial):
    frames, _ = serial
    svc = _service().start()
    svc.stop()
    with pytest.raises(ServiceStopped):
        svc.submit_frame(frames[0])
    with pytest.raises(ServiceStopped):
        svc.submit(np.zeros((130, 66, 3), np.uint8))
    # detect_frames soft-fails (ServiceStopped is not ServiceOverloaded:
    # callers must see the hard error)
    with pytest.raises(ServiceStopped):
        svc.detect_frames(frames[:1])


def test_future_timeout_leaves_no_orphan(serial):
    """Satellite: a caller abandoning f.get(timeout=...) must not leave
    an orphaned backlog entry that skews stats or blocks shutdown --
    the request is still served, its pending slot released, and the
    payload parks harmlessly in the future."""
    frames, _ = serial
    svc = _service()
    fut = svc.submit_frame(frames[0])     # worker not started yet ...
    with pytest.raises(Exception):        # queue.Empty
        fut.get(timeout=0.01)             # ... so the caller times out
    svc.start()                           # service still serves it
    deadline = time.monotonic() + 60
    while svc.stats["frame_answers"] < 1:
        assert time.monotonic() < deadline
        time.sleep(0.005)
    assert svc._pending_frames == 0       # slot released, stats sane
    assert svc.stats["frames"] == 1
    assert fut.get(timeout=5)["detections"] is not None
    t0 = time.monotonic()
    svc.stop()
    assert time.monotonic() - t0 < 15


def test_session_serve_wires_resilience_and_cascade_rungs():
    """api wiring: config.service.resilience reaches the engine and a
    cascade-enabled config backs the ladder with cascade rungs."""
    from repro.api.config import presets
    p = presets("resilient")
    sc = p.service.resilience
    assert sc.deadline_ms == 500.0 and sc.degrade_p99_ms == 120.0
    # engine-side rung selection (no training needed): a cascade handle
    # opens the cascade/coarse rungs, no handle means reduced-pyramid
    from repro.core.cascade import CascadeDetector
    svc = _service()
    assert svc._ladder.rungs == ("full", "reduced")
    assert svc._reduced.cfg.scales == (1.0,)
    svc2 = _service(cascade=object.__new__(CascadeDetector))
    assert svc2._ladder.rungs == ("full", "cascade", "coarse")
