"""DetectionSession facade: legacy-shim equivalence (golden fixtures +
scenes, byte-identical boxes), typed Detections contract, saturation
surfacing, warmup/cache stats, checkpoint round-trip, serve() wiring.
"""
import pathlib
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import DetectionSession, Detections, PipelineConfig
from repro.api.config import ServiceConfig
from repro.core.detector import DetectorConfig, FrameDetector, detect
from repro.core.video import TrackerConfig, VideoDetector
from repro.data.synth_pedestrian import ClipConfig, make_clip, make_scene

GOLDEN = pathlib.Path(__file__).parent / "golden" / "hog_golden.npz"

RNG = np.random.default_rng(42)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}
CFG = DetectorConfig(score_threshold=-10.0, scales=(1.0, 0.8))


def _scene(seed, h=200, w=160):
    rng = np.random.default_rng(seed)
    return make_scene(rng, h, w, n_people=1)[0]


def _session(cfg=CFG, svm=SVM):
    return DetectionSession(svm, PipelineConfig(detector=cfg))


def _assert_identical(legacy, api):
    """Byte-identical: exact float equality, exact ordering."""
    assert legacy == api


# ------------------------------------------------- shim equivalence

def test_detect_shim_equivalent_on_golden_windows():
    """The golden-fixture windows + golden SVM params through the
    legacy detect() and through the session: byte-identical boxes."""
    z = np.load(GOLDEN)
    svm = {"w": jnp.asarray(z["svm_w"]), "b": jnp.asarray(z["svm_b"])}
    cfg = DetectorConfig(score_threshold=-1e9, scales=(1.0,))
    ses = DetectionSession(svm, PipelineConfig(detector=cfg))
    for i in range(z["windows"].shape[0]):
        win = z["windows"][i]                       # (130, 66, 3) uint8
        legacy = detect(win, svm, cfg)
        api = ses.detect(win).to_list()
        assert legacy, f"golden window {i} produced no detection"
        _assert_identical(legacy, api)


def test_detect_shim_equivalent_on_scene():
    scene = _scene(0)
    legacy = detect(scene, SVM, CFG)
    fd = FrameDetector(SVM, CFG)
    ses = _session()
    assert legacy
    _assert_identical(legacy, fd(scene))
    _assert_identical(legacy, ses.detect(scene).to_list())


def test_detect_batch_shim_equivalent():
    frames = [_scene(1), _scene(2), _scene(3)]
    fd = FrameDetector(SVM, CFG)
    ses = _session()
    legacy = fd.detect_batch(frames)
    api = ses.detect_batch(frames)
    assert any(legacy)
    _assert_identical(legacy, api.to_list())
    # per-frame slicing agrees with the whole-batch decode
    for i in range(3):
        _assert_identical(legacy[i], api.frame(i).to_list())


def test_stream_shim_equivalent_to_video_detector():
    rng = np.random.default_rng(5)
    clip, _ = make_clip(rng, ClipConfig(n_frames=5, n_people=1,
                                        h=160, w=128, frame_noise=4.0))
    cfg = DetectorConfig(score_threshold=-10.0, scales=(1.0,))
    tcfg = TrackerConfig()
    legacy = VideoDetector(SVM, cfg, tcfg).process_clip(list(clip),
                                                        batch_size=3)
    ses = DetectionSession(SVM, PipelineConfig(detector=cfg, tracker=tcfg))
    api = [d.to_list() for d in ses.stream(list(clip), batch_size=3)]
    assert len(api) == 5 and all(api)
    _assert_identical(legacy, api)
    assert all({"box", "score", "scale", "track_id", "hits",
                "misses"} <= set(d) for dets in api for d in dets)


# -------------------------------------------------- typed Detections

def test_detections_lazy_accessors_and_len():
    d = _session().detect(_scene(0))
    lst = d.to_list()
    assert len(d) == len(lst)
    np.testing.assert_array_equal(
        d.boxes, np.asarray([x["box"] for x in lst], np.float32))
    np.testing.assert_array_equal(
        d.scores, np.asarray([x["score"] for x in lst], np.float32))
    assert list(iter(d)) == lst
    scores = [x["score"] for x in lst]
    assert scores == sorted(scores, reverse=True)


def test_detections_stack_and_frame_roundtrip():
    ses = _session()
    singles = [ses.detect(_scene(i)) for i in (1, 2)]
    batched = Detections.stack(singles)
    assert batched.batched and batched.batch_size == 2
    for i, s in enumerate(singles):
        _assert_identical(s.to_list(), batched.frame(i).to_list())
    assert [f.to_list() for f in batched] == batched.to_list()


def test_detections_from_list_passthrough():
    dets = [{"box": (0.0, 0.0, 10.0, 5.0), "score": 2.0, "scale": 1.0,
             "track_id": 7, "hits": 3, "misses": 0}]
    d = Detections.from_list(dets)
    assert d.to_list() == dets                  # extra keys preserved
    assert len(d) == 1 and not d.saturated
    np.testing.assert_array_equal(d.boxes, [[0.0, 0.0, 10.0, 5.0]])


def test_detections_empty_frame():
    d = _session(DetectorConfig(scales=(1.0,))).detect(
        np.zeros((64, 64, 3), np.uint8))        # smaller than one window
    assert d.to_list() == [] and len(d) == 0
    assert d.saturated is False


# --------------------------------------------------------- saturation

def test_saturated_flag_single_and_batch():
    cfg = DetectorConfig(score_threshold=-1e9, scales=(1.0,),
                         max_detections=4)
    ses = _session(cfg)
    scene = _scene(0)
    d = ses.detect(scene)
    assert d.saturated is True
    with pytest.warns(RuntimeWarning, match="max_detections=4"):
        d.to_list()

    b = ses.detect_batch([scene, scene])
    sat = b.saturated
    assert sat.shape == (2,) and sat.all()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        assert len(b.to_list()) == 2


def test_unsaturated_flag_false_no_warning():
    d = _session().detect(_scene(0))
    assert d.saturated is False
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        d.to_list()                              # must not warn


# ------------------------------------------------- warmup / cache stats

def test_warmup_compiles_ahead_and_counts():
    ses = _session(DetectorConfig(score_threshold=-10.0, scales=(1.0,)))
    stats = ses.warmup([(150, 120), (2, 150, 120)])
    assert (150, 120) in stats["warmed"]
    assert (2, 150, 120) in stats["warmed"]
    before = ses.cache_stats()
    d = ses.detect(np.zeros((150, 120, 3), np.uint8))
    d.block_until_ready()
    after = ses.cache_stats()
    # the warmed shape must not recompile: no new program cache misses
    assert after["frame_programs"]["misses"] == \
        before["frame_programs"]["misses"]
    assert after["calls"]["frames"] == before["calls"]["frames"] + 1


def test_warmup_rejects_bad_shape():
    with pytest.raises(ValueError, match="warmup shape"):
        _session().warmup([(1, 2, 3, 4)])


# --------------------------------------------- checkpoint + serve wiring

def test_save_load_roundtrip(tmp_path):
    ses = _session()
    ses.save(str(tmp_path / "ckpt"), step=3)
    back = DetectionSession.load(str(tmp_path / "ckpt"),
                                 PipelineConfig(detector=CFG))
    np.testing.assert_array_equal(np.asarray(back.svm["w"]),
                                  np.asarray(SVM["w"]))
    np.testing.assert_array_equal(np.asarray(back.svm["b"]),
                                  np.asarray(SVM["b"]))
    scene = _scene(0)
    _assert_identical(ses.detect(scene).to_list(),
                      back.detect(scene).to_list())


def test_load_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        DetectionSession.load(str(tmp_path / "nothing"))


def test_serve_shares_session_detector():
    ses = DetectionSession(SVM, PipelineConfig(
        detector=CFG, service=ServiceConfig(window_batch=8,
                                            frame_batch=2)))
    svc = ses.serve()
    try:
        assert svc._detector is ses.detector      # shared programs
        assert svc.batch == 8 and svc.frame_batch == 2
        svc.start()
        res = svc.detect_frames([_scene(0)])
        assert len(res) == 1
        assert "saturated" in res[0] and "ms" in res[0]
        _assert_identical(res[0]["detections"],
                          ses.detect(_scene(0)).to_list())
    finally:
        svc.stop()


def test_serve_detector_override_builds_own():
    ses = _session()
    svc = ses.serve(detector=DetectorConfig(scales=(1.0,)))
    assert svc._detector is not ses.detector
    assert svc._detector.cfg.scales == (1.0,)
