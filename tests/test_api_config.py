"""PipelineConfig tree: JSON round-trip, hog single-sourcing, presets."""
import dataclasses
import json

import pytest

from repro.api.config import (PipelineConfig, ServiceConfig, presets,
                              register_preset)
from repro.core.detector import DetectorConfig
from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.svm import SVMTrainConfig
from repro.core.video import TrackerConfig


# ------------------------------------------------------------ round trip

def test_round_trip_all_presets():
    """from_dict(to_dict(p)) == p for every registered preset -- both
    directly and through an actual JSON string (tuples -> lists ->
    tuples)."""
    assert presets(), "no presets registered"
    for name in presets():
        p = presets(name)
        assert PipelineConfig.from_dict(p.to_dict()) == p, name
        assert PipelineConfig.from_json(p.to_json()) == p, name


def test_to_dict_is_json_serializable():
    for name in presets():
        s = json.dumps(presets(name).to_dict())
        assert isinstance(json.loads(s), dict)


def test_round_trip_custom_tree():
    p = PipelineConfig(
        name="custom",
        hog=HOGConfig(mode="cordic", feat_dtype="bf16"),
        detector=DetectorConfig(hog=HOGConfig(mode="cordic",
                                              feat_dtype="bf16"),
                                scales=(1.0, 0.5), max_detections=17,
                                backend="kernel", batch_chunk=4),
        tracker=TrackerConfig(max_misses=5, emit_coasting=True),
        train=SVMTrainConfig(steps=123, neg_weight=2.5),
        service=ServiceConfig(window_batch=16, frame_batch=3))
    rt = PipelineConfig.from_json(p.to_json())
    assert rt == p
    assert rt.detector.scales == (1.0, 0.5)          # tuple restored
    assert isinstance(rt.detector.scales, tuple)


def test_from_dict_partial_uses_defaults():
    p = PipelineConfig.from_dict({"name": "half",
                                  "detector": {"score_threshold": 0.7}})
    assert p.name == "half"
    assert p.detector.score_threshold == 0.7
    assert p.detector.nms_iou == DetectorConfig().nms_iou
    assert p.train == SVMTrainConfig()


# -------------------------------------------------- hog single-sourcing

def test_detector_hog_follows_tree_hog():
    p = PipelineConfig(hog=HOGConfig(mode="cordic"))
    assert p.detector.hog.mode == "cordic"
    assert p.detector.hog == p.hog


def test_tree_hog_promotes_explicit_detector_hog():
    """Default tree hog + explicit detector hog: the explicit one wins
    and becomes the tree's hog (one source of truth either way)."""
    h = HOGConfig(mode="sector", feat_dtype="bf16")
    p = PipelineConfig(detector=DetectorConfig(hog=h))
    assert p.hog == h
    assert p.detector.hog == h


def test_explicit_tree_hog_overrides_detector():
    h = HOGConfig(mode="cordic")
    p = PipelineConfig(hog=h,
                       detector=DetectorConfig(hog=HOGConfig(mode="sector"),
                                               max_detections=9))
    assert p.detector.hog == h                 # tree hog wins
    assert p.detector.max_detections == 9      # other fields kept


# ---------------------------------------------------------------- presets

def test_builtin_presets_fold_paper_configs():
    assert {"default", "paper", "faithful", "perf"} <= set(presets())
    assert presets("paper").hog.mode == "sector"
    assert presets("faithful").hog.mode == "cordic"
    assert presets("perf").hog.feat_dtype == "bf16"
    assert presets("perf").detector.backend == "fused"
    # the train schedule comes from configs/hog_svm.py
    assert presets("paper").train.neg_weight == 6.0


def test_unknown_preset_raises_with_names():
    with pytest.raises(ValueError, match="paper"):
        presets("no-such-preset")


def test_register_preset_and_replace():
    p = register_preset("test-tmp", presets("paper").replace(name="tmp"))
    try:
        assert presets("test-tmp") is p
        assert p.name == "tmp"
        assert p.hog == presets("paper").hog
    finally:
        from repro.api import config as _c
        _c._PRESETS.pop("test-tmp", None)


def test_configs_hashable_for_program_cache():
    """The detector config inside the tree keys the compiled-program
    lru cache -- it must stay hashable."""
    for name in presets():
        hash(presets(name).detector)
        hash(presets(name).hog)
