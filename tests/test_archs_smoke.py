"""Per-architecture smoke tests: reduced configs, one forward + one grad
step + one prefill/decode roundtrip on CPU. Shape + finiteness asserts.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
        batch["positions"] = pos.astype(jnp.int32)
    if cfg.encoder_layers:
        batch["enc_input"] = jax.random.normal(
            ks[2], (B, cfg.encoder_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grad_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat)
    # embedding must receive gradient
    assert float(jnp.max(jnp.abs(grads["embed"]))) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode after prefill must match full forward logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    full = forward(params, batch, cfg).astype(jnp.float32)

    pre = {k: v for k, v in batch.items() if k != "labels"}
    pre["tokens"] = batch["tokens"][:, : S - 4]
    if cfg.mrope:
        pre["positions"] = batch["positions"][:, : S - 4]
    logits_last, cache = prefill(params, pre, cfg, max_len=S + 8)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(full[:, S - 5]),
        rtol=2e-2, atol=2e-2)

    enc = None
    if cfg.encoder_layers:
        from repro.models.model import encode
        enc = encode(params, batch["enc_input"], cfg)
    for t in range(S - 4, S):
        step_logits, cache = decode_step(
            params, batch["tokens"][:, t:t + 1], cache, cfg, enc=enc)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full[:, t]),
            rtol=5e-2, atol=5e-2)


def test_param_count_matches_init():
    """Analytic 6ND-side param counts equal the real pytree sizes."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        n_real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        n_calc = cfg.param_count()
        assert n_real == n_calc, (arch, n_real, n_calc)
