"""Concurrency stress for DetectionService.

N client threads hammer the one worker thread with a mix of window
requests, single-frame requests, and multi-frame (batched) requests --
plus malformed frames -- concurrently. Every request must complete,
frame results must match the serial FrameDetector exactly, and a
malformed request must be answered with an error without wedging the
microbatcher for its neighbors.

Marked `slow`: runs in the separate stress CI lane, not tier-1
(`-m "not slow"`).
"""
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.detector import DetectorConfig, FrameDetector
from repro.serve.engine import DetectionService, ServiceOverloaded

RNG = np.random.default_rng(21)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}
DET_CFG = DetectorConfig(score_threshold=-10.0, scales=(1.0,))

N_THREADS = 6


@pytest.mark.slow
def test_concurrent_mixed_requests_match_serial():
    frames_a = [RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
                for _ in range(N_THREADS)]
    frames_b = [RNG.integers(0, 256, (224, 192, 3)).astype(np.uint8)
                for _ in range(N_THREADS)]
    windows = [RNG.integers(0, 256, (130, 66, 3)).astype(np.uint8)
               for _ in range(N_THREADS)]
    bad = np.zeros((7,), np.uint8)                  # malformed frame

    serial = FrameDetector(SVM, DET_CFG)
    want_a = [serial(f) for f in frames_a]
    want_b = [serial(f) for f in frames_b]

    svc = DetectionService(SVM, batch_size=8, max_wait_ms=10.0,
                           detector=DET_CFG).start()
    results = [None] * N_THREADS
    errors = []

    def client(i):
        try:
            out = {}
            # batched request: both buckets interleaved + a malformed one
            out["frames"] = svc.detect_frames(
                [frames_a[i], bad, frames_b[i]])
            out["window"] = svc.detect([windows[i]])[0]
            out["single"] = svc.submit_frame(frames_a[i]).get(timeout=60)
            results[i] = out
        except Exception as e:                      # pragma: no cover
            errors.append((i, repr(e)))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "a client hung"
    assert not errors, errors

    def boxes(dets):
        return [(d["box"], round(d["score"], 4)) for d in dets]

    for i, out in enumerate(results):
        assert out is not None, f"client {i} never finished"
        ra, rbad, rb = out["frames"]
        assert "error" not in ra and "error" not in rb
        assert "error" in rbad and rbad["detections"] == []
        assert boxes(ra["detections"]) == boxes(want_a[i])
        assert boxes(rb["detections"]) == boxes(want_b[i])
        assert boxes(out["single"]["detections"]) == boxes(want_a[i])
        assert out["window"]["human"] in (0, 1)

    # the microbatcher actually batched: fewer device steps than frames
    assert svc.stats["frame_batches"] < svc.stats["frames"]
    # 2 good batched frames + 1 single per client; malformed never counts
    assert svc.stats["frames"] == 3 * N_THREADS
    svc.stop()


@pytest.mark.slow
def test_backpressure_rejects_but_recovers():
    svc = DetectionService(SVM, detector=DET_CFG, max_pending_frames=2)
    f = RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
    futs = [svc.submit_frame(f), svc.submit_frame(f)]   # fills the queue
    with pytest.raises(ServiceOverloaded):
        svc.submit_frame(f)
    assert svc.stats["frame_rejects"] == 1
    svc.start()                                     # worker drains the queue
    for fut in futs:
        assert "error" not in fut.get(timeout=60)
    # capacity is back
    assert "error" not in svc.submit_frame(f).get(timeout=60)
    svc.stop()


@pytest.mark.slow
def test_malformed_flood_does_not_wedge_worker():
    """A burst of garbage shapes interleaved with good frames: every
    request answered, good ones correct."""
    svc = DetectionService(SVM, batch_size=8, max_wait_ms=5.0,
                           detector=DET_CFG).start()
    good = RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
    want = FrameDetector(SVM, DET_CFG)(good)
    reqs = []
    for i in range(12):
        reqs.append(svc.submit_frame(
            good if i % 3 == 0 else np.zeros((i + 1,), np.uint8)))
    for i, fut in enumerate(reqs):
        res = fut.get(timeout=60)
        if i % 3 == 0:
            assert "error" not in res
            assert [d["box"] for d in res["detections"]] == \
                [d["box"] for d in want]
        else:
            assert "error" in res
    svc.stop()
