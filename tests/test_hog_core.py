"""Core HOG pipeline: paper geometry, numerics-mode equivalence, invariances."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hog import (HOGConfig, PAPER_HOG, gradients, grayscale,
                            hog_descriptor, mag_bin_cordic, mag_bin_ref,
                            mag_bin_sector)
from repro.core.cordic import cordic_mag_angle, cordic_gain

RNG = np.random.default_rng(7)


def test_paper_geometry():
    """130x66 window -> 16x8 cells -> 15x7 blocks -> 3780 features."""
    assert PAPER_HOG.active_h == 128 and PAPER_HOG.active_w == 64
    assert PAPER_HOG.cells_hw == (16, 8)
    assert PAPER_HOG.blocks_hw == (15, 7)
    assert PAPER_HOG.n_features == 3780            # 7x15x36, paper §IV.A


def test_descriptor_shape_and_finite():
    win = jnp.asarray(RNG.integers(0, 256, (3, 130, 66, 3)).astype(np.uint8))
    d = hog_descriptor(win)
    assert d.shape == (3, 3780)
    assert bool(jnp.all(jnp.isfinite(d)))


# --------------------------------------------------------------- CORDIC
@settings(max_examples=30, deadline=None)
@given(x=st.floats(-400, 400), y=st.floats(-400, 400))
def test_cordic_matches_atan2(x, y):
    if abs(x) < 1e-3 and abs(y) < 1e-3:
        return
    mag, ang = cordic_mag_angle(jnp.float32(x), jnp.float32(y))
    assert math.isclose(float(mag), math.hypot(x, y), rel_tol=1e-3, abs_tol=1e-3)
    want = math.degrees(math.atan2(y, x))
    got = float(ang)
    diff = abs((got - want + 180.0) % 360.0 - 180.0)
    # 15 iterations resolve to ~0.0035 deg; allow slack near axes
    assert diff < 0.01, (x, y, got, want)


def test_cordic_gain_value():
    assert math.isclose(cordic_gain(), 1.64676, rel_tol=1e-4)


# --------------------------------------------- numerics modes equivalence
def test_modes_agree_on_bins():
    fx = jnp.asarray(RNG.normal(size=4096).astype(np.float32) * 80)
    fy = jnp.asarray(RNG.normal(size=4096).astype(np.float32) * 80)
    m_r, b_r = mag_bin_ref(fx, fy)
    m_c, b_c = mag_bin_cordic(fx, fy)
    m_s, b_s = mag_bin_sector(fx, fy)
    # sector is exact vs ref (same fp32 ops reordered); cordic approximates
    assert int(jnp.sum(b_r != b_s)) == 0
    assert int(jnp.sum(b_r != b_c)) <= 2   # boundary-straddling pixels only
    np.testing.assert_allclose(m_r, m_s, rtol=1e-6)
    np.testing.assert_allclose(m_r, m_c, rtol=1e-3, atol=1e-2)


def test_full_window_mode_equivalence():
    win = jnp.asarray(RNG.integers(0, 256, (2, 130, 66, 3)).astype(np.uint8))
    d_ref = hog_descriptor(win, HOGConfig(mode="ref"))
    d_sec = hog_descriptor(win, HOGConfig(mode="sector"))
    d_cor = hog_descriptor(win, HOGConfig(mode="cordic"))
    np.testing.assert_allclose(d_ref, d_sec, rtol=1e-5, atol=1e-5)
    # CORDIC flips the bin of rare boundary-straddling pixels (the paper's
    # hardware differs from its Matlab oracle the same way), so individual
    # histogram entries can move; the DESCRIPTOR distance must stay small.
    rel = (jnp.linalg.norm(d_ref - d_cor, axis=-1)
           / jnp.linalg.norm(d_ref, axis=-1))
    assert float(jnp.max(rel)) < 0.02, float(jnp.max(rel))


# ------------------------------------------------------------ invariances
def test_illumination_invariance():
    """Block normalization kills global gain: HOG(a*I) ~= HOG(I)."""
    base = RNG.integers(40, 160, (130, 66, 3)).astype(np.float32)
    d1 = hog_descriptor(jnp.asarray(base))
    d2 = hog_descriptor(jnp.asarray(base * 1.5))
    np.testing.assert_allclose(d1, d2, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(shift=st.integers(-40, 40))
def test_constant_offset_invariance(shift):
    """Gradients kill global luma offsets exactly."""
    base = RNG.integers(60, 180, (130, 66)).astype(np.float32)
    d1 = hog_descriptor(jnp.asarray(base))
    d2 = hog_descriptor(jnp.asarray(np.clip(base + shift, 0, 255)))
    if 60 + shift >= 0 and 180 + shift <= 255:  # no clipping happened
        np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)


def test_gradient_eqs_1_2():
    g = jnp.asarray(RNG.random((10, 12)).astype(np.float32))
    fx, fy = gradients(g)
    # fx[i, j] belongs to interior pixel (i+1, j+1): f(x+1,y) - f(x-1,y)
    np.testing.assert_allclose(fx[3, 4], g[4, 6] - g[4, 4], rtol=1e-6)
    np.testing.assert_allclose(fy[3, 4], g[5, 5] - g[3, 5], rtol=1e-6)


def test_grayscale_matches_matlab_weights():
    rgb = jnp.asarray([[[100.0, 200.0, 50.0]]])
    want = 0.2989 * 100 + 0.5870 * 200 + 0.1140 * 50
    np.testing.assert_allclose(grayscale(rgb)[0, 0], want, rtol=1e-6)
