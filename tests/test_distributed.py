"""Distributed substrate tests on a forced 8-device host mesh:
MoE EP paths vs local oracle, DDP + int8 gradient compression, sharded
GSPMD train step, elastic checkpoint restore.

NOTE: this file must run in its own pytest process if other tests already
initialized jax with 1 device; we force the device count via conftest
fixtures by spawning where needed. Simpler: the whole test session sets
XLA_FLAGS in conftest BEFORE jax import IF REPRO_TEST_DEVICES is set.
These tests self-skip when only 1 device is available.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 host devices "
                                  "(run tests/run_multidevice.sh)")


def _mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


@multi
def test_moe_ep_a2a_matches_local():
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.models.moe import ShardingCtx, moe_ffn, _moe_local
    cfg = get_config("olmoe-1b-7b", smoke=True)
    mesh = _mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    y_local = _moe_local(x, lp, cfg)
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                      seq_sharded=True)
    y_ep = moe_ffn(x, lp, cfg, ctx)
    if cfg.shared_expert:
        from repro.models.layers import swiglu
        y_local = y_local + swiglu(x, lp["shared"])
    # EP capacity is per-shard, local capacity is global: with the smoke
    # configs' capacity_factor=8 nothing drops, so results must agree.
    np.testing.assert_allclose(
        np.asarray(y_ep, np.float32), np.asarray(y_local, np.float32),
        rtol=5e-2, atol=5e-3)


@multi
def test_moe_ep_replicated_matches_local():
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.models.moe import ShardingCtx, _moe_local, _moe_ep_replicated
    cfg = get_config("olmoe-1b-7b", smoke=True)
    mesh = _mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                      seq_sharded=False)
    y_rep = _moe_ep_replicated(x, lp, cfg, ctx)
    y_local = _moe_local(x, lp, cfg)
    np.testing.assert_allclose(
        np.asarray(y_rep, np.float32), np.asarray(y_local, np.float32),
        rtol=5e-2, atol=5e-3)


@multi
def test_gspmd_train_step_runs_and_learns():
    from repro.configs import get_config
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (init_train_state, jit_train_step)
    cfg = get_config("qwen3-14b", smoke=True)
    mesh = _mesh()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    state_shape = jax.eval_shape(lambda: state)
    batch = {
        "tokens": jnp.ones((8, 32), jnp.int32),
        "labels": jnp.ones((8, 32), jnp.int32),
    }
    batch_shape = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    step = jit_train_step(cfg, OptConfig(lr=1e-2, warmup_steps=1), mesh,
                          state_shape, batch_shape, donate=False)
    from repro.train.train_step import state_shardings
    sh = state_shardings(mesh, state_shape, cfg)
    state = jax.device_put(state, sh)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # constant batch must be memorized


@multi
def test_ddp_compressed_matches_uncompressed_direction():
    from repro.configs import get_config
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import init_ddp_state, make_ddp_train_step
    cfg = get_config("mamba2-130m", smoke=True)
    mesh = jax.make_mesh((8,), ("data",))
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.ones((8, 32), jnp.int32)}
    opt = OptConfig(lr=1e-2, warmup_steps=1)
    s_c = init_ddp_state(cfg, jax.random.PRNGKey(0))
    s_u = jax.tree.map(lambda x: x, s_c)
    step_c = jax.jit(make_ddp_train_step(cfg, opt, mesh, compress=True))
    step_u = jax.jit(make_ddp_train_step(cfg, opt, mesh, compress=False))
    with jax.set_mesh(mesh):
        losses_c, losses_u = [], []
        for _ in range(6):
            s_c, m_c = step_c(s_c, batch)
            s_u, m_u = step_u(s_u, batch)
            losses_c.append(float(m_c["loss"]))
            losses_u.append(float(m_u["loss"]))
    # both learn the constant batch; compression must not break descent
    assert losses_c[-1] < losses_c[0]
    assert losses_u[-1] < losses_u[0]
    assert abs(losses_c[-1] - losses_u[-1]) < 0.5 * abs(losses_u[0])


@multi
def test_checkpoint_elastic_restore(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,4) and (8,1): elastic."""
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "step": jnp.int32(7)}
    tree = jax.device_put(tree, {
        "w": NamedSharding(mesh_a, P("data", "model")),
        "step": NamedSharding(mesh_a, P())})
    mgr.save(100, tree)
    assert mgr.latest_step() == 100
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh_b = {"w": NamedSharding(mesh_b, P("data", "model")),
            "step": NamedSharding(mesh_b, P())}
    restored = mgr.restore(100, target, sh_b)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64).reshape(8, 8))
    assert int(restored["step"]) == 7


@multi
def test_checkpoint_async_and_gc(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((4, 4))}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    assert mgr.latest_step() == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]          # gc kept last 2
    restored = mgr.restore(4, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)


@multi
def test_compressed_psum_accuracy():
    from repro.train.grad_compress import compressed_psum_mean
    from jax import shard_map
    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096), jnp.float32)

    def body(xl):
        m, err = compressed_psum_mean(xl[0], "data")
        return m[None], err[None]

    mean_c, err = shard_map(body, mesh=mesh, in_specs=P("data", None),
                            out_specs=P("data", None))(x)
    want = jnp.mean(x, axis=0)
    got = np.asarray(mean_c[0])
    # int8 block quantization: ~1% of the per-block dynamic range
    scale = float(jnp.max(jnp.abs(x))) / 127
    assert np.max(np.abs(got - np.asarray(want))) < 8 * scale
