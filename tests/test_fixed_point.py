"""Fixed-point (numerics="fixed") datapath tests + the CORDIC 180-degree
boundary bugfix pin (DESIGN.md §12).

Covers: integer CORDIC bins vs the arctan2 oracle over a dense angle
sweep (exact bin edges, on-axis and zero-gradient inputs included), the
quantize/dequantize round-trip bound, int16 histogram overflow headroom
at the paper window and at UHD slab sizes, per-backend (ref|kernel|fused)
agreement for the whole fixed chain under the Pallas interpreter, the
int8 scoring matmul vs a numpy int32 oracle, and mode-dispatch hygiene
(unknown modes raise everywhere -- the PR 6 "identity trap" guard).
"""
import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import numerics as N, quant
from repro.core.cordic import cordic_mag_angle, cordic_mag_bin_fixed
from repro.core.hog import (HOGConfig, PAPER_HOG, cell_histograms,
                            mag_bin_cordic, mag_bin_fixed, mag_bin_ref)
from repro.core.stages import dense_blocks, window_blocks
from repro.core.detector import score_blocks
from repro.kernels.hog_gradient import (_mag_bin_fixed as kernel_mag_bin_fixed,
                                        mag_bin_impl)
from repro.kernels.svm_matmul import score_matmul_int8

RNG = np.random.default_rng(1234)

FIXED = HOGConfig(mode="cordic", numerics="fixed")


def _int_windows(b, h=130, w=66):
    """Integer-valued gray, the fixed chain's contract (stages rint
    gray before any kernel sees it)."""
    return jnp.asarray(RNG.integers(0, 256, size=(b, h, w))
                       .astype(np.float32))


# ------------------------------------------------ CORDIC golden sweep
# Satellite bugfix: cordic_mag_angle returns signed (-180, 180] angles
# while the chain bins unsigned [0, 180). For fy == 0 the iteration's
# +-atan(2^-14) residual used to flip mod(180 + eps, 180) to bin 8
# where arctan2 says bin 0. The sweep pins every implementation against
# the oracle, with exact bin-edge and zero-gradient inputs included.

def _dense_gradient_sweep():
    """Integer (fx, fy) pairs covering a dense angle sweep at several
    radii, plus exact bin-edge constructions, the axes, and zero."""
    pts = []
    for r in (3.0, 17.0, 100.0, 254.0):
        for t in np.linspace(0.0, 360.0, 721, endpoint=False):
            pts.append((round(r * math.cos(math.radians(t))),
                        round(r * math.sin(math.radians(t)))))
    # exact unsigned-bin edges: tan(20k deg) hits integer ratios only
    # approximately; include near-edge integer pairs on both sides
    for k in range(1, 9):
        t = math.radians(20.0 * k)
        for r in (50, 200):
            x = round(r * math.cos(t))
            for dy in (-1, 0, 1):
                pts.append((x, round(r * math.sin(t)) + dy))
    # the axes (the bugfix case) and zero gradient
    for v in (1, 2, 7, 255, 510):
        pts += [(v, 0), (-v, 0), (0, v), (0, -v)]
    pts.append((0, 0))
    arr = np.array(sorted(set(pts)), np.float32)
    return jnp.asarray(arr[:, 0]), jnp.asarray(arr[:, 1])


def _edge_tolerant_bin_match(b_test, b_oracle, fx, fy, max_edge_frac=0.02):
    """Bins must match except for inputs within float rounding of a
    20-degree boundary, where adjacent bins are acceptable (and rare)."""
    b_test, b_oracle = np.asarray(b_test), np.asarray(b_oracle)
    theta = np.degrees(np.arctan2(np.asarray(fy), np.asarray(fx))) % 180.0
    edge_dist = np.abs((theta + 10.0) % 20.0 - 10.0)
    mism = b_test != b_oracle
    # every mismatch sits on a bin edge and is off by exactly one bin
    # (mod 9: bins 0 and 8 are adjacent across the 0/180 seam)
    if mism.any():
        assert (edge_dist[mism] < 0.05).all(), \
            np.asarray(fx)[mism & (edge_dist >= 0.05)][:10]
        d = (b_test[mism] - b_oracle[mism]) % 9
        assert np.isin(d, (1, 8)).all()
    assert mism.mean() <= max_edge_frac


def test_cordic_float_bins_match_oracle_sweep():
    fx, fy = _dense_gradient_sweep()
    mag_c, b_c = mag_bin_cordic(fx, fy)
    mag_r, b_r = mag_bin_ref(fx, fy)
    _edge_tolerant_bin_match(b_c, b_r, fx, fy)
    np.testing.assert_allclose(mag_c, mag_r, rtol=1e-4, atol=1e-3)


def test_cordic_fixed_bins_match_oracle_sweep():
    fx, fy = _dense_gradient_sweep()
    mag_q, b_f = mag_bin_fixed(fx, fy)
    mag_r, b_r = mag_bin_ref(fx, fy)
    _edge_tolerant_bin_match(b_f, b_r, fx, fy)
    # mag_q holds half-gray units, rounded: |2*mag_q - mag| <= 1 + CORDIC err
    np.testing.assert_allclose(2.0 * np.asarray(mag_q), mag_r,
                               rtol=1e-3, atol=1.1)


def test_cordic_on_axis_pin():
    """fy == 0 must bin to 0 (angle exactly 0 or 180 folds to 0), never
    to 8 -- in the float CORDIC, the integer CORDIC, and the kernels."""
    fx = jnp.asarray([1., -1., 7., -7., 255., -255., 510., -510.])
    fy = jnp.zeros_like(fx)
    for impl in (mag_bin_cordic, mag_bin_fixed,
                 mag_bin_impl("cordic"), mag_bin_impl("fixed")):
        _, b = impl(fx, fy)
        assert int(jnp.sum(b != 0)) == 0, impl

    # zero gradient: mag 0, bin 0
    zero = jnp.zeros((4,), jnp.float32)
    for impl in (mag_bin_cordic, mag_bin_fixed):
        m, b = impl(zero, zero)
        assert int(jnp.sum(b != 0)) == 0 and float(jnp.sum(jnp.abs(m))) == 0

    # signed-angle contract unchanged: cordic_mag_angle still returns
    # exactly 0 / +-180 on the axis (the pin, not a new fold)
    mag, ang = cordic_mag_angle(fx, fy)
    np.testing.assert_allclose(np.abs(ang) % 180.0, 0.0, atol=0)
    np.testing.assert_allclose(mag, np.abs(np.asarray(fx)), rtol=1e-4)


def test_fixed_core_and_kernel_impls_bit_identical():
    fx, fy = _dense_gradient_sweep()
    m_core, b_core = cordic_mag_bin_fixed(fx, fy)
    m_kern, b_kern = kernel_mag_bin_fixed(fx, fy)
    assert jnp.array_equal(m_core, m_kern)
    assert jnp.array_equal(b_core, b_kern)


# ------------------------------------------------- quantizer properties

def _roundtrip_bound(v):
    q, scale = quant.quantize_blocks(v)
    back = quant.dequantize_blocks(q, scale)
    # per-block bound: |back - v| <= scale/2 (rint) with scale = max/127
    err = np.abs(np.asarray(back) - np.asarray(v))
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-7
    assert (err <= bound).all()
    assert np.abs(np.asarray(q)).max() <= 127


def test_quantize_roundtrip_bound_seeded():
    _roundtrip_bound(jnp.asarray(RNG.random((50, 36)).astype(np.float32)))
    _roundtrip_bound(jnp.asarray(
        RNG.normal(0, 3.0, (20, 7, 36)).astype(np.float32)))
    # zero blocks: scale 0, exact zeros back
    z = jnp.zeros((3, 36))
    q, s = quant.quantize_blocks(z)
    assert float(jnp.sum(jnp.abs(quant.dequantize_blocks(q, s)))) == 0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 16), scale=st.floats(1e-3, 1e3), seed=st.integers(0, 99))
def test_quantize_roundtrip_bound_property(n, scale, seed):
    r = np.random.default_rng(seed)
    _roundtrip_bound(jnp.asarray((r.random((n, 36)) * scale)
                                 .astype(np.float32)))


def test_quantize_code_recovery_exact():
    """Requantizing a dequantized grid recovers the int8 codes EXACTLY
    -- the property score_blocks relies on to requantize the public f32
    block grid instead of threading (q, scale) through every seam."""
    v = jnp.asarray(RNG.random((200, 36)).astype(np.float32))
    q, s = quant.quantize_blocks(v)
    back = quant.dequantize_blocks(q, s)
    q2, s2 = quant.quantize_blocks(back)
    assert jnp.array_equal(q, q2)
    np.testing.assert_allclose(s2, s, rtol=1e-6)


# --------------------------------------------- int16 histogram headroom

def test_int16_hist_never_overflows_worst_case():
    """Worst representable cell: every pixel at the max quantized
    magnitude. 8-bit gray bounds |fx|,|fy| <= 255, so mag_q <= 181
    half-units; even the loose |fx|,|fy| <= 510 bound gives 361 and
    64 * 361 = 23104 < 2^15. The bound is PER CELL, so slab and frame
    size never enter."""
    worst = int(jnp.rint(jnp.sqrt(510.0 ** 2 + 510.0 ** 2) / 2))
    assert worst == 361 and 64 * worst < 2 ** 15
    mag = jnp.full((1, 128, 64), worst, jnp.int32)
    bins = jnp.zeros((1, 128, 64), jnp.int32)
    hist = cell_histograms(mag, bins, PAPER_HOG)
    assert hist.dtype == jnp.int16
    assert int(hist[..., 0].min()) == 64 * worst  # no wraparound


def test_int16_hist_exact_at_uhd_slab():
    """Max-contrast checkerboard through the dense fixed chain at a UHD
    slab width: kernel int16 histograms equal the ref integer sums
    exactly (any overflow would wrap and break equality)."""
    from repro.kernels.dense_grad_hist import dense_grad_hist
    h, w = 130, 3842                       # one UHD-width slab + border
    yy, xx = np.mgrid[0:h, 0:w]
    gray = jnp.asarray((((yy // 2 + xx // 2) % 2) * 255).astype(np.float32))
    hist_k = dense_grad_hist(gray[None], mode="fixed")
    assert hist_k.dtype == jnp.int16
    geom = dataclasses.replace(FIXED, window_h=h, window_w=w)
    from repro.core.hog import gradients, _MAG_BIN_FAST
    fx, fy = gradients(gray[None])
    gw = (w - 2) // 8 * 8
    m, b = _MAG_BIN_FAST["fixed"](fx[..., :gw], fy[..., :gw], 9)
    hist_r = cell_histograms(m, b, dataclasses.replace(geom, window_w=gw + 2))
    assert jnp.array_equal(hist_k, hist_r.astype(jnp.int16))
    assert int(hist_r.max()) < 2 ** 15     # genuine headroom, not luck


# ------------------------------------- whole chain, per backend/layout

def _assert_fixed_close(k, r):
    """Backends agree up to ONE int8 code step per element. The f32
    sum-of-squares before the quantizer rounds differently per
    compilation context (v^2 reaches ~5e8 > 2^24), so a value sitting
    exactly on a rint boundary may flip by one code -- the same
    cross-backend property the float modes have, expressed on the code
    grid. Flips must be rare and never exceed one step."""
    k, r = np.asarray(k), np.asarray(r)
    step = np.abs(r).max(-1, keepdims=True) * np.float32(1 / 127)
    diff = np.abs(k - r)
    assert (diff <= step + 1e-6).all()
    assert (diff > 1e-6).mean() < 1e-3    # boundary flips are rare


@pytest.mark.parametrize("backend", ["kernel", "fused"])
def test_fixed_chain_window_backends_allclose(backend):
    win = _int_windows(3)
    r = window_blocks(win, FIXED, backend="ref")
    k = window_blocks(win, FIXED, backend=backend)
    assert r.dtype == k.dtype == jnp.float32
    _assert_fixed_close(k, r)


@pytest.mark.parametrize("backend", ["kernel", "fused"])
def test_fixed_chain_dense_backends_allclose(backend):
    scene = _int_windows(1, 240, 320)[0]
    r = dense_blocks(scene, FIXED, backend="ref")
    k = dense_blocks(scene, FIXED, backend=backend)
    _assert_fixed_close(k, r)


@pytest.mark.parametrize("backend", ["ref", "kernel", "fused"])
def test_fixed_chain_output_is_on_int8_grid(backend):
    """Every backend's fixed output must BE quantized: each block vector
    scaled to code range must hit integers. A backend that silently fell
    back to the fp32 normalize tail (the identity-trap class this PR's
    shared dispatch kills) fails this immediately."""
    win = _int_windows(2)
    out = np.asarray(window_blocks(win, FIXED, backend=backend))
    v = out.reshape(-1, 36)
    m = np.abs(v).max(axis=-1, keepdims=True)
    codes = v * (127.0 / np.where(m > 0, m, 1.0))
    assert np.abs(codes - np.rint(codes)).max() < 1e-3


def test_fixed_differs_from_float_but_close():
    """fixed is a real datapath change (quantization must show up) yet
    descriptor-level close to the float chain."""
    win = _int_windows(2)
    f32 = window_blocks(win, dataclasses.replace(FIXED, numerics="float"),
                        backend="ref")
    fxd = window_blocks(win, FIXED, backend="ref")
    diff = float(jnp.abs(f32 - fxd).max())
    assert 0 < diff < 0.02                 # ~ max block scale / 2


# ----------------------------------------------------- int8 scoring

def test_score_matmul_int8_matches_numpy_oracle():
    q = jnp.asarray(RNG.integers(-127, 128, size=(100, 36), dtype=np.int8))
    wq = jnp.asarray(RNG.integers(-127, 128, size=(36, 105), dtype=np.int8))
    out = score_matmul_int8(q, wq)
    oracle = np.asarray(q, np.int32) @ np.asarray(wq, np.int32)
    assert out.dtype == jnp.int32
    assert np.array_equal(np.asarray(out), oracle)


def test_score_matmul_int8_blocking_invariant():
    """Exact int32 accumulation: every M blocking gives identical bytes
    (the property that makes fixed-mode scoring shard-invariant)."""
    q = jnp.asarray(RNG.integers(-127, 128, size=(300, 36), dtype=np.int8))
    wq = jnp.asarray(RNG.integers(-127, 128, size=(36, 105), dtype=np.int8))
    full = score_matmul_int8(q, wq)
    for bm in (32, 64, 128):
        assert jnp.array_equal(score_matmul_int8(q, wq, block_m=bm), full)


def test_score_blocks_fixed_kernel_vs_xla_identical():
    """The int8 path's Pallas kernel and lax.dot_general forms agree to
    the byte (integer matmul + identical elementwise rescale)."""
    scene = _int_windows(1, 200, 150)[0]
    blocks = dense_blocks(scene, FIXED, backend="ref")
    w = jnp.asarray(RNG.normal(0, 0.05, size=3780).astype(np.float32))
    b = jnp.float32(-0.2)
    s_xla = score_blocks(blocks, w, b, FIXED, use_kernel=False)
    s_pal = score_blocks(blocks, w, b, FIXED, use_kernel=True)
    assert jnp.array_equal(s_xla, s_pal)
    assert bool(jnp.all(jnp.isfinite(s_xla)))


def test_quant_preset_detector_smoke():
    from repro.api.config import presets
    from repro.core.detector import FrameDetector
    cfg = presets("quant")
    assert cfg.hog.numerics == "fixed"
    svm = {"w": jnp.asarray(RNG.normal(0, .05, 3780).astype(np.float32)),
           "b": jnp.float32(-0.1)}
    det = FrameDetector(svm, cfg.detector)
    frame = RNG.integers(0, 256, (160, 120, 3)).astype(np.uint8)
    dets = det(frame)
    assert isinstance(dets, list)
    # round-trips through JSON like every preset
    from repro.api.config import PipelineConfig
    assert PipelineConfig.from_json(cfg.to_json()) == cfg


# ------------------------------------------------- dispatch hygiene

def test_unknown_modes_raise_everywhere():
    with pytest.raises(ValueError, match="numerics"):
        HOGConfig(numerics="int4")
    with pytest.raises(ValueError, match="feat_dtype"):
        HOGConfig(numerics="fixed", feat_dtype="bf16")
    with pytest.raises(ValueError, match="unknown"):
        N.spec_for(dataclasses.replace(PAPER_HOG, mode="bogus"))
    with pytest.raises(ValueError, match="unknown"):
        mag_bin_impl("bogus")
    with pytest.raises(ValueError, match="unknown"):
        N.finish_blocks(jnp.ones((2, 36)), 1e-2, "bogus")


def test_spec_table_is_single_source():
    """numerics="fixed" overrides cfg.mode; float modes map to their
    historical kernel/norm choices."""
    assert N.spec_for(FIXED).name == "fixed"
    assert N.spec_for(FIXED).quantized
    assert N.spec_for(HOGConfig(mode="cordic")).norm == "nr"
    assert N.spec_for(HOGConfig(mode="sector")).norm == "rsqrt"
    assert N.spec_for(HOGConfig(mode="ref")).kernel_mode == "sector"
    for spec in N.SPECS.values():
        assert spec.norm in N.NORM_RSQRT
