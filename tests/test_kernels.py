"""Per-kernel allclose vs the ref.py oracles + hypothesis shape/dtype sweeps.

Kernels run in Pallas interpret mode on CPU (the TPU BlockSpec pipeline is
executed in Python), oracles are the pure-jnp core pipeline.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.hog_gradient import hog_gradient
from repro.kernels.cell_hist import cell_hist
from repro.kernels.block_norm import block_norm
from repro.kernels.svm_matmul import svm_scores
from repro.kernels.fused_hog import fused_hog
from repro.core.hog import PAPER_HOG

RNG = np.random.default_rng(1234)


def _windows(b, h=130, w=66):
    return jnp.asarray(RNG.integers(0, 256, size=(b, h, w)).astype(np.float32))


# ---------------------------------------------------------------- gradient
@pytest.mark.parametrize("mode", ["sector", "cordic"])
def test_hog_gradient_matches_ref(mode):
    g = _windows(4)
    mag_k, bin_k = hog_gradient(g, mode=mode)
    mag_r, bin_r = ref.hog_gradient_ref(g, mode=mode)
    np.testing.assert_allclose(mag_k, mag_r, rtol=1e-5, atol=1e-4)
    assert int(jnp.sum(bin_k != bin_r)) == 0


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 6), h=st.integers(12, 80), w=st.integers(12, 80))
def test_hog_gradient_shape_sweep(b, h, w):
    g = _windows(b, h, w)
    mag_k, bin_k = hog_gradient(g, mode="sector", block_b=4)
    mag_r, bin_r = ref.hog_gradient_ref(g, mode="sector")
    np.testing.assert_allclose(mag_k, mag_r, rtol=1e-5, atol=1e-4)
    assert int(jnp.sum(bin_k != bin_r)) == 0
    assert int(jnp.min(bin_k)) >= 0 and int(jnp.max(bin_k)) <= 8


# --------------------------------------------------------------- histogram
def test_cell_hist_matches_ref():
    g = _windows(4)
    mag, b = ref.hog_gradient_ref(g, mode="sector")
    hk = cell_hist(mag, b)
    hr = ref.cell_hist_ref(mag, b)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-4)


def test_cell_hist_conserves_magnitude():
    """Histogram sum == total magnitude (hard binning conserves mass)."""
    g = _windows(3)
    mag, b = ref.hog_gradient_ref(g, mode="sector")
    hk = cell_hist(mag, b)
    np.testing.assert_allclose(jnp.sum(hk, axis=(1, 2, 3)),
                               jnp.sum(mag, axis=(1, 2)), rtol=1e-5)


@settings(max_examples=6, deadline=None)
@given(b=st.integers(1, 4), ch=st.integers(2, 6), cw=st.integers(2, 6))
def test_cell_hist_shape_sweep(b, ch, cw):
    mag = jnp.asarray(RNG.random((b, ch * 8, cw * 8)).astype(np.float32))
    bi = jnp.asarray(RNG.integers(0, 9, size=(b, ch * 8, cw * 8)).astype(np.int32))
    hk = cell_hist(mag, bi, block_b=2)
    hr = ref.cell_hist_ref(mag, bi)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-4)


# -------------------------------------------------------------- block norm
@pytest.mark.parametrize("mode", ["rsqrt", "nr"])
def test_block_norm_matches_ref(mode):
    hist = jnp.asarray(RNG.random((4, 16, 8, 9)).astype(np.float32) * 40)
    bk = block_norm(hist, mode=mode)
    br = ref.block_norm_ref(hist, mode=mode)
    np.testing.assert_allclose(bk, br, rtol=1e-4, atol=1e-5)


def test_block_norm_unit_energy():
    """Normalized blocks have ||v|| <= 1 (eq. 5 bounds the energy)."""
    hist = jnp.asarray(RNG.random((2, 16, 8, 9)).astype(np.float32) * 100)
    bk = block_norm(hist)
    norms = jnp.sqrt(jnp.sum(bk * bk, axis=-1))
    assert float(jnp.max(norms)) <= 1.0 + 1e-5


# --------------------------------------------------------------------- svm
@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 20), f=st.sampled_from([37, 128, 1000, 3780]))
def test_svm_scores_sweep(b, f):
    x = jnp.asarray(RNG.normal(size=(b, f)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=f).astype(np.float32))
    bias = jnp.float32(RNG.normal())
    sk = svm_scores(x, w, bias)
    sr = ref.svm_scores_ref(x, w, bias)
    np.testing.assert_allclose(sk, sr, rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------------- fused
@pytest.mark.parametrize("mode", ["sector", "cordic"])
def test_fused_hog_matches_ref(mode):
    g = _windows(4)
    dk = fused_hog(g, mode=mode)
    dr = ref.fused_hog_ref(g, mode=mode)
    np.testing.assert_allclose(dk, dr, rtol=1e-4, atol=1e-4)


def test_fused_matches_staged_pipeline():
    win = jnp.asarray(RNG.integers(0, 256, size=(6, 130, 66, 3)).astype(np.uint8))
    np.testing.assert_allclose(ops.hog_descriptor_fused(win),
                               ops.hog_descriptor_kernel(win),
                               rtol=1e-5, atol=1e-5)


def test_kernel_path_matches_ref_path():
    """End-to-end: kernel path == software path (the ModelSim-vs-Matlab
    equivalence check from the paper, on TPU kernels)."""
    from repro.core.pipeline import classify_windows
    from repro.core.svm import init_svm
    win = jnp.asarray(RNG.integers(0, 256, size=(6, 130, 66, 3)).astype(np.uint8))
    w = jnp.asarray(RNG.normal(size=3780).astype(np.float32) * 0.02)
    params = {"w": w, "b": jnp.float32(0.1)}
    out_ref = classify_windows(params, win, path="ref")
    out_k = classify_windows(params, win, path="kernel")
    out_f = classify_windows(params, win, path="fused")
    np.testing.assert_allclose(out_ref["score"], out_k["score"], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(out_ref["score"], out_f["score"], rtol=1e-3, atol=1e-3)
    assert (np.asarray(out_ref["human"]) == np.asarray(out_k["human"])).all()
    assert (np.asarray(out_ref["human"]) == np.asarray(out_f["human"])).all()
