"""Batched frame path + video tracking layer.

detect_batch must be box-for-box identical to per-frame detect() for
every numerics mode and every batch layout (scan / chunked / wide
vmap), compile once per (bucket, B) pair, and the tracker must hold
stable ids on constant-velocity motion -- the workload make_clip
generates.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.detector import (DetectorConfig, FrameDetector, _batch_fn,
                                 _round_up)
from repro.core.hog import PAPER_HOG
from repro.core.video import (Tracker, TrackerConfig, VideoDetector, iou_np)
from repro.data.synth_pedestrian import ClipConfig, make_clip

RNG = np.random.default_rng(7)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}


def _frames(n, h=160, w=128):
    return [RNG.integers(0, 256, (h, w, 3)).astype(np.uint8)
            for _ in range(n)]


def _assert_same(per_frame, batched):
    assert len(per_frame) == len(batched)
    for seq, bat in zip(per_frame, batched):
        assert [d["box"] for d in seq] == [d["box"] for d in bat]
        assert [d["scale"] for d in seq] == [d["scale"] for d in bat]
        np.testing.assert_allclose([d["score"] for d in seq],
                                   [d["score"] for d in bat],
                                   rtol=0, atol=1e-5)


# ----------------------------------------------- batched == sequential

@pytest.mark.parametrize("mode", ["ref", "cordic", "sector"])
def test_detect_batch_matches_sequential_per_mode(mode):
    cfg = DetectorConfig(hog=dataclasses.replace(PAPER_HOG, mode=mode),
                         score_threshold=-10.0, scales=(1.0, 0.8))
    det = FrameDetector(SVM, cfg)
    frames = _frames(4)
    _assert_same([det(f) for f in frames], det.detect_batch(frames))


@pytest.mark.parametrize("chunk", [2, 8])
def test_detect_batch_chunk_layouts_agree(chunk):
    """Scanned (chunk 1), chunked, and wide-vmap (chunk >= B) batch
    programs are the same numerics, just different schedules."""
    frames = _frames(4)
    base = FrameDetector(SVM, DetectorConfig(score_threshold=-10.0,
                                             scales=(1.0,)))
    alt = FrameDetector(SVM, DetectorConfig(score_threshold=-10.0,
                                            scales=(1.0,),
                                            batch_chunk=chunk))
    _assert_same(base.detect_batch(frames), alt.detect_batch(frames))


def test_detect_batch_mixed_true_sizes_share_bucket():
    """Frames of different true sizes that pad to one bucket batch
    together; each frame's out-of-frame mask stays its own."""
    det = FrameDetector(SVM, DetectorConfig(score_threshold=-10.0,
                                            scales=(1.0,)))
    frames = [RNG.integers(0, 256, (150, 100, 3)).astype(np.uint8),
              RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)]
    _assert_same([det(f) for f in frames], det.detect_batch(frames))
    for dets, (h, w) in zip(det.detect_batch(frames),
                            [(150, 100), (160, 128)]):
        for d in dets:
            assert d["box"][2] <= h + 1e-3 and d["box"][3] <= w + 1e-3


def test_detect_batch_mixed_buckets_raise():
    det = FrameDetector(SVM, DetectorConfig(scales=(1.0,)))
    with pytest.raises(ValueError, match="bucket"):
        det.detect_batch([np.zeros((160, 128, 3), np.uint8),
                          np.zeros((224, 160, 3), np.uint8)])


def test_detect_batch_edge_cases():
    det = FrameDetector(SVM, DetectorConfig(scales=(1.0,)))
    assert det.detect_batch([]) == []
    # frames smaller than one window -> one empty list per frame
    assert det.detect_batch([np.zeros((64, 64, 3), np.uint8)] * 3) == \
        [[], [], []]
    with pytest.raises(ValueError, match="frame"):
        det.detect_batch([np.zeros((5,), np.uint8)])
    # a bare RGB frame must be rejected, not parsed as H gray frames
    with pytest.raises(ValueError, match="single RGB frame"):
        det.detect_batch(np.zeros((160, 128, 3), np.uint8))


def test_detect_batch_compiles_once_per_bucket_batch_pair():
    # explicit schedule: batch_chunk=0 (the default) would resolve via
    # the autotune probe first, so the cache key under test would be the
    # resolved config, not this one
    cfg = DetectorConfig(score_threshold=-10.0, scales=(1.0,),
                         batch_chunk=1)
    det = FrameDetector(SVM, cfg)
    frames = _frames(3)
    r1 = det.detect_batch(frames)
    r2 = det.detect_batch(_frames(3))
    assert r1 and len(r2) == 3
    # donate must be passed the way detect_batch_raw passes it
    # (positionally): lru_cache keys f(x) and f(x, default) differently
    from repro.core.detector import _donate
    fn = _batch_fn(160, 128, _round_up(160, cfg.shape_bucket),
                   _round_up(128, cfg.shape_bucket), 3, cfg, _donate())
    assert fn._cache_size() == 1          # one trace, two batches
    # stacked-array input reuses the same program
    det.detect_batch(np.stack(_frames(3)))
    assert fn._cache_size() == 1


# ------------------------------------------------------------- tracking

def _truth_dets(truths, jitter_rng=None, drop=()):
    """Turn make_clip truth boxes into detector-style detections."""
    out = []
    for t, boxes in enumerate(truths):
        dets = []
        for g in boxes:
            if (t, g["id"]) in drop:
                continue
            box = np.asarray(g["box"], np.float64)
            if jitter_rng is not None:
                box += jitter_rng.normal(0, 1.0, 4)
            dets.append({"box": tuple(box), "score": 1.0, "scale": 1.0})
        out.append(dets)
    return out


def test_tracker_ids_stable_on_constant_velocity_clip():
    rng = np.random.default_rng(11)
    _, truths = make_clip(rng, ClipConfig(n_frames=12, n_people=2,
                                          h=320, w=480, speed=5.0))
    trk = Tracker(TrackerConfig())
    ids_per_person = {}
    for dets, gt in zip(_truth_dets(truths, np.random.default_rng(1)),
                        truths):
        out = trk.update(dets)
        assert len(out) == 2
        for d in out:
            # match the reported box back to the closest truth
            ious = [iou_np(np.asarray(d["box"]),
                           np.asarray(g["box"]))[0, 0] for g in gt]
            pid = gt[int(np.argmax(ious))]["id"]
            ids_per_person.setdefault(pid, set()).add(d["track_id"])
    assert all(len(v) == 1 for v in ids_per_person.values()), ids_per_person
    assert ids_per_person[0] != ids_per_person[1]


def test_tracker_coasts_through_missed_detection_and_keeps_id():
    rng = np.random.default_rng(12)
    _, truths = make_clip(rng, ClipConfig(n_frames=8, n_people=1,
                                          h=300, w=400, speed=5.0))
    trk = Tracker(TrackerConfig(max_misses=2))
    seen = set()
    for t, dets in enumerate(_truth_dets(truths, drop={(3, 0)})):
        for d in trk.update(dets):
            seen.add(d["track_id"])
    assert len(seen) == 1, seen          # id survived the dropped frame


def test_tracker_smooths_scores():
    trk = Tracker(TrackerConfig(score_alpha=0.5))
    trk.update([{"box": (0, 0, 130, 66), "score": 4.0}])
    out = trk.update([{"box": (1, 1, 131, 67), "score": 0.0}])
    assert abs(out[0]["score"] - 2.0) < 1e-9


def test_video_detector_process_clip_end_to_end():
    """Batched device path + tracker on a real clip: same per-frame
    structure as step(), ids present, batch chunks invisible."""
    rng = np.random.default_rng(13)
    clip, _ = make_clip(rng, ClipConfig(n_frames=5, n_people=1,
                                        h=160, w=128, frame_noise=4.0))
    vid = VideoDetector(SVM, DetectorConfig(score_threshold=-10.0,
                                            scales=(1.0,)))
    tracked = vid.process_clip(list(clip), batch_size=3)
    assert len(tracked) == 5
    for dets in tracked:
        assert dets, "threshold -10 must fire on every frame"
        for d in dets:
            assert {"box", "score", "scale", "track_id",
                    "hits", "misses"} <= set(d)
    # sequential step() on a fresh tracker sees identical detections,
    # so it must produce identical ids
    vid2 = VideoDetector(SVM, DetectorConfig(score_threshold=-10.0,
                                             scales=(1.0,)))
    stepped = [vid2.step(f) for f in clip]
    for a, b in zip(tracked, stepped):
        assert [d["track_id"] for d in a] == [d["track_id"] for d in b]
        assert [d["box"] for d in a] == [d["box"] for d in b]
