"""Optimizer + schedule + checkpoint (single-device parts) + property tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.train.optimizer import (OptConfig, adamw_update, global_norm,
                                   init_opt_state, schedule)


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(0.5)}


def test_adamw_minimizes_quadratic():
    params = _quad_params()
    state = init_opt_state(params)
    c = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                  weight_decay=0.0, clip_norm=1e9)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, c)
    assert float(loss(params)) < 1e-2


def test_warmup_cosine_schedule():
    c = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(schedule(jnp.int32(0), c)) == pytest.approx(0.1, abs=1e-6)
    assert float(schedule(jnp.int32(9), c)) == pytest.approx(1.0, abs=1e-6)
    # end of schedule decays to min_lr_frac
    assert float(schedule(jnp.int32(109), c)) == pytest.approx(0.1, rel=1e-2)


def test_clip_caps_update_norm():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    c = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=1, weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, state2, metrics = adamw_update(g, state, c)
    assert float(metrics["grad_norm"]) > 1e5
    # clipped: first moment bounded by (1-b1)*clip-scaled grad
    assert float(jnp.max(jnp.abs(state2["m"]["w"]))) < 1.0


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3))
def test_global_norm_homogeneous(scale):
    t = {"a": jnp.asarray([1.0, 2.0]), "b": jnp.asarray([[2.0]])}
    n1 = float(global_norm(t))
    n2 = float(global_norm(jax.tree.map(lambda x: x * scale, t)))
    assert n2 == pytest.approx(n1 * scale, rel=1e-4)


def test_checkpoint_roundtrip_single_device(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.int32(3)}
    mgr.save(7, tree)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    out = mgr.restore(7, target)
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert int(out["step"]) == 3


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"w": jnp.ones(3)})
    entries = os.listdir(tmp_path)
    assert "step_00000001" in entries
    assert not any(e.endswith(".tmp") for e in entries)


def test_quantize_roundtrip_property():
    from repro.train.grad_compress import _dequantize, _quantize
    x = jnp.asarray(np.random.default_rng(0).normal(size=5000)
                    .astype(np.float32))
    q, s = _quantize(x)
    err = np.asarray(x - _dequantize(q, s, x.shape[0]))
    blk_scale = np.asarray(s).max()
    assert np.max(np.abs(err)) <= blk_scale * 0.51  # half-ULP of int8 grid
