"""Golden-reference regression tests.

The seed suite cross-validated backends against EACH OTHER, which lets
all of them drift together silently. This module pins the numerics to
fixtures checked into the repo (tests/golden/hog_golden.npz): HOG
descriptors + SVM scores for three fixed-seed windows, computed by the
INDEPENDENT pure-numpy reference below (float64, no jax anywhere in the
reference path).

Two layers of protection:
  * the numpy reference must reproduce the committed fixtures almost
    bit-exactly -- catches accidental fixture or reference edits;
  * every stage backend (ref | kernel | fused) must reproduce the
    fixtures within its per-backend tolerance -- catches numerics drift
    in the jax/Pallas pipeline, which the backend-vs-backend tests
    cannot see.

Regenerate (only when the numerics are INTENTIONALLY changed -- say so
in the PR):  PYTHONPATH=src python tests/test_golden_reference.py --regen
"""
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hog import PAPER_HOG
from repro.core.pipeline import classify_windows
from repro.core.stages import window_descriptor

GOLDEN = pathlib.Path(__file__).parent / "golden" / "hog_golden.npz"
SEEDS = (0, 1, 2)

# per-backend absolute tolerance on descriptor elements (all in [-1, 1])
# and on the SVM score. ref is the float32 twin of the float64 reference;
# the Pallas backends accumulate in different orders.
TOL = {"ref": 2e-5, "kernel": 5e-5, "fused": 5e-5}


# --------------------------------------------------- pure-numpy reference

def numpy_hog_descriptor(window_rgb: np.ndarray) -> np.ndarray:
    """The paper's HOG chain in plain float64 numpy: BT.601 grayscale,
    central differences, arctan2 hard binning (9 unsigned bins), 8x8
    cell histograms, 2x2-block L2 norm (eps=1e-2). Mirrors the `ref`
    mode contract of core/hog.py without importing any of it."""
    g = (0.2989 * window_rgb[..., 0].astype(np.float64)
         + 0.5870 * window_rgb[..., 1]
         + 0.1140 * window_rgb[..., 2])
    g = g[:130, :66]
    fx = g[1:-1, 2:] - g[1:-1, :-2]
    fy = g[2:, 1:-1] - g[:-2, 1:-1]
    mag = np.sqrt(fx * fx + fy * fy)
    theta = np.mod(np.degrees(np.arctan2(fy, fx)), 180.0)
    b = np.clip(np.floor(theta / 20.0), 0, 8).astype(np.int64)

    hist = np.zeros((16, 8, 9))
    for ci in range(16):
        for cj in range(8):
            cm = mag[ci * 8:(ci + 1) * 8, cj * 8:(cj + 1) * 8]
            cb = b[ci * 8:(ci + 1) * 8, cj * 8:(cj + 1) * 8]
            for k in range(9):
                hist[ci, cj, k] = cm[cb == k].sum()

    desc = np.zeros((15, 7, 36))
    for bi in range(15):
        for bj in range(7):
            # cell order must match hog.block_normalize: (0,0) (0,1)
            # (1,0) (1,1), 9 bins each
            v = np.concatenate([hist[bi + i, bj + j]
                                for i in range(2) for j in range(2)])
            desc[bi, bj] = v / np.sqrt(np.sum(v * v) + 1e-2 ** 2)
    return desc.reshape(-1)


def _fixture_inputs():
    windows = np.stack([
        np.random.default_rng(s).integers(0, 256, (130, 66, 3))
        .astype(np.uint8) for s in SEEDS])
    wrng = np.random.default_rng(1234)
    w = (wrng.normal(size=3780) * 0.02).astype(np.float64)
    b = 0.125
    return windows, w, b


def _generate():
    windows, w, b = _fixture_inputs()
    desc = np.stack([numpy_hog_descriptor(win) for win in windows])
    scores = desc @ w + b
    GOLDEN.parent.mkdir(exist_ok=True)
    np.savez_compressed(
        GOLDEN, windows=windows, descriptors=desc.astype(np.float32),
        svm_w=w.astype(np.float32), svm_b=np.float32(b),
        scores=scores.astype(np.float32))
    return desc, scores


# ------------------------------------------------------------------ tests

@pytest.fixture(scope="module")
def golden():
    assert GOLDEN.exists(), f"missing fixture {GOLDEN}; run --regen"
    return dict(np.load(GOLDEN))


def test_fixture_inputs_are_reproducible(golden):
    """The committed windows/weights come from the fixed seeds."""
    windows, w, b = _fixture_inputs()
    np.testing.assert_array_equal(golden["windows"], windows)
    np.testing.assert_allclose(golden["svm_w"], w, atol=1e-7)
    np.testing.assert_allclose(golden["svm_b"], b, atol=1e-7)


def test_numpy_reference_matches_fixture(golden):
    """The float64 reference regenerates the committed descriptors and
    scores -- the fixture and the reference pin each other."""
    desc = np.stack([numpy_hog_descriptor(w) for w in golden["windows"]])
    np.testing.assert_allclose(desc, golden["descriptors"],
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(
        desc @ golden["svm_w"].astype(np.float64) + float(golden["svm_b"]),
        golden["scores"], rtol=0, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "kernel", "fused"])
def test_backend_reproduces_golden_descriptors(golden, backend):
    got = np.asarray(window_descriptor(jnp.asarray(golden["windows"]),
                                       PAPER_HOG, backend))
    np.testing.assert_allclose(got, golden["descriptors"],
                               rtol=0, atol=TOL[backend],
                               err_msg=f"{backend} descriptor drifted "
                                       f"from the golden reference")


@pytest.mark.parametrize("backend", ["ref", "kernel", "fused"])
def test_backend_reproduces_golden_scores(golden, backend):
    svm = {"w": jnp.asarray(golden["svm_w"]),
           "b": jnp.asarray(golden["svm_b"])}
    out = classify_windows(svm, jnp.asarray(golden["windows"]),
                           PAPER_HOG, backend)
    np.testing.assert_allclose(np.asarray(out["score"]), golden["scores"],
                               rtol=0, atol=5e-4,
                               err_msg=f"{backend} SVM score drifted "
                                       f"from the golden reference")
    assert np.asarray(out["human"]).tolist() == \
        (golden["scores"] > 0).astype(int).tolist()


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        desc, scores = _generate()
        print(f"wrote {GOLDEN} (descriptors {desc.shape}, "
              f"scores {np.round(scores, 4)})")
    else:
        print(__doc__)
