"""Two-stage cascade: region-planner invariants + scheduler behavior.

The cascade's correctness story (core/cascade.py, DESIGN.md §13) rests
on one planner invariant -- every candidate box's dilated rect is
covered by the returned region union (bounding rects only grow under
merging; edges only snap outward) -- from which threshold MONOTONICITY
follows: loosening the coarse reject threshold only adds candidate
boxes, so any survivor neighbourhood at a tight threshold is still
covered at a looser one. These tests pin the invariant directly
(random box sets, random planner knobs, subset-vs-superset coverage)
and the scheduler seams around it: the empty-frame shortcut, the dense
fallback below `min_frame_area`, region-local boxes mapping back to
frame coordinates, tracker-ROI promotion past the coarse gate, and
end-to-end retention vs the full dense pass on a trained head.
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.cascade import (CascadeConfig, CascadeDetector,
                                coarse_detector, plan_regions)
from repro.core.detector import DetectorConfig, FrameDetector
from repro.core.hog import HOGConfig

SEED = 11


def _rand_boxes(rng, n, h, w):
    y0 = rng.uniform(0, h * 0.8, n)
    x0 = rng.uniform(0, w * 0.8, n)
    return np.stack([y0, x0, y0 + rng.uniform(10, h * 0.3, n),
                     x0 + rng.uniform(10, w * 0.3, n)], -1).astype(np.float32)


def _covered(rect, regions, tol=1e-5):
    """rect fully inside the union of regions? (regions are axis-
    aligned; the planner only merges, so containment in ONE region is
    the realized invariant -- check that, the stronger condition)."""
    y0, x0, y1, x1 = rect
    return any(ry0 <= y0 + tol and rx0 <= x0 + tol
               and y1 <= ry1 + tol and x1 <= rx1 + tol
               for ry0, rx0, ry1, rx1 in regions)


def _dilated(boxes, frame_hw, cfg):
    h, w = frame_hw
    m = float(cfg.margin)
    return np.stack([
        np.clip(boxes[:, 0] - m, 0, h), np.clip(boxes[:, 1] - m, 0, w),
        np.clip(boxes[:, 2] + m, 0, h), np.clip(boxes[:, 3] + m, 0, w),
    ], axis=1)


# ------------------------------------------------------ planner invariants

def check_planner(seed):
    rng = np.random.default_rng(seed)
    h, w = int(rng.integers(200, 800)), int(rng.integers(200, 800))
    cfg = CascadeConfig(margin=int(rng.integers(0, 48)),
                        snap=int(rng.choice([16, 32, 64])),
                        max_regions=int(rng.integers(1, 6)))
    boxes = _rand_boxes(rng, int(rng.integers(1, 20)), h, w)
    regions = plan_regions(boxes, (h, w), cfg)
    assert 1 <= len(regions) <= cfg.max_regions
    for y0, x0, y1, x1 in regions:
        assert 0 <= y0 < y1 <= h and 0 <= x0 < x1 <= w
        # snapped: every edge on the grid unless clamped by the frame
        assert y0 % cfg.snap == 0 and x0 % cfg.snap == 0
        assert y1 % cfg.snap == 0 or y1 == h
        assert x1 % cfg.snap == 0 or x1 == w
    # coverage invariant: every dilated candidate box sits inside a region
    for rect in _dilated(boxes, (h, w), cfg):
        assert _covered(rect, regions), (rect, regions)


def test_planner_invariants_seeded():
    for s in range(40):
        check_planner(SEED * 1000 + s)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=100_000))
def test_planner_invariants_hypothesis(seed):
    check_planner(seed)


def check_threshold_monotonicity(seed):
    """Loosening the reject threshold never loses a survivor: the
    candidate set at a TIGHT threshold is a subset of the set at a
    LOOSE one, and the loose plan still covers every tight candidate's
    dilated neighbourhood."""
    rng = np.random.default_rng(seed)
    h = w = 640
    cfg = CascadeConfig(margin=24, snap=32,
                        max_regions=int(rng.integers(1, 5)))
    boxes = _rand_boxes(rng, 16, h, w)
    scores = rng.uniform(-1.0, 1.0, len(boxes)).astype(np.float32)
    tight, loose = 0.4, -0.2
    tight_boxes = boxes[scores > tight]
    loose_boxes = boxes[scores > loose]
    assert set(map(tuple, tight_boxes)) <= set(map(tuple, loose_boxes))
    if len(tight_boxes) == 0:
        return
    loose_regions = plan_regions(loose_boxes, (h, w), cfg)
    for rect in _dilated(tight_boxes, (h, w), cfg):
        assert _covered(rect, loose_regions), \
            "loose-threshold plan lost a tight-threshold survivor"


def test_threshold_monotonicity_seeded():
    for s in range(40):
        check_threshold_monotonicity(SEED * 2000 + s)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=100_000))
def test_threshold_monotonicity_hypothesis(seed):
    check_threshold_monotonicity(seed)


def test_planner_edge_cases():
    assert plan_regions(np.zeros((0, 4), np.float32), (480, 640)) == []
    # one box -> one snapped region containing it
    cfg = CascadeConfig(margin=16, snap=32, max_regions=4)
    r = plan_regions(np.asarray([[100, 100, 230, 166]], np.float32),
                     (480, 640), cfg)
    assert len(r) == 1 and _covered((84, 84, 246, 182), r)
    # max_regions=1 merges everything into one rect
    boxes = np.asarray([[0, 0, 50, 50], [400, 500, 470, 620]], np.float32)
    r1 = plan_regions(boxes, (480, 640),
                      dataclasses.replace(cfg, max_regions=1))
    assert len(r1) == 1
    for rect in _dilated(boxes, (480, 640), cfg):
        assert _covered(rect, r1)


# --------------------------------------------------------- scheduler seams

def _rand_head(rng, f):
    return {"w": rng.normal(0, 0.05, (f,)).astype(np.float32),
            "b": np.float32(0.0)}


def _fine_and_coarse(rng, fine_thr=-2.0, coarse_thr=0.0, **casc_kw):
    casc = CascadeConfig(coarse_threshold=coarse_thr, **casc_kw)
    fine_cfg = DetectorConfig(score_threshold=fine_thr)
    fine = FrameDetector(_rand_head(rng, fine_cfg.hog.n_features), fine_cfg)
    coarse = coarse_detector(
        _rand_head(rng, coarse_detector(
            {"w": np.zeros(756, np.float32), "b": 0.0}, fine_cfg,
            casc).cfg.hog.n_features),
        fine_cfg, casc)
    return CascadeDetector(fine, coarse, casc), fine


def test_empty_frame_shortcut():
    rng = np.random.default_rng(SEED)
    # coarse threshold far above any reachable score -> zero candidates
    casc, _ = _fine_and_coarse(rng, coarse_thr=1e9)
    out = casc.detect(rng.integers(0, 255, (320, 416, 3), np.uint8))
    assert out == []
    assert casc.stats["frames_empty"] == 1 and casc.stats["regions"] == 0


def test_dense_fallback_below_min_area():
    rng = np.random.default_rng(SEED + 1)
    casc, fine = _fine_and_coarse(rng, coarse_thr=1e9,
                                  min_frame_area=10**9)
    frame = rng.integers(0, 255, (320, 416, 3), np.uint8)
    assert casc.detect(frame) == fine.detect_raw(frame).to_list()
    assert casc.stats["frames_dense"] == 1


def test_roi_promotion_bypasses_coarse_gate():
    """With the coarse stage rejecting everything, a promoted ROI box
    still gets its neighbourhood scored by the fine stage -- and every
    returned box lands inside the planned region, in FRAME coords."""
    rng = np.random.default_rng(SEED + 2)
    casc, fine = _fine_and_coarse(rng, coarse_thr=1e9, margin=24, snap=32)
    frame = rng.integers(0, 255, (480, 640, 3), np.uint8)
    roi = (96.0, 96.0, 280.0, 240.0)
    out = casc.detect(frame, roi_boxes=[roi])
    assert out, "fine stage at threshold -2 must fire inside the ROI"
    assert casc.stats["regions"] == 1
    regions = plan_regions(np.asarray([roi], np.float32), (480, 640),
                           casc.cfg)
    (ry0, rx0, ry1, rx1), = regions
    for d in out:
        y0, x0, y1, x1 = d["box"]
        assert ry0 <= y0 and rx0 <= x0 and y1 <= ry1 and x1 <= rx1
    # the region-local detections must agree with a direct fine pass on
    # the same crop, offset back to frame coordinates
    crop_dets = fine.detect_raw(
        np.asarray(frame)[ry0:ry1, rx0:rx1]).to_list()
    crop_boxes = {tuple(round(v + o, 3) for v, o in
                        zip(d["box"], (ry0, rx0, ry0, rx0)))
                  for d in crop_dets}
    assert {tuple(round(v, 3) for v in d["box"])
            for d in out} <= crop_boxes


def test_region_area_accounting():
    rng = np.random.default_rng(SEED + 3)
    casc, _ = _fine_and_coarse(rng, coarse_thr=1e9, margin=16, snap=32)
    frame = rng.integers(0, 255, (480, 640, 3), np.uint8)
    casc.detect(frame, roi_boxes=[(0.0, 0.0, 160.0, 160.0)])
    assert 0.0 < casc.stats["region_area_frac"] < 0.5


# ----------------------------------------------------- end-to-end retention

@pytest.fixture(scope="module")
def trained():
    from repro.api import DetectionSession, presets
    # one bootstrap round keeps the quick head's score field clean
    # enough that region crops localize people stably (data/mining.py)
    sess = DetectionSession.train(presets("cascade"), n_pos=250, n_neg=180,
                                  hard_negative_rounds=1, mine_scenes=6)
    casc = sess.cascade(rng=np.random.default_rng(SEED))
    return sess, casc


def _iou(a, b):
    y0, x0 = max(a[0], b[0]), max(a[1], b[1])
    y1, x1 = min(a[2], b[2]), min(a[3], b[3])
    inter = max(0.0, y1 - y0) * max(0.0, x1 - x0)
    ua = (a[2] - a[0]) * (a[3] - a[1]) + (b[2] - b[0]) * (b[3] - b[1])
    return inter / (ua - inter + 1e-9)


def test_cascade_retention_on_synthetic_scene(trained):
    """Every dense-pass detection of an ACTUAL pedestrian must survive
    the cascade. The quickly-trained test head also fires on background
    clutter far from any person -- retention of those false positives
    is the BENCH's criterion (full training, benchmarks/bench_timing.py
    --cascade); the unit invariant is that no true detection is lost."""
    from repro.data.synth_pedestrian import make_scene
    sess, casc = trained
    rng = np.random.default_rng(SEED + 4)
    kept = total = 0
    for i in range(3):
        scene, truth = make_scene(rng, 480, 640, n_people=2,
                                  region=(0, 0, 320, 320))
        tboxes = [(y, x, y + th, x + tw) for y, x, th, tw in truth]
        full = [d for d in sess.detect(scene).to_list()
                if any(_iou(d["box"], t) >= 0.4 for t in tboxes)]
        cd = casc.detect(scene)
        total += len(full)
        # retained = matched directly (IoU >= 0.5, same class) OR a
        # cascade detection reports the same ground-truth pedestrian --
        # region-crop NMS may keep a slightly shifted box for the same
        # person (the crop's HOG grid is offset vs the full frame)
        for f in full:
            gt = max(range(len(tboxes)),
                     key=lambda j: _iou(f["box"], tboxes[j]))
            kept += any(
                f.get("class_id") == c.get("class_id")
                and (_iou(f["box"], c["box"]) >= 0.5
                     or _iou(c["box"], tboxes[gt]) >= 0.4)
                for c in cd)
    assert total > 0, "dense pass found nothing -- scene too hard"
    assert kept / total >= 0.99, f"cascade retained {kept}/{total}"


def test_cascade_stream_tracks_through_coarse_misses(trained):
    """Video contract: once a track exists, its predicted box is
    promoted past the coarse gate, so detections persist even when the
    coarse stage is blinded (threshold jacked to reject everything)."""
    from repro.data.synth_pedestrian import make_scene
    from repro.core.video import Tracker
    sess, casc = trained
    rng = np.random.default_rng(SEED + 5)
    scene, _ = make_scene(rng, 480, 640, n_people=1,
                          region=(0, 0, 288, 224))
    trk = Tracker()
    first = casc.detect(scene)
    if not first:
        pytest.skip("coarse stage found nothing on this seed")
    trk.update(first)
    blind = CascadeDetector(
        casc.fine, FrameDetector(
            casc.coarse.svm,
            dataclasses.replace(casc.coarse.cfg, score_threshold=1e9)),
        casc.cfg)
    out = blind.stream([scene, scene], tracker=trk)
    assert out[0], "promoted track ROI must keep detections alive"
    assert all("track_id" in d for d in out[0])


def test_fine_hysteresis_builds_looser_crop_detector():
    """fine_hysteresis > 0 gives the region-crop stage its own detector
    at (score_threshold - hysteresis); 0 reuses the fine detector
    object unchanged."""
    svm = {"w": np.zeros(3780, np.float32), "b": np.float32(0.0)}
    fine = FrameDetector(svm, DetectorConfig(score_threshold=4.0))
    casc0 = CascadeDetector(fine, fine, CascadeConfig())
    assert casc0._crop_fine is fine
    casc = CascadeDetector(fine, fine,
                           CascadeConfig(fine_hysteresis=1.5))
    assert casc._crop_fine is not fine
    assert casc._crop_fine.cfg.score_threshold == pytest.approx(2.5)
    # everything except the threshold band carries over
    assert casc._crop_fine.cfg.scales == fine.cfg.scales
    assert casc._crop_fine.svm is fine.svm


def test_mine_hard_negatives_geometry_and_dtype():
    """Mined crops come back stacked in the training-window geometry
    (uint8 RGB), for both the fine and the coarse head shapes."""
    from repro.core.cascade import coarse_hog
    from repro.core.hog import PAPER_HOG
    from repro.data.mining import mine_hard_negatives
    rng = np.random.default_rng(SEED + 6)
    # an untrained (zero) head fires nowhere at a positive threshold...
    svm = {"w": np.zeros(3780, np.float32), "b": np.float32(0.0)}
    out = mine_hard_negatives(svm, DetectorConfig(score_threshold=0.5),
                              1, rng, scene_hw=(256, 256), threshold=0.5)
    assert out.shape == (0, PAPER_HOG.window_h, PAPER_HOG.window_w, 3)
    # ...and fires everywhere at a negative one: crops must stack to
    # the requested window geometry
    ch = coarse_hog(PAPER_HOG)
    csvm = {"w": np.zeros(ch.n_features, np.float32), "b": np.float32(0.0)}
    out = mine_hard_negatives(
        csvm, DetectorConfig(hog=ch, scales=(0.5,)), 1, rng,
        scene_hw=(256, 256), threshold=-1.0,
        window_hw=(ch.window_h, ch.window_w))
    assert out.ndim == 4 and len(out) > 0
    assert out.shape[1:] == (ch.window_h, ch.window_w, 3)
    assert out.dtype == np.uint8
