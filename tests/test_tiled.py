"""Intra-frame (tiled) detection: seam identity + planner unit tests.

Two families, mirroring tests/test_sharded.py:

  * PLANNER / ARITHMETIC UNITS (always run, any device count): the
    banded-resize row identity that makes slab tiling exact, the
    row-sliced matmul identity the matmul resize mode relies on, the
    exact top-k merge, slab/scale-group planning, and auto-K.
  * TILED EQUIVALENCE (self-skip below 2 devices): single-frame and
    batched (data x tile) tiled programs must produce byte-identical
    `Detections.to_list()` output vs the untiled path, for both tile
    modes, divisible and non-divisible tile counts (padded-tile
    masking), and boxes that straddle slab seams. The CI `uhd-smoke`
    lane forces 8 host devices via REPRO_TEST_DEVICES=8.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.detector import (DetectorConfig, FrameDetector,
                                 _autotune_key_str, _frame_program,
                                 _resolve_fp, _resolve_k, _tiled_single_fn)
from repro.core.hog import PAPER_HOG
from repro.core.tiling import (band_rows, band_weights, extend_band,
                               merge_topk, resize_banded, scale_groups,
                               slab_pixel_rows, slab_rows)
from repro.launch.mesh import make_tiled_mesh
from repro.serve.engine import DetectionService

multi = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs forced host devices (REPRO_TEST_DEVICES=8, CI lane "
           "'uhd-smoke')")

RNG = np.random.default_rng(23)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}


def _frame(h=160, w=128):
    return RNG.integers(0, 256, (h, w, 3)).astype(np.uint8)


# --------------------------------------------- planner / arithmetic units

def test_banded_resize_rows_are_tiling_invariant():
    """The contract slab tiling rests on: computing a row-slice of the
    banded resize from sliced tables is BITWISE equal to slicing the
    full output. (Per-output-element kernel, fixed tap order.)"""
    g = jnp.asarray(RNG.random((160, 128)).astype(np.float32))
    sh = 128                                    # downscale rows 160 -> 128
    lo, w = band_weights(160, sh)
    full = band_rows(g, jnp.asarray(lo), jnp.asarray(w))
    for a, b in [(0, 40), (37, 91), (100, sh)]:
        part = band_rows(g, jnp.asarray(lo[a:b]), jnp.asarray(w[a:b]))
        assert np.array_equal(np.asarray(part), np.asarray(full)[a:b])


def test_banded_resize_matches_reference_resize():
    """resize_banded is the same separable linear resize as
    jax.image.resize(method='linear') up to float summation order."""
    g = jnp.asarray(RNG.random((160, 128)).astype(np.float32))
    got = np.asarray(resize_banded(g, 128, 102)[:128, :102])
    want = np.asarray(jax.image.resize(g, (128, 102), "linear"))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_extended_band_tables_pad_with_zero_weight():
    """Zero-extending the tap tables (so every tile slices equal-shape
    windows) must not change any real output row."""
    lo, w = band_weights(160, 100)
    lo2, w2 = extend_band(lo, w, 128)
    assert lo2.shape[0] == 128 and w2.shape[0] == 128
    assert np.array_equal(lo2[:100], lo) and np.array_equal(w2[:100], w)
    assert np.all(w2[100:] == 0)


def test_merge_topk_matches_global_topk():
    """Per-tile local top-k lists merged with merge_topk must equal
    lax.top_k over the concatenated scores, including tie-breaking by
    lowest index and -inf phantom padding."""
    n, k, fp = 300, 32, 4
    s = RNG.random(n).astype(np.float32)
    s[50:60] = s[7]                             # ties across tiles
    idx = np.arange(n)
    locs, loci = [], []
    for d in range(fp):
        sl = slice(d * 75, (d + 1) * 75)
        st, it = jax.lax.top_k(jnp.asarray(s[sl]), k)
        locs.append(st)
        loci.append(jnp.asarray(idx[sl])[it])
    ms, mi = merge_topk(jnp.stack(locs), jnp.stack(loci), k)
    ws, wi = jax.lax.top_k(jnp.asarray(s), k)
    assert np.array_equal(np.asarray(ms), np.asarray(ws))
    assert np.array_equal(np.asarray(mi), np.asarray(wi))


def test_slab_and_scale_group_planning():
    assert slab_rows(5, 2) == 3 and slab_rows(5, 8) == 1
    assert slab_pixel_rows(3, PAPER_HOG) == 3 * 8 + 122
    per_scale = ((1.0, 5, 9), (0.8, 3, 6), (0.5, 1, 2))
    groups = scale_groups(per_scale, 2)
    assert len(groups) == 2
    assert sorted(i for g in groups for i in g) == [0, 1, 2]
    # greedy balance: the largest scale sits alone in one bin
    loads = [sum(per_scale[i][1] * per_scale[i][2] for i in g)
             for g in groups]
    assert max(loads) == 45
    # more tiles than scales -> empty groups allowed, nothing dropped
    groups8 = scale_groups(per_scale, 8)
    assert len(groups8) == 8
    assert sorted(i for g in groups8 for i in g) == [0, 1, 2]


def test_resolve_k_auto_scales_with_grid():
    auto = DetectorConfig(max_detections=0)
    assert _resolve_k(auto, 100) == 100          # clamped to n
    assert _resolve_k(auto, 60_000) == 256       # historical constant
    assert _resolve_k(auto, 244_026) == 954      # ceil(n/256) at ~4K
    pinned = DetectorConfig(max_detections=512)
    assert _resolve_k(pinned, 244_026) == 512    # explicit override wins


def test_frame_program_k_follows_auto_rule():
    cfg = DetectorConfig(scales=(1.0,))
    small = _frame_program(160, 128, cfg)
    assert small.k == min(small.n_positions, 256)
    big = _frame_program(2176, 3840, cfg)
    assert big.n_positions > 65_536
    assert big.k == -(-big.n_positions // 256)
    pin = _frame_program(2176, 3840,
                         dataclasses.replace(cfg, max_detections=512))
    assert pin.k == 512


def test_resolve_fp_and_mesh_guards():
    n = jax.device_count()
    with pytest.raises(ValueError) as ei:
        _resolve_fp(DetectorConfig(frame_parallel=n + 1))
    assert str(n) in str(ei.value)
    with pytest.raises(ValueError):
        make_tiled_mesh(1, n + 1)
    with pytest.raises(ValueError):
        make_tiled_mesh(n + 1, 1)
    mesh = make_tiled_mesh(1, 0)                 # 0 = all remaining
    assert mesh.axis_names == ("data", "tile") and mesh.size == n


def test_serve_reports_saturated_frames():
    """A pinned tiny K with an accept-everything threshold must surface
    through the service's frames_saturated counter (satellite: expose
    Detections.saturated in serve stats)."""
    cfg = DetectorConfig(score_threshold=-10.0, scales=(1.0,),
                         max_detections=4)
    svc = DetectionService(SVM, detector=cfg).start()
    try:
        res = svc.submit_frame(_frame()).get(timeout=60)
        assert "error" not in res
        assert res["saturated"] is True
        assert svc.stats["frames_saturated"] >= 1
        assert svc.stats["tile_devices"] == 1
    finally:
        svc.stop()


# ----------------------------------------------- tiled-vs-untiled identity

def _tiled_case(resize, mode, fp, h=160, w=128, scales=(1.0, 0.8)):
    """to_list() of the tiled single-frame path vs untiled, same
    pyramid_resize (identity is per resize mode; banded vs matmul
    differ in float summation order by design)."""
    base = DetectorConfig(score_threshold=-5.0, scales=scales,
                          pyramid_resize=resize)
    frame = _frame(h, w)
    plain = FrameDetector(SVM, base)
    tiled = FrameDetector(SVM, dataclasses.replace(
        base, frame_parallel=fp, tile_mode=mode))
    want = plain.detect_raw(frame).to_list()
    got = tiled.detect_raw(frame).to_list()
    assert want, "threshold must admit boxes or the test is vacuous"
    assert got == want
    return want


@multi
@pytest.mark.parametrize("resize,mode,fp", [
    ("banded", "slab", 2),
    ("banded", "slab", 3),        # non-divisible slab split
    ("matmul", "slab", 2),        # row-sliced matmul resize path
    ("banded", "scale", 2),
])
def test_tiled_matches_untiled(resize, mode, fp):
    if fp > jax.device_count():
        pytest.skip(f"needs {fp} devices")
    _tiled_case(resize, mode, fp)


@multi
@pytest.mark.parametrize("mode,fp", [("slab", 2), ("scale", 2)])
def test_tiled_matches_untiled_fixed_numerics(mode, fp):
    """numerics="fixed" across the tile mesh: slab halos recompute the
    same integer gradients/histograms bit for bit and the int8 scoring
    matmul is associative in int32, so tiled-vs-untiled must be
    byte-identical in BOTH tile modes -- the quantized chain has no
    float-summation-order escape hatch."""
    if fp > jax.device_count():
        pytest.skip(f"needs {fp} devices")
    from repro.configs import hog_svm
    base = DetectorConfig(hog=hog_svm.QUANT, score_threshold=-5.0,
                          scales=(1.0, 0.8), pyramid_resize="banded")
    frame = _frame()
    plain = FrameDetector(SVM, base)
    tiled = FrameDetector(SVM, dataclasses.replace(
        base, frame_parallel=fp, tile_mode=mode))
    want = plain.detect_raw(frame).to_list()
    got = tiled.detect_raw(frame).to_list()
    assert want, "threshold must admit boxes or the test is vacuous"
    assert got == want


@multi
def test_tiled_slab_overhang_tiles_are_masked():
    """fp larger than the smallest score grid: at 160x128/scale 1.0 the
    grid has 5 score rows, so with fp=8 several tiles own only
    overhang rows -- their candidates must be masked out, not merged."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    _tiled_case("banded", "slab", 8)


@multi
def test_tiled_scale_groups_with_empty_tiles():
    """fp=8 over 2 pyramid scales: six tiles get EMPTY scale groups and
    must contribute only phantom (-inf) rows to the merge."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    _tiled_case("banded", "scale", 8)


@multi
def test_tiled_keeps_seam_straddling_boxes():
    """Boxes whose windows span a slab seam live in the halo of the
    owning tile; they must survive tiling. With h=160 and fp=2 the
    seam sits at scaled row 3*8=24 -- every kept 128-tall window from
    score rows 0-2 crosses it."""
    dets = _tiled_case("banded", "slab", 2)
    sph = 5                                     # (160 - 128) // 8 + 1
    seam_y = slab_rows(sph, 2) * PAPER_HOG.cell
    straddle = [d for d in dets                 # box = (y0, x0, y1, x1)
                if d["box"][0] < seam_y < d["box"][2]]
    assert straddle, "no kept box straddles the slab seam"


@multi
def test_tiled_batch_matches_single_device():
    """2-D (data x tile) schedule, non-divisible B: dp=2 x fp=2 over a
    3-frame batch must match the single-device untiled batch byte for
    byte (pad-and-mask on the data axis, merge inside shard_map)."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    base = DetectorConfig(score_threshold=-5.0, scales=(1.0, 0.8),
                          pyramid_resize="banded", batch_chunk=1)
    frames = np.stack([_frame() for _ in range(3)])
    plain = FrameDetector(SVM, base)
    tiled = FrameDetector(SVM, dataclasses.replace(
        base, data_parallel=2, frame_parallel=2))
    want = [d.to_list() for d in plain.detect_batch_raw(frames)]
    got = [d.to_list() for d in tiled.detect_batch_raw(frames)]
    assert got == want
    # autotune keys carry the 2-D mesh layout (chunk pinned here, so
    # check the key formatter the report/disk cache share)
    key = _autotune_key_str((160, 128, 160, 128, 4, base, "rgb-uint8", 2, 2))
    assert key.endswith("mesh=data:2,tile:2 [rgb-uint8]")


@multi
def test_area_threshold_routes_small_frames_untiled():
    """frame_parallel_min_area above the bucket area: results identical
    AND no tiled program is ever built (the routing happens before the
    program cache)."""
    base = DetectorConfig(score_threshold=-5.0, scales=(1.0,),
                          pyramid_resize="banded")
    frame = _frame()
    want = FrameDetector(SVM, base).detect_raw(frame).to_list()
    misses = _tiled_single_fn.cache_info().misses
    routed = FrameDetector(SVM, dataclasses.replace(
        base, frame_parallel=0, frame_parallel_min_area=10 ** 9))
    assert routed.frame_devices == jax.device_count()
    assert routed.detect_raw(frame).to_list() == want
    assert _tiled_single_fn.cache_info().misses == misses
