"""Disk-persisted autotune decisions (core/autotune_cache.py).

Unit tests of path resolution, the store/lookup round-trip (including
the JSON string-key -> int-key restoration), corruption tolerance and
fingerprint scoping, plus one end-to-end test: a probed detect_batch
schedule written by one "process" is restored from disk by the next
(memory cache cleared) without re-probing.
"""
import dataclasses
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import autotune_cache, detector
from repro.core.detector import DetectorConfig, FrameDetector

RNG = np.random.default_rng(5)
SVM = {"w": jnp.asarray(RNG.normal(size=3780).astype(np.float32) * .01),
       "b": jnp.float32(0.0)}


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune_cache._reset_for_tests()
    yield
    autotune_cache._reset_for_tests()


def test_path_resolution(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    assert autotune_cache.cache_path().endswith("autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
    assert autotune_cache.cache_path() is None          # disabled
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    assert autotune_cache.cache_path() == str(tmp_path / "c.json")


def test_store_lookup_roundtrip(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune_cache.store("k1", 4, {1: 9.5, 4: 3.25})
    got = autotune_cache.lookup("k1")
    assert got == {"chunk": 4, "probe_ms": {1: 9.5, 4: 3.25}}
    assert all(isinstance(c, int) for c in got["probe_ms"])  # not JSON str
    assert autotune_cache.lookup("other-key") is None
    s = autotune_cache.stats()
    assert s["probes"] == 1 and s["writes"] == 1 and s["disk_hits"] == 1
    assert s["path"] == str(path)
    # entries are scoped to the host fingerprint
    on_disk = json.loads(path.read_text())
    assert set(on_disk) == {autotune_cache.host_fingerprint()}


def test_disabled_cache_still_counts_probes(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
    autotune_cache.store("k1", 1, {1: 2.0})
    assert autotune_cache.lookup("k1") is None
    s = autotune_cache.stats()
    assert s["probes"] == 1 and s["writes"] == 0 and s["path"] is None
    assert not list(tmp_path.iterdir())


def test_corrupt_file_degrades_to_probe(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    assert autotune_cache.lookup("k1") is None
    assert autotune_cache.stats()["load_errors"] == 1
    autotune_cache.store("k1", 2, {1: 5.0, 2: 1.0})     # recovers the file
    assert autotune_cache.lookup("k1")["chunk"] == 2
    json.loads(path.read_text())                        # valid again


def test_other_host_fingerprint_is_ignored(monkeypatch, tmp_path):
    path = tmp_path / "autotune.json"
    entry = {"some-other-host": {"k1": {"chunk": 7, "probe_ms": {"1": 1.0}}}}
    path.write_text(json.dumps(entry))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    assert autotune_cache.lookup("k1") is None
    assert autotune_cache.stats()["disk_hits"] == 0


def test_entry_key_tracks_config(monkeypatch):
    a = DetectorConfig()
    b = dataclasses.replace(a, score_threshold=0.25)
    assert autotune_cache.entry_key("K", a) != autotune_cache.entry_key("K", b)
    assert autotune_cache.entry_key("K", a) == autotune_cache.entry_key("K", a)


def test_probe_persists_and_warm_start_restores(monkeypatch, tmp_path):
    """End to end: batch_chunk=0 probes (2 candidates at B=2), writes
    the decision to disk; a cold in-memory cache then restores it from
    disk -- no probe, source=='disk', counters say so."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    # threshold unique to this test: the autotune key includes the full
    # config, and an identical tuple probed by an earlier module would
    # memory-hit here and leave nothing to persist
    cfg = DetectorConfig(score_threshold=-9.625, scales=(1.0,),
                         batch_chunk=0)
    frames = np.stack([RNG.integers(0, 256, (160, 128, 3)).astype(np.uint8)
                       for _ in range(2)])
    det = FrameDetector(SVM, cfg)
    want = [d.to_list() for d in det.detect_batch_raw(frames)]
    key = detector._autotune_key_str(
        (160, 128, 160, 128, 2, cfg, "rgb-uint8", 1, 1))
    entry = detector.autotune_report()[key]
    assert entry["source"] == "probe"
    assert set(entry["probe_ms"]) == {1, 2}
    s = autotune_cache.stats()
    assert s["probes"] == 1 and s["writes"] == 1

    # "new process": drop the in-memory decision, keep the disk file
    saved = {k: v for k, v in detector._AUTOTUNE.items()}
    detector._AUTOTUNE.clear()
    autotune_cache._reset_for_tests()
    got = [d.to_list() for d in det.detect_batch_raw(frames)]
    assert got == want
    entry2 = detector.autotune_report()[key]
    assert entry2["source"] == "disk"
    assert entry2["chunk"] == entry["chunk"]
    assert entry2["probe_ms"] == entry["probe_ms"]      # int keys restored
    s2 = autotune_cache.stats()
    assert s2["disk_hits"] == 1 and s2["probes"] == 0
    # third call: pure memory hit, disk untouched
    det.detect_batch_raw(frames)
    assert autotune_cache.stats()["memory_hits"] == 1
    detector._AUTOTUNE.update(saved)
