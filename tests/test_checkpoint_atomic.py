"""Crash-safety of checkpoint/manager.py (PR 9 satellite).

The invariant: at EVERY instant during a save -- including re-saving an
existing step -- at least one complete, readable copy of the newest
committed checkpoint exists on disk, and a writer killed at any point
leaves debris the next CheckpointManager() silently settles
(`_recover`): complete .tmp dirs commit, truncated ones vanish,
orphaned .old dirs restore. heads.json rides the same discipline via
`atomic_write_json`.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, atomic_write_json,
                                      _step_of)

TREE = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.float32(2.5)}


def _assert_restores(mgr, step, expect_w):
    got = mgr.restore(step, {"w": np.zeros((2, 3), np.float32),
                             "b": np.float32(0)})
    np.testing.assert_array_equal(np.asarray(got["w"]), expect_w)


def test_save_leaves_no_debris(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, TREE)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000001"]          # no .tmp, no .old
    _assert_restores(mgr, 1, TREE["w"])


def test_resave_same_step_keeps_a_valid_copy(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, TREE)
    newer = {"w": TREE["w"] + 1, "b": TREE["b"]}
    mgr.save(1, newer)                         # overwrite commit
    assert sorted(os.listdir(tmp_path)) == ["step_00000001"]
    _assert_restores(mgr, 1, TREE["w"] + 1)


def test_recover_finishes_complete_tmp(tmp_path):
    """Writer killed AFTER metadata.json but BEFORE the commit rename:
    every byte is on disk, so recovery completes the commit."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, TREE)
    # forge the crash: demote the committed dir back to .tmp
    os.rename(tmp_path / "step_00000002", tmp_path / "step_00000002.tmp")
    mgr2 = CheckpointManager(str(tmp_path))
    assert sorted(os.listdir(tmp_path)) == ["step_00000002"]
    assert mgr2.latest_step() == 2
    _assert_restores(mgr2, 2, TREE["w"])


def test_recover_discards_truncated_tmp(tmp_path):
    """Writer killed mid-leaf-write: no metadata.json, so the .tmp is
    debris -- removed, never surfaced as a checkpoint."""
    d = tmp_path / "step_00000003.tmp"
    d.mkdir()
    (d / "w.npy").write_bytes(b"\x93NUMPY-truncat")
    mgr = CheckpointManager(str(tmp_path))
    assert os.listdir(tmp_path) == []
    assert mgr.latest_step() is None


def test_recover_restores_orphaned_old(tmp_path):
    """Writer killed between `final -> .old` and `tmp -> final`: the
    .old IS the newest complete copy -- restored, not deleted."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, TREE)
    os.rename(tmp_path / "step_00000004", tmp_path / "step_00000004.old")
    mgr2 = CheckpointManager(str(tmp_path))
    assert sorted(os.listdir(tmp_path)) == ["step_00000004"]
    _assert_restores(mgr2, 4, TREE["w"])


def test_recover_drops_superseded_old(tmp_path):
    """.old next to a committed step is a leftover from a completed
    re-save: removed."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, TREE)
    old = tmp_path / "step_00000005.old"
    old.mkdir()
    (old / "metadata.json").write_text("{}")
    CheckpointManager(str(tmp_path))
    assert sorted(os.listdir(tmp_path)) == ["step_00000005"]


def test_latest_step_ignores_debris_and_foreign_names(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, TREE)
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000008.old").mkdir()
    (tmp_path / "heads.json").write_text("{}")
    (tmp_path / "step_notanumber").mkdir()
    assert mgr.latest_step() == 7
    assert _step_of("step_00000042") == 42
    assert _step_of("step_00000042.tmp") is None
    assert _step_of("step_00000042.old") is None
    assert _step_of("notes.txt") is None


def test_gc_keeps_newest_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, TREE)
    steps = sorted(d for d in os.listdir(tmp_path))
    assert steps == ["step_00000003", "step_00000004"]


def test_atomic_write_json_no_truncated_reader_view(tmp_path):
    p = tmp_path / "manifest.json"
    atomic_write_json(str(p), {"v": 1}, indent=2)
    assert json.loads(p.read_text()) == {"v": 1}
    atomic_write_json(str(p), {"v": 2})        # overwrite in place
    assert json.loads(p.read_text()) == {"v": 2}
    assert sorted(os.listdir(tmp_path)) == ["manifest.json"]   # no .tmp


def test_heads_manifest_uses_atomic_writer(tmp_path):
    """heads.json survives a stale .tmp from a prior kill: save()
    replaces it atomically and load() reads a complete manifest."""
    import jax.numpy as jnp
    from repro.core.heads import HeadRegistry
    reg = HeadRegistry()
    reg.add("person", {"w": jnp.zeros(3780, np.float32),
                       "b": jnp.float32(0)})
    path = str(tmp_path)
    stale = tmp_path / "heads.json.tmp"
    stale.write_text("{trunca")
    reg.save(path)
    assert not stale.exists() or json.loads(stale.read_text())
    loaded = HeadRegistry.load(path)
    assert loaded.names == reg.names
