"""Optional-`hypothesis` shim for the property tests.

`hypothesis` is a dev-only dependency (requirements-dev.txt). When it is
not installed the property tests must SKIP, not kill collection, so test
modules import `given / settings / st` from here instead of from
`hypothesis` directly.

Without hypothesis, `@given(...)` replaces the test with a zero-argument
function that calls `pytest.skip` at runtime (zero-arg so pytest does not
try to resolve the strategy parameters as fixtures), `@settings(...)` is
a no-op, and `st` is a stub whose strategy constructors return opaque
placeholders.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """`st.floats(...)`, `st.integers(...)`, ... -> placeholder."""

        def __getattr__(self, name):
            def _make(*args, **kwargs):
                return ("<strategy>", name, args, kwargs)
            return _make

    st = _StrategyStub()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            _skipped.__module__ = fn.__module__
            return _skipped
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
