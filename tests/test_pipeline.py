"""GPipe pipeline parallelism: pipelined == sequential, grads flow."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.pipeline import bubble_fraction, gpipe_apply

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 host devices")


def _layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])


def _params(L, d, key):
    ks = jax.random.split(key, 2)
    return {"w": jax.random.normal(ks[0], (L, d, d)) * (d ** -0.5),
            "b": jax.random.normal(ks[1], (L, d)) * 0.1}


def _sequential(params, x_micro):
    def one(x):
        def body(c, lp):
            return _layer_fn(lp, c), None
        y, _ = jax.lax.scan(body, x, params)
        return y
    return jax.vmap(one)(x_micro)


@multi
def test_gpipe_matches_sequential():
    mesh = jax.make_mesh((4,), ("pipe",))
    L, d, M, B = 8, 16, 6, 2
    params = _params(L, d, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, d))
    want = _sequential(params, x)
    got = gpipe_apply(_layer_fn, params, x, mesh, axis="pipe")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@multi
def test_gpipe_backward_matches_sequential():
    """GPipe backward (autodiff through ppermute) == sequential grads."""
    mesh = jax.make_mesh((4,), ("pipe",))
    L, d, M, B = 4, 8, 4, 2
    params = _params(L, d, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, d))

    def loss_pipe(p):
        return jnp.sum(gpipe_apply(_layer_fn, p, x, mesh) ** 2)

    def loss_seq(p):
        return jnp.sum(_sequential(p, x) ** 2)

    g1 = jax.grad(loss_pipe)(params)
    g2 = jax.grad(loss_seq)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(0.75)
    assert bubble_fraction(32, 4) == pytest.approx(3 / 35)
    assert bubble_fraction(8, 1) == 0.0
