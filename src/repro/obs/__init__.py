"""Observability: off-process metrics export (DESIGN.md §15)."""
from repro.obs.metrics import (CallbackSink, Emitter, JsonlSink,
                               MetricsConfig, MetricsSink, NullSink,
                               RingSink, TeeSink, make_sink)

__all__ = [
    "CallbackSink", "Emitter", "JsonlSink", "MetricsConfig",
    "MetricsSink", "NullSink", "RingSink", "TeeSink", "make_sink",
]
