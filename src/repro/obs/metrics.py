"""Pluggable metrics export: the serve telemetry that used to die with
the process, as a stream of structured events (DESIGN.md §15).

PR 9 put rolling p50/p99, shed/retry/restart/breaker/ladder counters
into `DetectionService.stats` -- rich, but in-process only: when the
worker dies or the run ends, the story dies with it. This module is the
HomebrewNLP `wandblog.py` idiom reduced to a protocol: the engine emits
plain dicts, a `MetricsSink` decides where they go, and nothing in the
hot path knows (or imports) the destination.

Event schema -- every event is a flat JSON-safe dict with three fields
stamped by the `Emitter` plus kind-specific payload:

    kind             event type (below)          (stamped)
    seq              per-emitter sequence number (stamped)
    t_ms             ms since emitter creation   (stamped)

    service_start    platform snapshot, config knobs
    batch            n frames, latency_ms, queue_depth, rung,
                     devices_used/devices_total occupancy
    rung_transition  rung_from, rung_to, p99_ms, queue_depth, direction
    deadline_shed    n shed, queue_depth, deadline_ms
    worker_failure   error, transient, retries_left, breaker state
    restart          restarts total, breaker state
    service_stop     final counter totals (frames, sheds, restarts, ...)
    stage_timing     per-stage ms from the session timing hook

Sinks: `JsonlSink` (one JSON object per line -- `tail -f`-able and
re-parseable, the round-trip contract tests/test_metrics.py pins),
`RingSink` (bounded in-memory deque for tests and the `stats()` tail),
`CallbackSink` (bridge to whatever process-local consumer), `TeeSink`
(fan-out), `NullSink` (disabled -- the default, zero overhead).

Emission is guarded by `platform.is_main()` (rank 0 only) so the
future multi-host path inherits single-writer semantics for free.

This module must import cleanly WITHOUT jax: `repro.api.config` loads
it for the `ServiceConfig.metrics` knob on the pre-jax-init path.
"""
from __future__ import annotations

import io
import json
import threading
import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional,
                    Protocol, Tuple, runtime_checkable)


@runtime_checkable
class MetricsSink(Protocol):
    """Anything that accepts structured events. `emit` must be cheap
    and non-raising from the engine's point of view (the Emitter wraps
    it defensively); `close` flushes/releases."""

    def emit(self, event: Dict[str, Any]) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """Metrics disabled: the default. Exists so the engine can emit
    unconditionally without `if sink is not None` at every site."""

    def emit(self, event: Dict[str, Any]) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append mode: `tail -f` it live, or
    re-parse it after the run. Writes are line-buffered and locked so
    supervisor-thread and caller-thread events interleave whole-line.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f: Optional[io.TextIOBase] = open(path, "a",
                                                encoding="utf-8")

    def emit(self, event: Dict[str, Any]) -> None:
        line = json.dumps(event, sort_keys=True, default=_json_default)
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    @staticmethod
    def read(path: str) -> List[Dict[str, Any]]:
        """Re-parse a JSONL stream (skips blank lines)."""
        out = []
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


class RingSink:
    """Bounded in-memory ring: the last `capacity` events, for tests
    and the `stats()["metrics"]` tail. Thread-safe."""

    def __init__(self, capacity: int = 256):
        self._events: deque = deque(maxlen=max(1, int(capacity)))
        self._lock = threading.Lock()

    def emit(self, event: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(event)

    def close(self) -> None:
        pass

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.get("kind") == kind]
        return evs

    def counts(self) -> Dict[str, int]:
        return dict(Counter(e.get("kind", "?") for e in self.events()))


class CallbackSink:
    """Bridge to an arbitrary consumer: `fn(event)` per event."""

    def __init__(self, fn: Callable[[Dict[str, Any]], None]):
        self._fn = fn

    def emit(self, event: Dict[str, Any]) -> None:
        self._fn(event)

    def close(self) -> None:
        pass


class TeeSink:
    """Fan one event stream out to several sinks."""

    def __init__(self, sinks: Iterable[MetricsSink]):
        self.sinks: Tuple[MetricsSink, ...] = tuple(sinks)

    def emit(self, event: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


def _json_default(o):
    """Last-resort encoder: numpy/jax scalars and arrays reach the
    sink occasionally (latencies, occupancy); keep the stream valid."""
    if hasattr(o, "item"):
        try:
            return o.item()
        except Exception:
            pass
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


@dataclass(frozen=True)
class MetricsConfig:
    """The `ServiceConfig.metrics` knob. All-default == disabled.

    jsonl_path   append events to this JSONL file ("" = off)
    ring         also keep the last N events in memory (0 = off);
                 surfaced as `stats()["metrics"]["recent"]` counts
    rank0_only   only emit from `platform.is_main()` (default True --
                 the multi-host single-writer guard)
    stage_timing forward the session's per-stage timing dict as
                 `stage_timing` events (off by default: it's verbose)
    """

    jsonl_path: str = ""
    ring: int = 0
    rank0_only: bool = True
    stage_timing: bool = False

    @property
    def enabled(self) -> bool:
        return bool(self.jsonl_path) or self.ring > 0


def make_sink(cfg: MetricsConfig,
              extra: Optional[MetricsSink] = None
              ) -> Tuple[MetricsSink, Optional[RingSink]]:
    """Build the sink stack a MetricsConfig describes. Returns the
    (possibly Tee'd) sink plus the RingSink handle when one was made,
    so the engine can surface its counts in `stats()`."""
    sinks: List[MetricsSink] = []
    ring: Optional[RingSink] = None
    if cfg.jsonl_path:
        sinks.append(JsonlSink(cfg.jsonl_path))
    if cfg.ring > 0:
        ring = RingSink(cfg.ring)
        sinks.append(ring)
    if extra is not None:
        sinks.append(extra)
    if not sinks:
        return NullSink(), None
    sink = sinks[0] if len(sinks) == 1 else TeeSink(sinks)
    return sink, ring


class Emitter:
    """What the engine actually holds: stamps kind/seq/t_ms, applies
    the rank-0 guard once at construction, and swallows sink errors so
    a full disk can never take the serve loop down (first failure is
    recorded in `dropped`)."""

    def __init__(self, sink: MetricsSink, rank0_only: bool = True):
        self._sink = sink
        self._seq = 0
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self.dropped = 0
        self.last_error: Optional[str] = None
        if rank0_only:
            from repro import platform as _platform
            self._active = _platform.is_main()
        else:
            self._active = True
        if isinstance(sink, NullSink):
            self._active = False

    @property
    def active(self) -> bool:
        return self._active

    def emit(self, kind: str, **payload) -> None:
        if not self._active:
            return
        with self._lock:
            seq = self._seq
            self._seq += 1
        event = {"kind": kind, "seq": seq,
                 "t_ms": round((time.perf_counter() - self._t0) * 1e3, 3)}
        event.update(payload)
        try:
            self._sink.emit(event)
        except Exception as exc:             # noqa: BLE001 - never fatal
            self.dropped += 1
            self.last_error = f"{type(exc).__name__}: {exc}"

    def close(self) -> None:
        try:
            self._sink.close()
        except Exception as exc:             # noqa: BLE001
            self.last_error = f"{type(exc).__name__}: {exc}"
