"""Sharding rules: parameter/activation PartitionSpecs per mesh + profile.

Axis roles (DESIGN.md §5):
  'pod'   -- pure data parallelism across pods (slow ICI: only gradient
             all-reduces cross it; FSDP weight gathers stay intra-pod)
  'data'  -- FSDP/ZeRO-3 weight-shard axis + batch axis
  'model' -- tensor parallel (attention heads / FFN columns / MoE experts)

Profiles are the §Perf hillclimb lever:
  baseline  -- 2D weight sharding (fsdp x tp), batch over dp, seq over tp
               for prefill/train, KV-heads over tp for decode
  kv_seq    -- decode variant: KV cache sharded on LENGTH over 'model'
               (flash-decode style) instead of padding kv heads
  no_seq    -- activations: batch-only sharding (no sequence parallelism)

GSPMD pads non-divisible dims (e.g. 40 heads on 16-way tp, hymba d=1600),
so rules never need per-arch special-casing; padding waste shows up in the
roofline table and is attacked in §Perf.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.configs import ModelConfig
from repro.models.moe import ShardingCtx


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_ctx(mesh: Mesh, seq_sharded: bool = True,
             profile=None) -> ShardingCtx:
    kw = {}
    if profile is not None:
        kw = dict(seq_sharded=profile.seq_sharded,
                  bf16_scores=profile.bf16_scores,
                  banded=profile.banded_window,
                  flash_vjp=profile.flash_vjp)
    else:
        kw = dict(seq_sharded=seq_sharded)
    return ShardingCtx(mesh=mesh, dp_axes=dp_axes(mesh), tp_axis="model",
                       **kw)


# ---------------------------------------------------------------------
# parameter rules: (path regex) -> PartitionSpec, first match wins.
# Layer-stacked leaves have a leading L axis (never sharded).
# ---------------------------------------------------------------------

_PARAM_RULES = [
    # embeddings: vocab x d_model, 2D-sharded
    (r"embed$", P("model", "data")),
    (r"lm_head$", P("data", "model")),
    (r"meta$", P(None, None)),
    # attention / cross-attention projections
    (r"(attn|xattn)/w[qkv]$", P(None, "data", "model")),
    (r"(attn|xattn)/wo$", P(None, "model", "data")),
    (r"(attn|xattn)/[qk]_norm$", P(None, None)),
    # dense MLP
    (r"mlp/w_(gate|up)$", P(None, "data", "model")),
    (r"mlp/w_down$", P(None, "model", "data")),
    # MoE: experts over 'model' (EP), d_model over 'data' (FSDP)
    (r"moe/router$", P(None, "data", None)),
    (r"moe/w_(gate|up)$", P(None, "model", "data", None)),
    (r"moe/w_down$", P(None, "model", None, "data")),
    (r"moe/shared/w_(gate|up)$", P(None, "data", "model")),
    (r"moe/shared/w_down$", P(None, "model", "data")),
    # SSM
    (r"ssm/in_proj$", P(None, "data", "model")),
    (r"ssm/out_proj$", P(None, "model", "data")),
    (r"ssm/conv_[wb]$", P(None, None)),
    (r"ssm/norm_scale$", P(None, "model")),
    (r"ssm/(A_log|D_skip|dt_bias)$", P(None, None)),
    # everything else (norm scales/biases): replicated
    (r".*", P(None, None)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape: Any, cfg: ModelConfig,
                encoder_prefixless: bool = True) -> Any:
    """Pytree of PartitionSpec matching the params pytree structure."""
    def spec_for(path, leaf):
        s = _path_str(path)
        ndim = len(leaf.shape)
        for pat, spec in _PARAM_RULES:
            if re.search(pat, s):
                # enc stacks reuse the same leaf names; unstacked leaves
                # (final_norm etc.) drop the leading-L axis of the rule
                spec = _fit(spec, ndim)
                return spec
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def _fit(spec: P, ndim: int) -> P:
    t = tuple(spec)
    if len(t) > ndim:          # rule written for stacked leaf; strip lead
        t = t[len(t) - ndim:]
    if len(t) < ndim:          # rule shorter: right-pad with None
        t = t + (None,) * (ndim - len(t))
    return P(*t)


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from a PartitionSpec wherever the dim is not evenly
    divisible (explicit input shardings require exact divisibility; e.g.
    mamba2's in_proj columns = 3352 on a 16-way 'model' axis, or the
    long_500k batch of 1). Dropping = replicating that dim."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        ax = list(axes) if isinstance(axes, tuple) else [axes]
        def size(a):
            s = 1
            for x in a:
                s *= mesh.shape[x]
            return s
        while ax and shape[i] % size(ax) != 0:
            ax.pop()
        out.append(tuple(ax) if len(ax) > 1 else (ax[0] if ax else None))
    # spec shorter than rank: remaining dims replicated (P pads with None)
    return P(*out)


def fit_tree(specs: Any, shapes: Any, mesh: Mesh) -> Any:
    """fit_spec over a pytree of specs + matching ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, x: fit_spec(s, x.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def param_shardings(mesh: Mesh, params_shape: Any, cfg: ModelConfig) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, cfg),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------
# activation/batch rules
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Profile:
    name: str = "baseline"
    seq_sharded: bool = True        # shard sequence over 'model' (SP/CP)
    kv_shard_dim: str = "length"    # "length" | "heads" (decode cache);
    # heads-sharding needs kv_heads % tp == 0, which no assigned arch
    # satisfies on a 16-way axis -- length (flash-decode) is the default
    # ---- §Perf levers (see EXPERIMENTS.md §Perf) ----
    bf16_scores: bool = False       # half-width attention score tensors
    banded_window: bool = False     # block-banded sliding-window attn
    constrain_grads: bool = False   # pin grads to param sharding
    #                                 (all-reduce -> reduce-scatter)
    flash_vjp: bool = False         # LSE-saving attention custom VJP


PROFILES = {
    "baseline": Profile(),
    "kv_heads": Profile(name="kv_heads", kv_shard_dim="heads"),
    "no_seq": Profile(name="no_seq", seq_sharded=False),
    "perf": Profile(name="perf", bf16_scores=True, banded_window=True,
                    constrain_grads=True),
    # dense-arch §Perf iteration 3: bf16 scores REGRESSED on full
    # attention (see EXPERIMENTS.md §Perf) -> flash VJP instead
    "flashgrad": Profile(name="flashgrad", flash_vjp=True,
                         constrain_grads=True),
}


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str,
                profile: Profile = PROFILES["baseline"]) -> Dict[str, P]:
    """PartitionSpecs for the input batch dict, keyed like input_specs()."""
    dp = dp_axes(mesh)
    seq = "model" if profile.seq_sharded else None
    if kind == "train":
        sp = {"tokens": P(dp, seq), "labels": P(dp, seq)}
        if cfg.mrope:
            sp["positions"] = P(dp, seq, None)
        if cfg.encoder_layers:
            sp["enc_input"] = P(dp, seq, None)
        return sp
    if kind == "prefill":
        sp = {"tokens": P(dp, seq)}
        if cfg.mrope:
            sp["positions"] = P(dp, seq, None)
        if cfg.encoder_layers:
            sp["enc_input"] = P(dp, seq, None)
        return sp
    # decode
    sp = {"token": P(dp, None)}
    if cfg.encoder_layers:
        sp["enc_states"] = P(dp, None, None)
    return sp


def cache_specs_tree(cfg: ModelConfig, mesh: Mesh,
                     profile: Profile = PROFILES["baseline"]) -> Dict[str, P]:
    """Sharding for the KV/SSM cache pytree (leading L axis unsharded)."""
    dp = dp_axes(mesh)
    out: Dict[str, P] = {"idx": P()}
    if cfg.has_attention:
        if profile.kv_shard_dim == "length":
            kv = P(None, dp, "model", None, None)   # (L, B, S, K, hd)
        else:
            kv = P(None, dp, None, "model", None)
        out["k"] = kv
        out["v"] = kv
    if cfg.has_ssm:
        out["state"] = P(None, dp, "model", None, None)  # (L,B,H,N,P)
        out["conv"] = P(None, dp, None, None)            # (L,B,k-1,C)
    return out
