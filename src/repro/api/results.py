"""Typed detection results -- device-resident until the host asks.

The legacy entry points each returned ad-hoc lists of dicts, decoded
eagerly on every call (one host sync per frame even when the caller only
wanted a count or wanted to stack results). `Detections` is the one
result type of the api layer:

  * holds the RAW device outputs of the compiled detection program --
    top-k `scores`, box-table `index`, NMS `keep` mask, and the
    threshold-candidate count `n_valid` -- plus the program's static
    host-side decode tables (pure geometry, numpy),
  * is a registered jax pytree, so batched results ride through
    jit/vmap/scan untouched,
  * decodes LAZILY: nothing syncs to host until `.to_list()` /
    `.boxes` / `len()` is called, and the decode is cached,
  * `.to_list()` reproduces the legacy dict contract byte for byte
    (`{"box": (y0, x0, y1, x1), "score", "scale"}`, descending score),
  * `.saturated` answers programmatically what used to be only a
    RuntimeWarning: did more candidates clear the threshold than the
    program's top-k could hold? (per-frame bool array on batches),
  * a leading batch axis makes a batch-of-frames result: `d.frame(i)`
    slices one frame out, `Detections.stack([...])` goes the other way,
  * `Detections.from_list(dicts)` wraps already-host results (the
    tracking path) so `stream()` returns the same type; extra keys such
    as `track_id` pass through `.to_list()` unchanged (they do not
    survive pytree flattening, which keeps only the device arrays).

Multi-class results (stacked-head scoring, DESIGN.md §13) carry a CLASS
axis ahead of the top-k axis -- (K, k) per frame, (B, K, k) per batch --
plus a static tuple of class names as pytree aux data. Decoding runs the
per-class slots independently (each class had its own device NMS) and
merges by descending score; every dict gains `class_id` (head index) and
`label`. `for_class()` slices one class back out as a plain single-head
result.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.detector import DecodeTables


class Detections:
    """Results of one detection call: a single frame (1-D top-k axis) or
    a stacked batch of frames (leading batch axis), optionally with a
    class axis between the two (see module docstring; `classes` names
    the heads). Construct via the session/detector, `from_list`, or
    `stack` -- the raw constructor mirrors the compiled program's
    outputs."""

    def __init__(self, scores, index, keep, n_valid, tables,
                 _lists: Optional[list] = None,
                 classes: Optional[Tuple[str, ...]] = None):
        self._scores = scores          # (..., K) f32, top-k order, -inf pad
        self._index = index            # (..., K) i32 rows into tables.boxes
        self._keep = keep              # (..., K) bool NMS keep mask
        self._n_valid = n_valid        # (...,)   i32 threshold candidates
        self._tables = tables          # static: .boxes (N,4), .scales (N,), .k
        self._lists = _lists           # cached host decode
        self._classes = tuple(classes) if classes is not None else None

    # ------------------------------------------------------ constructors
    @classmethod
    def empty(cls, tables, classes=None) -> "Detections":
        """Single-frame empty result (frame smaller than one window)."""
        if classes is not None:
            nc = len(classes)
            return cls(np.zeros((nc, 0), np.float32),
                       np.zeros((nc, 0), np.int32), np.zeros((nc, 0), bool),
                       np.zeros((nc,), np.int32), tables, _lists=[[]],
                       classes=classes)
        return cls(np.zeros((0,), np.float32), np.zeros((0,), np.int32),
                   np.zeros((0,), bool), 0, tables, _lists=[[]])

    @classmethod
    def empty_batch(cls, tables, n: int, classes=None) -> "Detections":
        """Batched empty result: n frames, zero candidate slots each."""
        lists = [[] for _ in range(n)]
        if classes is not None:
            nc = len(classes)
            return cls(np.zeros((n, nc, 0), np.float32),
                       np.zeros((n, nc, 0), np.int32),
                       np.zeros((n, nc, 0), bool),
                       np.zeros((n, nc), np.int32), tables, _lists=lists,
                       classes=classes)
        return cls(np.zeros((n, 0), np.float32), np.zeros((n, 0), np.int32),
                   np.zeros((n, 0), bool), np.zeros((n,), np.int32), tables,
                   _lists=lists)

    @classmethod
    def from_list(cls, dets: Sequence[Dict[str, Any]]) -> "Detections":
        """Wrap host-side detection dicts (e.g. tracker output). Extra
        keys (track_id, class_id, hits, ...) are preserved by
        to_list()."""
        dets = list(dets)
        boxes = np.asarray([d["box"] for d in dets],
                           np.float32).reshape(-1, 4)
        scores = np.asarray([d["score"] for d in dets], np.float32)
        scales = np.asarray([d.get("scale", 1.0) for d in dets], np.float32)
        k = len(dets)
        tables = DecodeTables(boxes, scales, k)
        return cls(scores, np.arange(k, dtype=np.int32),
                   np.ones((k,), bool), k, tables, _lists=[dets])

    @classmethod
    def stack(cls, dets: Sequence["Detections"]) -> "Detections":
        """Stack single-frame results that share decode tables into one
        batched result (the inverse of .frame(i))."""
        dets = list(dets)
        if not dets:
            raise ValueError("stack() needs at least one Detections")
        if any(d.batched for d in dets):
            raise ValueError("stack() takes single-frame Detections")
        t0 = dets[0]._tables
        c0 = dets[0]._classes
        for d in dets[1:]:
            same = d._tables is t0 or (
                d._tables.k == t0.k
                and np.array_equal(d._tables.boxes, t0.boxes)
                and np.array_equal(d._tables.scales, t0.scales))
            if not same:
                raise ValueError("stack() needs results from the same "
                                 "compiled program (same decode tables)")
            if d._classes != c0:
                raise ValueError("stack() needs results with the same "
                                 "class names")
        nv = [np.asarray(d._n_valid, np.int32) for d in dets] \
            if c0 is not None else \
            [np.int32(int(d._n_valid)) for d in dets]
        return cls(np.stack([np.asarray(d._scores) for d in dets]),
                   np.stack([np.asarray(d._index) for d in dets]),
                   np.stack([np.asarray(d._keep) for d in dets]),
                   np.stack(nv), t0, classes=c0)

    # -------------------------------------------------------- structure
    @property
    def classes(self) -> Optional[Tuple[str, ...]]:
        """Head names on a multi-class result, None on single-head."""
        return self._classes

    @property
    def batched(self) -> bool:
        return np.ndim(self._scores) == (3 if self._classes else 2)

    @property
    def batch_size(self) -> int:
        if not self.batched:
            raise ValueError("single-frame Detections has no batch axis")
        return int(np.shape(self._scores)[0])

    def frame(self, i: int) -> "Detections":
        """Slice one frame out of a batched result (no host sync)."""
        if not self.batched:
            raise ValueError("frame() on a single-frame Detections")
        lists = None if self._lists is None else [self._lists[i]]
        return Detections(self._scores[i], self._index[i], self._keep[i],
                          self._n_valid[i], self._tables, _lists=lists,
                          classes=self._classes)

    def for_class(self, c) -> "Detections":
        """Slice one head (by name or index) out of a multi-class
        result, as a plain single-head Detections."""
        if self._classes is None:
            raise ValueError("for_class() on a single-head Detections")
        k = self._classes.index(c) if isinstance(c, str) else int(c)
        sl = (slice(None), k) if self.batched else k
        nv = np.asarray(self._n_valid)[sl]
        return Detections(self._scores[sl], self._index[sl], self._keep[sl],
                          nv if self.batched else int(nv), self._tables)

    def block_until_ready(self) -> "Detections":
        """Wait for the device computation backing this result."""
        jax.block_until_ready((self._scores, self._index,
                               self._keep, self._n_valid))
        return self

    # ----------------------------------------------------------- decode
    @property
    def saturated(self):
        """True when more candidates cleared the score threshold than
        the program's top-k (`max_detections`) could hold -- the tail
        was dropped BEFORE NMS. bool for a frame, (B,) array per batch;
        with a class axis the array keeps it ((K,) / (B, K)), one flag
        per head."""
        n_valid = np.asarray(self._n_valid)
        if self.batched or self._classes is not None:
            return n_valid > self._tables.k
        return bool(int(n_valid) > self._tables.k)

    def _decode_slots(self, top, idx, kp, n_valid, label=None) -> List[dict]:
        n_valid = int(n_valid)
        if n_valid > self._tables.k:
            who = f" (head '{label}')" if label is not None else ""
            warnings.warn(
                f"{n_valid} detection candidates cleared the "
                f"threshold but max_detections={self._tables.k}{who}; the "
                f"lowest-scoring {n_valid - self._tables.k} were "
                f"dropped before NMS (lowest kept score {top[-1]:.3f})",
                RuntimeWarning, stacklevel=5)
        kept = np.flatnonzero(kp & np.isfinite(top))
        boxes = self._tables.boxes[idx[kept]]
        scales = self._tables.scales[idx[kept]]
        return [{"box": tuple(float(v) for v in boxes[r]),
                 "score": float(top[kept[r]]),
                 "scale": float(scales[r])}
                for r in range(len(kept))]

    def _decode_frame(self, scores, index, keep, n_valid) -> List[dict]:
        top = np.asarray(scores)
        idx = np.asarray(index)
        kp = np.asarray(keep)
        if self._classes is None:
            return self._decode_slots(top, idx, kp, n_valid)
        # class axis: each head's slots decode independently (each had
        # its own device NMS), then merge by descending score -- the
        # stable sort keeps head order on ties
        merged: List[dict] = []
        nv = np.asarray(n_valid)
        for ci, name in enumerate(self._classes):
            for d in self._decode_slots(top[ci], idx[ci], kp[ci], nv[ci],
                                        label=name):
                d["class_id"] = ci
                d["label"] = name
                merged.append(d)
        merged.sort(key=lambda d: -d["score"])
        return merged

    def _decoded(self) -> list:
        if self._lists is None:
            if self.batched:
                top = np.asarray(self._scores)
                idx = np.asarray(self._index)
                kp = np.asarray(self._keep)
                nv = np.asarray(self._n_valid)
                self._lists = [self._decode_frame(top[i], idx[i], kp[i],
                                                  nv[i])
                               for i in range(top.shape[0])]
            else:
                self._lists = [self._decode_frame(
                    self._scores, self._index, self._keep, self._n_valid)]
        return self._lists

    def to_list(self):
        """The legacy host contract: list of detection dicts for a
        frame, list of per-frame lists for a batch. Multi-class dicts
        additionally carry `class_id` and `label`."""
        lists = self._decoded()
        return lists if self.batched else lists[0]

    # ---------------------------------------------- kept-array accessors
    def _kept(self) -> List[dict]:
        if self.batched:
            raise ValueError("array accessors are per-frame; use "
                             ".frame(i) or .to_list() on a batch")
        return self._decoded()[0]

    @property
    def boxes(self) -> np.ndarray:
        """(M, 4) kept boxes as (y0, x0, y1, x1), descending score."""
        return np.asarray([d["box"] for d in self._kept()],
                          np.float32).reshape(-1, 4)

    @property
    def scores(self) -> np.ndarray:
        return np.asarray([d["score"] for d in self._kept()], np.float32)

    @property
    def scales(self) -> np.ndarray:
        return np.asarray([d["scale"] for d in self._kept()], np.float32)

    @property
    def class_ids(self) -> np.ndarray:
        """(M,) head index per kept detection (zeros on single-head)."""
        return np.asarray([d.get("class_id", 0) for d in self._kept()],
                          np.int32)

    def __len__(self) -> int:
        """Batch: number of frames. Single frame: kept detections."""
        return self.batch_size if self.batched else len(self._kept())

    def __iter__(self) -> Iterator:
        """Batch: per-frame Detections. Single frame: detection dicts."""
        if self.batched:
            return (self.frame(i) for i in range(self.batch_size))
        return iter(self._kept())

    def __repr__(self) -> str:
        cl = f", classes={len(self._classes)}" if self._classes else ""
        if self.batched:
            return (f"Detections(batch={self.batch_size}, "
                    f"k={self._tables.k}{cl})")
        if self._lists is not None:
            return f"Detections(n={len(self._lists[0])}, decoded{cl})"
        return f"Detections(k={self._tables.k}, device-resident{cl})"


def _flatten(d: Detections):
    return ((d._scores, d._index, d._keep, d._n_valid),
            (d._tables, d._classes))


def _unflatten(aux, children) -> Detections:
    return Detections(*children, aux[0], classes=aux[1])


jax.tree_util.register_pytree_node(Detections, _flatten, _unflatten)
