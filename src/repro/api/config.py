"""The unified configuration tree of the detection API.

Before this layer every host-facing entry point carried its own config
surface (`DetectorConfig`, `HOGConfig`, `TrackerConfig`,
`SVMTrainConfig`, plus loose `DetectionService` kwargs), so composing a
deployment meant threading four dataclasses through five call sites.
`PipelineConfig` is the one tree the session facade (api/session.py)
consumes: it nests all of them plus the serving knobs, keeps the HOG
geometry single-sourced (`detector.hog` always equals `hog`), and
round-trips through plain JSON so a deployment's exact configuration
can be checked in, diffed, and shipped to a service.

Presets fold in the paper-workload variants from configs/hog_svm.py:

    presets("paper")      sector-compare binning (TPU-native default)
    presets("faithful")   CORDIC magnitude/angle + NR rsqrt datapath
    presets("perf")       bf16 descriptors through the dense-grid fused
                          Pallas backend (whole-scene HOG tiled over
                          VMEM row slabs + MXU matmul scoring with f32
                          accumulation) and autotuned batch scheduling
                          (batch_chunk=0: scan-vs-vmap probed per
                          (bucket, B) at first use)
    presets("sharded")    every visible device on the batch axis
                          (detector.data_parallel=0 resolves to
                          jax.device_count() at first use): detect_batch
                          / stream / serve shard B/n_devices frames per
                          chip over the 'data' mesh, autotuned per-device
                          schedule -- the multi-device serving default
    presets("uhd")        intra-frame parallelism for big frames:
                          frames >= 1280x720 split their pyramid over
                          every visible device (detector.frame_parallel=0,
                          row-slab tiles, banded resize) with an exact
                          top-k merge -- single-frame UHD latency path,
                          box-identical to untiled (DESIGN.md §11)
    presets("quant")      the paper's fixed-point datapath end to end:
                          integer CORDIC gradient/bin unit, int16 cell
                          histograms, int8 block descriptors with
                          per-block scale, int8x int8->int32 MXU scoring
                          (HOGConfig.numerics="fixed", DESIGN.md §12).
                          Accuracy within 1.5 points of fp32 on the
                          paper's Table I split (bench_accuracy.py);
                          byte-identical under data/tile sharding
    presets("cascade")    two-stage scheduling: the half-resolution
                          coarse head rejects empty neighbourhoods at a
                          loose threshold and the full dense chain runs
                          only on surviving snapped crops, with
                          tracker-predicted boxes promoted past the
                          coarse gate on video (core/cascade.py,
                          DESIGN.md §13)
    presets("resilient")  the serving-SLO variant: 500 ms per-request
                          deadlines, supervised-worker retry/backoff, a
                          5-failure circuit breaker, and the cascade-
                          backed degradation ladder (p99 >= 120 ms or
                          32 pending frames drops a rung; DESIGN.md §14)
    presets("default")    the plain DetectorConfig defaults

`presets()` lists the registered names; `register_preset` adds
deployment-local ones.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.core.cascade import CascadeConfig
from repro.core.detector import DetectorConfig
from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.svm import SVMTrainConfig
from repro.core.video import TrackerConfig
from repro.obs.metrics import MetricsConfig
from repro.serve.resilience import ResilienceConfig, RetryPolicy


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the DetectionService front-end (serve/engine.py)."""

    window_batch: int = 64        # padded micro-batch of the window path
    max_wait_ms: float = 2.0      # straggler deadline when coalescing
    frame_batch: int = 8          # frames per batched detection step
    max_pending_frames: int = 256  # backpressure bound (ServiceOverloaded)
    # deadlines / retry / breaker / degradation ladder (DESIGN.md §14);
    # the defaults are inert -- supervision and transient retry are
    # always on, deadlines and the ladder only when configured
    resilience: ResilienceConfig = ResilienceConfig()
    # structured-event export (obs/metrics.py, DESIGN.md §15); the
    # default is disabled -- a jsonl_path or ring size turns it on
    metrics: MetricsConfig = MetricsConfig()


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything one detection deployment needs, as one pytree.

    `hog` is the single source of truth for the window geometry and
    numerics mode: `detector.hog` is forced to match it at construction
    (passing a non-default `detector.hog` with a default `hog` promotes
    the detector's -- whichever was explicitly set wins).
    """

    name: str = "default"
    hog: HOGConfig = PAPER_HOG
    detector: DetectorConfig = DetectorConfig()
    tracker: TrackerConfig = TrackerConfig()
    train: SVMTrainConfig = SVMTrainConfig()
    service: ServiceConfig = ServiceConfig()
    cascade: CascadeConfig = CascadeConfig()

    def __post_init__(self):
        if self.detector.hog != self.hog:
            if self.hog == PAPER_HOG:
                object.__setattr__(self, "hog", self.detector.hog)
            else:
                object.__setattr__(
                    self, "detector",
                    dataclasses.replace(self.detector, hog=self.hog))

    # -------------------------------------------------- JSON round trip
    def to_dict(self) -> Dict[str, Any]:
        """Nested plain-python dict (json.dumps-able as is)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PipelineConfig":
        """Inverse of to_dict; accepts JSON-decoded dicts (lists become
        the tuples the dataclasses expect). `from_dict(to_dict(p)) == p`."""
        return _build(cls, d)

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "PipelineConfig":
        return cls.from_dict(json.loads(s))

    def replace(self, **kw) -> "PipelineConfig":
        return dataclasses.replace(self, **kw)


def _build(cls, d: Dict[str, Any]):
    """Reconstruct a (nested) config dataclass from a plain dict. Field
    types are taken from the class defaults -- every field of the config
    tree has an instance default, so no annotation parsing is needed."""
    kwargs = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        if dataclasses.is_dataclass(f.default) and isinstance(v, dict):
            v = _build(type(f.default), v)
        elif isinstance(v, list):        # JSON has no tuples
            v = tuple(v)
        kwargs[f.name] = v
    return cls(**kwargs)


# ------------------------------------------------------- preset registry

_PRESETS: Dict[str, PipelineConfig] = {}


def register_preset(name: str, cfg: PipelineConfig) -> PipelineConfig:
    _PRESETS[name] = cfg
    return cfg


def presets(name: Optional[str] = None):
    """presets() -> registered names; presets(name) -> PipelineConfig."""
    if name is None:
        return tuple(sorted(_PRESETS))
    try:
        return _PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown preset {name!r}; registered: "
            f"{', '.join(sorted(_PRESETS))}") from None


def _register_builtin() -> None:
    # deferred import: configs/hog_svm pulls in the synthetic-data module
    from repro.configs import hog_svm

    register_preset("default", PipelineConfig())
    register_preset("paper", PipelineConfig(
        name="paper", hog=hog_svm.CONFIG,
        detector=DetectorConfig(hog=hog_svm.CONFIG, score_threshold=0.5),
        train=hog_svm.TRAIN))
    register_preset("faithful", PipelineConfig(
        name="faithful", hog=hog_svm.FAITHFUL,
        detector=DetectorConfig(hog=hog_svm.FAITHFUL, score_threshold=0.5),
        train=hog_svm.TRAIN))
    # perf: dense-grid fused Pallas HOG (fused_hog.dense_fused_hog, bf16
    # descriptors) feeding the MXU matmul scorer with f32 accumulation;
    # batch_chunk=0 autotunes the detect_batch scan-vs-vmap schedule
    register_preset("perf", PipelineConfig(
        name="perf", hog=hog_svm.PERF,
        detector=DetectorConfig(hog=hog_svm.PERF, score_threshold=0.5,
                                backend="fused", batch_chunk=0),
        train=hog_svm.TRAIN))
    # sharded: the paper numerics on every visible device -- the frame
    # batch rides the 'data' mesh axis (core/detector.py sharded path),
    # with the per-device scan-vs-vmap schedule autotuned at first use
    register_preset("sharded", PipelineConfig(
        name="sharded", hog=hog_svm.CONFIG,
        detector=DetectorConfig(hog=hog_svm.CONFIG, score_threshold=0.5,
                                data_parallel=0, batch_chunk=0),
        train=hog_svm.TRAIN))
    # uhd: single-frame latency on big frames -- every visible device
    # tiles ONE frame's pyramid (frame_parallel=0, row-slab mode) with
    # the banded O(taps)-per-pixel resize; frames below 1280x720 keep
    # the untiled program. max_detections=0 scales top-k with the
    # window grid so 4K frames don't saturate. See DESIGN.md §11.
    register_preset("uhd", PipelineConfig(
        name="uhd", hog=hog_svm.CONFIG,
        detector=DetectorConfig(hog=hog_svm.CONFIG, score_threshold=0.5,
                                frame_parallel=0, tile_mode="slab",
                                pyramid_resize="banded",
                                frame_parallel_min_area=1280 * 720,
                                batch_chunk=0),
        train=hog_svm.TRAIN))
    # quant: the hardware paper's fixed-point datapath as a first-class
    # mode -- numerics="fixed" routes every backend through the integer
    # CORDIC mag/bin unit, int16 histograms, per-block int8 descriptors
    # and the int8 x int8 -> int32 scoring matmul (fused dense backend,
    # autotuned schedule). See DESIGN.md §12 and the BENCH "quant"
    # section for int8-vs-bf16 scoring timings.
    register_preset("quant", PipelineConfig(
        name="quant", hog=hog_svm.QUANT,
        detector=DetectorConfig(hog=hog_svm.QUANT, score_threshold=0.5,
                                backend="fused", batch_chunk=0),
        train=hog_svm.TRAIN))
    # cascade: two-stage scheduling -- the 66x34 half-resolution coarse
    # head sweeps the frame at a loose threshold and only its hit
    # neighbourhoods run the full dense chain (core/cascade.py,
    # DESIGN.md §13). session.cascade() builds the scheduler; BENCH
    # "cascade" records the retention/speedup gate.
    register_preset("cascade", PipelineConfig(
        name="cascade", hog=hog_svm.CONFIG,
        detector=DetectorConfig(hog=hog_svm.CONFIG, score_threshold=0.5),
        train=hog_svm.TRAIN,
        cascade=CascadeConfig(enabled=True)))
    # resilient: the serving-SLO deployment -- 500 ms request budgets
    # shed doomed work pre-compute, the cascade rungs back the
    # degradation ladder (full -> cascade -> coarse on overload, with
    # hysteresis), and the breaker fail-fasts admission after repeated
    # worker deaths (serve/resilience.py, DESIGN.md §14).
    register_preset("resilient", PipelineConfig(
        name="resilient", hog=hog_svm.CONFIG,
        detector=DetectorConfig(hog=hog_svm.CONFIG, score_threshold=0.5),
        train=hog_svm.TRAIN,
        cascade=CascadeConfig(enabled=True),
        service=ServiceConfig(resilience=ResilienceConfig(
            deadline_ms=500.0,
            retry=RetryPolicy(max_attempts=3, backoff_base_ms=5.0,
                              backoff_cap_ms=200.0),
            breaker_failures=5, breaker_reset_s=5.0,
            degrade_p99_ms=120.0, degrade_depth=32,
            recover_dwell=3))))


_register_builtin()
