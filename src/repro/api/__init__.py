# The unified detection API: one config tree, one typed result, one
# session facade over the image / batch / video / service paths.
# (DESIGN.md §8; the paper's one-command co-processor interface, §VI.)
from repro.api.config import (PipelineConfig, ServiceConfig, presets,
                              register_preset)
from repro.api.results import Detections
from repro.api.session import DetectionSession
