# The unified detection API: one config tree, one typed result, one
# session facade over the image / batch / video / service paths.
# (DESIGN.md §8; the paper's one-command co-processor interface, §VI.)
# Multi-class heads + two-stage cascade ride the same facade
# (DESIGN.md §13): HeadRegistry-backed sessions score K heads in one
# widened matmul; session.cascade() builds the coarse-reject scheduler.
from repro.api.config import (PipelineConfig, ServiceConfig, presets,
                              register_preset)
from repro.api.results import Detections
from repro.api.session import DetectionSession
from repro.core.cascade import CascadeConfig, CascadeDetector
from repro.core.heads import HeadRegistry, SVMHead
