"""DetectionSession -- the one host-facing entry point of the system.

The paper's co-processor has a single command interface the host CPU
drives (§VI); the repro had grown five -- `detect()`,
`FrameDetector.__call__`, `detect_batch`, `VideoDetector.process_clip`,
`DetectionService.detect_frames` -- each with its own config and result
shape. `DetectionSession` owns the SVM parameters and the compiled
detection programs once, and exposes every path behind one facade built
from one `PipelineConfig`:

    session = DetectionSession.train(presets("paper"))   # or (svm, cfg)
    session.warmup([(480, 640), (8, 480, 640)])          # compile ahead
    dets   = session.detect(frame)          # -> Detections (lazy decode)
    batch  = session.detect_batch(frames)   # -> batched Detections
    frames = session.stream(clip)           # -> tracked, per-frame
    svc    = session.serve().start()        # -> DetectionService

Compiled-program policy: programs are cached per frame-shape bucket in
the module-level lru caches of core/detector.py (shared across sessions
with equal configs -- a second session costs nothing). `warmup(shapes)`
compiles ahead of traffic, `cache_stats()` reports hits/misses/size,
`clear_cache()` evicts (process-wide; documented in DESIGN.md §8).

SVM parameters round-trip through checkpoint/manager.py
(`session.save(dir)` / `DetectionSession.load(dir, cfg)`), so CLI runs
and services skip retraining.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import PipelineConfig, presets
from repro.api.results import Detections
from repro.core.detector import (FrameDetector, _batch_fn, _frame_program,
                                 _sharded_batch_fn, _single_fn,
                                 _tile_local_fn, _tiled_batch_fn,
                                 _tiled_single_fn)
from repro.core.heads import HeadRegistry
from repro.core.hog import hog_descriptor
from repro.core.svm import SVMParams, train_svm
from repro.core.video import Tracker

ConfigLike = Union[PipelineConfig, str, None]


def _as_config(config: ConfigLike) -> PipelineConfig:
    if config is None:
        return PipelineConfig()
    if isinstance(config, str):
        return presets(config)
    return config


class DetectionSession:
    """SVM params + one PipelineConfig -> every detection path.

    Construct with trained params, or via `train` (synthetic data,
    config.train schedule) or `load` (checkpoint directory).
    """

    def __init__(self, svm: Union[SVMParams, HeadRegistry],
                 config: ConfigLike = None):
        self.config = _as_config(config)
        if isinstance(svm, HeadRegistry):
            # multi-head session: stack every public head into one
            # widened parameter block (core/heads.py); per-head
            # threshold overrides land in class_thresholds, and the
            # head names ride into every Detections as class labels
            self.registry: Optional[HeadRegistry] = svm
            stacked, names, thresholds = svm.stacked()
            det_cfg = self.config.detector
            resolved = tuple(det_cfg.score_threshold if t is None else t
                             for t in thresholds)
            det_cfg = dataclasses.replace(det_cfg,
                                          class_thresholds=resolved)
            self.svm = stacked
            self.detector = FrameDetector(stacked, det_cfg, classes=names)
        else:
            self.registry = None
            self.svm = svm
            self.detector = FrameDetector(svm, self.config.detector)
        self._class_detectors: Dict[Tuple[str, ...], FrameDetector] = {}
        self.train_losses = None       # set by train()
        self.mined_negatives = 0       # hard negatives added by train()
        self._warm: set = set()
        self._stats = {"frames": 0, "batches": 0, "clips": 0}

    # ------------------------------------------------------ construction
    @classmethod
    def train(cls, config: ConfigLike = None, n_pos: int = 1500,
              n_neg: int = 1000, seed: int = 0, data_cfg=None,
              rng: Optional[np.random.Generator] = None,
              hard_negative_rounds: int = 0, mine_scenes: int = 16
              ) -> "DetectionSession":
        """Train the SVM on synthetic pedestrian windows using the
        tree's `hog` geometry and `train` schedule. Pass `rng` to
        share a caller's stream (it advances by the window draws).

        `hard_negative_rounds` > 0 adds that many bootstrapping rounds
        (data/mining.py): each sweeps the current head over
        `mine_scenes` person-free scenes at a loose threshold and
        retrains with the firing windows as extra negatives -- the fix
        for the dense-scan domain gap (downscaled pyramid levels are
        smoother than any window-sized training negative), and what the
        cascade's retention contract is calibrated against."""
        from repro.data.mining import mine_hard_negatives
        from repro.data.synth_pedestrian import (PedestrianDataConfig,
                                                 make_windows)
        config = _as_config(config)
        if rng is None:
            rng = np.random.default_rng(seed)
        x, y = make_windows(n_pos, n_neg,
                            data_cfg or PedestrianDataConfig(), rng)
        feats = np.asarray(hog_descriptor(jnp.asarray(x), config.hog))
        labels = np.asarray(y)
        svm, losses = train_svm(jnp.asarray(feats), jnp.asarray(labels),
                                config.train)
        mined = 0
        for _ in range(int(hard_negative_rounds)):
            neg = mine_hard_negatives(svm, config.detector, mine_scenes,
                                      rng)
            if not len(neg):
                break
            mined += len(neg)
            feats = np.concatenate(
                [feats, np.asarray(hog_descriptor(jnp.asarray(neg),
                                                  config.hog))])
            labels = np.concatenate(
                [labels, np.zeros(len(neg), labels.dtype)])
            svm, losses = train_svm(jnp.asarray(feats),
                                    jnp.asarray(labels), config.train)
        session = cls(svm, config)
        session.train_losses = losses
        session.mined_negatives = mined
        return session

    @classmethod
    def load(cls, path: str, config: ConfigLike = None,
             step: Optional[int] = None) -> "DetectionSession":
        """Restore SVM params saved by `save` (checkpoint/manager.py
        layout); `step=None` takes the latest committed step. A
        directory carrying a `heads.json` manifest restores as a
        multi-head session (HeadRegistry round-trip)."""
        from repro.checkpoint.manager import CheckpointManager
        config = _as_config(config)
        if HeadRegistry.is_registry_checkpoint(path):
            return cls(HeadRegistry.load(path, step), config)
        mgr = CheckpointManager(path)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
        skeleton = {
            "w": jax.ShapeDtypeStruct((config.hog.n_features,), jnp.float32),
            "b": jax.ShapeDtypeStruct((), jnp.float32)}
        return cls(mgr.restore(step, skeleton), config)

    def save(self, path: str, step: int = 0) -> None:
        """Persist the SVM params (atomic-commit checkpoint layout); a
        registry-backed session writes the multi-head layout (parameter
        pytree + heads.json) that `load` detects."""
        from repro.checkpoint.manager import CheckpointManager
        if self.registry is not None:
            self.registry.save(path, step)
            return
        CheckpointManager(path).save(step, self.svm)

    # ------------------------------------------------------------ facade
    def _detector_for(self, classes) -> FrameDetector:
        """The compiled-program handle scoring `classes`: the default
        stacked detector for None, else a cached per-subset handle
        (its own stacked block + thresholds; programs per bucket are
        shared process-wide via the detector's lru caches)."""
        if classes is None:
            return self.detector
        if self.registry is None:
            raise ValueError(
                "detect(classes=...) needs a HeadRegistry-backed "
                "session; this one holds plain single-head params")
        names = (classes,) if isinstance(classes, str) else tuple(classes)
        det = self._class_detectors.get(names)
        if det is None:
            stacked, names, thresholds = self.registry.stacked(names)
            det_cfg = self.config.detector
            resolved = tuple(det_cfg.score_threshold if t is None else t
                             for t in thresholds)
            det_cfg = dataclasses.replace(det_cfg,
                                          class_thresholds=resolved)
            det = FrameDetector(stacked, det_cfg, classes=names)
            self._class_detectors[names] = det
        return det

    def detect(self, image, classes=None) -> Detections:
        """One frame -> Detections (device-resident, lazy decode).
        `classes` picks a head subset on a registry-backed session (a
        name or sequence of names; None = every public head)."""
        self._stats["frames"] += 1
        return self._detector_for(classes).detect_raw(image)

    def detect_batch(self, frames, classes=None) -> Detections:
        """Stacked (B, H, W[, 3]) array or frame list -> one batched
        Detections; same one-bucket-per-call contract as the detector.
        With `config.detector.data_parallel != 1` the batch runs
        sharded, B/n_devices frames per device (pad-and-mask for
        non-divisible B; results byte-identical to single-device).
        `classes` picks a head subset on a registry-backed session."""
        self._stats["batches"] += 1
        return self._detector_for(classes).detect_batch_raw(frames)

    @property
    def data_devices(self) -> int:
        """Devices the batch axis resolves to (1 = unsharded)."""
        return self.detector.data_devices

    def stream(self, frames, batch_size: int = 8,
               tracker: Optional[Tracker] = None) -> List[Detections]:
        """Recorded clip -> per-frame TRACKED detections.

        Detection runs through the batched device path in `batch_size`
        chunks; the IoU tracker (config.tracker) associates in frame
        order, so `to_list()` entries carry track_id/hits/misses. Pass
        a Tracker to keep identities across multiple stream() calls.
        """
        self._stats["clips"] += 1
        trk = Tracker(self.config.tracker) if tracker is None else tracker
        n = len(frames)
        out: List[Detections] = []
        for i in range(0, n, max(1, batch_size)):
            chunk = [frames[j] for j in range(i, min(i + batch_size, n))]
            per_frame = (self.detector.detect_batch(chunk)
                         if len(chunk) > 1 else [self.detector(chunk[0])])
            out.extend(Detections.from_list(trk.update(d))
                       for d in per_frame)
        return out

    def cascade(self, coarse_svm: Optional[SVMParams] = None,
                rng: Optional[np.random.Generator] = None):
        """Build the two-stage CascadeDetector (core/cascade.py) over
        THIS session's fine detector: a half-resolution coarse head
        sweeps each frame at `config.cascade.coarse_threshold` and only
        its hit neighbourhoods run the dense chain. The coarse params
        come from (in order) the `coarse_svm` argument, the registry's
        auxiliary "_coarse" head, or a fresh synthetic training run
        (cached back into the registry when one is present)."""
        from repro.core.cascade import (_COARSE_NAME, CascadeDetector,
                                        coarse_detector, train_coarse_head)
        ccfg = self.config.cascade
        if coarse_svm is None:
            if self.registry is not None and _COARSE_NAME in self.registry:
                coarse_svm = self.registry.single(_COARSE_NAME)
            else:
                coarse_svm, _ = train_coarse_head(
                    self.config.hog, self.config.train, rng=rng)
                if self.registry is not None:
                    self.registry.add(_COARSE_NAME, coarse_svm,
                                      metadata={"role": "cascade-coarse"},
                                      replace=True)
        coarse = coarse_detector(coarse_svm, self.detector.cfg, ccfg)
        return CascadeDetector(self.detector, coarse, ccfg)

    def serve(self, **overrides) -> "DetectionService":
        """Build a DetectionService on THIS session's detector and
        config (service knobs from config.service; any engine kwarg can
        be overridden). Resilience knobs ride along from
        config.service.resilience, and a cascade-enabled config wires
        the session's CascadeDetector as the service's degradation
        rungs (full -> cascade -> coarse, DESIGN.md §14). Caller
        starts/stops it."""
        from repro.serve.engine import DetectionService
        sc = self.config.service
        opts = dict(batch_size=sc.window_batch,
                    cfg=self.config.hog,
                    path=self.config.detector.backend,
                    max_wait_ms=sc.max_wait_ms,
                    detector=self.config.detector,
                    frame_batch=sc.frame_batch,
                    max_pending_frames=sc.max_pending_frames,
                    resilience=sc.resilience,
                    metrics=sc.metrics)
        # an explicit detector override builds its own FrameDetector;
        # otherwise the service shares this session's handle (and with
        # it every already-compiled program). frame_detector rides in
        # opts so callers can override it like any other engine kwarg.
        opts["frame_detector"] = \
            None if "detector" in overrides else self.detector
        if self.config.cascade.enabled and "cascade" not in overrides:
            opts["cascade"] = self.cascade()
        opts.update(overrides)
        return DetectionService(self.svm, **opts)

    # --------------------------------------------- compiled-program cache
    def warmup(self, shapes: Iterable[Tuple[int, ...]]) -> Dict:
        """Compile ahead of traffic. `shapes` mixes (h, w) single-frame
        and (B, h, w) batched entries; each compiles (and runs on a
        zero frame) exactly the program live traffic of that shape
        would hit -- under `detector.data_parallel != 1` a (B, h, w)
        entry compiles the SHARDED per-bucket program (including the
        pad-and-mask variant when B does not divide the mesh), so a
        serving deployment warms the same multi-device executables its
        microbatcher will dispatch. Returns cache_stats()."""
        for s in shapes:
            s = tuple(int(v) for v in s)
            if len(s) == 2:
                d = self.detector.detect_raw(np.zeros(s + (3,), np.uint8))
            elif len(s) == 3:
                d = self.detector.detect_batch_raw(
                    np.zeros(s + (3,), np.uint8))
            else:
                raise ValueError(
                    f"warmup shape must be (h, w) or (B, h, w), got {s}")
            d.block_until_ready()
            self._warm.add(s)
        return self.cache_stats()

    def cache_stats(self) -> Dict:
        """Hit/miss/size counters of the process-wide compiled-program
        caches plus this session's call and warmup bookkeeping. The
        "autotune" section reports how the batch-schedule decisions were
        sourced -- in-memory hit, disk-cache restore, or a live probe --
        plus the resolved cache path (core/autotune_cache.py). The
        "platform" block (repro.platform.describe()) records the
        environment -- backend, device count, x64, XLA flags -- so a
        checked-in stats dump is attributable to the host that made it.
        """
        from repro import platform
        from repro.core import autotune_cache
        fi = _frame_program.cache_info()
        si = _single_fn.cache_info()
        tli = _tile_local_fn.cache_info()
        ti = _tiled_single_fn.cache_info()
        bi = _batch_fn.cache_info()
        shi = _sharded_batch_fn.cache_info()
        tbi = _tiled_batch_fn.cache_info()
        try:
            devices = self.detector.data_devices
        except ValueError:        # config names more devices than exist
            devices = None
        try:
            tiles = self.detector.frame_devices
        except ValueError:
            tiles = None
        return {
            "frame_programs": {"hits": fi.hits + si.hits + ti.hits
                               + tli.hits,
                               "misses": fi.misses + si.misses + ti.misses
                               + tli.misses,
                               "size": fi.currsize + si.currsize
                               + ti.currsize + tli.currsize,
                               "maxsize": fi.maxsize + si.maxsize
                               + ti.maxsize + tli.maxsize},
            "batch_programs": {"hits": bi.hits + shi.hits + tbi.hits,
                               "misses": bi.misses + shi.misses
                               + tbi.misses,
                               "size": bi.currsize + shi.currsize
                               + tbi.currsize,
                               "maxsize": bi.maxsize + shi.maxsize
                               + tbi.maxsize},
            "mesh": {"data_parallel": self.config.detector.data_parallel,
                     "devices": devices,
                     "frame_parallel": self.config.detector.frame_parallel,
                     "tile_devices": tiles},
            "autotune": autotune_cache.stats(),
            "platform": platform.describe(),
            "warmed": sorted(self._warm),
            "calls": dict(self._stats),
        }

    def clear_cache(self) -> None:
        """Evict ALL compiled detection programs (process-wide: the
        caches are shared by every session/detector in the process)."""
        _frame_program.cache_clear()
        _single_fn.cache_clear()
        _batch_fn.cache_clear()
        _sharded_batch_fn.cache_clear()
        _tile_local_fn.cache_clear()
        _tiled_single_fn.cache_clear()
        _tiled_batch_fn.cache_clear()
        self._warm.clear()
