"""DetectionSession -- the one host-facing entry point of the system.

The paper's co-processor has a single command interface the host CPU
drives (§VI); the repro had grown five -- `detect()`,
`FrameDetector.__call__`, `detect_batch`, `VideoDetector.process_clip`,
`DetectionService.detect_frames` -- each with its own config and result
shape. `DetectionSession` owns the SVM parameters and the compiled
detection programs once, and exposes every path behind one facade built
from one `PipelineConfig`:

    session = DetectionSession.train(presets("paper"))   # or (svm, cfg)
    session.warmup([(480, 640), (8, 480, 640)])          # compile ahead
    dets   = session.detect(frame)          # -> Detections (lazy decode)
    batch  = session.detect_batch(frames)   # -> batched Detections
    frames = session.stream(clip)           # -> tracked, per-frame
    svc    = session.serve().start()        # -> DetectionService

Compiled-program policy: programs are cached per frame-shape bucket in
the module-level lru caches of core/detector.py (shared across sessions
with equal configs -- a second session costs nothing). `warmup(shapes)`
compiles ahead of traffic, `cache_stats()` reports hits/misses/size,
`clear_cache()` evicts (process-wide; documented in DESIGN.md §8).

SVM parameters round-trip through checkpoint/manager.py
(`session.save(dir)` / `DetectionSession.load(dir, cfg)`), so CLI runs
and services skip retraining.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import PipelineConfig, presets
from repro.api.results import Detections
from repro.core.detector import (FrameDetector, _batch_fn, _frame_program,
                                 _sharded_batch_fn, _single_fn,
                                 _tile_local_fn, _tiled_batch_fn,
                                 _tiled_single_fn)
from repro.core.hog import hog_descriptor
from repro.core.svm import SVMParams, train_svm
from repro.core.video import Tracker

ConfigLike = Union[PipelineConfig, str, None]


def _as_config(config: ConfigLike) -> PipelineConfig:
    if config is None:
        return PipelineConfig()
    if isinstance(config, str):
        return presets(config)
    return config


class DetectionSession:
    """SVM params + one PipelineConfig -> every detection path.

    Construct with trained params, or via `train` (synthetic data,
    config.train schedule) or `load` (checkpoint directory).
    """

    def __init__(self, svm: SVMParams, config: ConfigLike = None):
        self.config = _as_config(config)
        self.svm = svm
        self.detector = FrameDetector(svm, self.config.detector)
        self.train_losses = None       # set by train()
        self._warm: set = set()
        self._stats = {"frames": 0, "batches": 0, "clips": 0}

    # ------------------------------------------------------ construction
    @classmethod
    def train(cls, config: ConfigLike = None, n_pos: int = 1500,
              n_neg: int = 1000, seed: int = 0, data_cfg=None,
              rng: Optional[np.random.Generator] = None
              ) -> "DetectionSession":
        """Train the SVM on synthetic pedestrian windows using the
        tree's `hog` geometry and `train` schedule. Pass `rng` to
        share a caller's stream (it advances by the window draws)."""
        from repro.data.synth_pedestrian import (PedestrianDataConfig,
                                                 make_windows)
        config = _as_config(config)
        if rng is None:
            rng = np.random.default_rng(seed)
        x, y = make_windows(n_pos, n_neg,
                            data_cfg or PedestrianDataConfig(), rng)
        feats = hog_descriptor(jnp.asarray(x), config.hog)
        svm, losses = train_svm(feats, jnp.asarray(y), config.train)
        session = cls(svm, config)
        session.train_losses = losses
        return session

    @classmethod
    def load(cls, path: str, config: ConfigLike = None,
             step: Optional[int] = None) -> "DetectionSession":
        """Restore SVM params saved by `save` (checkpoint/manager.py
        layout); `step=None` takes the latest committed step."""
        from repro.checkpoint.manager import CheckpointManager
        config = _as_config(config)
        mgr = CheckpointManager(path)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
        skeleton = {
            "w": jax.ShapeDtypeStruct((config.hog.n_features,), jnp.float32),
            "b": jax.ShapeDtypeStruct((), jnp.float32)}
        return cls(mgr.restore(step, skeleton), config)

    def save(self, path: str, step: int = 0) -> None:
        """Persist the SVM params (atomic-commit checkpoint layout)."""
        from repro.checkpoint.manager import CheckpointManager
        CheckpointManager(path).save(step, self.svm)

    # ------------------------------------------------------------ facade
    def detect(self, image) -> Detections:
        """One frame -> Detections (device-resident, lazy decode)."""
        self._stats["frames"] += 1
        return self.detector.detect_raw(image)

    def detect_batch(self, frames) -> Detections:
        """Stacked (B, H, W[, 3]) array or frame list -> one batched
        Detections; same one-bucket-per-call contract as the detector.
        With `config.detector.data_parallel != 1` the batch runs
        sharded, B/n_devices frames per device (pad-and-mask for
        non-divisible B; results byte-identical to single-device)."""
        self._stats["batches"] += 1
        return self.detector.detect_batch_raw(frames)

    @property
    def data_devices(self) -> int:
        """Devices the batch axis resolves to (1 = unsharded)."""
        return self.detector.data_devices

    def stream(self, frames, batch_size: int = 8,
               tracker: Optional[Tracker] = None) -> List[Detections]:
        """Recorded clip -> per-frame TRACKED detections.

        Detection runs through the batched device path in `batch_size`
        chunks; the IoU tracker (config.tracker) associates in frame
        order, so `to_list()` entries carry track_id/hits/misses. Pass
        a Tracker to keep identities across multiple stream() calls.
        """
        self._stats["clips"] += 1
        trk = Tracker(self.config.tracker) if tracker is None else tracker
        n = len(frames)
        out: List[Detections] = []
        for i in range(0, n, max(1, batch_size)):
            chunk = [frames[j] for j in range(i, min(i + batch_size, n))]
            per_frame = (self.detector.detect_batch(chunk)
                         if len(chunk) > 1 else [self.detector(chunk[0])])
            out.extend(Detections.from_list(trk.update(d))
                       for d in per_frame)
        return out

    def serve(self, **overrides) -> "DetectionService":
        """Build a DetectionService on THIS session's detector and
        config (service knobs from config.service; any engine kwarg can
        be overridden). Caller starts/stops it."""
        from repro.serve.engine import DetectionService
        sc = self.config.service
        opts = dict(batch_size=sc.window_batch,
                    cfg=self.config.hog,
                    path=self.config.detector.backend,
                    max_wait_ms=sc.max_wait_ms,
                    detector=self.config.detector,
                    frame_batch=sc.frame_batch,
                    max_pending_frames=sc.max_pending_frames)
        # an explicit detector override builds its own FrameDetector;
        # otherwise the service shares this session's handle (and with
        # it every already-compiled program). frame_detector rides in
        # opts so callers can override it like any other engine kwarg.
        opts["frame_detector"] = \
            None if "detector" in overrides else self.detector
        opts.update(overrides)
        return DetectionService(self.svm, **opts)

    # --------------------------------------------- compiled-program cache
    def warmup(self, shapes: Iterable[Tuple[int, ...]]) -> Dict:
        """Compile ahead of traffic. `shapes` mixes (h, w) single-frame
        and (B, h, w) batched entries; each compiles (and runs on a
        zero frame) exactly the program live traffic of that shape
        would hit -- under `detector.data_parallel != 1` a (B, h, w)
        entry compiles the SHARDED per-bucket program (including the
        pad-and-mask variant when B does not divide the mesh), so a
        serving deployment warms the same multi-device executables its
        microbatcher will dispatch. Returns cache_stats()."""
        for s in shapes:
            s = tuple(int(v) for v in s)
            if len(s) == 2:
                d = self.detector.detect_raw(np.zeros(s + (3,), np.uint8))
            elif len(s) == 3:
                d = self.detector.detect_batch_raw(
                    np.zeros(s + (3,), np.uint8))
            else:
                raise ValueError(
                    f"warmup shape must be (h, w) or (B, h, w), got {s}")
            d.block_until_ready()
            self._warm.add(s)
        return self.cache_stats()

    def cache_stats(self) -> Dict:
        """Hit/miss/size counters of the process-wide compiled-program
        caches plus this session's call and warmup bookkeeping. The
        "autotune" section reports how the batch-schedule decisions were
        sourced -- in-memory hit, disk-cache restore, or a live probe --
        plus the resolved cache path (core/autotune_cache.py)."""
        from repro.core import autotune_cache
        fi = _frame_program.cache_info()
        si = _single_fn.cache_info()
        tli = _tile_local_fn.cache_info()
        ti = _tiled_single_fn.cache_info()
        bi = _batch_fn.cache_info()
        shi = _sharded_batch_fn.cache_info()
        tbi = _tiled_batch_fn.cache_info()
        try:
            devices = self.detector.data_devices
        except ValueError:        # config names more devices than exist
            devices = None
        try:
            tiles = self.detector.frame_devices
        except ValueError:
            tiles = None
        return {
            "frame_programs": {"hits": fi.hits + si.hits + ti.hits
                               + tli.hits,
                               "misses": fi.misses + si.misses + ti.misses
                               + tli.misses,
                               "size": fi.currsize + si.currsize
                               + ti.currsize + tli.currsize,
                               "maxsize": fi.maxsize + si.maxsize
                               + ti.maxsize + tli.maxsize},
            "batch_programs": {"hits": bi.hits + shi.hits + tbi.hits,
                               "misses": bi.misses + shi.misses
                               + tbi.misses,
                               "size": bi.currsize + shi.currsize
                               + tbi.currsize,
                               "maxsize": bi.maxsize + shi.maxsize
                               + tbi.maxsize},
            "mesh": {"data_parallel": self.config.detector.data_parallel,
                     "devices": devices,
                     "frame_parallel": self.config.detector.frame_parallel,
                     "tile_devices": tiles},
            "autotune": autotune_cache.stats(),
            "warmed": sorted(self._warm),
            "calls": dict(self._stats),
        }

    def clear_cache(self) -> None:
        """Evict ALL compiled detection programs (process-wide: the
        caches are shared by every session/detector in the process)."""
        _frame_program.cache_clear()
        _single_fn.cache_clear()
        _batch_fn.cache_clear()
        _sharded_batch_fn.cache_clear()
        _tile_local_fn.cache_clear()
        _tiled_single_fn.cache_clear()
        _tiled_batch_fn.cache_clear()
        self._warm.clear()
