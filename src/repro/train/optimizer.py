"""AdamW built from scratch (no optax in this container) with fp32 master
weights, global-norm clipping, and warmup-cosine schedule. Optimizer state
inherits the parameters' sharding (ZeRO: fully sharded moments)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(step: Array, c: OptConfig) -> Array:
    warm = jnp.minimum(1.0, (step + 1) / max(c.warmup_steps, 1))
    t = jnp.clip((step - c.warmup_steps)
                 / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr * warm * cos


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _is_matrix(p: Array) -> bool:
    return p.ndim >= 2


def adamw_update(grads: Any, state: Dict[str, Any], c: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    """Returns (new bf16/model-dtype params, new state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, c)
    b1, b2 = c.betas
    t = (step + 1).astype(jnp.float32)
    corr = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        u = corr * m2 / (jnp.sqrt(v2) + c.eps)
        if _is_matrix(w):
            u = u + c.weight_decay * w
        return m2, v2, w - lr * u

    flat, treedef = jax.tree.flatten(grads)
    ms = treedef.flatten_up_to(state["m"])
    vs = treedef.flatten_up_to(state["v"])
    ws = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat, ms, vs, ws)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step + 1, "m": new_m, "v": new_v, "master": new_w}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_w, new_state, metrics
