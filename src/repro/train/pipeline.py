"""GPipe-style pipeline parallelism over a mesh axis (the multi-pod
'pod'/'pipe' axis: inter-pod ICI is slow, so only stage boundaries --
one (B_mb, S, D) activation per tick -- cross it).

`gpipe_apply` runs a layer stack split into P contiguous stages across a
1-D mesh axis with M microbatches and the classic (M + P - 1)-tick
schedule; activations hop stages via `lax.ppermute`. Written functionally,
so jax.grad differentiates straight through it (the transpose of ppermute
is the reverse hop): GPipe's backward schedule emerges from autodiff.

Bubble fraction = (P-1)/(M+P-1), reported by `bubble_fraction`. Stage
assignment must be uniform (n_layers % P == 0).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_apply(layer_fn: Callable[[Any, Array], Array],
                layers_params: Any, x_micro: Array, mesh: Mesh,
                axis: str = "pipe"):
    """Run a layer stack as a GPipe pipeline.

    layer_fn(lp, x) -> x: applies ONE layer (lp = that layer's params).
    layers_params: pytree with leading L axis (L % n_stages == 0).
    x_micro: (M, B_mb, S, D) microbatched inputs (replicated over axis).
    Returns (M, B_mb, S, D) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    L = jax.tree.leaves(layers_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    M = x_micro.shape[0]
    ticks = M + n_stages - 1

    def run(local_layers, xs):
        # local_layers: (L/P, ...) this stage's layers; xs: (M, ...)
        sid = jax.lax.axis_index(axis)

        def stage(x):
            def body(c, lp):
                return layer_fn(lp, c), None
            y, _ = jax.lax.scan(body, x, local_layers)
            return y

        def tick(carry, t):
            buf, outs = carry                   # buf: activation entering
            m_in = jnp.clip(t, 0, M - 1)
            inject = (sid == 0) & (t < M)
            x_in = jnp.where(inject, xs[m_in], buf)
            y = stage(x_in)
            out_slot = t - (n_stages - 1)
            collect = (sid == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.dynamic_update_slice_in_dim(
                outs,
                jnp.where(collect, y, jax.lax.dynamic_slice_in_dim(
                    outs, jnp.clip(out_slot, 0, M - 1), 1, axis=0)[0]
                )[None],
                jnp.clip(out_slot, 0, M - 1), axis=0)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages)
                          for i in range(n_stages)])
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(ticks))
        # only the last stage holds real outputs: gather + take last
        # (ppermute is a permutation, so one->all must use all_gather)
        outs = jax.lax.all_gather(outs, axis)[n_stages - 1]
        return outs

    spec_layers = jax.tree.map(lambda _: P(axis), layers_params)
    return shard_map(
        run, mesh=mesh,
        in_specs=(spec_layers, P()),
        out_specs=P(),
        check_vma=False,
    )(layers_params, x_micro)
