"""Training steps: GSPMD/FSDP (big models) and DDP+compression (small).

`make_train_step(cfg, mesh, ...)` returns a jit'able (state, batch) ->
(state, metrics) function with explicit in/out shardings:
  * loss -> grads (remat'd scan over layers)
  * optional microbatch gradient accumulation (lax.scan over microbatches)
  * AdamW on fp32 master weights (ZeRO: states sharded like params)

`make_ddp_train_step` is the shard_map trainer used for small models and
the gradient-compression + straggler-tolerance features: weights are
replicated, the batch is sharded over dp, gradients all-reduce explicitly
(optionally int8-compressed with error feedback).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.configs import ModelConfig
from repro.models.model import loss_fn
from repro.models.moe import ShardingCtx
from repro.sharding.rules import (PROFILES, Profile, batch_specs, dp_axes,
                                  make_ctx, param_shardings, param_specs)
from repro.train.grad_compress import (compress_tree_psum_mean,
                                       init_residuals)
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state)

Array = jax.Array


# ---------------------------------------------------------------- GSPMD

def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    ctx: Optional[ShardingCtx] = None,
                    microbatches: int = 1,
                    constrain_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics). state =
    {"params", "opt"}. Shardings are applied by the caller via jit.

    constrain_grads (§Perf): pin each gradient to its parameter's
    sharding BEFORE the optimizer. Without it GSPMD materializes fully-
    replicated gradients via fp32 all-reduce (~4 bytes/param/device on
    the wire); with it the reduction becomes a reduce-scatter and each
    device only ever holds its 1/N shard.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch, cfg, ctx)

    def pin_grads(grads):
        if not constrain_grads or ctx is None:
            return grads
        from jax.sharding import NamedSharding
        from repro.sharding.rules import fit_tree, param_specs
        specs = fit_tree(param_specs(grads, cfg), grads, ctx.mesh)
        return jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(
                g, NamedSharding(ctx.mesh, sp)), grads, specs)

    def step(state, batch):
        params = state["params"]
        if microbatches > 1:
            def mb(carry, mbatch):
                acc, lsum = carry
                l, g = grads_of(params, mbatch)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            split = jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch)
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, 0.0), split)
            loss = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        else:
            loss, grads = grads_of(params, batch)
        grads = pin_grads(grads)
        new_params, new_opt, metrics = adamw_update(grads, state["opt"], opt)
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_params, params)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def init_train_state(cfg: ModelConfig, key: Array) -> Dict[str, Any]:
    from repro.models.model import init_params
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}


from repro.sharding.rules import fit_tree


def state_shardings(mesh: Mesh, state_shape: Any, cfg: ModelConfig) -> Any:
    """NamedShardings for the whole train state (opt state mirrors params,
    ZeRO-style; scalars replicated). Divisibility-fitted per leaf."""
    specs = {
        "params": param_specs(state_shape["params"], cfg),
        "opt": {
            "step": P(),
            "m": param_specs(state_shape["opt"]["m"], cfg),
            "v": param_specs(state_shape["opt"]["v"], cfg),
            "master": param_specs(state_shape["opt"]["master"], cfg),
        },
    }
    specs = fit_tree(specs, state_shape, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def jit_train_step(cfg: ModelConfig, opt: OptConfig, mesh: Mesh,
                   state_shape: Any, batch_shape: Any,
                   profile: Profile = PROFILES["baseline"],
                   microbatches: int = 1, donate: bool = True):
    """AOT-ready jit'd train step with explicit shardings."""
    ctx = make_ctx(mesh, profile=profile)
    step = make_train_step(cfg, opt, ctx, microbatches,
                           constrain_grads=profile.constrain_grads)
    st_sh = state_shardings(mesh, state_shape, cfg)
    b_specs = {k: v for k, v in
               batch_specs(cfg, mesh, "train", profile).items()
               if k in batch_shape}
    b_specs = fit_tree(b_specs, batch_shape, mesh)
    b_sh = {k: NamedSharding(mesh, v) for k, v in b_specs.items()}
    out_metrics_sh = {"grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P()),
                      "loss": NamedSharding(mesh, P())}
    return jax.jit(step,
                   in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, out_metrics_sh),
                   donate_argnums=(0,) if donate else ())


# ------------------------------------------------------------------ DDP

def make_ddp_train_step(cfg: ModelConfig, opt: OptConfig, mesh: Mesh,
                        compress: bool = True):
    """shard_map DDP trainer: replicated weights, explicit (optionally
    int8-compressed) gradient all-reduce over the dp axes."""
    dp = dp_axes(mesh)
    axis = dp[-1]  # compress over the innermost dp axis (cross-pod in 3D)

    def local_step(state, batch):
        params = state["params"]
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg, None)
        loss = jax.lax.pmean(loss, axis)
        if compress:
            grads, new_res = compress_tree_psum_mean(
                grads, axis, state["residual"])
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_res = state["residual"]
        if len(dp) > 1:   # outer dp axes: plain pmean (intra-pod, fast)
            for a in dp[:-1]:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, a), grads)
                loss = jax.lax.pmean(loss, a)
        new_params, new_opt, metrics = adamw_update(grads, state["opt"], opt)
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_params, params)
        new_state = {"params": new_params, "opt": new_opt,
                     "residual": new_res}
        return new_state, dict(metrics, loss=loss)

    from jax import shard_map

    def step(state, batch):
        def spec_of_state(tree):
            return jax.tree.map(lambda _: P(), tree)
        return shard_map(
            local_step, mesh=mesh,
            in_specs=(spec_of_state(state),
                      jax.tree.map(lambda _: P(dp), batch)),
            out_specs=(spec_of_state(state),
                       {"grad_norm": P(), "lr": P(), "loss": P()}),
            check_vma=False,
        )(state, batch)

    return step


def init_ddp_state(cfg: ModelConfig, key: Array) -> Dict[str, Any]:
    from repro.models.model import init_params
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params),
            "residual": init_residuals(params)}
