"""Gradient compression for cross-pod data-parallel all-reduce.

int8 block-quantized all-reduce with error feedback (1-bit-Adam-family
trick): each shard quantizes (grad + residual) to int8 with a per-block
fp32 scale, psums the int8 payload (as int32 accumulators), dequantizes,
and keeps the quantization error as residual for the next step. Cuts
cross-pod gradient bytes 4x (bf16) / 2x (int8 vs bf16) while keeping
convergence (validated in tests/test_distributed.py on a 4-device mesh).

Used by the DDP trainer (launch/train.py --ddp) where the gradient
all-reduce is an explicit shard_map collective; the GSPMD/FSDP trainer
leaves reduction to the compiler (compression there would need custom
partitioning hooks -- recorded as future work in DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
BLOCK = 2048


def _quantize(x: Array) -> Tuple[Array, Array]:
    """fp32 (N,) -> (int8 payload (N,), fp32 per-block scales)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: Array, scale: Array, n: int) -> Array:
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n]


def compressed_psum_mean(x: Array, axis_name: str,
                         residual: Optional[Array] = None
                         ) -> Tuple[Array, Array]:
    """Inside shard_map: mean-all-reduce of x over `axis_name` in int8.

    Returns (mean, new_residual). Scales are psum'd in fp32 (tiny), the
    int8 payload rides as int32 partial sums (wire format int8; the
    int32 accumulation mirrors what a switch/ICI reduction would do).
    """
    flat = x.astype(jnp.float32).reshape(-1)
    if residual is not None:
        flat = flat + residual.reshape(-1)
    q, scale = _quantize(flat)
    err = flat - _dequantize(q, scale, flat.shape[0])
    # wire payload is int8 (+ one fp32 scale per 2048 block ~ 0.2%
    # overhead): 2x fewer bytes than a bf16 ring all-reduce. Each
    # shard's payload keeps its own scale, so the mean is EXACT up to
    # the local quantization error already captured in `err`.
    n_dev = jax.lax.psum(1, axis_name)
    qs = jax.lax.all_gather(q, axis_name)                # (P, nblk*B) int8
    ss = jax.lax.all_gather(scale, axis_name)            # (P, nblk) fp32
    tot = jnp.sum(qs.astype(jnp.float32).reshape(qs.shape[0], -1, BLOCK)
                  * ss[..., None], axis=0).reshape(-1)[:flat.shape[0]]
    mean = tot / n_dev
    return mean.reshape(x.shape).astype(x.dtype), err.reshape(x.shape)


def compress_tree_psum_mean(grads: Any, axis_name: str,
                            residuals: Optional[Any] = None
                            ) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree.flatten(grads)
    res = (treedef.flatten_up_to(residuals) if residuals is not None
           else [None] * len(leaves))
    out, errs = [], []
    for g, r in zip(leaves, res):
        m, e = compressed_psum_mean(g, axis_name, r)
        out.append(m)
        errs.append(e)
    return treedef.unflatten(out), treedef.unflatten(errs)


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
