"""Synthetic pedestrian-window generator -- INRIA/MIT stand-in.

The paper trains on 4,202 positive + 2,795 negative 130x66 RGB windows from
the INRIA and MIT pedestrian sets and evaluates on 294 windows (160 with
person / 134 without). Those datasets are not redistributable in this
offline container, so this module synthesizes structured windows with the
same geometry and a difficulty calibrated to land the linear HOG+SVM in the
paper's accuracy band (~84 %):

  positives: articulated pedestrian silhouettes (head / torso / two legs /
    arms) with randomized pose, scale, position, contrast, clothing split,
    occlusion, on cluttered backgrounds;
  negatives: background clutter with *hard* distractors -- vertical bars
    (tree trunks / poles), blobs, edges -- that excite the same vertical-
    gradient bins a pedestrian does.

Everything is numpy (data pipeline, not jitted).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

H, W = 130, 66  # the paper's window


@dataclasses.dataclass(frozen=True)
class PedestrianDataConfig:
    n_pos: int = 4202            # paper's training split
    n_neg: int = 2795
    n_test_pos: int = 160        # paper's Table I eval split
    n_test_neg: int = 134
    noise_std: float = 26.0      # additive pixel noise (8-bit scale)
    min_contrast: float = 2.0    # person-vs-background luma gap (low = hard)
    max_contrast: float = 60.0
    occlusion_p: float = 0.65    # probability of a partial occluder
    distractor_strength: float = 1.2
    humanoid_neg_p: float = 0.18  # fraction of negatives that are person-like
    seed: int = 0


def _smooth_noise(rng: np.random.Generator, h: int, w: int,
                  scale: int = 8) -> np.ndarray:
    """Cheap Perlin-ish background: upsampled low-res noise."""
    small = rng.normal(size=(h // scale + 2, w // scale + 2))
    ys = np.linspace(0, small.shape[0] - 1.001, h)
    xs = np.linspace(0, small.shape[1] - 1.001, w)
    y0, x0 = ys.astype(int), xs.astype(int)
    fy, fx = ys - y0, xs - x0
    a = small[y0][:, x0]
    b = small[y0][:, x0 + 1]
    c = small[y0 + 1][:, x0]
    d = small[y0 + 1][:, x0 + 1]
    return (a * np.outer(1 - fy, 1 - fx) + b * np.outer(1 - fy, fx)
            + c * np.outer(fy, 1 - fx) + d * np.outer(fy, fx))


def _background(rng: np.random.Generator, cfg: PedestrianDataConfig) -> np.ndarray:
    base = rng.uniform(60, 190)
    grad = np.linspace(0, rng.uniform(-30, 30), H)[:, None]
    tex = _smooth_noise(rng, H, W, scale=int(rng.integers(6, 16))) * rng.uniform(5, 25)
    img = base + grad + tex
    # occasional horizon edge
    if rng.random() < 0.4:
        y = int(rng.integers(20, H - 20))
        img[y:] += rng.uniform(-35, 35)
    return img


def _ellipse_mask(h: int, w: int, cy: float, cx: float,
                  ry: float, rx: float) -> np.ndarray:
    yy, xx = np.mgrid[0:h, 0:w]
    return (((yy - cy) / ry) ** 2 + ((xx - cx) / rx) ** 2) <= 1.0


def _person_mask(rng: np.random.Generator) -> np.ndarray:
    """Articulated silhouette in the 130x66 window, Dalal-style framing."""
    m = np.zeros((H, W), dtype=bool)
    scale = rng.uniform(0.82, 1.0)
    cx = W / 2 + rng.uniform(-6, 6)
    top = 14 + rng.uniform(-4, 6)

    head_r = 6.5 * scale * rng.uniform(0.85, 1.15)
    head_cy = top + head_r
    m |= _ellipse_mask(H, W, head_cy, cx + rng.uniform(-1.5, 1.5),
                       head_r, head_r * rng.uniform(0.8, 1.0))

    torso_top = head_cy + head_r * rng.uniform(0.7, 1.1)
    torso_h = 42 * scale * rng.uniform(0.9, 1.1)
    torso_w = 10.5 * scale * rng.uniform(0.85, 1.25)
    m |= _ellipse_mask(H, W, torso_top + torso_h / 2, cx,
                       torso_h / 2, torso_w)

    # arms: slight sway
    for side in (-1, 1):
        if rng.random() < 0.85:
            ax = cx + side * (torso_w + rng.uniform(0, 3.5))
            atop = torso_top + rng.uniform(0, 6)
            ah = torso_h * rng.uniform(0.7, 1.0)
            m |= _ellipse_mask(H, W, atop + ah / 2,
                               ax + side * rng.uniform(-1, 3),
                               ah / 2, 2.6 * scale)

    # legs: stride angle
    hip_y = torso_top + torso_h
    leg_h = min(H - 6 - hip_y, 50 * scale * rng.uniform(0.9, 1.05))
    spread = rng.uniform(1.5, 9.0)
    for side in (-1, 1):
        lx = cx + side * spread * rng.uniform(0.6, 1.2)
        m |= _ellipse_mask(H, W, hip_y + leg_h / 2, lx,
                           leg_h / 2, 3.4 * scale)
    return m


def _positive(rng: np.random.Generator, cfg: PedestrianDataConfig) -> np.ndarray:
    img = _background(rng, cfg)
    mask = _person_mask(rng)
    bg_mean = float(img[mask].mean()) if mask.any() else 128.0
    contrast = rng.uniform(cfg.min_contrast, cfg.max_contrast)
    sign = -1.0 if rng.random() < 0.5 else 1.0
    person_luma = np.clip(bg_mean + sign * contrast, 10, 245)
    # clothing split: torso vs legs can differ
    split_y = int(rng.uniform(60, 85))
    upper = mask & (np.arange(H)[:, None] < split_y)
    lower = mask & ~upper
    img[upper] = person_luma + rng.normal(0, 6)
    img[lower] = np.clip(person_luma + rng.uniform(-40, 40), 10, 245)
    # partial occluder (pole / bag) over the person
    if rng.random() < cfg.occlusion_p:
        x0 = int(rng.integers(8, W - 14))
        wd = int(rng.integers(4, 10))
        img[:, x0:x0 + wd] = rng.uniform(30, 220)
    return img


def _humanoid_negative(rng: np.random.Generator,
                       cfg: PedestrianDataConfig) -> np.ndarray:
    """Hard negative: person-like vertical structure that is NOT a person
    (mannequin-ish pole cluster / hydrant / narrow trunk pair). Excites the
    same vertical-edge bins as a pedestrian."""
    img = _background(rng, cfg)
    bg_mean = float(img.mean())
    luma = np.clip(bg_mean + rng.choice([-1, 1]) * rng.uniform(10, 60), 10, 245)
    cx = W / 2 + rng.uniform(-8, 8)
    # a head-ish blob at a WRONG height or proportion
    if rng.random() < 0.7:
        cy = rng.uniform(10, 50)
        r = rng.uniform(3, 12)
        img[_ellipse_mask(H, W, cy, cx + rng.uniform(-6, 6), r,
                          r * rng.uniform(0.5, 1.6))] = luma
    # a single wide trunk or two parallel bars (leg-like but rigid)
    if rng.random() < 0.5:
        wd = rng.uniform(4, 9)
        img[_ellipse_mask(H, W, H * 0.65, cx, H * 0.38, wd)] = luma
    else:
        for side in (-1, 1):
            img[_ellipse_mask(H, W, H * 0.65, cx + side * rng.uniform(3, 7),
                              H * 0.38, rng.uniform(2.2, 4.0))] = luma
    return img


def _negative(rng: np.random.Generator, cfg: PedestrianDataConfig) -> np.ndarray:
    if rng.random() < cfg.humanoid_neg_p:
        return _humanoid_negative(rng, cfg)
    img = _background(rng, cfg)
    s = cfg.distractor_strength
    kind = rng.integers(0, 4)
    if kind == 0:      # vertical bars: trunks / poles (hard negatives)
        for _ in range(int(rng.integers(1, 4))):
            x0 = int(rng.integers(0, W - 8))
            wd = int(rng.integers(3, 12))
            img[:, x0:x0 + wd] += rng.uniform(-70, 70) * s
    elif kind == 1:    # blobs (bushes, rocks)
        for _ in range(int(rng.integers(2, 6))):
            cy, cx = rng.uniform(10, H - 10), rng.uniform(5, W - 5)
            ry, rx = rng.uniform(5, 25), rng.uniform(4, 18)
            mask = _ellipse_mask(H, W, cy, cx, ry, rx)
            img[mask] += rng.uniform(-60, 60) * s
    elif kind == 2:    # building edges: rectangles
        for _ in range(int(rng.integers(1, 3))):
            y0, x0 = int(rng.integers(0, H - 20)), int(rng.integers(0, W - 15))
            hh, ww = int(rng.integers(15, 60)), int(rng.integers(10, 40))
            img[y0:y0 + hh, x0:x0 + ww] += rng.uniform(-55, 55) * s
    # kind == 3: pure textured background
    return img


def _to_rgb(rng: np.random.Generator, gray: np.ndarray,
            noise_std: float) -> np.ndarray:
    """Give the luma image a mild random chroma + per-channel noise."""
    tint = rng.uniform(0.9, 1.1, size=3)
    rgb = np.stack([gray * t for t in tint], axis=-1)
    rgb += rng.normal(0, noise_std, size=rgb.shape)
    return np.clip(rgb, 0, 255).astype(np.uint8)


def make_windows(n_pos: int, n_neg: int, cfg: PedestrianDataConfig,
                 rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    xs = np.empty((n_pos + n_neg, H, W, 3), dtype=np.uint8)
    ys = np.concatenate([np.ones(n_pos, np.int32), np.zeros(n_neg, np.int32)])
    for i in range(n_pos):
        xs[i] = _to_rgb(rng, _positive(rng, cfg), cfg.noise_std)
    for i in range(n_neg):
        xs[n_pos + i] = _to_rgb(rng, _negative(rng, cfg), cfg.noise_std)
    perm = rng.permutation(len(ys))
    return xs[perm], ys[perm]


def make_dataset(cfg: PedestrianDataConfig = PedestrianDataConfig()):
    """Returns (x_train, y_train, x_test, y_test) with the paper's split sizes."""
    rng = np.random.default_rng(cfg.seed)
    x_tr, y_tr = make_windows(cfg.n_pos, cfg.n_neg, cfg, rng)
    x_te, y_te = make_windows(cfg.n_test_pos, cfg.n_test_neg, cfg, rng)
    return x_tr, y_tr, x_te, y_te


@dataclasses.dataclass(frozen=True)
class ClipConfig:
    """Synthetic video clip: pedestrians walking across a static
    cluttered background with constant-velocity motion + jitter."""

    n_frames: int = 16
    h: int = 240
    w: int = 320
    n_people: int = 2
    speed: float = 4.0          # px/frame trajectory magnitude (per axis)
    jitter: float = 0.6         # per-frame gaussian position jitter (px)
    frame_noise: float = 8.0    # per-frame pixel noise (temporal flicker)
    n_distractors: int = 3      # static clutter blobs/bars in the bg


def make_clip(rng: np.random.Generator,
              cfg: ClipConfig = ClipConfig()):
    """Video clip for the batched/tracking path.

    Each pedestrian keeps ONE rendered appearance for the whole clip
    and moves on a constant-velocity trajectory (chosen so the full
    path stays in-frame) with small gaussian jitter; the background and
    its clutter are static, only per-frame sensor noise changes. This
    is the workload the tracker's constant-velocity prediction and the
    batched detector are built for.

    Returns (frames, truths): frames (T, H, W, 3) uint8, truths[t] a
    list of {"id": person, "box": (y0, x0, y1, x1)} per frame.
    """
    pcfg = PedestrianDataConfig()
    h, w, T = cfg.h, cfg.w, cfg.n_frames
    if h < H or w < W:
        raise ValueError(f"clip frames must fit the {H}x{W} window, "
                         f"got ({h}, {w})")
    bg = _smooth_noise(rng, h, w, 12) * 20 + rng.uniform(70, 170)
    for _ in range(cfg.n_distractors):          # static clutter
        cy, cx = rng.uniform(0, h), rng.uniform(0, w)
        ry, rx = rng.uniform(8, 40), rng.uniform(5, 25)
        bg[_ellipse_mask(h, w, cy, cx, ry, rx)] += rng.uniform(-50, 50)
    bg = np.clip(bg, 0, 255)

    sprites, starts, vels = [], [], []
    for _ in range(cfg.n_people):
        sprites.append(_positive(rng, pcfg))
        v = rng.uniform(-cfg.speed, cfg.speed, size=2)
        # start uniformly inside the interval that keeps the whole
        # trajectory in-bounds; shrink the velocity if none exists
        pos = np.empty(2)
        for ax, lim in ((0, h - H), (1, w - W)):
            travel = v[ax] * (T - 1)
            lo, hi = max(0.0, -travel), min(lim, lim - travel)
            if lo > hi:
                v[ax] = np.sign(v[ax]) * lim / (T - 1)
                travel = v[ax] * (T - 1)
                lo, hi = max(0.0, -travel), min(lim, lim - travel)
            pos[ax] = rng.uniform(lo, hi)
        starts.append(pos)
        vels.append(v)

    tint = rng.uniform(0.9, 1.1, size=3)        # constant chroma per clip
    frames = np.empty((T, h, w, 3), np.uint8)
    truths = []
    for t in range(T):
        scene = bg.copy()
        boxes = []
        for i in range(cfg.n_people):
            y, x = starts[i] + vels[i] * t + rng.normal(0, cfg.jitter, 2)
            y0 = int(np.clip(round(y), 0, h - H))
            x0 = int(np.clip(round(x), 0, w - W))
            scene[y0:y0 + H, x0:x0 + W] = sprites[i]
            boxes.append({"id": i,
                          "box": (float(y0), float(x0),
                                  float(y0 + H), float(x0 + W))})
        rgb = np.stack([scene * c for c in tint], axis=-1)
        rgb += rng.normal(0, cfg.frame_noise, size=rgb.shape)
        frames[t] = np.clip(rgb, 0, 255).astype(np.uint8)
        truths.append(boxes)
    return frames, truths


def make_scene(rng: np.random.Generator, h: int = 320, w: int = 240,
               n_people: int = 2,
               region: Tuple[int, int, int, int] = None
               ) -> Tuple[np.ndarray, list]:
    """A larger scene with pasted pedestrians, for the sliding-window
    detector example. Returns (rgb uint8 (h,w,3), list of (y,x,130,66)
    boxes). `region` = (y0, x0, y1, x1) confines the paste positions to
    a sub-rectangle -- the cascade bench (benchmarks/bench_timing.py)
    uses it to build CLUSTERED scenes where people occupy one corner of
    an otherwise empty frame, the sparse-traffic shape the coarse-reject
    stage is built for."""
    cfg = PedestrianDataConfig()
    base = _background(rng, cfg)
    scene = np.clip(base + _smooth_noise(rng, h, w, 12)[:h, :w] * 10
                    if base.shape == (h, w) else
                    _smooth_noise(rng, h, w, 12) * 20 + rng.uniform(70, 170),
                    0, 255)
    ry0, rx0, ry1, rx1 = (0, 0, h, w) if region is None else region
    ry1 = min(ry1, h)
    rx1 = min(rx1, w)
    if ry1 - ry0 < H or rx1 - rx0 < W:
        raise ValueError(f"region {(ry0, rx0, ry1, rx1)} cannot fit one "
                         f"{H}x{W} window")
    boxes = []
    for _ in range(n_people):
        win = _positive(rng, cfg)
        y0 = int(rng.integers(ry0, ry1 - H)) if ry1 - ry0 > H else ry0
        x0 = int(rng.integers(rx0, rx1 - W)) if rx1 - rx0 > W else rx0
        scene[y0:y0 + H, x0:x0 + W] = win
        boxes.append((y0, x0, H, W))
    return _to_rgb(rng, scene, cfg.noise_std), boxes
