"""Synthetic LM token pipeline: structured streams a transformer can
actually learn (Zipf unigrams + copy/induction motifs + local n-gram
grammar), so the train_lm example shows a real loss curve offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int = 512
    seq_len: int = 256
    batch: int = 8
    seed: int = 0
    motif_p: float = 0.35       # probability a span is a repeated motif
    bigram_alpha: float = 0.7   # strength of the bigram grammar


def _bigram_table(rng: np.random.Generator, vocab: int) -> np.ndarray:
    """Sparse random bigram transition table (each token has ~8 likely
    successors) -- gives the stream learnable local structure."""
    succ = rng.integers(0, vocab, size=(vocab, 8))
    return succ


def sequence(rng: np.random.Generator, cfg: LMDataConfig,
             succ: np.ndarray) -> np.ndarray:
    out = np.empty(cfg.seq_len + 1, np.int64)
    zipf_p = 1.0 / np.arange(1, cfg.vocab + 1) ** 1.1
    zipf_p /= zipf_p.sum()
    t = 0
    out[0] = rng.integers(0, cfg.vocab)
    while t < cfg.seq_len:
        if rng.random() < cfg.motif_p and t > 16:
            # induction motif: copy an earlier span
            start = int(rng.integers(0, t - 8))
            ln = int(rng.integers(4, min(16, t - start)))
            ln = min(ln, cfg.seq_len - t)
            out[t + 1:t + 1 + ln] = out[start:start + ln]
            t += ln
        else:
            prev = out[t]
            if rng.random() < cfg.bigram_alpha:
                out[t + 1] = succ[prev, rng.integers(0, succ.shape[1])]
            else:
                out[t + 1] = rng.choice(cfg.vocab, p=zipf_p)
            t += 1
    return out


def batches(cfg: LMDataConfig) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(cfg.seed)
    succ = _bigram_table(rng, cfg.vocab)
    while True:
        toks = np.stack([sequence(rng, cfg, succ)
                         for _ in range(cfg.batch)])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
