"""Hard-negative mining on synthetic scenes (Dalal-Triggs bootstrapping).

A head trained only on window-sized synthetic crops has a domain gap at
detection time: the pyramid's downscaled levels average the per-pixel
sensor noise away, so background there is SMOOTHER than any training
negative and the dense score field lights up far from every pedestrian
(empty 640x480 scenes score 8+ at sub-unit scales). The classic fix is
bootstrapping: sweep the current head over person-free scenes at a very
loose threshold, crop every firing window back to training-window
geometry, and retrain with those crops as negatives. Two rounds drop
the empty-scene detection count from ~20 to ~0-3 at threshold 3 while
keeping every pedestrian -- which is what makes the two-stage cascade's
retention/speedup contract (core/cascade.py, BENCH_detect.json
`cascade`) meaningful. `DetectionSession.train(hard_negative_rounds=N)`
and `train_coarse_head` drive this loop.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.svm import SVMParams

MINE_THRESHOLD = -1.0      # loose sweep gate: mine anything remotely firing


def mine_hard_negatives(svm: SVMParams, det_cfg, n_scenes: int,
                        rng: np.random.Generator,
                        scene_hw: Tuple[int, int] = (480, 640),
                        threshold: float = MINE_THRESHOLD,
                        window_hw: Optional[Tuple[int, int]] = None
                        ) -> np.ndarray:
    """Sweep `svm` over `n_scenes` person-free synthetic scenes with the
    given DetectorConfig at a LOOSE threshold and return every firing
    window as a training-geometry crop: (N, wh, ww, 3) uint8, where
    (wh, ww) defaults to det_cfg's HOG window. N varies with how noisy
    the head still is -- it shrinks round over round.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.detector import FrameDetector
    from repro.data.synth_pedestrian import make_scene

    h, w = int(scene_hw[0]), int(scene_hw[1])
    wh, ww = window_hw or (det_cfg.hog.window_h, det_cfg.hog.window_w)
    det = FrameDetector(svm, dataclasses.replace(
        det_cfg, score_threshold=float(threshold), class_thresholds=()))
    crops = []
    for _ in range(int(n_scenes)):
        scene, _ = make_scene(rng, h, w, n_people=0)
        for d in det.detect_raw(scene).to_list():
            y0, x0, y1, x1 = [int(round(v)) for v in d["box"]]
            y0, x0 = max(0, y0), max(0, x0)
            y1, x1 = min(h, y1), min(w, x1)
            if y1 - y0 < wh // 3 or x1 - x0 < ww // 3:
                continue
            crops.append(np.asarray(jax.image.resize(
                jnp.asarray(scene[y0:y1, x0:x1], jnp.float32),
                (wh, ww, 3), "linear")))
    if not crops:
        return np.zeros((0, wh, ww, 3), np.uint8)
    return np.clip(np.stack(crops), 0, 255).astype(np.uint8)
