from repro.data.synth_pedestrian import PedestrianDataConfig, make_dataset
