"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic restore.

Layout (one directory per step):
    ckpt_dir/step_000100.tmp/...   (written, fsync'd)
    ckpt_dir/step_000100/          (atomic rename = commit)
Leaves are stored as raw .npy files keyed by pytree path; metadata.json
carries the step and tree structure and is written LAST, so its
presence in a .tmp dir is the completion marker the crash-recovery
scan keys on. Restore takes a target shape/sharding pytree, so a
checkpoint written on one mesh restores onto ANY mesh (elastic
scaling): values are read on host and device_put with the new
NamedShardings.

Crash safety: every file is fsync'd before the commit rename and the
PARENT DIRECTORY is fsync'd after it (a rename the directory never
made durable can vanish on power loss). Re-committing an existing step
swaps the old dir to `<name>.old` first -- never an rmtree-then-rename
window with NO valid checkpoint on disk -- and `__init__` runs
`_recover()`: complete .tmp dirs (metadata.json present) are finished,
truncated ones removed, and an orphaned .old is restored when its
commit is missing. `atomic_write_json` is the same temp+fsync+rename
discipline for single manifests (core/heads.py uses it for
heads.json).

Async: `save_async` snapshots to host (device_get) synchronously -- the
only part that must be consistent -- then writes in a daemon thread so
the train loop resumes immediately (preemption-safe: a killed writer
leaves only a .tmp dir, never a corrupt commit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from jax.sharding import NamedSharding


def _fsync_dir(path: str) -> None:
    """fsync a directory so a rename inside it survives power loss
    (no-op on filesystems that refuse O_RDONLY dir fds)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:          # pragma: no cover -- exotic filesystems
        return
    try:
        os.fsync(fd)
    except OSError:          # pragma: no cover
        pass
    finally:
        os.close(fd)


def atomic_write_json(path: str, obj: Any, **json_kw) -> None:
    """Durable single-file JSON write: temp file in the target's
    directory, fsync, rename over the destination, fsync the
    directory. A reader never observes a truncated file."""
    d = os.path.dirname(os.path.abspath(path))
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, **json_kw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(d)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _step_of(name: str) -> Optional[int]:
    """step_00000100 -> 100; None for .tmp/.old/foreign entries."""
    if not name.startswith("step_"):
        return None
    digits = name[len("step_"):]
    return int(digits) if digits.isdigit() else None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._recover()

    # --------------------------------------------------- crash recovery
    def _recover(self) -> None:
        """Settle the debris of a writer killed mid-save.

        .tmp with metadata.json  -> every leaf was written and fsync'd
                                    (metadata is written last): finish
                                    the commit.
        .tmp without             -> truncated write: remove.
        .old with no commit      -> the swap's rename never happened:
                                    restore the old checkpoint.
        .old with a commit       -> superseded: remove.
        """
        for name in sorted(os.listdir(self.dir)):
            p = os.path.join(self.dir, name)
            if name.endswith(".tmp"):
                final = p[:-len(".tmp")]
                if (os.path.exists(os.path.join(p, "metadata.json"))
                        and not os.path.exists(final)):
                    os.rename(p, final)
                else:
                    shutil.rmtree(p, ignore_errors=True)
            elif name.endswith(".old"):
                final = p[:-len(".old")]
                if os.path.exists(final):
                    shutil.rmtree(p, ignore_errors=True)
                else:
                    os.rename(p, final)
        _fsync_dir(self.dir)

    # ------------------------------------------------------------- save
    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        old = os.path.join(self.dir, name + ".old")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in flat.items():
            fn = os.path.join(tmp, key.replace("/", "__") + ".npy")
            with open(fn, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        # metadata LAST: its presence marks the .tmp complete (recovery
        # finishes such a dir instead of discarding it)
        meta = {"step": step, "keys": sorted(flat.keys())}
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            # swap, don't rmtree-then-rename: a crash between those two
            # would leave NO valid copy of this step on disk
            if os.path.exists(old):
                shutil.rmtree(old)
            os.rename(final, old)
        os.rename(tmp, final)            # atomic commit
        _fsync_dir(self.dir)             # make the commit durable
        shutil.rmtree(old, ignore_errors=True)
        self._gc()

    def save(self, step: int, tree: Any) -> None:
        self._write(step, _flatten(tree))

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()                       # one writer at a time
        host_tree = jax.device_get(tree)  # consistent snapshot
        flat = _flatten(host_tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [s for s in (_step_of(d) for d in os.listdir(self.dir))
                 if s is not None]
        return max(steps) if steps else None

    def restore(self, step: int, target: Any,
                shardings: Optional[Any] = None) -> Any:
        """target: pytree of arrays or ShapeDtypeStructs (the skeleton).
        shardings: matching pytree of NamedSharding (or None -> host)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "metadata.json")) as f:
            meta = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        sh_flat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
            if shardings is not None else [None] * len(paths))
        leaves = []
        for (kpath, leaf), sh in zip(paths, sh_flat):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kpath)
            arr = np.load(os.path.join(path, key.replace("/", "__") + ".npy"))
            if arr.dtype.kind == "V":
                # bf16 (and other ml_dtypes) round-trip np.save as raw
                # void bytes: re-view with the target's dtype
                arr = arr.view(np.dtype(leaf.dtype))
            want = jax.numpy.asarray(arr).astype(leaf.dtype)
            if sh is not None:
                want = jax.device_put(want, sh)   # reshard to the new mesh
            leaves.append(want)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # --------------------------------------------------------------- gc
    def _gc(self) -> None:
        all_steps = sorted(
            s for s in (_step_of(d) for d in os.listdir(self.dir))
            if s is not None)
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
