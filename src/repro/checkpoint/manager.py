"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic restore.

Layout (one directory per step):
    ckpt_dir/step_000100.tmp/...   (written, fsync'd)
    ckpt_dir/step_000100/          (atomic rename = commit)
Leaves are stored as raw .npy files keyed by pytree path; metadata.json
carries the step and tree structure. Restore takes a target
shape/sharding pytree, so a checkpoint written on one mesh restores onto
ANY mesh (elastic scaling): values are read on host and device_put with
the new NamedShardings.

Async: `save_async` snapshots to host (device_get) synchronously -- the
only part that must be consistent -- then writes in a daemon thread so
the train loop resumes immediately (preemption-safe: a killed writer
leaves only a .tmp dir, never a corrupt commit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from jax.sharding import NamedSharding


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in flat.items():
            fn = os.path.join(tmp, key.replace("/", "__") + ".npy")
            with open(fn, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        meta = {"step": step, "keys": sorted(flat.keys())}
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic commit
        self._gc()

    def save(self, step: int, tree: Any) -> None:
        self._write(step, _flatten(tree))

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()                       # one writer at a time
        host_tree = jax.device_get(tree)  # consistent snapshot
        flat = _flatten(host_tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, target: Any,
                shardings: Optional[Any] = None) -> Any:
        """target: pytree of arrays or ShapeDtypeStructs (the skeleton).
        shardings: matching pytree of NamedSharding (or None -> host)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "metadata.json")) as f:
            meta = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        sh_flat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
            if shardings is not None else [None] * len(paths))
        leaves = []
        for (kpath, leaf), sh in zip(paths, sh_flat):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kpath)
            arr = np.load(os.path.join(path, key.replace("/", "__") + ".npy"))
            if arr.dtype.kind == "V":
                # bf16 (and other ml_dtypes) round-trip np.save as raw
                # void bytes: re-view with the target's dtype
                arr = arr.view(np.dtype(leaf.dtype))
            want = jax.numpy.asarray(arr).astype(leaf.dtype)
            if sh is not None:
                want = jax.device_put(want, sh)   # reshard to the new mesh
            leaves.append(want)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # --------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(int(d.split("_")[1])
                           for d in os.listdir(self.dir)
                           if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
