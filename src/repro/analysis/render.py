"""Render EXPERIMENTS.md tables from results/dryrun.json."""
from __future__ import annotations

import json
import sys


def dryrun_table(path: str = "results/dryrun.json",
                 profile: str = "baseline") -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["| arch | shape | mesh | compile_s | peak GiB/dev | arg GiB | "
           "status |", "|---|---|---|---|---|---|---|"]
    for k in sorted(rows):
        r = rows[k]
        if r.get("profile") != profile:
            continue
        if r.get("status") == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['compile_s']} | {r['mem']['peak_bytes']/2**30:.2f} | "
                f"{r['mem']['argument_bytes']/2**30:.2f} | ok |")
        elif r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | -- | "
                       f"-- | -- | {r['reason'].split(':')[0]} |")
    return "\n".join(out)


def roofline_table(path: str = "results/dryrun.json",
                   profile: str = "baseline") -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["| arch | shape | mesh | T_comp (s) | T_mem (s) | T_coll (s) | "
           "bottleneck | 6ND/HLO | MFU |",
           "|---|---|---|---|---|---|---|---|---|"]
    for k in sorted(rows):
        r = rows[k]
        if r.get("profile") != profile or r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_coll_s']:.3f} | {r['bottleneck']} | "
            f"{r['useful_flops_frac']:.2f} | {r['mfu']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    profile = sys.argv[2] if len(sys.argv) > 2 else "baseline"
    if which == "dryrun":
        print(dryrun_table(profile=profile))
    else:
        print(roofline_table(profile=profile))
