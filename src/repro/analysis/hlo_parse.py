"""Post-SPMD HLO text parser: per-device FLOPs / HBM bytes / collective
bytes with while-loop (scan-over-layers) trip-count correction.

XLA's HloCostAnalysis visits each `while` body ONCE (trip counts are not
static in general), so a scan-over-L-layers model under-reports compute
and collectives by ~L. This parser rebuilds the call graph
(entry -> while bodies / fusion calls), extracts trip counts from the
loop-condition constants, and scales every computation's contribution by
the product of trip counts along its call chain.

Per-instruction models:
  dot          flops = 2 * prod(result_shape) * prod(contracting dims)
  convolution  flops = 2 * prod(result) * prod(kernel spatial) * Cin/groups
  collectives  bytes = sum of operand sizes (resolved through the
               instruction table, operands are printed without types)
  HBM bytes    fusion/dot/conv/scatter/gather/dus instructions:
               operands + result (approximates one read + one write per
               fused region, the TPU HBM-traffic model)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# computation headers start at column 0: "[ENTRY ]%name (params...) -> ... {"
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _tuple_bytes(type_text: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _first_shapes(type_text))


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: List[int]
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: Dict[str, Instr]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if (not line.startswith(" ") and line.rstrip().endswith("{")):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), {})
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # result type = everything before the opcode word
        shapes = _first_shapes(rest.split("(")[0])
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        rdims = ([int(d) for d in shapes[0][1].split(",") if d]
                 if shapes else [])
        # opcode = first identifier after the type spec
        op_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", rest)
        opcode = op_m.group(1) if op_m else ""
        # operand names: %foo inside the first (...) after opcode
        operands: List[str] = []
        if op_m:
            depth = 0
            start = rest.index("(", op_m.start())
            for i in range(start, len(rest)):
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                    if depth == 0:
                        operands = re.findall(r"%([\w.\-]+)",
                                              rest[start:i + 1])
                        break
        cur.instrs[name] = Instr(name, opcode, rbytes, rdims, operands,
                                 rest)
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    # contracting dims from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    if not m:
        return 0.0
    lhs_name = ins.operands[0] if ins.operands else None
    lhs = comp.instrs.get(lhs_name)
    contract = 1
    if lhs is not None and lhs.result_dims:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs.result_dims[int(d)]
    else:
        contract = 1
    out = 1
    for d in ins.result_dims:
        out *= d
    return 2.0 * out * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    rhs = comp.instrs.get(ins.operands[1]) if len(ins.operands) > 1 else None
    out = 1
    for d in ins.result_dims:
        out *= d
    if rhs is None or not rhs.result_dims:
        return 2.0 * out
    kernel = 1
    for d in rhs.result_dims:
        kernel *= d
    # kernel = spatial... x Cin x Cout; divide by Cout (already in result)
    cout = max(rhs.result_dims[-1], 1)
    m = re.search(r"feature_group_count=(\d+)", ins.raw)
    groups = int(m.group(1)) if m else 1
    return 2.0 * out * (kernel / cout) / groups


_MEM_OPS = ("fusion", "dot", "convolution", "scatter", "gather",
            "dynamic-update-slice", "dynamic-slice", "copy", "reduce",
            "sort", "iota", "broadcast", "transpose", "concatenate",
            "slice", "pad", "reverse", "select-and-scatter") + COLLECTIVES


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, float] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str, Optional[int]]] = dataclasses.field(
        default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    tot = 0
    for o in ins.operands:
        src = comp.instrs.get(o)
        if src is not None:
            tot += src.result_bytes
    return tot


def comp_stats(comps: Dict[str, Computation]) -> Dict[str, CompStats]:
    out: Dict[str, CompStats] = {}
    for cname, comp in comps.items():
        st = CompStats()
        for ins in comp.instrs.values():
            # HBM model: every materialized buffer crosses HBM twice
            # (written by its producer, read by its consumer). Operands
            # are NOT added -- they were counted as their producers'
            # results (avoids double-counting fused chains).
            if ins.opcode == "dot":
                st.flops += _dot_flops(ins, comp)
                st.mem_bytes += 2 * ins.result_bytes
            elif ins.opcode == "convolution":
                st.flops += _conv_flops(ins, comp)
                st.mem_bytes += 2 * ins.result_bytes
            elif ins.opcode in COLLECTIVES:
                b = _operand_bytes(ins, comp) or ins.result_bytes
                st.coll_bytes += b
                st.coll_detail[ins.opcode] = (
                    st.coll_detail.get(ins.opcode, 0.0) + b)
                st.mem_bytes += 2 * ins.result_bytes
            elif ins.opcode == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                mb = re.search(r"body=%?([\w.\-]+)", ins.raw)
                # XLA annotates static trip counts in backend_config
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                               ins.raw)
                if mc and mb:
                    st.whiles.append((mc.group(1), mb.group(1),
                                      int(mt.group(1)) if mt else None))
            elif ins.opcode == "fusion":
                st.mem_bytes += 2 * ins.result_bytes
                m = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                if m:
                    st.calls.append(m.group(1))
            elif ins.opcode in ("call", "conditional", "custom-call"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                     ins.raw):
                    st.calls.append(m.group(1))
                st.mem_bytes += 2 * ins.result_bytes
            elif ins.opcode in _MEM_OPS:
                st.mem_bytes += 2 * ins.result_bytes
        out[cname] = st
    return out


def trip_count(cond_name: str, comps: Dict[str, Computation],
               hint: Optional[int] = None) -> int:
    """Trip count from the condition's comparison constant."""
    cond = comps.get(cond_name)
    if cond is not None:
        consts = []
        for ins in cond.instrs.values():
            m = re.search(r"s32\[\]\s*constant\((\d+)\)", ins.raw)
            if m:
                consts.append(int(m.group(1)))
        if consts:
            return max(consts)
    return hint or 1


def aggregate(hlo: str, layer_hint: Optional[int] = None
              ) -> Dict[str, float]:
    """Whole-module totals (per device) with trip-count scaling."""
    comps = parse_computations(hlo)
    stats = comp_stats(comps)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))

    import functools

    @functools.lru_cache(maxsize=None)
    def total(cname: str) -> Tuple[float, float, float]:
        st = stats.get(cname)
        if st is None:
            return (0.0, 0.0, 0.0)
        f, m, c = st.flops, st.mem_bytes, st.coll_bytes
        for callee in st.calls:
            # fusion/reduce bodies: intermediates stay in VMEM -- count
            # their flops and (rare) collectives, not their buffers
            cf, cm, cc = total(callee)
            f, c = f + cf, c + cc
        for cond, body, known in st.whiles:
            t = known or trip_count(cond, comps, layer_hint)
            bf, bm, bc = total(body)
            cf, cm, cc = total(cond)
            f += t * (bf + cf)
            m += t * (bm + cm)
            c += t * (bc + cc)
        return (f, m, c)

    f, m, c = total(entry.name)
    # collective detail (unscaled-by-path approximation: scale every
    # non-entry computation reachable through whiles uniformly)
    detail: Dict[str, float] = {}

    @functools.lru_cache(maxsize=None)
    def coll_detail(cname: str) -> Tuple[Tuple[str, float], ...]:
        st = stats.get(cname)
        if st is None:
            return ()
        acc = dict(st.coll_detail)
        for callee in st.calls:
            for k, v in coll_detail(callee):
                acc[k] = acc.get(k, 0.0) + v
        for cond, body, known in st.whiles:
            t = known or trip_count(cond, comps, layer_hint)
            for k, v in coll_detail(body):
                acc[k] = acc.get(k, 0.0) + t * v
        return tuple(acc.items())

    detail = dict(coll_detail(entry.name))
    return {"flops": f, "mem_bytes": m, "coll_bytes": c,
            **{f"coll/{k}": v for k, v in detail.items()}}
