"""Three-term roofline model from the compiled dry-run artifact.

v5e per-chip constants (the TARGET hardware; this container only compiles):
    197 TFLOP/s bf16  |  819 GB/s HBM  |  ~50 GB/s/link ICI

Terms (seconds, per step, per chip -- the mesh is SPMD so per-chip ==
global/chips):
    T_compute = FLOPs_dev / PEAK
    T_memory  = HBM_bytes_dev / HBM_BW
    T_coll    = collective_bytes_dev / ICI_BW

FLOPs/bytes come from the trip-count-corrected HLO parse (hlo_parse.py);
`cost_analysis()` numbers are reported alongside for reference (they
undercount scan bodies). MODEL_FLOPS = 6*N*D (active N for MoE; 2*N*D for
inference) cross-checks how much compiled compute is useful.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link (per-chip effective)


@dataclasses.dataclass
class Roofline:
    name: str
    flops_dev: float
    mem_bytes_dev: float
    coll_bytes_dev: float
    model_flops_dev: float = 0.0
    cost_flops: float = 0.0           # raw cost_analysis (uncorrected)
    cost_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.mem_bytes_dev / HBM_BW

    @property
    def t_coll(self) -> float:
        return self.coll_bytes_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_coll}
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Roofline step time (perfect overlap: max of the three)."""
        return max(self.t_compute, self.t_memory, self.t_coll)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/padding/capacity waste)."""
        if self.flops_dev <= 0:
            return 0.0
        return self.model_flops_dev / self.flops_dev

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        if self.step_time <= 0:
            return 0.0
        return (self.model_flops_dev / PEAK_FLOPS) / self.step_time

    def row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_coll_s": self.t_coll,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "flops_dev": self.flops_dev,
            "mem_bytes_dev": self.mem_bytes_dev,
            "coll_bytes_dev": self.coll_bytes_dev,
            "model_flops_dev": self.model_flops_dev,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu": self.mfu,
        }


def model_flops(cfg, shape, n_chips: int) -> float:
    """6ND train / 2ND forward (active params for MoE), per device."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * d
    else:  # decode: one token per sequence
        d = shape.global_batch
        total = 2.0 * n_active * d
    return total / n_chips
