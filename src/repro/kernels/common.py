"""Shared Pallas kernel utilities."""
from __future__ import annotations

import jax

# TPU is the compile target; this container is CPU-only, so kernels are
# validated with the Pallas interpreter (executes the kernel body in
# Python with the same BlockSpec pipeline semantics).
INTERPRET = jax.default_backend() == "cpu"

# v5e geometry the BlockSpecs are sized for
VMEM_BYTES = 128 * 1024 * 1024   # 128 MiB VMEM per core (v5e: 128MB unified)
LANE = 128                       # vector lane width / MXU tile edge
SUBLANE = 8                      # f32 sublane height


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
