"""Pallas TPU kernel: flash attention (forward) for the LM substrate.

This is the kernel §Perf identified as the remaining lever for every
memory-bound train/prefill cell: the XLA-level attention materializes
S x S score tensors in HBM; this kernel keeps (bq x bk) score TILES in
VMEM with the online-softmax recurrence, so HBM traffic is O(S*hd), not
O(S^2) -- the same BRAM-residency insight the paper's FPGA pipeline uses
for HOG cells (DESIGN.md §2), applied to attention.

Layout: q (B, H, S, hd); k, v (B, K, S, hd) with H = K*rep (GQA: the kv
block index maps h -> h // rep, so KV heads are never materialized
repeated). Grid (B*H, nQ, nK) with the K axis innermost: the output
block (bq, hd) is revisited across the K sweep while the running
(max, sum, acc) state lives in VMEM scratch.

Causal masking skips fully-masked K blocks (no compute, no traffic).
Validated against kernels/ref.py (pure-jnp oracle) in interpret mode;
sized for v5e VMEM: default (bq, bk) = (512, 512), fp32 accumulators.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, cdiv

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q0 = i * bq
    k0 = j * bk

    def compute():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        if causal:
            qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip K blocks entirely above the diagonal band
        pl.when(k0 <= q0 + bq - 1)(compute)
    else:
        compute()

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512,
                    interpret: bool = INTERPRET) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, K, S, hd), H % K == 0 -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    rep = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq = cdiv(S, bq)
    nk = cdiv(S, bk)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(hd)

    grid = (B * H, nq, nk)

    def qmap(h, i, j):
        return (h, i, 0)

    def kvmap(h, i, j):
        return ((h % H) // rep + (h // H) * K, j, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), qmap),
            pl.BlockSpec((1, bk, hd), kvmap),
            pl.BlockSpec((1, bk, hd), kvmap),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), qmap),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q.reshape(B * H, S, hd),
      k.reshape(B * K, S, hd),
      v.reshape(B * K, S, hd))
    return out.reshape(B, H, S, hd)
