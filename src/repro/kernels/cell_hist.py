"""Pallas TPU kernel: per-cell orientation histograms (HOG stage 3b).

Input : mag (B, Ha, Wa) f32, bin (B, Ha, Wa) int32    (paper: 128 x 64)
Output: hist (B, ch, cw, 9) f32                        (paper: 16 x 8 x 9)

TPU adaptation of the paper's BRAM accumulate-per-bin pipeline: the
scatter "hist[bin] += mag" serializes on TPU, so the accumulation is
re-expressed as a dense one-hot contraction,

    hist[c, b] = sum_px mag[c, px] * [bin[c, px] == b]

which the compiler maps onto vector selects + tree reductions (and, in
the fused kernel, onto an MXU matmul over the 64-px cell axis). This is
the "adder tree in space, not time" translation (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _kernel(mag_ref, bin_ref, hist_ref, *, cell: int, bins: int):
    mag = mag_ref[...]                               # (TB, Ha, Wa)
    bi = bin_ref[...]
    tb, ha, wa = mag.shape
    ch, cw = ha // cell, wa // cell
    # (TB, ch, py, cw, px)
    m = mag.reshape(tb, ch, cell, cw, cell)
    b = bi.reshape(tb, ch, cell, cw, cell)
    # fixed chain: int32 magnitudes accumulate exactly, stored int16
    # (per-cell bound 64 * 361 < 2^15); float chains accumulate f32
    acc = jnp.zeros((tb, ch, cw, bins), mag.dtype)
    zero = jnp.zeros((), mag.dtype)
    for k in range(bins):                            # bins is static (9)
        sel = jnp.where(b == k, m, zero)
        acc = acc.at[..., k].set(jnp.sum(sel, axis=(2, 4)))
    hist_ref[...] = acc.astype(hist_ref.dtype)


@partial(jax.jit, static_argnames=("cell", "bins", "block_b", "interpret"))
def cell_hist(mag: jax.Array, bin_idx: jax.Array, cell: int = 8,
              bins: int = 9, block_b: int = 8,
              interpret: bool = INTERPRET) -> jax.Array:
    B, Ha, Wa = mag.shape
    ch, cw = Ha // cell, Wa // cell
    tb = min(block_b, B)
    # int32 magnitudes (fixed chain) store int16 histograms
    out_dtype = jnp.int16 if jnp.issubdtype(mag.dtype, jnp.integer) \
        else jnp.float32
    return pl.pallas_call(
        partial(_kernel, cell=cell, bins=bins),
        grid=(cdiv(B, tb),),
        in_specs=[
            pl.BlockSpec((tb, Ha, Wa), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, Ha, Wa), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ch, cw, bins), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, ch, cw, bins), out_dtype),
        interpret=interpret,
    )(mag, bin_idx)
