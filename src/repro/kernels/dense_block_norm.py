"""Pallas TPU kernel: DENSE block L2 normalization (eq. 5, whole scene).

Input : hist (B, ch, cw, bins) f32 -- the scene's cell-histogram grid
Output: blocks (B, bh, bw, block^2*bins) f32, L2-normalized

Dense companion of block_norm.py: instead of one megablock holding the
whole scene's cell grid, the kernel tiles over ROW SLABS of the BLOCK
grid (`row_blocks` block rows per program). A block row r reads cell
rows r..r+block-1, so -- as in dense_grad_hist.py -- the wrapper passes
`block` vertically shifted views of the histogram buffer instead of
overlapping BlockSpecs; slab i of view j holds cell rows i*TR+j ..
i*TR+j+TR-1, exactly the j-th cell row of every block in the slab.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import numerics as N
from repro.kernels.common import INTERPRET, cdiv


def _kernel(*refs, block: int, eps: float, mode: str):
    views, out_ref = refs[:-1], refs[-1]
    bw = out_ref.shape[-2]
    parts = []
    for i in range(block):                        # cell-row offset
        h = views[i][...]                         # (1, TR, cw, bins)
        for j in range(block):                    # cell-col offset
            parts.append(h[:, :, j:j + bw, :])
    v = jnp.concatenate(parts, axis=-1)           # (1, TR, bw, bd)
    # shared normalize tail: rsqrt flavor + int8 quantize for "fixed"
    out_ref[...] = N.finish_blocks(v, eps, mode)


@partial(jax.jit, static_argnames=("block", "eps", "mode", "row_blocks",
                                   "interpret"))
def dense_block_norm(hist: jax.Array, block: int = 2, eps: float = 1e-2,
                     mode: str = "rsqrt", row_blocks: int = 16,
                     interpret: bool = INTERPRET) -> jax.Array:
    """(B, ch, cw, bins) f32 -> (B, bh, bw, block^2*bins) f32."""
    B, ch, cw, bins = hist.shape
    bh, bw = ch - block + 1, cw - block + 1
    bd = block * block * bins
    tr = min(row_blocks, bh)
    s = cdiv(bh, tr)
    # pad cell rows so every shifted view tiles into s full slabs; the
    # zero rows only feed block rows >= bh, sliced off below (the zero
    # vectors normalize to zero -- eps^2 keeps the rsqrt finite)
    chp = s * tr + block - 1
    if chp != ch:
        hist = jnp.pad(hist, ((0, 0), (0, chp - ch), (0, 0), (0, 0)))
    views = [hist[:, j:j + s * tr] for j in range(block)]
    out = pl.pallas_call(
        partial(_kernel, block=block, eps=eps, mode=mode),
        grid=(B, s),
        in_specs=[pl.BlockSpec((1, tr, cw, bins),
                               lambda b, i: (b, i, 0, 0))] * block,
        out_specs=pl.BlockSpec((1, tr, bw, bd), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, s * tr, bw, bd), jnp.float32),
        interpret=interpret,
    )(*views)
    return out[:, :bh]
