"""Jit'd public wrappers around the Pallas kernels.

`hog_descriptor_kernel`  -- staged kernels (gradient -> hist -> norm)
`hog_descriptor_fused`   -- single fused kernel (§Perf artifact)
`svm_score_kernel`       -- MXU-tiled scoring
All take the same HOGConfig as the pure-jnp path, so core/pipeline.py can
switch paths with a string.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hog import HOGConfig, PAPER_HOG, grayscale
from repro.kernels.hog_gradient import hog_gradient
from repro.kernels.cell_hist import cell_hist
from repro.kernels.block_norm import block_norm
from repro.kernels.svm_matmul import svm_scores
from repro.kernels.fused_hog import fused_hog


def _to_gray(windows: jax.Array, cfg: HOGConfig) -> jax.Array:
    gray = grayscale(windows) if windows.shape[-1] == 3 else windows
    gray = gray.astype(jnp.float32)
    return gray[..., : cfg.active_h + 2, : cfg.active_w + 2]


def _kernel_mode(cfg: HOGConfig) -> str:
    # the kernels implement the two hardware modes; "ref" maps to sector
    # (bit-identical bins, see tests/test_kernels_hog.py)
    return "cordic" if cfg.mode == "cordic" else "sector"


@partial(jax.jit, static_argnames=("cfg",))
def hog_descriptor_kernel(windows: jax.Array,
                          cfg: HOGConfig = PAPER_HOG) -> jax.Array:
    gray = _to_gray(windows, cfg)
    mode = _kernel_mode(cfg)
    mag, b = hog_gradient(gray, mode=mode)
    hist = cell_hist(mag, b, cell=cfg.cell, bins=cfg.bins)
    blocks = block_norm(hist, block=cfg.block, eps=cfg.eps,
                        mode=("nr" if mode == "cordic" else "rsqrt"))
    return blocks.reshape(blocks.shape[0], cfg.n_features)


@partial(jax.jit, static_argnames=("cfg",))
def hog_descriptor_fused(windows: jax.Array,
                         cfg: HOGConfig = PAPER_HOG) -> jax.Array:
    gray = _to_gray(windows, cfg)
    return fused_hog(gray, cell=cfg.cell, block=cfg.block, bins=cfg.bins,
                     eps=cfg.eps, mode=_kernel_mode(cfg))


@jax.jit
def svm_score_kernel(feats: jax.Array, w: jax.Array,
                     bias: jax.Array) -> jax.Array:
    return svm_scores(feats, w, bias)
