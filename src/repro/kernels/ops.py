"""Jit'd public wrappers around the Pallas kernels.

`hog_descriptor_kernel`  -- staged kernels (gradient -> hist -> norm)
`hog_descriptor_fused`   -- single fused kernel (§Perf artifact)
`svm_score_kernel`       -- MXU-tiled scoring

Both HOG wrappers are thin views over the canonical stage chain in
core/stages.py (window layout, "kernel" / "fused" backends) -- the same
stage list that core/hog.py and the dense detector instantiate, so the
implementations cannot drift.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.stages import window_descriptor
from repro.kernels.svm_matmul import svm_scores


@partial(jax.jit, static_argnames=("cfg",))
def hog_descriptor_kernel(windows: jax.Array,
                          cfg: HOGConfig = PAPER_HOG) -> jax.Array:
    return window_descriptor(windows, cfg, backend="kernel")


@partial(jax.jit, static_argnames=("cfg",))
def hog_descriptor_fused(windows: jax.Array,
                         cfg: HOGConfig = PAPER_HOG) -> jax.Array:
    return window_descriptor(windows, cfg, backend="fused")


@jax.jit
def svm_score_kernel(feats: jax.Array, w: jax.Array,
                     bias: jax.Array) -> jax.Array:
    return svm_scores(feats, w, bias)
