"""Pallas TPU kernel: batched linear-SVM scoring (eq. 6, SVMCLASSIFY block).

Input : feats (B, F) f32, w (F,) f32, b () f32     (paper: F = 3780)
Output: scores (B,) f32

The FPGA evaluates W.X serially (one MAC per cycle); the TPU evaluates a
(TB, TF) x (TF, 1) matmul per grid step on the MXU. F = 3780 is padded to
3840 = 30*128 so every K tile is lane-aligned; the K grid dimension
accumulates partial products into the output block (revisited-block
accumulation, the canonical Pallas matmul pattern).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv, round_up, LANE


def _kernel(x_ref, w_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                                  # (TB, TF)
    w = w_ref[...]                                  # (TF, 1)
    out_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (TB, 1) on the MXU


@partial(jax.jit, static_argnames=("block_b", "block_f", "interpret"))
def svm_scores(feats: jax.Array, w: jax.Array, bias: jax.Array,
               block_b: int = 128, block_f: int = 512,
               interpret: bool = INTERPRET) -> jax.Array:
    B, F = feats.shape
    Bp = round_up(B, 8)
    tb = min(block_b, Bp)
    tf = min(block_f, round_up(F, LANE))
    # every K tile must be in-bounds: pad F to a multiple of the K tile
    # (zero padding contributes exactly 0 to the accumulation)
    Fp = round_up(F, tf)
    feats = jnp.pad(feats, ((0, Bp - B), (0, Fp - F)))
    wp = jnp.pad(w, (0, Fp - F)) if Fp != F else w
    out = pl.pallas_call(
        _kernel,
        grid=(cdiv(Bp, tb), cdiv(Fp, tf)),
        in_specs=[
            pl.BlockSpec((tb, tf), lambda i, k: (i, k)),
            pl.BlockSpec((tf, 1), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((tb, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        interpret=interpret,
    )(feats, wp.reshape(Fp, 1))
    return out[:B, 0] + bias


# ---------------------------------------------------------- dense scoring
# The dense detector scores every window position at cell stride; the
# 15x7x36 "conv" over the scene's block grid factors into ONE matmul
# (P block positions x 36) @ (36 x 105 window offsets) followed by 105
# cheap shifted adds (core/detector.py:score_blocks). This kernel is the
# matmul half on the MXU, grid over M tiles with the full (K, N) weight
# tile resident -- K=36, N=105 pad to one (40, 128) sublane/lane tile.


def _score_kernel(x_ref, w_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def score_matmul(flat: jax.Array, wt: jax.Array, block_m: int = 512,
                 interpret: bool = INTERPRET) -> jax.Array:
    """(M, K) block rows @ (K, N) per-offset weights -> (M, N) f32.

    Accepts f32 or bf16 inputs (the perf preset's bf16 descriptors);
    accumulation is always f32 (`preferred_element_type`).
    """
    M, K = flat.shape
    K2, N = wt.shape
    assert K == K2, (flat.shape, wt.shape)
    Mp = round_up(M, 8)
    Kp = round_up(K, 8)
    Np = round_up(N, LANE)
    tm = min(block_m, Mp)
    Mp = round_up(Mp, tm)
    flat = jnp.pad(flat, ((0, Mp - M), (0, Kp - K)))
    wt = jnp.pad(wt, ((0, Kp - K), (0, Np - N)))
    out = pl.pallas_call(
        _score_kernel,
        grid=(cdiv(Mp, tm),),
        in_specs=[
            pl.BlockSpec((tm, Kp), lambda i: (i, 0)),
            pl.BlockSpec((Kp, Np), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(flat, wt)
    return out[:M, :N]


def _score_kernel_i8(x_ref, w_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def score_matmul_int8(q: jax.Array, wq: jax.Array, block_m: int = 512,
                      interpret: bool = INTERPRET) -> jax.Array:
    """(M, K) int8 block rows @ (K, N) int8 weights -> (M, N) int32.

    The fixed-mode twin of `score_matmul`: codes in [-127, 127] over
    K = 36 accumulate to at most 36 * 127^2 < 2^20, so the int32 MXU
    accumulation is EXACT -- which is why quantized scoring is
    byte-identical under any M blocking, tiling, or sharding (integer
    adds are associative; there is no rounding to reorder). Padding is
    zeros, contributing exact 0s. int8 min tile is (32, 128), hence the
    32-row/col alignment.
    """
    M, K = q.shape
    K2, N = wq.shape
    assert K == K2, (q.shape, wq.shape)
    assert q.dtype == jnp.int8 and wq.dtype == jnp.int8, (q.dtype, wq.dtype)
    Mp = round_up(M, 32)
    Kp = round_up(K, 32)
    Np = round_up(N, LANE)
    tm = min(block_m, Mp)
    Mp = round_up(Mp, tm)
    q = jnp.pad(q, ((0, Mp - M), (0, Kp - K)))
    wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
    out = pl.pallas_call(
        _score_kernel_i8,
        grid=(cdiv(Mp, tm),),
        in_specs=[
            pl.BlockSpec((tm, Kp), lambda i: (i, 0)),
            pl.BlockSpec((Kp, Np), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=interpret,
    )(q, wq)
    return out[:M, :N]
