"""Pallas TPU kernel: DENSE gradient -> mag/bin -> cell histograms.

Input : gray scene (B, H, W) f32, H = gh + 2 with gh a whole number of
        cells (the dense-layout trim, core/stages.py)
Output: hist (B, ch, cw, bins) f32 -- the whole scene's cell grid

The window kernels (hog_gradient.py + cell_hist.py) tile over a BATCH
of small windows: one VMEM block per window slab, geometry sized for
130x66 tiles. Pushing a dense 640x480 scene through them lands the
whole frame in a single megablock -- no grid, no pipelining, and a
VMEM ceiling on scene size. This kernel instead tiles the chain over
ROW SLABS of the scene's CELL GRID (`row_cells` cell rows = 8*row_cells
pixel rows per program), the dense analogue of how the paper's FPGA
streams rows through BUFFER_GRADIENT: each slab's gradients, bins and
cell histograms live entirely in VMEM and the grid pipelines slabs
against the HBM loads.

Halo: the central-difference gradient at interior row r reads gray rows
r-1..r+1. Pallas block index maps address whole blocks, so instead of
overlapping BlockSpecs the wrapper passes THREE vertically shifted
views of the gray buffer (rows 0.., 1.., 2..); slab i of each view
lines up so the kernel sees its one-row halo for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv
from repro.kernels.hog_gradient import mag_bin_impl


def _kernel(up_ref, mid_ref, dn_ref, hist_ref, *, cell: int, bins: int,
            mode: str):
    up = up_ref[...]                              # rows r-1   (1, R, W)
    mid = mid_ref[...]                            # rows r
    dn = dn_ref[...]                              # rows r+1
    fx = mid[:, :, 2:] - mid[:, :, :-2]           # eq. (1)
    fy = dn[:, :, 1:-1] - up[:, :, 1:-1]          # eq. (2)
    tb, rr, gw = fx.shape
    gw = gw // cell * cell                        # trim ragged right edge
    fx, fy = fx[:, :, :gw], fy[:, :, :gw]
    mag, b = mag_bin_impl(mode)(fx, fy)
    tr, cw = rr // cell, gw // cell
    m = mag.reshape(tb, tr, cell, cw, cell)
    bi = b.reshape(tb, tr, cell, cw, cell)
    # fixed chain accumulates int32, stores int16 (per-cell bound, so
    # slab height never matters); float chains accumulate f32
    acc = jnp.zeros((tb, tr, cw, bins), m.dtype)
    zero = jnp.zeros((), m.dtype)
    for k in range(bins):                         # bins is static (9)
        acc = acc.at[..., k].set(
            jnp.sum(jnp.where(bi == k, m, zero), axis=(2, 4)))
    hist_ref[...] = acc.astype(hist_ref.dtype)


@partial(jax.jit, static_argnames=("cell", "bins", "mode", "row_cells",
                                   "interpret"))
def dense_grad_hist(gray: jax.Array, cell: int = 8, bins: int = 9,
                    mode: str = "sector", row_cells: int = 8,
                    interpret: bool = INTERPRET) -> jax.Array:
    """(B, H, W) f32 dense scene -> (B, ch, cw, bins) cell histograms."""
    B, H, W = gray.shape
    gh = (H - 2) // cell * cell
    ch, cw = gh // cell, (W - 2) // cell
    tr = min(row_cells, ch)
    s = cdiv(ch, tr)
    # pad rows so the slab grid tiles exactly; the padded rows only feed
    # cell rows >= ch, which are sliced off below
    hp = s * tr * cell + 2
    if hp != H:
        gray = jnp.pad(gray, ((0, 0), (0, max(0, hp - H)), (0, 0)))
    rows = tr * cell
    out_dtype = jnp.int16 if mode == "fixed" else jnp.float32
    out = pl.pallas_call(
        partial(_kernel, cell=cell, bins=bins, mode=mode),
        grid=(B, s),
        in_specs=[pl.BlockSpec((1, rows, W), lambda b, i: (b, i, 0))] * 3,
        out_specs=pl.BlockSpec((1, tr, cw, bins), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, s * tr, cw, bins), out_dtype),
        interpret=interpret,
    )(gray[:, 0:hp - 2, :], gray[:, 1:hp - 1, :], gray[:, 2:hp, :])
    return out[:, :ch]
