"""Pallas TPU kernel: gradient + magnitude + orientation-bin (HOG stage 3).

Input : gray windows (B, H, W) float32   (paper: H=130, W=66)
Output: magnitude   (B, H-2, W-2) float32
        bin index   (B, H-2, W-2) int32  (9 unsigned-orientation bins)

Adaptation of the paper's CORDIC stage (Figs. 7-8) to the TPU VPU:
  * mode="sector": the classifier consumes only the BIN, so the angle is
    never materialized -- 8 cross-multiplication boundary tests replace
    the 15-iteration CORDIC rotation (see DESIGN.md §2). No trig, no
    division, branch-free: pure VPU mul/cmp/add.
  * mode="cordic": the faithful datapath -- 15 LUT-driven shift-add
    rotations, gain-corrected magnitude, then binning. Kept as the
    validation mode for the paper's numerics.

Grid: one program per TB-window slab; W sits in the lane dimension
(66 -> 128 lane padding; the fused kernel in fused_hog.py repacks to
recover this, see §Perf).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cordic import ATAN_LUT_DEG, cordic_gain
from repro.kernels.common import INTERPRET, cdiv

_BOUNDARIES = tuple((math.cos(math.radians(20.0 * (k + 1))),
                     math.sin(math.radians(20.0 * (k + 1))))
                    for k in range(8))


def _mag_bin_sector(fx, fy):
    mag = jnp.sqrt(fx * fx + fy * fy)
    flip = fy < 0
    ux = jnp.where(flip, -fx, fx)
    uy = jnp.where(flip, -fy, fy)
    on_axis = (uy == 0) & (ux < 0)
    ux = jnp.where(on_axis, -ux, ux)
    b = jnp.zeros(fx.shape, jnp.int32)
    for cb, sb in _BOUNDARIES:
        b += ((uy * cb - ux * sb) >= 0.0).astype(jnp.int32)
    return mag, b


def _mag_bin_cordic(fx, fy, iters: int = 15):
    neg_x = fx < 0
    x0 = jnp.where(neg_x, -fx, fx)
    y0 = jnp.where(neg_x, -fy, fy)
    z0 = jnp.zeros_like(fx)
    x, y, z = x0, y0, z0
    for i in range(iters):                       # fixed-depth HW pipeline
        p = 2.0 ** (-i)
        d = jnp.where(y < 0, -1.0, 1.0)
        x, y, z = x + d * y * p, y - d * x * p, z + d * ATAN_LUT_DEG[i]
    mag = x * (1.0 / cordic_gain(iters))
    ang = jnp.where(neg_x, jnp.where(fy >= 0, z + 180.0, z - 180.0), z)
    both_zero = (fx == 0) & (fy == 0)
    mag = jnp.where(both_zero, 0.0, mag)
    ang = jnp.where(both_zero, 0.0, ang)
    theta = jnp.mod(ang, 180.0)
    b = jnp.clip(jnp.floor(theta / 20.0), 0, 8).astype(jnp.int32)
    return mag, b


def _kernel(gray_ref, mag_ref, bin_ref, *, mode: str):
    g = gray_ref[...]                            # (TB, H, W)
    fx = g[:, 1:-1, 2:] - g[:, 1:-1, :-2]        # eq. (1)
    fy = g[:, 2:, 1:-1] - g[:, :-2, 1:-1]        # eq. (2)
    if mode == "sector":
        mag, b = _mag_bin_sector(fx, fy)
    else:
        mag, b = _mag_bin_cordic(fx, fy)
    mag_ref[...] = mag
    bin_ref[...] = b


@partial(jax.jit, static_argnames=("mode", "block_b", "interpret"))
def hog_gradient(gray: jax.Array, mode: str = "sector",
                 block_b: int = 8, interpret: bool = INTERPRET):
    """(B, H, W) f32 -> (mag, bin) each (B, H-2, W-2)."""
    B, H, W = gray.shape
    tb = min(block_b, B)
    grid = (cdiv(B, tb),)
    out_shape = (
        jax.ShapeDtypeStruct((B, H - 2, W - 2), jnp.float32),
        jax.ShapeDtypeStruct((B, H - 2, W - 2), jnp.int32),
    )
    return pl.pallas_call(
        partial(_kernel, mode=mode),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, H, W), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((tb, H - 2, W - 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, H - 2, W - 2), lambda i: (i, 0, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(gray)
