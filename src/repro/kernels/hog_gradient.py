"""Pallas TPU kernel: gradient + magnitude + orientation-bin (HOG stage 3).

Input : gray windows (B, H, W) float32   (paper: H=130, W=66)
Output: magnitude   (B, H-2, W-2) float32
        bin index   (B, H-2, W-2) int32  (9 unsigned-orientation bins)

Adaptation of the paper's CORDIC stage (Figs. 7-8) to the TPU VPU:
  * mode="sector": the classifier consumes only the BIN, so the angle is
    never materialized -- 8 cross-multiplication boundary tests replace
    the 15-iteration CORDIC rotation (see DESIGN.md §2). No trig, no
    division, branch-free: pure VPU mul/cmp/add.
  * mode="cordic": the faithful datapath -- 15 LUT-driven shift-add
    rotations, gain-corrected magnitude, then binning. Kept as the
    validation mode for the paper's numerics.

Grid: one program per TB-window slab; W sits in the lane dimension
(66 -> 128 lane padding; the fused kernel in fused_hog.py repacks to
recover this, see §Perf).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cordic import (ANG_180, ATAN_LUT_DEG, ATAN_LUT_FIXED,
                               MAG_FRAC_BITS, _INV_GAIN_HALF, cordic_gain)
from repro.kernels.common import INTERPRET, cdiv

_BOUNDARIES = tuple((math.cos(math.radians(20.0 * (k + 1))),
                     math.sin(math.radians(20.0 * (k + 1))))
                    for k in range(8))


def _mag_bin_sector(fx, fy):
    mag = jnp.sqrt(fx * fx + fy * fy)
    flip = fy < 0
    ux = jnp.where(flip, -fx, fx)
    uy = jnp.where(flip, -fy, fy)
    on_axis = (uy == 0) & (ux < 0)
    ux = jnp.where(on_axis, -ux, ux)
    b = jnp.zeros(fx.shape, jnp.int32)
    for cb, sb in _BOUNDARIES:
        b += ((uy * cb - ux * sb) >= 0.0).astype(jnp.int32)
    return mag, b


def _mag_bin_cordic(fx, fy, iters: int = 15):
    neg_x = fx < 0
    x0 = jnp.where(neg_x, -fx, fx)
    y0 = jnp.where(neg_x, -fy, fy)
    z0 = jnp.zeros_like(fx)
    x, y, z = x0, y0, z0
    for i in range(iters):                       # fixed-depth HW pipeline
        p = 2.0 ** (-i)
        d = jnp.where(y < 0, -1.0, 1.0)
        x, y, z = x + d * y * p, y - d * x * p, z + d * ATAN_LUT_DEG[i]
    mag = x * (1.0 / cordic_gain(iters))
    # on-axis pin (fy == 0 -> angle exactly 0/180): without it the
    # +-atan(2^-14) iteration residual leaks through the unsigned fold
    # below as mod(180 + eps, 180) ~= 179.997 -> bin 8 where the arctan2
    # oracle says bin 0 (the 180-degree off-by-one this PR sweeps)
    z = jnp.where(fy == 0, 0.0, z)
    ang = jnp.where(neg_x, jnp.where(fy >= 0, z + 180.0, z - 180.0), z)
    both_zero = (fx == 0) & (fy == 0)
    mag = jnp.where(both_zero, 0.0, mag)
    ang = jnp.where(both_zero, 0.0, ang)
    theta = jnp.mod(ang, 180.0)
    b = jnp.clip(jnp.floor(theta / 20.0), 0, 8).astype(jnp.int32)
    return mag, b


def _mag_bin_fixed(fx, fy, iters: int = 15):
    """Integer shift-add CORDIC (core/cordic.py:cordic_mag_bin_fixed,
    unrolled for the Mosaic pipeline). fx/fy must be integer-valued f32;
    returns (mag int32 in half-gray units, bin int32)."""
    xi = jnp.round(fx).astype(jnp.int32)
    yi = jnp.round(fy).astype(jnp.int32)
    neg_x = xi < 0
    x = jnp.where(neg_x, -xi, xi) << MAG_FRAC_BITS
    y = jnp.where(neg_x, -yi, yi) << MAG_FRAC_BITS
    z = jnp.zeros_like(x)
    for i in range(iters):                       # static shifts + LUT ints
        xs, ys = x >> i, y >> i
        d = y < 0
        x, y, z = (jnp.where(d, x - ys, x + ys),
                   jnp.where(d, y + xs, y - xs),
                   jnp.where(d, z - ATAN_LUT_FIXED[i], z + ATAN_LUT_FIXED[i]))
    z = jnp.where(yi == 0, 0, z)                 # same on-axis pin
    ang = jnp.where(neg_x, jnp.where(yi >= 0, z + ANG_180, z - ANG_180), z)
    theta = jnp.mod(ang, ANG_180)
    b = jnp.minimum(theta // (ANG_180 // 9), 8).astype(jnp.int32)
    mag = jnp.rint(x.astype(jnp.float32)
                   * jnp.float32(_INV_GAIN_HALF)).astype(jnp.int32)
    both_zero = (xi == 0) & (yi == 0)
    return jnp.where(both_zero, 0, mag), jnp.where(both_zero, 0, b)


#: numerics-mode -> mag/bin implementation, the Pallas twin of
#: core/hog.py:_MAG_BIN. Every kernel (staged gradient, dense grad+hist,
#: both fused variants) dispatches through mag_bin_impl, so a mode that
#: exists in one backend exists in all of them (core/numerics.py).
MAG_BIN_IMPLS = {
    "sector": _mag_bin_sector,
    "cordic": _mag_bin_cordic,
    "fixed": _mag_bin_fixed,
}


def mag_bin_impl(mode: str):
    try:
        return MAG_BIN_IMPLS[mode]
    except KeyError:
        raise ValueError(
            f"unknown kernel numerics mode {mode!r}; expected one of "
            f"{sorted(MAG_BIN_IMPLS)}") from None


def mag_dtype(mode: str):
    """Magnitude dtype a mode's mag/bin impl produces (int32 for the
    fixed-point chain, f32 otherwise)."""
    mag_bin_impl(mode)
    return jnp.int32 if mode == "fixed" else jnp.float32


def _kernel(gray_ref, mag_ref, bin_ref, *, mode: str):
    g = gray_ref[...]                            # (TB, H, W)
    fx = g[:, 1:-1, 2:] - g[:, 1:-1, :-2]        # eq. (1)
    fy = g[:, 2:, 1:-1] - g[:, :-2, 1:-1]        # eq. (2)
    mag, b = mag_bin_impl(mode)(fx, fy)
    mag_ref[...] = mag
    bin_ref[...] = b


@partial(jax.jit, static_argnames=("mode", "block_b", "interpret"))
def hog_gradient(gray: jax.Array, mode: str = "sector",
                 block_b: int = 8, interpret: bool = INTERPRET):
    """(B, H, W) f32 -> (mag, bin) each (B, H-2, W-2)."""
    B, H, W = gray.shape
    tb = min(block_b, B)
    grid = (cdiv(B, tb),)
    out_shape = (
        jax.ShapeDtypeStruct((B, H - 2, W - 2), mag_dtype(mode)),
        jax.ShapeDtypeStruct((B, H - 2, W - 2), jnp.int32),
    )
    return pl.pallas_call(
        partial(_kernel, mode=mode),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, H, W), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((tb, H - 2, W - 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, H - 2, W - 2), lambda i: (i, 0, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(gray)
