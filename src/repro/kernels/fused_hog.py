"""Pallas TPU kernel: FUSED HOG window pipeline (stages 3-6 in one kernel).

Input : gray (B, 130, 66) f32
Output: descriptors (B, 3780) f32

This is the beyond-paper §Perf artifact. The staged kernels round-trip
(B,128,64) magnitude/bin and (B,16,8,9) histograms through HBM between
pallas_calls; per window that is ~98 KB of intermediate traffic for a
15 KB descriptor. Fusing the whole chain keeps every intermediate in
VMEM: HBM traffic drops to 34 KB in + 15 KB out per window (~3.5x less),
and the pipeline becomes compute-bound on the VPU -- mirroring how the
paper's FPGA streams cell data through BUFFER_HOG_PRENORM without ever
leaving on-chip BRAM. That correspondence (BRAM dataflow == VMEM fusion)
is the paper's core insight mapped to TPU (DESIGN.md §2).

The SVM dot product could fuse here too; it is kept separate because the
weight tile is shared across the whole batch and the MXU matmul in
svm_matmul.py already runs at roofline for F=3780.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import numerics as N
from repro.kernels.common import INTERPRET, cdiv
from repro.kernels.hog_gradient import mag_bin_impl


def _norm_flavor(mode: str) -> str:
    # the normalize tail is a MODE-DERIVED property, not a second ad-hoc
    # predicate: SPECS is the same table stages.py dispatches on, so the
    # fused kernels can never disagree with the staged ones about which
    # rsqrt (or quantizer) a mode uses. This replaces the old
    # `_nr_rsqrt if mode == "cordic" else rsqrt` inline test that made
    # NR engagement a fused-kernel-local decision.
    return N.SPECS[mode].norm


def _kernel(gray_ref, desc_ref, *, cell: int, block: int, bins: int,
            eps: float, mode: str):
    g = gray_ref[...]                                    # (TB, H, W)
    fx = g[:, 1:-1, 2:] - g[:, 1:-1, :-2]
    fy = g[:, 2:, 1:-1] - g[:, :-2, 1:-1]
    tb, ha, wa = fx.shape
    ha = (ha // cell) * cell
    wa = (wa // cell) * cell
    fx, fy = fx[:, :ha, :wa], fy[:, :ha, :wa]
    mag, b = mag_bin_impl(mode)(fx, fy)

    ch, cw = ha // cell, wa // cell
    m = mag.reshape(tb, ch, cell, cw, cell)
    bi = b.reshape(tb, ch, cell, cw, cell)
    hist = jnp.zeros((tb, ch, cw, bins), m.dtype)
    zero = jnp.zeros((), m.dtype)
    for k in range(bins):
        hist = hist.at[..., k].set(
            jnp.sum(jnp.where(bi == k, m, zero), axis=(2, 4)))
    hist = N.store_hist(hist)

    bh, bw = ch - block + 1, cw - block + 1
    parts = [hist[:, i:i + bh, j:j + bw, :]
             for i in range(block) for j in range(block)]
    v = jnp.concatenate(parts, axis=-1)                  # (TB, bh, bw, 36)
    v = N.finish_blocks(v, eps, _norm_flavor(mode))
    desc_ref[...] = v.reshape(tb, bh * bw * block * block * bins)


@partial(jax.jit, static_argnames=("cell", "block", "bins", "eps", "mode",
                                   "block_b", "interpret"))
def fused_hog(gray: jax.Array, cell: int = 8, block: int = 2, bins: int = 9,
              eps: float = 1e-2, mode: str = "sector", block_b: int = 8,
              interpret: bool = INTERPRET) -> jax.Array:
    B, H, W = gray.shape
    ha = ((H - 2) // cell) * cell
    wa = ((W - 2) // cell) * cell
    ch, cw = ha // cell, wa // cell
    bh, bw = ch - block + 1, cw - block + 1
    nf = bh * bw * block * block * bins
    tb = min(block_b, B)
    return pl.pallas_call(
        partial(_kernel, cell=cell, block=block, bins=bins, eps=eps,
                mode=mode),
        grid=(cdiv(B, tb),),
        in_specs=[pl.BlockSpec((tb, H, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tb, nf), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nf), jnp.float32),
        interpret=interpret,
    )(gray)


# ------------------------------------------------------------ dense grid
# The window kernel above fuses the chain for a BATCH of 130x66 tiles.
# The dense variant fuses the same chain for a WHOLE SCENE, tiled over
# row slabs of the scene's block grid so arbitrarily tall frames stream
# through a fixed VMEM budget (the dense analogue of the paper's
# BUFFER_HOG_PRENORM row streaming). A slab of `row_blocks` block rows
# needs `row_blocks + block - 1` cell rows of histogram, i.e. a
# one-cell-row recompute overlap between neighboring slabs -- the
# wrapper hands each program its overlapping gray rows through a
# host-side clamped gather (one XLA gather, ~15% duplicated rows),
# which keeps the BlockSpecs plain and non-overlapping.

def _dense_kernel(slab_ref, out_ref, *, cell: int, block: int, bins: int,
                  eps: float, mode: str):
    g = slab_ref[0, 0]                                   # (K, W)
    fx = g[1:-1, 2:] - g[1:-1, :-2]
    fy = g[2:, 1:-1] - g[:-2, 1:-1]
    rr, gw = fx.shape
    gw = gw // cell * cell
    fx, fy = fx[:, :gw], fy[:, :gw]
    mag, b = mag_bin_impl(mode)(fx, fy)

    cr, cw = rr // cell, gw // cell                      # tr+block-1 cell rows
    m = mag.reshape(cr, cell, cw, cell)
    bi = b.reshape(cr, cell, cw, cell)
    hist = jnp.zeros((cr, cw, bins), m.dtype)
    zero = jnp.zeros((), m.dtype)
    for k in range(bins):
        hist = hist.at[..., k].set(
            jnp.sum(jnp.where(bi == k, m, zero), axis=(1, 3)))
    hist = N.store_hist(hist)

    tr, bw = cr - block + 1, cw - block + 1
    parts = [hist[i:i + tr, j:j + bw, :]
             for i in range(block) for j in range(block)]
    v = jnp.concatenate(parts, axis=-1)                  # (tr, bw, bd)
    out_ref[...] = N.finish_blocks(v, eps, _norm_flavor(mode))[None]


@partial(jax.jit, static_argnames=("cell", "block", "bins", "eps", "mode",
                                   "row_blocks", "interpret"))
def dense_fused_hog(gray: jax.Array, cell: int = 8, block: int = 2,
                    bins: int = 9, eps: float = 1e-2, mode: str = "sector",
                    row_blocks: int = 8,
                    interpret: bool = INTERPRET) -> jax.Array:
    """(B, H, W) f32 dense scene -> (B, bh, bw, block^2*bins) f32."""
    B, H, W = gray.shape
    gh = (H - 2) // cell * cell
    ch, cw = gh // cell, (W - 2) // cell
    bh, bw = ch - block + 1, cw - block + 1
    bd = block * block * bins
    tr = min(row_blocks, bh)
    s = cdiv(bh, tr)
    k = (tr + block - 1) * cell + 2          # gray rows each slab reads
    starts = np.arange(s) * tr * cell
    idx = np.minimum(starts[:, None] + np.arange(k)[None, :], H - 1)
    slabs = gray[:, idx, :]                  # (B, s, K, W) clamped gather
    out = pl.pallas_call(
        partial(_dense_kernel, cell=cell, block=block, bins=bins, eps=eps,
                mode=mode),
        grid=(B, s),
        in_specs=[pl.BlockSpec((1, 1, k, W), lambda b, i: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((1, tr, bw, bd), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, s * tr, bw, bd), jnp.float32),
        interpret=interpret,
    )(slabs)
    return out[:, :bh]
