# Pallas TPU kernels for the paper's compute hot-spots (HOG + SVM), each
# with a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.
from repro.kernels.hog_gradient import hog_gradient
from repro.kernels.cell_hist import cell_hist
from repro.kernels.block_norm import block_norm
from repro.kernels.svm_matmul import svm_scores, score_matmul
from repro.kernels.fused_hog import fused_hog, dense_fused_hog
from repro.kernels.dense_grad_hist import dense_grad_hist
from repro.kernels.dense_block_norm import dense_block_norm
from repro.kernels.flash_attention import flash_attention
