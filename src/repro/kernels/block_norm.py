"""Pallas TPU kernel: block L2 normalization (HOG stages 4-5, eq. 5).

Input : hist (B, ch, cw, 9) f32         (paper: 16 x 8 x 9)
Output: blocks (B, bh, bw, 36) f32      (paper: 15 x 7 x 36), normalized

v_i / sqrt(||v||^2 + eps^2) per 2x2-cell block. The paper's hardware
approximates the reciprocal sqrt with a Newton-Raphson unit (47-cycle
block latency); mode="nr" reproduces those numerics (2 NR iterations
from an exponent-halved seed), mode="rsqrt" uses the VPU's native
rsqrt -- the same approximation baked into silicon (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import numerics as N
from repro.kernels.common import INTERPRET, cdiv

#: back-compat alias -- the canonical NR rsqrt (and the whole normalize
#: tail) lives in core/numerics.py, shared by every backend
_nr_rsqrt = N.nr_rsqrt


def _kernel(hist_ref, out_ref, *, block: int, eps: float, mode: str):
    h = hist_ref[...]                                # (TB, ch, cw, bins)
    tb, ch, cw, bins = h.shape
    bh, bw = ch - block + 1, cw - block + 1
    parts = [h[:, i:i + bh, j:j + bw, :]
             for i in range(block) for j in range(block)]
    v = jnp.concatenate(parts, axis=-1)              # (TB, bh, bw, 36)
    # shared normalize tail: rsqrt flavor + int8 quantize for "fixed"
    out_ref[...] = N.finish_blocks(v, eps, mode)


@partial(jax.jit, static_argnames=("block", "eps", "mode", "block_b",
                                   "interpret"))
def block_norm(hist: jax.Array, block: int = 2, eps: float = 1e-2,
               mode: str = "rsqrt", block_b: int = 8,
               interpret: bool = INTERPRET) -> jax.Array:
    B, ch, cw, bins = hist.shape
    bh, bw = ch - block + 1, cw - block + 1
    bd = block * block * bins
    tb = min(block_b, B)
    return pl.pallas_call(
        partial(_kernel, block=block, eps=eps, mode=mode),
        grid=(cdiv(B, tb),),
        in_specs=[pl.BlockSpec((tb, ch, cw, bins), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((tb, bh, bw, bd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, bh, bw, bd), jnp.float32),
        interpret=interpret,
    )(hist)
