"""Pallas TPU kernel: block L2 normalization (HOG stages 4-5, eq. 5).

Input : hist (B, ch, cw, 9) f32         (paper: 16 x 8 x 9)
Output: blocks (B, bh, bw, 36) f32      (paper: 15 x 7 x 36), normalized

v_i / sqrt(||v||^2 + eps^2) per 2x2-cell block. The paper's hardware
approximates the reciprocal sqrt with a Newton-Raphson unit (47-cycle
block latency); mode="nr" reproduces those numerics (2 NR iterations
from an exponent-halved seed), mode="rsqrt" uses the VPU's native
rsqrt -- the same approximation baked into silicon (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, cdiv


def _nr_rsqrt(x, iters: int = 2):
    # exponent-halving bit-hack seed (hardware seed LUT) + NR refinement
    i = jax.lax.bitcast_convert_type(x, jnp.int32)
    y = jax.lax.bitcast_convert_type(jnp.int32(0x5F3759DF) - (i >> 1),
                                     jnp.float32)
    for _ in range(iters):
        y = y * (1.5 - 0.5 * x * y * y)
    return y


def _kernel(hist_ref, out_ref, *, block: int, eps: float, mode: str):
    h = hist_ref[...]                                # (TB, ch, cw, bins)
    tb, ch, cw, bins = h.shape
    bh, bw = ch - block + 1, cw - block + 1
    parts = [h[:, i:i + bh, j:j + bw, :]
             for i in range(block) for j in range(block)]
    v = jnp.concatenate(parts, axis=-1)              # (TB, bh, bw, 36)
    ss = jnp.sum(v * v, axis=-1, keepdims=True) + eps * eps
    inv = _nr_rsqrt(ss) if mode == "nr" else jax.lax.rsqrt(ss)
    out_ref[...] = v * inv


@partial(jax.jit, static_argnames=("block", "eps", "mode", "block_b",
                                   "interpret"))
def block_norm(hist: jax.Array, block: int = 2, eps: float = 1e-2,
               mode: str = "rsqrt", block_b: int = 8,
               interpret: bool = INTERPRET) -> jax.Array:
    B, ch, cw, bins = hist.shape
    bh, bw = ch - block + 1, cw - block + 1
    bd = block * block * bins
    tb = min(block_b, B)
    return pl.pallas_call(
        partial(_kernel, block=block, eps=eps, mode=mode),
        grid=(cdiv(B, tb),),
        in_specs=[pl.BlockSpec((tb, ch, cw, bins), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((tb, bh, bw, bd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, bh, bw, bd), jnp.float32),
        interpret=interpret,
    )(hist)
