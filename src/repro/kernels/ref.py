"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These delegate to repro.core.hog -- the software pipeline IS the oracle,
exactly as the paper validates its ModelSim waveforms against the Matlab
implementation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hog as H
from repro.core.svm import svm_score


def hog_gradient_ref(gray, mode: str = "sector"):
    fx, fy = H.gradients(gray.astype(jnp.float32))
    return H._MAG_BIN[mode](fx, fy, 9)


def cell_hist_ref(mag, bin_idx, cell: int = 8, bins: int = 9):
    B, Ha, Wa = mag.shape
    cfg = dataclasses.replace(H.PAPER_HOG, window_h=Ha + 2, window_w=Wa + 2,
                              cell=cell, bins=bins)
    return H.cell_histograms(mag, bin_idx, cfg)


def block_norm_ref(hist, block: int = 2, eps: float = 1e-2,
                   mode: str = "rsqrt"):
    B, ch, cw, bins = hist.shape
    cfg = dataclasses.replace(H.PAPER_HOG, window_h=ch * 8 + 2,
                              window_w=cw * 8 + 2, block=block, bins=bins,
                              eps=eps)
    # mode here is the NORM flavor ("rsqrt" | "nr" | "fixed"), same
    # vocabulary the block-norm kernels take
    return H.block_normalize(hist, cfg, norm=mode)


def svm_scores_ref(feats, w, bias):
    return svm_score({"w": w, "b": bias}, feats)


def fused_hog_ref(gray, mode: str = "sector"):
    B, Hh, Ww = gray.shape
    numerics = "fixed" if mode == "fixed" else "float"
    cfg = dataclasses.replace(H.PAPER_HOG, window_h=Hh, window_w=Ww,
                              mode="cordic" if mode == "fixed" else mode,
                              numerics=numerics)
    return H.hog_descriptor(gray, cfg)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle for kernels/flash_attention.py: masked softmax attention.
    q: (B,H,S,hd); k,v: (B,K,S,hd) GQA."""
    B, H, S, hd = q.shape
    rep = H // k.shape[1]
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / jnp.sqrt(hd).astype(q.dtype)
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m, s, -1e30)
    w = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vv)
