"""The one owner of process-level configuration (DESIGN.md §15).

Before this module, five entry points each mutated `XLA_FLAGS` / env
their own way: `tests/conftest.py` appended the forced-host-device
flag, `benchmarks/bench_timing.py` carried its own self-forcing block,
`launch/dryrun.py` *overwrote* `XLA_FLAGS` outright (clobbering any
operator-set flags), and CI lanes exported ad-hoc variables. Every one
of those is a pre-jax-init footgun: jax reads `XLA_FLAGS` exactly once,
at first backend initialization, so a mutation that lands late is
silently ignored and a clobber silently discards operator intent.

This module is the bayespec `config.py` idiom: importing it applies the
`REPRO_*` environment knobs exactly once (idempotence guard), BEFORE
jax initializes, and everything else imports from here instead of
touching `os.environ` itself. The repo-wide invariant, enforced by
tests/test_platform.py and the grep gate in CI review:

    no jax-affecting `os.environ[...]` mutation outside this file.

Environment knobs consumed by `apply()`:

    REPRO_TEST_DEVICES=N   force N host devices (merged into XLA_FLAGS;
                           an operator-set count in XLA_FLAGS wins)
    REPRO_XLA_FLAGS=...    extra XLA flags appended (existing flags of
                           the same name win -- append never clobbers)
    REPRO_X64=1|0          jax x64 mode (via JAX_ENABLE_X64, setdefault)
    REPRO_PLATFORM=cpu|... pin the jax platform (via JAX_PLATFORMS,
                           setdefault)
    REPRO_SEED=N           deterministic seed for benches/harnesses
                           (`default_seed()`)
    REPRO_AUTOTUNE_CACHE   autotune disk-cache path ("" disables;
                           resolved by `autotune_cache_path()`)

`describe()` snapshots the resolved environment (backend, device count,
x64, flags, seed, what apply() changed) for BENCH json rows, serve
stats, and metrics streams -- so every recorded number carries the
environment it was measured under. `is_main()` is the HomebrewNLP-style
rank-0 guard (`jax.process_index() == 0`) that the metrics emitter and
the future multi-host path share.

jax is only imported lazily (describe / is_main): importing this module
must stay legal BEFORE jax init, which is the whole point.
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import MutableMapping, Optional

_FORCE_FLAG = "xla_force_host_platform_device_count"

#: what apply() changed, keyed by knob -- doubles as the idempotence
#: guard (a non-None value means apply() already ran for this process)
_APPLIED: Optional[dict] = None


# ----------------------------------------------------------- flag merge

def _get_flags(env: MutableMapping) -> str:
    return env.get("XLA_FLAGS", "")


def _flag_value(flags: str, name: str) -> Optional[str]:
    """Value of `--name=value` in an XLA_FLAGS string, or None."""
    for tok in flags.split():
        if tok.startswith(f"--{name}="):
            return tok.split("=", 1)[1]
        if tok == f"--{name}":
            return ""
    return None


def _merge_xla_flag(name: str, value, env: MutableMapping) -> str:
    """Append `--name=value` to XLA_FLAGS unless the flag is already
    present -- an operator-set flag ALWAYS wins (append/merge, never
    clobber). Returns the effective value (existing or appended)."""
    flags = _get_flags(env)
    existing = _flag_value(flags, name)
    if existing is not None:
        return existing
    env["XLA_FLAGS"] = (flags + " " if flags else "") + f"--{name}={value}"
    return str(value)


def _jax_initialized() -> bool:
    """Best-effort: has a jax backend already been created (at which
    point XLA_FLAGS mutations are ignored)? Version-tolerant."""
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:                      # pragma: no cover - jax drift
        return False


def force_host_devices(n: int, env: Optional[MutableMapping] = None) -> int:
    """Merge `--xla_force_host_platform_device_count=n` into XLA_FLAGS.

    Must run before jax first initializes (the same contract the old
    per-entry-point blocks had); warns when it cannot take effect. An
    operator-set count in XLA_FLAGS wins over `n` -- callers get the
    EFFECTIVE count back so they can assert on it. This is the one
    implementation behind conftest's REPRO_TEST_DEVICES, the bench
    `--sharded`/`--uhd` self-forcing, and dryrun's 512-device mesh.
    """
    env = os.environ if env is None else env
    if env is os.environ and _jax_initialized() \
            and _flag_value(_get_flags(env), _FORCE_FLAG) != str(n):
        warnings.warn(
            f"force_host_devices({n}) after jax initialized its "
            f"backend: XLA_FLAGS changes are ignored now; set "
            f"REPRO_TEST_DEVICES or import repro.platform earlier",
            RuntimeWarning, stacklevel=2)
    return int(_merge_xla_flag(_FORCE_FLAG, int(n), env))


def forced_host_devices(env: Optional[MutableMapping] = None
                        ) -> Optional[int]:
    """The forced host device count currently in XLA_FLAGS, or None."""
    env = os.environ if env is None else env
    v = _flag_value(_get_flags(env), _FORCE_FLAG)
    try:
        return int(v) if v else None
    except ValueError:
        return None


# ---------------------------------------------------------------- apply

def apply(env: Optional[MutableMapping] = None,
          force: bool = False) -> dict:
    """Consume the REPRO_* knobs exactly once per process.

    Importing this module calls apply() -- every entry point that does
    `import repro.platform` (directly or via repro.api / the serve
    engine) gets the same resolved environment. Re-entry is a no-op
    returning the first application's record; `force=True` re-applies
    (used with an explicit `env` by tests -- applying twice is safe
    because every mutation is a merge or a setdefault).
    """
    global _APPLIED
    if _APPLIED is not None and not force and env is None:
        return _APPLIED
    env = os.environ if env is None else env
    applied: dict = {}

    n = env.get("REPRO_TEST_DEVICES")
    if n:
        applied["forced_host_devices"] = force_host_devices(int(n), env)

    extra = env.get("REPRO_XLA_FLAGS")
    if extra:
        merged = []
        for tok in extra.split():
            name = tok.lstrip("-").split("=", 1)[0]
            value = tok.split("=", 1)[1] if "=" in tok else ""
            merged.append(f"--{name}={_merge_xla_flag(name, value, env)}")
        applied["xla_flags_extra"] = " ".join(merged)

    x64 = env.get("REPRO_X64")
    if x64 is not None:
        # setdefault: an explicit JAX_ENABLE_X64 from the operator wins
        env.setdefault("JAX_ENABLE_X64", "1" if x64 == "1" else "0")
        applied["x64"] = env["JAX_ENABLE_X64"] == "1"

    plat = env.get("REPRO_PLATFORM")
    if plat:
        env.setdefault("JAX_PLATFORMS", plat)
        applied["jax_platforms"] = env["JAX_PLATFORMS"]

    if env is os.environ:
        _APPLIED = applied
    return applied


def hermetic_autotune(env: Optional[MutableMapping] = None) -> None:
    """Disable the autotune DISK cache unless the operator pointed
    REPRO_AUTOTUNE_CACHE somewhere explicitly (setdefault to ""):
    tests and benches must probe live, not inherit a stale ~/.cache
    decision from a previous run."""
    (os.environ if env is None else env).setdefault(
        "REPRO_AUTOTUNE_CACHE", "")


def autotune_cache_path(env: Optional[MutableMapping] = None
                        ) -> Optional[str]:
    """Resolved autotune disk-cache path: $REPRO_AUTOTUNE_CACHE if set
    ("" disables -> None), else ~/.cache/repro/autotune.json. The one
    resolution core/autotune_cache.py consumes."""
    env = os.environ if env is None else env
    p = env.get("REPRO_AUTOTUNE_CACHE")
    if p is not None:
        return os.path.expanduser(p) if p else None
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def default_seed(env: Optional[MutableMapping] = None) -> int:
    """Deterministic-seed plumbing: $REPRO_SEED, default 0. Benches and
    harnesses derive their numpy/jax streams from this so a CI lane can
    replay a run exactly by exporting one variable."""
    env = os.environ if env is None else env
    try:
        return int(env.get("REPRO_SEED", "0"))
    except ValueError:
        return 0


# ------------------------------------------------------------- snapshot

def is_main() -> bool:
    """Rank-0 guard (`jax.process_index() == 0`): only the main process
    of a multi-host mesh logs, checkpoints, and emits metrics. True on
    single-process deployments and when jax is unavailable."""
    try:
        import jax
        return jax.process_index() == 0
    except Exception:
        return True


def describe() -> dict:
    """Snapshot of the resolved platform: what environment did this
    measurement/serve run under? Touches jax device state (initializes
    the backend if nothing else has), so callers on the pre-init path
    must not describe() before their flags are set -- benches call it
    at record time, the serve engine at construction."""
    import platform as host
    import jax
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
        "device_kind": str(getattr(dev, "device_kind", "?")),
        "process_index": int(jax.process_index()),
        "process_count": int(jax.process_count()),
        "x64": bool(jax.config.jax_enable_x64),
        "jax_version": jax.__version__,
        "machine": host.machine(),
        "python": host.python_version(),
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "forced_host_devices": forced_host_devices(),
        "autotune_cache": autotune_cache_path(),
        "seed": default_seed(),
        "applied": dict(_APPLIED or {}),
    }


def _reset_for_tests() -> None:
    global _APPLIED
    _APPLIED = None


# one application per process, at first import -- the module IS the seam
apply()
