"""Batched detection pipeline -- the "co-processor" as a sharded service op.

`classify_windows(params, windows)` is the TPU equivalent of the paper's
Fig. 6 datapath: grayscale -> HOG -> SVM -> {0, 1}, for a BATCH of windows
(the FPGA streams one window; the TPU streams a batch per grid step).

Execution paths (all numerically cross-validated in tests):
  * path="ref"     pure-jnp oracle (core/hog.py), mode per HOGConfig
  * path="kernel"  Pallas kernels (kernels/ops.py): gradient+bin, cell
                   histogram, block-norm, SVM matmul as separate kernels
  * path="fused"   single fused Pallas kernel per window batch (the §Perf
                   hillclimb artifact)

`shard_over_data()` places a window batch across the 'data' axis of the
production mesh -- detection is embarrassingly data-parallel, which is the
co-processor scaling story at pod scale (see launch/dryrun.py --arch
hog_svm_coproc).
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.svm import SVMParams, svm_score

Array = jax.Array


@partial(jax.jit, static_argnames=("cfg", "path"))
def extract_features(windows: Array, cfg: HOGConfig = PAPER_HOG,
                     path: str = "ref") -> Array:
    """(B, 130, 66, 3) uint8 -> (B, 3780) float32 descriptors.

    One stage chain (core/stages.py), three backends; windows smaller
    than the configured geometry raise ValueError at trace time.
    """
    from repro.core.stages import window_descriptor
    return window_descriptor(windows, cfg, backend=path)


@partial(jax.jit, static_argnames=("cfg", "path"))
def classify_windows(params: SVMParams, windows: Array,
                     cfg: HOGConfig = PAPER_HOG, path: str = "ref") -> Dict[str, Array]:
    """Full co-processor op: windows -> {score, human}. (Fig. 6 datapath.)"""
    feats = extract_features(windows, cfg, path)
    if path in ("kernel", "fused"):
        from repro.kernels import ops
        score = ops.svm_score_kernel(feats, params["w"], params["b"])
    elif cfg.feat_dtype == "bf16":
        # §Perf: bf16 descriptors AND weights on the wire, fp32 MXU
        # accumulation -- otherwise XLA promotes the descriptor back to
        # f32 before the dot and the down-cast is dead code
        score = jax.lax.dot_general(
            feats, params["w"].astype(jnp.bfloat16)[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0] + params["b"]
    else:
        score = svm_score(params, feats)
    return {"score": score, "human": (score > 0).astype(jnp.int32)}


def shard_over_data(mesh: Mesh, windows: Array) -> Array:
    """Place a window batch on the mesh, batch over every data-like axis."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    spec = P(data_axes, *([None] * (windows.ndim - 1)))
    return jax.device_put(windows, NamedSharding(mesh, spec))


def detection_step_specs(mesh: Mesh):
    """(in_shardings, out_shardings) for jit'ing classify_windows on a mesh."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    w_spec = {"w": NamedSharding(mesh, P(None)),
              "b": NamedSharding(mesh, P())}
    x_spec = NamedSharding(mesh, P(data_axes, None, None, None))
    out_spec = {"score": NamedSharding(mesh, P(data_axes)),
                "human": NamedSharding(mesh, P(data_axes))}
    return (w_spec, x_spec), out_spec
