"""Disk persistence for the scan-vs-vmap batch-schedule autotune.

The in-memory autotune (core/detector.py:_autotune_chunk) probes each
new (true-shape, bucket, B, mesh) tuple at first use -- a few compiles
plus timed runs, paid once per process. This module lets warm starts
skip the probe entirely: decisions are keyed by a HOST FINGERPRINT
(machine, jax backend/version, device kind/count, cpu count) plus the
mesh-tagged autotune key and a digest of the DetectorConfig, and stored
in one JSON file.

Path resolution: $REPRO_AUTOTUNE_CACHE if set (empty string DISABLES
persistence -- tests and benches use this for hermetic probes),
otherwise ~/.cache/repro/autotune.json.

Everything is best-effort: a missing, corrupt or unwritable cache file
degrades to probing, never to an error. Writes are atomic
(temp + rename) so concurrent processes at worst lose each other's
newest entries, never corrupt the file. `stats()` feeds the "autotune"
section of DetectionSession.cache_stats(): how many schedule decisions
came from memory, from disk, or had to be probed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import tempfile
from typing import Optional

from repro import platform as repro_platform

_STATS = {"memory_hits": 0, "disk_hits": 0, "probes": 0, "writes": 0,
          "load_errors": 0}
_CACHE: Optional[dict] = None       # parsed file content, memoized
_CACHE_PATH: Optional[str] = None   # path _CACHE was loaded from


def cache_path() -> Optional[str]:
    """Resolved cache file path, or None when persistence is disabled
    (REPRO_AUTOTUNE_CACHE set to an empty string). Resolution lives in
    repro.platform -- the one owner of env interpretation."""
    return repro_platform.autotune_cache_path()


def host_fingerprint() -> str:
    """A schedule probed on one host is only trusted on an equivalent
    one: same architecture, jax backend + version, device kind and
    count, and cpu count. Touches jax device state, so only called on
    the autotune path (which is about to probe devices anyway)."""
    import jax
    dev = jax.devices()[0]
    return "|".join([
        platform.machine(), jax.default_backend(),
        str(getattr(dev, "device_kind", "?")), str(jax.device_count()),
        str(os.cpu_count()), jax.__version__])


def entry_key(report_key: str, cfg) -> str:
    """The on-disk key: the human-readable mesh-tagged autotune key
    (autotune_report format) plus a digest of every DetectorConfig
    field -- backend, scales, numerics mode etc. all change what the
    probe measured."""
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    return f"{report_key} cfg={hashlib.sha1(blob.encode()).hexdigest()[:12]}"


def _load(path: str) -> dict:
    global _CACHE, _CACHE_PATH
    if _CACHE is not None and _CACHE_PATH == path:
        return _CACHE
    data: dict = {}
    try:
        with open(path) as f:
            loaded = json.load(f)
        if isinstance(loaded, dict):
            data = loaded
        else:
            _STATS["load_errors"] += 1
    except FileNotFoundError:
        pass
    except Exception:
        _STATS["load_errors"] += 1
    _CACHE, _CACHE_PATH = data, path
    return data


def lookup(key: str) -> Optional[dict]:
    """Disk decision for `key` under this host's fingerprint, as
    {"chunk": int, "probe_ms": {int: float}}, or None."""
    path = cache_path()
    if path is None:
        return None
    host = _load(path).get(host_fingerprint())
    e = host.get(key) if isinstance(host, dict) else None
    if not isinstance(e, dict) or "chunk" not in e:
        return None
    _STATS["disk_hits"] += 1
    try:
        probe = {int(c): float(v)
                 for c, v in dict(e.get("probe_ms", {})).items()}
    except (TypeError, ValueError):
        probe = {}
    return {"chunk": int(e["chunk"]), "probe_ms": probe}


def store(key: str, chunk: int, probe_ms: dict) -> None:
    """Record a freshly probed decision (counts the probe even when
    persistence is disabled, so stats stay truthful)."""
    _STATS["probes"] += 1
    path = cache_path()
    if path is None:
        return
    global _CACHE
    data = dict(_load(path))
    host = dict(data.get(host_fingerprint(), {}))
    host[key] = {"chunk": int(chunk),
                 "probe_ms": {str(c): float(v) for c, v in probe_ms.items()}}
    data[host_fingerprint()] = host
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".autotune.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, path)          # atomic publish
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        _CACHE = data
        _STATS["writes"] += 1
    except OSError:
        pass                               # best-effort: probing still won


def note_memory_hit() -> None:
    _STATS["memory_hits"] += 1


def stats() -> dict:
    """Counters + resolved path, surfaced by cache_stats()."""
    return {**_STATS, "path": cache_path()}


def _reset_for_tests() -> None:
    global _CACHE, _CACHE_PATH
    _CACHE = _CACHE_PATH = None
    for k in _STATS:
        _STATS[k] = 0
