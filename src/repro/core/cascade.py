"""Two-stage cascade: cheap coarse reject, full HOG+SVM on survivors.

The dense path scores every window of every pyramid scale; on sparse
scenes (most of serving traffic) nearly all of that work scores empty
background. The cascade runs a CHEAP first stage over the whole frame --
a half-resolution coarse head (66x34 window = the pedestrian geometry at
1/2 scale, 756 features vs 3780) swept over a reduced scale set -- and
promotes only the neighbourhoods of its loose-threshold hits to the full
pipeline, which then runs dense on a handful of snapped crops instead of
the whole frame. The speed trick of "HOG based Fast Human Detection"
(PAPERS.md, arXiv 1501.02058), re-cut for this codebase: both stages are
the SAME compiled dense program family (core/detector.py), just with
different HOG geometry, so the cascade is purely a scheduler.

Stage layout per frame:

    coarse FrameDetector (66x34 head, coarse_scales, LOOSE threshold)
        -> candidate boxes                      [cheap: ~25% of the
    + tracker-predicted ROI boxes (video)         fine-stage pixels]
        -> plan_regions(): dilate, merge overlapping neighbourhoods,
           cap at max_regions, snap OUTWARD to the snap grid
        -> fine FrameDetector on each cropped region (full window,
           full scales), boxes offset back to frame coordinates
        -> one host NMS per class across regions (crops can overlap)

Monotonicity contract (pinned by tests/test_cascade.py): loosening the
coarse threshold only ADDS candidate boxes, and `plan_regions` guarantees
every candidate's dilated box is covered by some region -- bounding
rects only grow under merging and edges only snap outward -- so a looser
reject threshold never loses a survivor.

Tracker ROI promotion: predicted track boxes from `core/video.py` enter
the planner alongside the coarse hits, so a track whose pedestrian the
coarse stage misses on a hard frame (blur, partial occlusion) still gets
its neighbourhood scored by the fine stage. That is the video contract:
detection quality degrades toward the coarse stage only for NEW objects,
never for tracked ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detector import DetectorConfig, FrameDetector, _nms
from repro.core.hog import HOGConfig
from repro.core.svm import SVMParams

# coarse head geometry: the paper's 130x66 pedestrian window at half
# resolution (active 64x32 -> 7x3 blocks -> 756 features, ~20% of the
# fine head's 3780); scales chosen so the coarse sweep covers the same
# person heights as the fine sweep's (1.0, 0.8, 0.64) at ~25% of the
# fine stage's summed pixel area
COARSE_WINDOW = (66, 34)
_COARSE_NAME = "_coarse"                    # registry name (auxiliary)


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Knobs of the two-stage scheduler (core/cascade.py)."""

    enabled: bool = False          # session/bench opt-in
    coarse_scales: Tuple[float, ...] = (0.5, 0.4, 0.32)
    #   sweep scales of the 66x34 coarse head; 0.5 matches fine scale
    #   1.0 (both see a 132px person), 0.32 matches 0.64
    coarse_threshold: float = 0.0  # LOOSE coarse score gate -- must sit
    #   well below the fine threshold so borderline pedestrians survive
    #   to the fine stage (which applies the real threshold)
    coarse_max_detections: int = 64
    margin: int = 24               # px each candidate box dilates by
    #   before region planning: fine-stage context + tracker jitter
    snap: int = 36                 # region edges snap OUTWARD to this
    #   grid, so region shapes quantize into few compiled buckets
    #   (shape_bucket-friendly) instead of one program per frame. The
    #   default is a multiple of the HOG cell stride (6 px): a region
    #   origin that lies on the cell grid keeps the crop's scale-1.0
    #   window grid aligned with the full-frame grid, so interior
    #   scale-1.0 detections reproduce exactly in the crop instead of
    #   wobbling by the origin offset mod cell
    max_regions: int = 4           # overlapping neighbourhoods merge
    #   until at most this many crops run the fine stage
    min_frame_area: int = 0        # frames below this skip the cascade
    #   and run the fine stage dense (tiny frames: coarse overhead wins)
    fine_hysteresis: float = 0.0   # the fine stage runs region CROPS at
    #   (score_threshold - this): a crop's HOG grid is offset relative
    #   to the full frame (region origins snap to `snap`, not the cell
    #   stride, and each pyramid level of a crop resamples differently),
    #   so per-window scores jitter by up to ~1-2 around the full-pass
    #   value; a hysteresis band keeps borderline full-pass detections
    #   from dropping out of the crop pass. 0 = crops run at the exact
    #   fine threshold (byte-compatible with the fine detector's cfg)


# --------------------------------------------------------------- planner

def _snap_regions(rects: Sequence[Tuple[float, float, float, float]],
                  frame_hw: Tuple[int, int], snap: int
                  ) -> List[Tuple[int, int, int, int]]:
    h, w = frame_hw
    out = []
    for y0, x0, y1, x1 in rects:
        y0 = max(0, int(np.floor(y0 / snap)) * snap)
        x0 = max(0, int(np.floor(x0 / snap)) * snap)
        y1 = min(h, int(np.ceil(y1 / snap)) * snap)
        x1 = min(w, int(np.ceil(x1 / snap)) * snap)
        if y1 > y0 and x1 > x0:
            out.append((y0, x0, y1, x1))
    return out


def plan_regions(boxes, frame_hw: Tuple[int, int],
                 cfg: Optional[CascadeConfig] = None
                 ) -> List[Tuple[int, int, int, int]]:
    """Candidate boxes -> at most `max_regions` fine-stage crops.

    `boxes` is (N, 4) of (y0, x0, y1, x1) in frame coordinates (coarse
    hits + promoted track predictions). Every box is dilated by
    `margin`, overlapping dilated boxes merge into one neighbourhood
    (connected components of the overlap graph), components merge
    further -- closest pair first -- until at most `max_regions` remain,
    and each component's bounding rect snaps OUTWARD to the `snap` grid.

    Coverage invariant (the basis of threshold monotonicity): every
    input box's dilated rect lies inside the returned union -- a box is
    inside its component's bounding rect by construction, merging only
    unions rects, and snapping only moves edges outward.
    """
    cfg = cfg or CascadeConfig()
    h, w = int(frame_hw[0]), int(frame_hw[1])
    boxes = np.asarray(boxes, np.float32).reshape(-1, 4)
    if len(boxes) == 0:
        return []
    m = float(cfg.margin)
    rects = np.stack([
        np.clip(boxes[:, 0] - m, 0, h), np.clip(boxes[:, 1] - m, 0, w),
        np.clip(boxes[:, 2] + m, 0, h), np.clip(boxes[:, 3] + m, 0, w),
    ], axis=1)
    # connected components of the pairwise-overlap graph (union-find)
    n = len(rects)
    parent = list(range(n))

    def find(i):
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    overlap = ((rects[:, None, 0] < rects[None, :, 2])
               & (rects[None, :, 0] < rects[:, None, 2])
               & (rects[:, None, 1] < rects[None, :, 3])
               & (rects[None, :, 1] < rects[:, None, 3]))
    for i in range(n):
        for j in range(i + 1, n):
            if overlap[i, j]:
                parent[find(i)] = find(j)
    comps: Dict[int, List[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), []).append(i)
    bounds = [(float(rects[ix, 0].min()), float(rects[ix, 1].min()),
               float(rects[ix, 2].max()), float(rects[ix, 3].max()))
              for ix in (np.asarray(c) for c in comps.values())]
    # cap at max_regions: repeatedly merge the closest pair (rect gap)
    while len(bounds) > max(1, cfg.max_regions):
        best, bi, bj = None, 0, 1
        for i in range(len(bounds)):
            for j in range(i + 1, len(bounds)):
                a, b = bounds[i], bounds[j]
                dy = max(0.0, max(a[0], b[0]) - min(a[2], b[2]))
                dx = max(0.0, max(a[1], b[1]) - min(a[3], b[3]))
                gap = dy * dy + dx * dx
                if best is None or gap < best:
                    best, bi, bj = gap, i, j
        a, b = bounds[bi], bounds[bj]
        merged = (min(a[0], b[0]), min(a[1], b[1]),
                  max(a[2], b[2]), max(a[3], b[3]))
        bounds = [r for k, r in enumerate(bounds) if k not in (bi, bj)]
        bounds.append(merged)
    return sorted(_snap_regions(bounds, (h, w), max(1, cfg.snap)))


# ----------------------------------------------------- degraded entry points

def reduced_detector(det: FrameDetector, n_scales: int = 1
                     ) -> FrameDetector:
    """Degradation-ladder rung "reduced" (serve/resilience.py): the SAME
    head and numerics on a truncated pyramid -- only the first
    `n_scales` scales are swept, so far-away (small) pedestrians are
    the quality traded for latency under overload. Shares the svm
    params and class labels, so recovered full-pipeline results are
    byte-identical to an undegraded run."""
    cfg = dataclasses.replace(det.cfg,
                              scales=det.cfg.scales[:max(1, int(n_scales))])
    return FrameDetector(det.svm, cfg, classes=det.classes)


# ------------------------------------------------------------ coarse head

def coarse_hog(fine: HOGConfig) -> HOGConfig:
    """The coarse stage's HOG geometry: the fine config's numerics on
    the half-resolution window."""
    return dataclasses.replace(fine, window_h=COARSE_WINDOW[0],
                               window_w=COARSE_WINDOW[1])


def train_coarse_head(fine_hog: HOGConfig, train_cfg=None,
                      n_pos: int = 1500, n_neg: int = 1000,
                      rng: Optional[np.random.Generator] = None,
                      hard_negative_rounds: int = 1,
                      mine_scenes: int = 12
                      ) -> Tuple[SVMParams, HOGConfig]:
    """Train the cascade's coarse SVM: synthetic pedestrian windows
    downsampled to the 66x34 coarse geometry, same numerics as the fine
    chain, then `hard_negative_rounds` of scene-level bootstrapping
    (data/mining.py) so the LOOSE reject gate stays quiet on empty
    frames -- without it the coarse sweep fires all over downscaled
    background and every frame promotes to a full-frame region.
    Returns (params, coarse HOGConfig)."""
    import jax
    import jax.numpy as jnp

    from repro.core.hog import hog_descriptor
    from repro.core.svm import SVMTrainConfig, train_svm
    from repro.data.mining import mine_hard_negatives
    from repro.data.synth_pedestrian import PedestrianDataConfig, \
        make_windows
    rng = np.random.default_rng(0) if rng is None else rng
    x, y = make_windows(n_pos, n_neg, PedestrianDataConfig(), rng)
    ch = coarse_hog(fine_hog)
    small = jax.image.resize(
        jnp.asarray(x, jnp.float32),
        (x.shape[0], ch.window_h, ch.window_w, x.shape[-1]), "linear")
    feats = np.asarray(hog_descriptor(small, ch))
    labels = np.asarray(y)
    tc = train_cfg or SVMTrainConfig()
    svm, _ = train_svm(jnp.asarray(feats), jnp.asarray(labels), tc)
    sweep = DetectorConfig(hog=ch, scales=CascadeConfig().coarse_scales)
    for _ in range(int(hard_negative_rounds)):
        neg = mine_hard_negatives(svm, sweep, mine_scenes, rng)
        if not len(neg):
            break
        feats = np.concatenate(
            [feats, np.asarray(hog_descriptor(jnp.asarray(neg, jnp.float32),
                                              ch))])
        labels = np.concatenate([labels, np.zeros(len(neg), labels.dtype)])
        svm, _ = train_svm(jnp.asarray(feats), jnp.asarray(labels), tc)
    return svm, ch


def coarse_detector(coarse_svm: SVMParams, fine_cfg: DetectorConfig,
                    cascade: CascadeConfig) -> FrameDetector:
    """Build the stage-1 detector: coarse head geometry, the cascade's
    reduced scale sweep and LOOSE threshold, same backend/numerics
    family as the fine stage."""
    ccfg = dataclasses.replace(
        fine_cfg, hog=coarse_hog(fine_cfg.hog),
        scales=cascade.coarse_scales,
        score_threshold=cascade.coarse_threshold,
        max_detections=cascade.coarse_max_detections,
        class_thresholds=(), frame_parallel=1)
    return FrameDetector(coarse_svm, ccfg)


# --------------------------------------------------------------- cascade

class CascadeDetector:
    """Two-stage scheduler over a coarse and a fine FrameDetector.

    `detect(frame, roi_boxes=...)` returns the legacy list-of-dicts
    contract of the fine detector (multi-class dicts keep class_id /
    label), plus cumulative `stats`: frames, frames_empty (coarse
    rejected everything), frames_dense (below min_frame_area -> full
    pass), regions, region_area_frac (fine-stage pixel fraction vs
    dense).
    """

    def __init__(self, fine: FrameDetector, coarse: FrameDetector,
                 cfg: Optional[CascadeConfig] = None):
        self.fine = fine
        self.coarse = coarse
        self.cfg = cfg or CascadeConfig()
        hyst = float(self.cfg.fine_hysteresis)
        if hyst > 0:
            fc = fine.cfg
            self._crop_fine = FrameDetector(fine.svm, dataclasses.replace(
                fc, score_threshold=fc.score_threshold - hyst,
                class_thresholds=tuple(t - hyst
                                       for t in fc.class_thresholds)),
                classes=fine.classes)
        else:
            self._crop_fine = fine
        self.stats: Dict[str, float] = {
            "frames": 0, "frames_empty": 0, "frames_dense": 0,
            "regions": 0, "region_area_frac": 0.0}

    def _merge(self, dets: List[dict]) -> List[dict]:
        """One NMS pass per class across region-local results (regions
        may overlap after snapping)."""
        out: List[dict] = []
        by_class: Dict[object, List[dict]] = {}
        for d in dets:
            by_class.setdefault(d.get("class_id"), []).append(d)
        for ds in by_class.values():
            ds.sort(key=lambda d: -d["score"])
            boxes = np.asarray([d["box"] for d in ds],
                               np.float32).reshape(-1, 4)
            scores = np.asarray([d["score"] for d in ds], np.float32)
            out.extend(ds[i] for i in
                       _nms(boxes, scores, self.fine.cfg.nms_iou))
        out.sort(key=lambda d: -d["score"])
        return out

    def detect(self, frame, roi_boxes: Sequence = ()) -> List[dict]:
        """One frame -> detection dicts. `roi_boxes` are promoted
        regions (tracker-predicted boxes) that bypass the coarse gate."""
        frame = np.asarray(frame)
        h, w = int(frame.shape[0]), int(frame.shape[1])
        self.stats["frames"] += 1
        if h * w < self.cfg.min_frame_area:
            self.stats["frames_dense"] += 1
            self.stats["region_area_frac"] += 1.0
            return self.fine.detect_raw(frame).to_list()
        cand = [d["box"] for d in self.coarse.detect_raw(frame).to_list()]
        cand += [tuple(float(v) for v in b) for b in roi_boxes]
        if not cand:
            self.stats["frames_empty"] += 1
            return []
        regions = plan_regions(np.asarray(cand, np.float32), (h, w),
                               self.cfg)
        self.stats["regions"] += len(regions)
        area = sum((y1 - y0) * (x1 - x0) for y0, x0, y1, x1 in regions)
        self.stats["region_area_frac"] += min(1.0, area / float(h * w))
        dets: List[dict] = []
        for y0, x0, y1, x1 in regions:
            # crops run through the hysteresis-banded detector (equal to
            # self.fine when cfg.fine_hysteresis == 0)
            for d in self._crop_fine.detect_raw(
                    frame[y0:y1, x0:x1]).to_list():
                by0, bx0, by1, bx1 = d["box"]
                d = dict(d)
                d["box"] = (by0 + y0, bx0 + x0, by1 + y0, bx1 + x0)
                dets.append(d)
        return self._merge(dets)

    def detect_degraded(self, frame, mode: str = "cascade",
                        roi_boxes: Sequence = ()) -> List[dict]:
        """Degraded-mode entry point for the serving ladder
        (serve/resilience.py): "cascade" runs the normal two-stage
        schedule (coarse reject + fine on survivors), "coarse" serves
        the stage-1 hits ALONE -- no fine pass at all, the cheapest
        rung. Coarse-only detections carry `stage="coarse"` so callers
        can tell the quality class apart; their scores are the coarse
        head's margins and are NOT comparable to fine-stage scores."""
        if mode == "cascade":
            return self.detect(frame, roi_boxes=roi_boxes)
        if mode != "coarse":
            raise ValueError(f"unknown degraded mode {mode!r}; "
                             f"'cascade' or 'coarse'")
        self.stats["frames"] += 1
        dets = []
        for d in self.coarse.detect_raw(np.asarray(frame)).to_list():
            d = dict(d)
            d["stage"] = "coarse"
            dets.append(d)
        return dets

    def stream(self, frames, tracker=None) -> List[List[dict]]:
        """Video path: frame-at-a-time cascade with tracker-guided ROI
        promotion -- every live track's PREDICTED box enters the region
        planner before detection, so tracked objects bypass the coarse
        reject entirely. Returns per-frame tracked dicts."""
        from repro.core.video import Tracker
        trk = Tracker() if tracker is None else tracker
        out = []
        for frame in frames:
            rois = [t.predicted for t in trk.tracks]
            dets = self.detect(frame, roi_boxes=rois)
            out.append(trk.update(dets))
        return out
