"""Single numerics-mode dispatch table shared by every backend.

PR 6 taught us the "identity trap": a mode predicate duplicated across
backends (ref vs kernel vs fused) eventually disagrees in one of them,
and the divergent backend silently falls back to a different numerics
path. Concretely: `fused_hog.py` engaged the Newton-Raphson rsqrt only
under `mode == "cordic"` while `stages.py` made the same decision with
its own `_use_nr`, so any new mode had to update N scattered if-chains
or quietly normalize in fp32 somewhere.

This module is now the ONE place that maps a numerics-mode name to its
per-stage choices. Backends dispatch through:

  * ``spec_for(cfg)``       -- HOGConfig -> NumericsSpec (the mode row),
  * ``MAG_BIN`` impls stay in core/hog.py keyed by ``spec.name``; the
    Pallas twin table is ``kernels/hog_gradient.py:MAG_BIN_IMPLS``,
  * ``store_hist(hist)``    -- histogram accumulator -> stored dtype,
  * ``finish_blocks(v, eps, norm)`` -- the block-normalize tail
    (rsqrt flavor + optional int8 quantize-dequantize), used verbatim by
    the ref path and every Pallas block-norm kernel.

Unknown modes raise ValueError everywhere instead of falling through an
else-branch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import quant

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class NumericsSpec:
    """One numerics mode's per-stage choices.

    name        -- the mag/bin implementation key (core/hog.py _MAG_BIN
                   and kernels/hog_gradient.py MAG_BIN_IMPLS),
    kernel_mode -- what the gradient/hist Pallas kernels receive,
    norm        -- block-normalize tail flavor ("rsqrt" | "nr" | "fixed"),
    quantized   -- True iff the chain runs the fixed-point datapath
                   (rint'd gray in, int16 histograms, int8 descriptors,
                   int8 scoring matmul).
    """

    name: str
    kernel_mode: str
    norm: str
    quantized: bool


SPECS: Dict[str, NumericsSpec] = {
    "ref": NumericsSpec("ref", "sector", "rsqrt", False),
    "sector": NumericsSpec("sector", "sector", "rsqrt", False),
    "cordic": NumericsSpec("cordic", "cordic", "nr", False),
    "fixed": NumericsSpec("fixed", "fixed", "fixed", True),
}


def spec_for(cfg) -> NumericsSpec:
    """HOGConfig -> NumericsSpec. ``numerics="fixed"`` overrides ``mode``
    (the fixed datapath IS a mag/bin choice; cfg.mode only picks the
    float flavor)."""
    name = "fixed" if getattr(cfg, "numerics", "float") == "fixed" else cfg.mode
    try:
        return SPECS[name]
    except KeyError:
        raise ValueError(
            f"unknown numerics mode {name!r}; expected one of "
            f"{sorted(SPECS)}") from None


def nr_rsqrt(x: Array, iters: int = 2) -> Array:
    """Newton-Raphson reciprocal sqrt, faithful to the hardware unit.

    Seed = the exponent-halving bit manipulation (0x5f3759df), i.e. the
    integer-datapath seed a hardware rsqrt unit derives before its NR
    refinement stages; two NR iterations then reach ~1e-6 relative error,
    matching the paper's Block_NormalizationCore ([3]'s scheme).
    """
    xf = x.astype(jnp.float32)
    i = jax.lax.bitcast_convert_type(xf, jnp.int32)
    y = jax.lax.bitcast_convert_type(jnp.int32(0x5F3759DF) - (i >> 1),
                                     jnp.float32)
    for _ in range(iters):
        y = y * (1.5 - 0.5 * xf * y * y)
    return y


#: which rsqrt each norm flavor uses. "fixed" shares the hardware NR unit
#: (the FPGA's normalizer is the same core) and then quantizes.
NORM_RSQRT = {
    "rsqrt": jax.lax.rsqrt,
    "nr": nr_rsqrt,
    "fixed": nr_rsqrt,
}


def finish_blocks(v: Array, eps: float, norm: str) -> Array:
    """The block-normalize tail: (..., bd) raw block vectors -> (..., bd)
    L2-normalized f32 blocks (eq. 5), quantized onto the per-block int8
    grid when norm == "fixed".

    EVERY backend's normalize stage ends here -- ref (core/hog.py), the
    standalone block_norm kernel, dense_block_norm, and both fused
    kernels -- so a mode cannot normalize differently in one backend.

    In fixed mode the incoming vectors hold int16 histogram counts in
    half-gray units; eps is scaled by quant.MAG_SCALE so eq. 5 stays the
    same *relative* regularizer as the float chain (v/s normalized equals
    v normalized with eps*s).
    """
    try:
        rs = NORM_RSQRT[norm]
    except KeyError:
        raise ValueError(
            f"unknown norm flavor {norm!r}; expected one of "
            f"{sorted(NORM_RSQRT)}") from None
    v = v.astype(jnp.float32)
    e = eps * quant.MAG_SCALE if norm == "fixed" else eps
    # e * e in Python (f64) then one f32 round -- bit-identical to the
    # historical `+ cfg.eps ** 2` weak-scalar add
    ss = jnp.sum(v * v, axis=-1, keepdims=True) + jnp.float32(e * e)
    out = v * rs(ss)
    if norm == "fixed":
        out = quant.quantize_dequantize(out)
    return out


def store_hist(hist: Array) -> Array:
    """Histogram accumulator -> stored dtype: int16 for integer (fixed
    chain) accumulators, passthrough for float. The int16 bound is
    per-cell: 64 px * mag_q<=361 = 23104 < 2^15 regardless of slab or
    frame size (bounds per cell, not per slab)."""
    if jnp.issubdtype(hist.dtype, jnp.integer):
        return hist.astype(jnp.int16)
    return hist
