"""Intra-frame tile planning: the geometry, resize arithmetic and exact
top-k merge behind the tiled (frame-parallel) detection path.

The paper's thesis is that HOG+SVM wins come from parallel hardware
decomposition, not algorithm changes; the UHD follow-up (PAPERS.md,
arxiv 2204.10619) splits one 3840x2160 frame into parallel processing
lanes. This module is that decomposition for the jax_pallas detector
(DESIGN.md §11): one frame's pyramid work is laid over the 'tile' axis
of a device mesh, each tile produces a LOCAL top-k over the window
positions it owns, and a device-side merge re-ranks the union so the
result is box-identical to the untiled program.

Two decompositions (DetectorConfig.tile_mode):

  * "slab"  -- row-slabs of each scale's score grid. A tile owning
    `slab` score rows recomputes a halo of (window_blocks + block - 2)
    cell rows = 122 px so its descriptors are exact (the same halo rule
    the PR-4 dense kernels use inside one device, lifted to the mesh).
  * "scale" -- whole pyramid scales, greedily balanced over tiles by
    window count (scales are independent until top-k).

Box-identity rests on two arithmetic facts, both load-bearing and both
pinned by tests/test_tiled.py:

  * the banded resize (`resize_banded`) applies the exact
    jax.image.resize "linear" taps as <= ~4 fixed-order multiply-adds
    PER OUTPUT ELEMENT, so any row-slice of its output equals the
    bitwise row-slice of the full output (tiling-invariant by
    construction), and
  * the "matmul" resize mode stays exact under slab tiling only by
    running the FULL untiled product per tile and slicing result rows
    afterwards: XLA's GEMM blocking (and with it the fp32 accumulation
    order) depends on the operand shapes, so even an output-row-sliced
    weight matrix can differ from the full product in final ulps --
    and windowing the *reduction* axis certainly does.

`merge_topk` makes the union re-rank exact: every tile's local list is
ordered by (-score, global flat index) -- the same key lax.top_k sorts
the untiled score vector by -- so one two-key sort of the union
reproduces the untiled top-k including tie-breaks, and a single
nms_keep over the merged list equals untiled NMS.
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ------------------------------------------------- banded exact resize

@lru_cache(maxsize=256)
def band_weights(src: int, dst: int) -> Tuple[np.ndarray, np.ndarray]:
    """Band form of the (dst, src) resize weight matrix: per output row
    the first source tap `lo[i]` and the T-wide tap weights `w[i, :]`
    (zero-padded; T = widest support over all rows, <= ~4 for the
    pyramid's scales). Same interpolation weights as the matmul form
    (_resize_weights -- exact jax.image.resize "linear" incl.
    anti-aliasing), just stored by support instead of dense."""
    from repro.core.detector import _resize_weights
    full = _resize_weights(src, dst)                       # (dst, src)
    nz = np.abs(full) > 0
    assert nz.any(axis=1).all(), "resize weight row with empty support"
    first = nz.argmax(axis=1)
    last = src - 1 - nz[:, ::-1].argmax(axis=1)
    T = int((last - first + 1).max())
    w = np.zeros((dst, T), np.float32)
    rows = np.arange(dst)
    for t in range(T):
        col = first + t
        ok = col <= last
        w[ok, t] = full[rows[ok], col[ok]]
    return first.astype(np.int32), w


def extend_band(lo: np.ndarray, w: np.ndarray,
                ext: int) -> Tuple[np.ndarray, np.ndarray]:
    """Zero-extend a band table to `ext` output rows: rows past the real
    dst have all-zero weights (and lo 0), so a tile whose slab runs past
    the scaled image computes exact zeros there -- those rows only ever
    feed masked (phantom) score rows."""
    if ext <= len(lo):
        return lo, w
    lo2 = np.zeros(ext, np.int32)
    lo2[: len(lo)] = lo
    w2 = np.zeros((ext, w.shape[1]), np.float32)
    w2[: len(w)] = w
    return lo2, w2


def band_rows(g_pad: Array, lo: Array, w: Array) -> Array:
    """out[i, :] = sum_t w[i, t] * g_pad[lo[i] + t, :], t ascending.

    Per-output-element arithmetic with a fixed accumulation order, so
    computing any subset of output rows (sliced lo/w) yields the
    bitwise row-slice of the full output -- the tiling invariance the
    tiled path's box-identity rests on. `g_pad` must carry T extra
    trailing rows (zeros; only zero-weight taps can reach them)."""
    acc = w[:, 0:1] * g_pad[lo]
    for t in range(1, w.shape[1]):
        acc = acc + w[:, t:t + 1] * g_pad[lo + t]
    return acc


def band_cols(g_pad: Array, lo: Array, w: Array) -> Array:
    """Column-axis version of band_rows: out[:, j] = sum_t w[j, t] *
    g_pad[:, lo[j] + t]. Same fixed-order, per-element contract."""
    acc = g_pad[:, lo] * w[:, 0]
    for t in range(1, w.shape[1]):
        acc = acc + g_pad[:, lo + t] * w[:, t]
    return acc


def resize_banded(g: Array, sh: int, sw: int) -> Array:
    """Full-frame banded resize (ph, pw) -> (sh, sw): rows then columns,
    each axis as band_rows/band_cols over the exact production taps.

    O(T) work per output element instead of the matmul form's O(src) --
    the difference between ~1.06 s and ~0.03 s of resize per 4K frame
    on the CPU host. The accumulation ORDER differs from the matmul
    form, so "banded" and "matmul" scores differ in final float ulps;
    each mode is self-consistent and exactly tiling-invariant (banded
    per-element; matmul by slicing rows of the full product)."""
    ph, pw = g.shape
    if sh != ph:
        lo, w = band_weights(ph, sh)
        g = band_rows(jnp.pad(g, ((0, w.shape[1]), (0, 0))),
                      jnp.asarray(lo), jnp.asarray(w))
    if sw != pw:
        lo, w = band_weights(pw, sw)
        g = band_cols(jnp.pad(g, ((0, 0), (0, w.shape[1]))),
                      jnp.asarray(lo), jnp.asarray(w))
    return g


# --------------------------------------------------- tile decomposition

def slab_rows(sph: int, fp: int) -> int:
    """Score rows each of fp tiles owns (ceil; the last tiles may own
    fewer real rows -- the overhang is masked as phantom rows)."""
    return -(-sph // fp)


def slab_pixel_rows(slab: int, hcfg) -> int:
    """Scaled-pixel rows one tile must compute to produce `slab` EXACT
    score rows: (slab + window_blocks + block - 2) cell rows of `cell`
    px plus the 2-px gradient border. The (wbh + block - 2)-cell-row
    overhang past the owned rows is the descriptor halo -- 122 px for
    the 130x66 window (15 window block rows, 2x2 blocks, 8-px cells)."""
    return (slab + hcfg.blocks_hw[0] + hcfg.block - 2) * hcfg.cell + 2


def scale_groups(per_scale: Sequence[Tuple[float, int, int]],
                 fp: int) -> Tuple[Tuple[int, ...], ...]:
    """Greedy balance of pyramid scales over fp tiles by window count:
    largest scale first into the least-loaded group. Groups may be
    empty when fp exceeds the scale count (those tiles contribute only
    -inf padding). Each group keeps ascending scale order so its
    concatenated global index table stays monotone -- the local-top-k
    tie-break contract merge_topk relies on."""
    loads = [0] * fp
    bins: List[List[int]] = [[] for _ in range(fp)]
    order = sorted(range(len(per_scale)),
                   key=lambda i: (-per_scale[i][1] * per_scale[i][2], i))
    for i in order:
        j = min(range(fp), key=lambda j: (loads[j], j))
        bins[j].append(i)
        loads[j] += per_scale[i][1] * per_scale[i][2]
    return tuple(tuple(sorted(b)) for b in bins)


# ------------------------------------------------------- exact merge

def merge_topk(scores: Array, idx: Array, k: int) -> Tuple[Array, Array]:
    """Exact global top-k from stacked per-tile local top-k lists.

    scores/idx: (fp, k) local lists (scores descending, -inf padded;
    idx = global flat window index, n for phantom pad rows). An
    ascending two-key sort on (-score, idx) reproduces lax.top_k's
    order and tie-breaking (equal scores -> lower flat index first)
    over the FULL window table: any member of the global top-k has at
    most k-1 better candidates globally, hence at most k-1 better in
    its own tile, so it survives its tile's local top-k and is present
    in the union. -inf rows match too: each tile's local list keeps its
    k lowest-index masked positions, which covers the k globally
    lowest. Float negation is exact (sign-bit flip), so -(-s) == s
    bitwise, including -inf."""
    neg, order = jax.lax.sort((-scores.reshape(-1), idx.reshape(-1)),
                              num_keys=2)
    return -neg[:k], order[:k]
