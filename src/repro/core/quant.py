"""int8 descriptor quantization for the fixed-point datapath (DESIGN.md §12).

The paper's 54x speedup is a fixed-point story: the FPGA keeps gradients,
histograms and descriptors in narrow integer registers end to end. The
`numerics="fixed"` mode mirrors that on TPU:

  * gray is rounded to 8-bit integers (the camera's own precision), so
    central-difference gradients are exact integers in [-510, 510],
  * CORDIC magnitude/angle runs on an int32 shift-add datapath
    (core/cordic.py:cordic_mag_bin_fixed) and stores magnitudes in units
    of 2 gray levels (MAG_SCALE) -- the per-cell sum of <= 64 such
    magnitudes is bounded by 64 * 361 < 2^15, so cell histograms are
    honest int16 accumulators,
  * the L2-normalized block vectors (components in [0, 1]) quantize to
    int8 with ONE scale per 36-dim block: scale = max(v)/127,
    q = rint(v/scale). Per-block scaling keeps low-energy blocks at full
    7-bit resolution instead of wasting range on the scene's loudest
    block,
  * SVM weights quantize per window-offset column (signed symmetric,
    scale = max|w|/127), and the dense scoring matmul runs int8 x int8
    -> int32 with an exact rank-1 f32 rescale.

Everything here is per-element or per-block local and round-to-nearest
deterministic, which is what makes fixed-mode results byte-identical
across the data/tile mesh axes: integer matmuls are exact under any
blocking, and the f32 rescale is elementwise.

The quantizer is idempotent on its own output (already-on-grid values
requantize to the same int8 codes), so the scoring path can recover
(q, scale) from the dequantized block grid the stage chain returns --
one array keeps flowing through every existing detector/sharding seam.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

Q_MAX = 127.0        # symmetric int8 code range [(-)127 .. 127]

#: fixed-chain magnitudes are stored in units of 2 gray levels: the max
#: gradient magnitude sqrt(510^2 + 510^2) ~= 721.2 halves to 361, so a
#: full 64-px cell sums to <= 23104 < 2^15 -- the int16 histogram bound.
MAG_SCALE = 0.5


def quantize_blocks(v: Array):
    """(..., bd) f32 block vectors -> (int8 codes, (...) f32 per-block scale).

    scale = max|v|/127 per block vector; zero blocks get scale 0 and all-
    zero codes. Block-norm output is nonnegative, but abs() keeps the
    quantizer total for any caller.
    """
    m = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    scale = m * jnp.float32(1.0 / Q_MAX)
    safe = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.rint(v / safe).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_blocks(q: Array, scale: Array) -> Array:
    """Inverse of quantize_blocks: (..., bd) int8 + (...) scale -> f32."""
    return q.astype(jnp.float32) * scale[..., None]


def quantize_dequantize(v: Array) -> Array:
    """Round v onto its per-block int8 grid (the fixed chain's public
    f32 output: exactly the values the int8 scoring path reconstructs)."""
    q, scale = quantize_blocks(v)
    return dequantize_blocks(q, scale)


def quantize_weight_columns(wt: Array):
    """(K, N) f32 weights -> (int8 codes, (N,) f32 per-column scale).

    Symmetric per-column quantization of the per-offset SVM weight tile
    (detector.py:score_blocks): scale = max|w_col|/127, codes in
    [-127, 127].
    """
    m = jnp.max(jnp.abs(wt), axis=0, keepdims=True)
    scale = m * jnp.float32(1.0 / Q_MAX)
    safe = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.rint(wt / safe).astype(jnp.int8)
    return q, scale[0]


def rescale_scores(contrib_i32: Array, row_scale: Array,
                   col_scale: Array) -> Array:
    """Exact rank-1 dequantization of the int32 scoring matmul:
    (M, N) i32 * row (M,) * col (N,) -> (M, N) f32, fixed multiply order
    so every tile/shard computes bit-identical values."""
    return (contrib_i32.astype(jnp.float32)
            * row_scale[:, None]) * col_scale[None, :]
