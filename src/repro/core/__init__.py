# The paper's primary contribution: the HOG+SVM human-detection
# co-processor, as composable JAX modules.
from repro.core.hog import (HOGConfig, PAPER_HOG, hog_descriptor,
                            hog_descriptor_batch)
from repro.core.cordic import cordic_mag_angle, cordic_gain
from repro.core.svm import (SVMParams, SVMTrainConfig, init_svm, svm_score,
                            predict, hinge_loss, train_svm, accuracy_table)
from repro.core.detector import (DetectorConfig, FrameDetector, detect,
                                 scene_blocks, score_map)
from repro.core.pipeline import classify_windows, extract_features
from repro.core.stages import dense_blocks, window_blocks, window_descriptor
