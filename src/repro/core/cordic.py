"""CORDIC (COordinate Rotation DIgital Computer) -- vectoring mode.

Faithful to the paper's hardware unit (Fig. 7-8): 15 iterations, a 15-entry
arctan lookup table, shift-add datapath. The paper uses it to produce both
the gradient magnitude (eq. 3) and the gradient angle (eq. 4) from (fx, fy).

On TPU this runs vectorized on the VPU via `lax.fori_loop`; the "shifts"
are exact multiplications by 2^-i (the paper's datapath is IEEE-754 fp32,
so this is bit-faithful in spirit: same iteration, same LUT).

Vectoring mode drives y -> 0 while accumulating the rotation angle in z:
    if y < 0:  x -= y*2^-i ; y += x*2^-i ; z -= atan(2^-i)
    else:      x += y*2^-i ; y -= x*2^-i ; z += atan(2^-i)
After n iterations x ~= K * sqrt(x0^2 + y0^2) with gain
K = prod_i sqrt(1 + 2^-2i); we divide the gain back out (the FPGA does the
same with a constant multiplier).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

MAX_ITERS = 15  # the paper: "Calculating up to n = 14 (ie. up to 15 angle
                # values from the Lookup Table are retrieved)"

# the hardware LUT: atan(2^-i) in degrees, i = 0..14
ATAN_LUT_DEG = tuple(math.degrees(math.atan(2.0 ** -i))
                     for i in range(MAX_ITERS))


def cordic_gain(iters: int = MAX_ITERS) -> float:
    g = 1.0
    for i in range(iters):
        g *= math.sqrt(1.0 + 2.0 ** (-2 * i))
    return g


@partial(jax.jit, static_argnames=("iters",))
def cordic_mag_angle(x: Array, y: Array,
                     iters: int = MAX_ITERS) -> Tuple[Array, Array]:
    """Vectorized CORDIC vectoring. Returns (magnitude, angle_degrees).

    Angle covers the full (-180, 180] range: inputs in the left half-plane
    are pre-rotated by 180 deg (sign flip), exactly what the hardware's
    quadrant-correction stage does, then the iterative rotation refines
    within (-90, 90).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)

    # quadrant correction: fold into the right half-plane
    neg_x = x < 0
    x0 = jnp.where(neg_x, -x, x)
    y0 = jnp.where(neg_x, -y, y)
    # after folding, true angle = z + 180 if (neg_x and y>=0) else z - 180
    lut = jnp.asarray(ATAN_LUT_DEG[:iters], dtype=jnp.float32)

    def body(i, carry):
        cx, cy, cz = carry
        p = jnp.exp2(-i.astype(jnp.float32))
        d = jnp.where(cy < 0, -1.0, 1.0)            # rotate toward y == 0
        nx = cx + d * cy * p
        ny = cy - d * cx * p
        nz = cz + d * lut[i]
        return nx, ny, nz

    z0 = jnp.zeros_like(x0)
    xf, _, zf = jax.lax.fori_loop(0, iters, body, (x0, y0, z0))

    mag = xf / jnp.float32(cordic_gain(iters))
    ang = jnp.where(neg_x, jnp.where(y >= 0, zf + 180.0, zf - 180.0), zf)
    # exact zero input: angle 0, magnitude 0
    both_zero = (x == 0) & (y == 0)
    return jnp.where(both_zero, 0.0, mag), jnp.where(both_zero, 0.0, ang)
