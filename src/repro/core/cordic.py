"""CORDIC (COordinate Rotation DIgital Computer) -- vectoring mode.

Faithful to the paper's hardware unit (Fig. 7-8): 15 iterations, a 15-entry
arctan lookup table, shift-add datapath. The paper uses it to produce both
the gradient magnitude (eq. 3) and the gradient angle (eq. 4) from (fx, fy).

On TPU this runs vectorized on the VPU via `lax.fori_loop`; the "shifts"
are exact multiplications by 2^-i (the paper's datapath is IEEE-754 fp32,
so this is bit-faithful in spirit: same iteration, same LUT).

Vectoring mode drives y -> 0 while accumulating the rotation angle in z:
    if y < 0:  x -= y*2^-i ; y += x*2^-i ; z -= atan(2^-i)
    else:      x += y*2^-i ; y -= x*2^-i ; z += atan(2^-i)
After n iterations x ~= K * sqrt(x0^2 + y0^2) with gain
K = prod_i sqrt(1 + 2^-2i); we divide the gain back out (the FPGA does the
same with a constant multiplier).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

MAX_ITERS = 15  # the paper: "Calculating up to n = 14 (ie. up to 15 angle
                # values from the Lookup Table are retrieved)"

# the hardware LUT: atan(2^-i) in degrees, i = 0..14
ATAN_LUT_DEG = tuple(math.degrees(math.atan(2.0 ** -i))
                     for i in range(MAX_ITERS))


def cordic_gain(iters: int = MAX_ITERS) -> float:
    g = 1.0
    for i in range(iters):
        g *= math.sqrt(1.0 + 2.0 ** (-2 * i))
    return g


@partial(jax.jit, static_argnames=("iters",))
def cordic_mag_angle(x: Array, y: Array,
                     iters: int = MAX_ITERS) -> Tuple[Array, Array]:
    """Vectorized CORDIC vectoring. Returns (magnitude, angle_degrees).

    Angle covers the full (-180, 180] range: inputs in the left half-plane
    are pre-rotated by 180 deg (sign flip), exactly what the hardware's
    quadrant-correction stage does, then the iterative rotation refines
    within (-90, 90).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)

    # quadrant correction: fold into the right half-plane
    neg_x = x < 0
    x0 = jnp.where(neg_x, -x, x)
    y0 = jnp.where(neg_x, -y, y)
    # after folding, true angle = z + 180 if (neg_x and y>=0) else z - 180
    lut = jnp.asarray(ATAN_LUT_DEG[:iters], dtype=jnp.float32)

    def body(i, carry):
        cx, cy, cz = carry
        p = jnp.exp2(-i.astype(jnp.float32))
        d = jnp.where(cy < 0, -1.0, 1.0)            # rotate toward y == 0
        nx = cx + d * cy * p
        ny = cy - d * cx * p
        nz = cz + d * lut[i]
        return nx, ny, nz

    z0 = jnp.zeros_like(x0)
    xf, _, zf = jax.lax.fori_loop(0, iters, body, (x0, y0, z0))

    # on-axis inputs (y == 0) have an exact angle of 0 or 180, but the
    # iteration leaves a +-atan(2^-14) ~= 0.003 deg residual in z. Signed
    # output that residual is harmless; the descriptor chain's unsigned
    # fold (mod 180) flips 180+eps / 0-eps to ~179.997 -> bin 8 instead of
    # the oracle's bin 0. Pin z exactly on the axis.
    zf = jnp.where(y == 0, 0.0, zf)

    mag = xf / jnp.float32(cordic_gain(iters))
    ang = jnp.where(neg_x, jnp.where(y >= 0, zf + 180.0, zf - 180.0), zf)
    # exact zero input: angle 0, magnitude 0
    both_zero = (x == 0) & (y == 0)
    return jnp.where(both_zero, 0.0, mag), jnp.where(both_zero, 0.0, ang)


# ---------------------------------------------------------------------------
# fixed-point CORDIC -- the numerics="fixed" gradient unit
# ---------------------------------------------------------------------------

#: angle registers hold degrees in Q16: 1 LSB = 2^-16 deg. 15 LUT entries
#: stay exact (atan(1) = 45 deg = 0x2D0000) and the total LUT rounding
#: error is < 15 LSB ~= 0.0002 deg, far inside a 20-deg bin.
ANG_FRAC_BITS = 16
ANG_180 = 180 << ANG_FRAC_BITS

ATAN_LUT_FIXED = tuple(int(round(d * (1 << ANG_FRAC_BITS)))
                       for d in ATAN_LUT_DEG)

#: x/y registers hold gray-level units in Q8 (8 fractional bits): inputs
#: are integer central differences |fx|,|fy| <= 510, so |x| stays under
#: 721.2 * gain * 2^8 < 2^19 -- comfortable in int32 with 15 right-shifts.
MAG_FRAC_BITS = 8

#: fixed-chain magnitudes leave in units of 2 gray levels (see
#: core/quant.py MAG_SCALE): combined un-gain + Q8 + halving multiplier.
_INV_GAIN_HALF = 1.0 / (cordic_gain(MAX_ITERS) * (1 << MAG_FRAC_BITS) * 2)


@partial(jax.jit, static_argnames=("iters", "bins"))
def cordic_mag_bin_fixed(fx: Array, fy: Array, iters: int = MAX_ITERS,
                         bins: int = 9) -> Tuple[Array, Array]:
    """Integer shift-add CORDIC: (fx, fy) -> (mag_q int32, bin int32).

    The hardware datapath proper: int32 registers, arithmetic right
    shifts for the 2^-i rotations, Q16-degree angle accumulation, and the
    unsigned fold + bin divide in integer arithmetic. Inputs must be
    integer-valued (f32 holding whole gray-level differences is fine).

    mag_q is the CORDIC magnitude rounded to half-gray-level units
    (<= 361 for 8-bit frames), sized so an 8x8 cell's histogram sum fits
    int16. bin is the unsigned orientation bin in [0, bins).
    """
    xi = jnp.round(fx).astype(jnp.int32)
    yi = jnp.round(fy).astype(jnp.int32)

    neg_x = xi < 0
    x = jnp.where(neg_x, -xi, xi) << MAG_FRAC_BITS
    y = jnp.where(neg_x, -yi, yi) << MAG_FRAC_BITS
    z = jnp.zeros_like(x)

    lut = jnp.asarray(ATAN_LUT_FIXED[:iters], dtype=jnp.int32)

    def body(i, carry):
        cx, cy, cz = carry
        xs = jax.lax.shift_right_arithmetic(cx, i)
        ys = jax.lax.shift_right_arithmetic(cy, i)
        d = cy < 0
        nx = jnp.where(d, cx - ys, cx + ys)
        ny = jnp.where(d, cy + xs, cy - xs)
        nz = jnp.where(d, cz - lut[i], cz + lut[i])
        return nx, ny, nz

    xf, _, zf = jax.lax.fori_loop(0, iters, body, (x, y, z))

    # same on-axis pin as the float path: y == 0 angles are exactly 0/180
    zf = jnp.where(yi == 0, 0, zf)
    ang = jnp.where(neg_x, jnp.where(yi >= 0, zf + ANG_180, zf - ANG_180), zf)
    theta = jnp.mod(ang, ANG_180)                     # [0, 180) in Q16 deg
    b = jnp.minimum(theta // (ANG_180 // bins), bins - 1).astype(jnp.int32)

    mag_q = jnp.rint(xf.astype(jnp.float32)
                     * jnp.float32(_INV_GAIN_HALF)).astype(jnp.int32)

    both_zero = (xi == 0) & (yi == 0)
    return (jnp.where(both_zero, 0, mag_q),
            jnp.where(both_zero, 0, b))
