"""The canonical HOG stage chain, instantiated per backend and layout.

Every HOG consumer in the repo used to carry its own copy of the chain:
`core/hog.py` (per-window, pure jnp), `core/detector.py:scene_blocks`
(dense whole-scene, pure jnp) and `kernels/ops.py` (per-window, Pallas).
This module is the single definition they all share now (DESIGN.md §3):

    grayscale -> gradients -> mag/bin -> cell_histograms -> block_normalize

*Backends* supply the stage implementations:

  * "ref"    -- pure-jnp oracles from core/hog.py (mode per HOGConfig:
               ref | cordic | sector),
  * "kernel" -- staged Pallas kernels (gradient+bin, cell histogram,
               block norm as separate pallas_calls),
  * "fused"  -- the single fused Pallas kernel (all stages in VMEM).

*Layouts* supply the geometry:

  * window -- a batch of fixed windows; the active region is cropped to
              `cfg` geometry and the block grid collates to a
              (..., n_features) descriptor,
  * dense  -- a whole scene; the gradient field is trimmed to whole
              cells and the normalized block grid (..., BH, BW, 36) is
              returned for dense matmul scoring (detector.py).

The Pallas backends carry LAYOUT-SPECIFIC kernels: the window kernels
tile over the batch of small windows, while the dense kernels
(kernels/dense_grad_hist.py, kernels/dense_block_norm.py,
fused_hog.dense_fused_hog) tile over row slabs of the scene's cell
grid, so a whole 4K frame streams through a fixed VMEM budget instead
of landing in one megablock.

Because block normalization (eq. 5) is window-independent, the two
layouts agree wherever a window tiles onto the scene's cell grid --
that equivalence is what makes dense detection exact, and it is tested
per backend and per numerics mode in tests/test_stages_detector.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import numerics as N
from repro.core.hog import (HOGConfig, PAPER_HOG, _MAG_BIN_FAST,
                            block_normalize, cell_histograms, gradients,
                            grayscale)

Array = jax.Array

#: The canonical stage order. `grayscale` is shared across backends
#: (layout-independent); the remaining stages are backend-specific.
STAGE_ORDER = ("grayscale", "grad_mag_bin", "cell_hist", "block_norm")


@dataclasses.dataclass(frozen=True)
class StageSet:
    """One backend's implementation of the canonical chain.

    Each stage callable takes the geometry-adjusted HOGConfig (window_h/
    window_w describe the actual gray tile, so cells_hw/blocks_hw match
    the data). `fused` short-circuits the whole chain in one call.
    """

    name: str
    grad_mag_bin: Optional[Callable[[Array, HOGConfig],
                                    Tuple[Array, Array]]] = None
    cell_hist: Optional[Callable[[Array, Array, HOGConfig], Array]] = None
    block_norm: Optional[Callable[[Array, HOGConfig], Array]] = None
    fused: Optional[Callable[[Array, HOGConfig], Array]] = None
    # dense-layout variants: kernels tiled over the SCENE's cell grid
    # (row slabs) rather than over a batch of window tiles. When absent,
    # the dense layout falls back to the window-layout stages (correct
    # for the pure-jnp ref backend, which is shape-agnostic).
    dense_grad_hist: Optional[Callable[[Array, HOGConfig], Array]] = None
    dense_block_norm: Optional[Callable[[Array, HOGConfig], Array]] = None
    dense_fused: Optional[Callable[[Array, HOGConfig], Array]] = None


# ---------------------------------------------------------------- backends

# All per-mode choices (mag/bin impl, kernel mode string, rsqrt flavor,
# quantized datapath) come from ONE table: core/numerics.py SPECS. The
# scattered _use_nr / _kernel_mode predicates this file used to carry
# were the PR 6 identity-trap shape -- a new mode could engage NR rsqrt
# in one backend and fall back to fp32 rsqrt in another.

def _cast_feat(blocks: Array, cfg: HOGConfig) -> Array:
    if cfg.feat_dtype == "bf16" and blocks.dtype != jnp.bfloat16:
        return blocks.astype(jnp.bfloat16)
    return blocks


def _ref_grad_mag_bin(gray: Array, cfg: HOGConfig) -> Tuple[Array, Array]:
    fx, fy = gradients(gray)
    # _MAG_BIN_FAST == _MAG_BIN except "ref", whose arctan2 binning is
    # replaced by the bit-compatible sector predicate (hog.py) -- the
    # arctan2 form was ~half the dense hot path's runtime on CPU
    return _MAG_BIN_FAST[N.spec_for(cfg).name](fx, fy, cfg.bins)


def _ref_cell_hist(mag: Array, b: Array, cfg: HOGConfig) -> Array:
    return cell_histograms(mag, b, cfg)


def _ref_block_norm(hist: Array, cfg: HOGConfig) -> Array:
    return block_normalize(hist, cfg, norm=N.spec_for(cfg).norm)


def _pallas_grad_mag_bin(gray: Array, cfg: HOGConfig) -> Tuple[Array, Array]:
    from repro.kernels.hog_gradient import hog_gradient
    return hog_gradient(gray, mode=N.spec_for(cfg).kernel_mode)


def _pallas_cell_hist(mag: Array, b: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.cell_hist import cell_hist
    return cell_hist(mag, b, cell=cfg.cell, bins=cfg.bins)


def _pallas_block_norm(hist: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.block_norm import block_norm
    out = block_norm(hist, block=cfg.block, eps=cfg.eps,
                     mode=N.spec_for(cfg).norm)
    return _cast_feat(out, cfg)


def _pallas_fused(gray: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.fused_hog import fused_hog
    desc = fused_hog(gray, cell=cfg.cell, block=cfg.block, bins=cfg.bins,
                     eps=cfg.eps, mode=N.spec_for(cfg).kernel_mode)
    bh, bw = cfg.blocks_hw
    return _cast_feat(desc.reshape(desc.shape[0], bh, bw, cfg.block_dim),
                      cfg)


def _pallas_dense_grad_hist(gray: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.dense_grad_hist import dense_grad_hist
    return dense_grad_hist(gray, cell=cfg.cell, bins=cfg.bins,
                           mode=N.spec_for(cfg).kernel_mode)


def _pallas_dense_block_norm(hist: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.dense_block_norm import dense_block_norm
    out = dense_block_norm(hist, block=cfg.block, eps=cfg.eps,
                           mode=N.spec_for(cfg).norm)
    return _cast_feat(out, cfg)


def _pallas_dense_fused(gray: Array, cfg: HOGConfig) -> Array:
    from repro.kernels.fused_hog import dense_fused_hog
    out = dense_fused_hog(gray, cell=cfg.cell, block=cfg.block,
                          bins=cfg.bins, eps=cfg.eps,
                          mode=N.spec_for(cfg).kernel_mode)
    return _cast_feat(out, cfg)


BACKENDS = {
    "ref": StageSet("ref", _ref_grad_mag_bin, _ref_cell_hist,
                    _ref_block_norm),
    "kernel": StageSet("kernel", _pallas_grad_mag_bin, _pallas_cell_hist,
                       _pallas_block_norm,
                       dense_grad_hist=_pallas_dense_grad_hist,
                       dense_block_norm=_pallas_dense_block_norm),
    "fused": StageSet("fused", fused=_pallas_fused,
                      dense_fused=_pallas_dense_fused),
}


def get_backend(backend: str) -> StageSet:
    try:
        return BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown stage backend {backend!r}; "
            f"expected one of {sorted(BACKENDS)}") from None


# ------------------------------------------------------------- stage chain

def run_stages(gray: Array, geom: HOGConfig, backend: str = "ref",
               layout: str = "window") -> Array:
    """Run the canonical chain on prepared gray tiles.

    gray: (B, geom.window_h', geom.window_w') f32 where the interior
    (shape - 2) is a whole number of cells; `geom` is the geometry-
    adjusted config (see `window_blocks` / `dense_blocks`).
    Returns the normalized block grid (B, bh, bw, block_dim).

    `layout="dense"` selects the backend's dense-grid kernels (tiled
    over the scene's cell rows) when it has them; backends without
    dense variants (ref, whose pure-jnp stages are shape-agnostic)
    run the window-layout stages on the scene directly.
    """
    ss = get_backend(backend)
    if N.spec_for(geom).quantized:
        # fixed datapath entry: snap gray to whole 8-bit levels HERE, the
        # one seam every backend/layout/tile shares, so central-difference
        # gradients are exact integers and the whole chain downstream is
        # deterministic integer arithmetic (byte-identical under data/
        # tile sharding). Luma of a uint8 frame is within rounding of
        # this anyway -- the camera never produced fractional gray.
        gray = jnp.rint(gray)
    if layout == "dense":
        if ss.dense_fused is not None:
            return ss.dense_fused(gray, geom)
        if ss.dense_grad_hist is not None:
            hist = ss.dense_grad_hist(gray, geom)
            return ss.dense_block_norm(hist, geom)
    if ss.fused is not None:
        return ss.fused(gray, geom)
    mag, b = ss.grad_mag_bin(gray, geom)
    hist = ss.cell_hist(mag, b, geom)
    return ss.block_norm(hist, geom)


# ------------------------------------------------------------------ layout

def validate_window(window: Array, cfg: HOGConfig) -> None:
    """Reject windows smaller than the configured detection window.

    Anything >= (cfg.window_h, cfg.window_w) is top-left-anchored and
    cropped; anything smaller used to be silently cropped into a garbage
    descriptor -- now it raises.
    """
    spatial = window.shape[-3:-1] if window.shape[-1] == 3 \
        else window.shape[-2:]
    if len(spatial) < 2 or spatial[0] < cfg.window_h \
            or spatial[1] < cfg.window_w:
        raise ValueError(
            f"window spatial shape {tuple(spatial)} is smaller than the "
            f"configured detection window ({cfg.window_h}, {cfg.window_w}); "
            f"HOG expects (..., H>={cfg.window_h}, W>={cfg.window_w}[, 3])")


def _to_gray(x: Array) -> Array:
    gray = grayscale(x) if x.shape[-1] == 3 else x
    return gray.astype(jnp.float32)


def _flatten_batch(x: Array):
    """(..., H, W) -> ((B, H, W), unflatten) so Pallas backends see the
    one-batch-axis contract regardless of the caller's leading dims."""
    lead = x.shape[:-2]
    flat = x.reshape((-1,) + x.shape[-2:])

    def unflatten(y: Array) -> Array:
        return y.reshape(lead + y.shape[1:])

    return flat, unflatten


def window_blocks(windows: Array, cfg: HOGConfig = PAPER_HOG,
                  backend: str = "ref") -> Array:
    """Window layout: (..., H, W[, 3]) -> (..., bh, bw, block_dim)."""
    validate_window(windows, cfg)
    gray = _to_gray(windows)[..., : cfg.active_h + 2, : cfg.active_w + 2]
    geom = dataclasses.replace(cfg, window_h=cfg.active_h + 2,
                               window_w=cfg.active_w + 2)
    flat, unflatten = _flatten_batch(gray)
    return unflatten(run_stages(flat, geom, backend))


def window_descriptor(windows: Array, cfg: HOGConfig = PAPER_HOG,
                      backend: str = "ref") -> Array:
    """Window layout, collated: (..., H, W[, 3]) -> (..., n_features)."""
    blocks = window_blocks(windows, cfg, backend)
    return blocks.reshape(blocks.shape[:-3] + (cfg.n_features,))


def dense_blocks(image: Array, cfg: HOGConfig = PAPER_HOG,
                 backend: str = "ref") -> Array:
    """Dense layout: (..., H, W[, 3]) -> (..., BH, BW, block_dim).

    The gradient field is trimmed so it tiles into whole cells; the
    resulting block grid is shared by every window position at cell
    stride (the dense-HOG amortization, detector.py).
    """
    gray = _to_gray(image)
    h, w = gray.shape[-2], gray.shape[-1]
    gh = (h - 2) // cfg.cell * cfg.cell
    gw = (w - 2) // cfg.cell * cfg.cell
    if gh < cfg.cell * cfg.block or gw < cfg.cell * cfg.block:
        raise ValueError(
            f"scene spatial shape {(h, w)} is too small for even one "
            f"{cfg.block}x{cfg.block}-cell block of {cfg.cell}px cells")
    gray = gray[..., : gh + 2, : gw + 2]
    geom = dataclasses.replace(cfg, window_h=gh + 2, window_w=gw + 2)
    flat, unflatten = _flatten_batch(gray)
    return unflatten(run_stages(flat, geom, backend, layout="dense"))
