"""Named SVM heads -> one stacked parameter block (DESIGN.md §13).

The scoring path evaluates a linear SVM as one (BH*BW, 36) @ (36, 105)
MXU matmul; K classifiers widen that to (36, 105*K) -- near-free on the
hardware. `HeadRegistry` is the host-side subsystem that owns the K: it
keeps NAMED heads (pedestrian, vehicle, a user's custom head), each a
plain `{"w": (F,), "b": ()}` parameter dict plus an optional per-head
score threshold and free-form metadata, and stacks any subset into the
`{"w": (K, F), "b": (K,)}` block the detector's multi-head program
consumes (`core/detector.py:score_blocks`). Stacking order is the
caller's class order: head k of the stacked block IS class_id k of the
resulting Detections.

Names starting with an underscore (e.g. the cascade's "_coarse" head,
`core/cascade.py`) are auxiliary: they save/load with the registry but
are excluded from default stacking, so `detect()` without an explicit
class list never scores them.

Persistence rides the existing checkpoint layout
(`checkpoint/manager.py`): parameters land as one pytree
`{name: {"w", "b"}}` under atomic step directories, while `heads.json`
next to them records order, thresholds and metadata -- the session
(`api/session.py`) routes `save`/`load` here whenever that manifest is
present, so single-head checkpoints stay readable by old code.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core.svm import SVMParams

HEADS_MANIFEST = "heads.json"


@dataclasses.dataclass
class SVMHead:
    """One named classifier: params + decode-time policy."""
    name: str
    params: SVMParams                       # {"w": (F,), "b": ()}
    threshold: Optional[float] = None       # None -> detector default
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_features(self) -> int:
        return int(np.shape(self.params["w"])[0])


class HeadRegistry:
    """Ordered, named SVM heads with stacking and checkpoint round-trip.

    Insertion order is stacking order; `stacked()` turns any subset into
    the detector's `{"w": (K, F), "b": (K,)}` parameter block.
    """

    def __init__(self, heads: Sequence[SVMHead] = ()):
        self._heads: Dict[str, SVMHead] = {}
        for h in heads:
            self.add(h.name, h.params, h.threshold, h.metadata)

    # ------------------------------------------------------- membership
    def add(self, name: str, params: SVMParams,
            threshold: Optional[float] = None,
            metadata: Optional[Dict[str, Any]] = None,
            replace: bool = False) -> SVMHead:
        """Register a head. Params are snapshotted to host float32 (w
        flattened to (F,)) so stacking is pure numpy; re-adding an
        existing name needs `replace=True`."""
        if not name:
            raise ValueError("head name must be non-empty")
        if name in self._heads and not replace:
            raise ValueError(f"head {name!r} already registered "
                             f"(pass replace=True to overwrite)")
        w = np.asarray(params["w"], np.float32).reshape(-1)
        b = np.float32(np.asarray(params["b"], np.float32).reshape(()))
        head = SVMHead(name, {"w": w, "b": b},
                       None if threshold is None else float(threshold),
                       dict(metadata or {}))
        self._heads[name] = head
        return head

    def remove(self, name: str) -> None:
        del self._heads[name]

    def get(self, name: str) -> SVMHead:
        return self._heads[name]

    def __contains__(self, name: str) -> bool:
        return name in self._heads

    def __len__(self) -> int:
        return len(self._heads)

    def __iter__(self) -> Iterator[SVMHead]:
        return iter(self._heads.values())

    def __repr__(self) -> str:
        return f"HeadRegistry({list(self._heads)})"

    @property
    def names(self) -> Tuple[str, ...]:
        """Default stacking order: every PUBLIC head (no '_' prefix),
        in insertion order."""
        return tuple(n for n in self._heads if not n.startswith("_"))

    @property
    def n_features(self) -> Optional[int]:
        """Feature width of the default (public) stack. Auxiliary
        '_'-prefixed heads may carry a different HOG geometry (the
        cascade's half-resolution coarse head does) -- uniformity is
        enforced per stacking subset, not registry-wide."""
        for n, h in self._heads.items():
            if not n.startswith("_"):
                return h.n_features
        for h in self._heads.values():
            return h.n_features
        return None

    # ---------------------------------------------------------- stacking
    def stacked(self, names: Optional[Sequence[str]] = None
                ) -> Tuple[SVMParams, Tuple[str, ...],
                           Tuple[Optional[float], ...]]:
        """Stack a subset of heads (default: all public ones) into the
        multi-head parameter block. Returns `({"w": (K, F), "b": (K,)},
        names, thresholds)` -- row k of w is head names[k], so class_id
        k of the detections is names[k]; thresholds keeps each head's
        override (None = use the detector's score_threshold)."""
        names = tuple(self.names if names is None else names)
        if not names:
            raise ValueError("no heads to stack (registry empty or all "
                             "auxiliary); pass explicit names")
        missing = [n for n in names if n not in self._heads]
        if missing:
            raise KeyError(f"unknown heads {missing}; registered: "
                           f"{list(self._heads)}")
        heads = [self._heads[n] for n in names]
        widths = {h.n_features for h in heads}
        if len(widths) > 1:
            raise ValueError(
                f"stacked heads must share one HOG geometry; got "
                f"feature widths { {n: self._heads[n].n_features for n in names} }")
        svm: SVMParams = {
            "w": np.stack([h.params["w"] for h in heads]),
            "b": np.asarray([h.params["b"] for h in heads], np.float32)}
        return svm, names, tuple(h.threshold for h in heads)

    def single(self, name: str) -> SVMParams:
        """One head's plain single-head `{"w": (F,), "b": ()}` params."""
        return dict(self._heads[name].params)

    # -------------------------------------------------------- checkpoint
    def save(self, path: str, step: int = 0) -> None:
        """Persist all heads: one checkpoint step for the parameter
        pytree + `heads.json` (order/thresholds/metadata) at the root."""
        from repro.checkpoint.manager import CheckpointManager
        if not self._heads:
            raise ValueError("cannot save an empty HeadRegistry")
        tree = {n: {"w": h.params["w"], "b": h.params["b"]}
                for n, h in self._heads.items()}
        CheckpointManager(path).save(step, tree)
        manifest = {
            "version": 1,
            "heads": [{"name": h.name, "threshold": h.threshold,
                       "n_features": h.n_features,
                       "metadata": h.metadata} for h in self._heads.values()],
        }
        from repro.checkpoint.manager import atomic_write_json
        atomic_write_json(os.path.join(path, HEADS_MANIFEST), manifest,
                          indent=2)

    @classmethod
    def load(cls, path: str, step: Optional[int] = None) -> "HeadRegistry":
        """Restore a registry saved by `save` (latest step by default)."""
        import jax

        from repro.checkpoint.manager import CheckpointManager
        with open(os.path.join(path, HEADS_MANIFEST)) as f:
            manifest = json.load(f)
        mgr = CheckpointManager(path)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
        skeleton = {h["name"]: {
            "w": jax.ShapeDtypeStruct((int(h["n_features"]),), np.float32),
            "b": jax.ShapeDtypeStruct((), np.float32)}
            for h in manifest["heads"]}
        tree = mgr.restore(step, skeleton)
        reg = cls()
        for h in manifest["heads"]:
            reg.add(h["name"], tree[h["name"]], h.get("threshold"),
                    h.get("metadata"))
        return reg

    @staticmethod
    def is_registry_checkpoint(path: str) -> bool:
        return os.path.exists(os.path.join(path, HEADS_MANIFEST))
