"""Linear SVM -- training (in-framework, replacing the paper's Matlab step)
and inference (eqs. 6-7).

The paper trains W, b offline in Matlab and burns them into TrainedData_MEM;
the hardware evaluates D(X) = sign(W.X + b). Here both halves live in the
framework:

  * `train_svm`      -- primal hinge-loss + L2, Pegasos-style SGD schedule
                        (lr_t = 1/(lambda*t)), full-JAX `lax.scan` loop.
  * `svm_score`      -- the co-processor op: scores = X @ W + b. The batched
                        Pallas kernel lives in kernels/svm_matmul.py; this is
                        its oracle.
  * `predict`        -- sign thresholding per eq. (7).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
SVMParams = Dict[str, Array]   # {"w": (F,), "b": ()}


def init_svm(n_features: int, dtype=jnp.float32) -> SVMParams:
    return {"w": jnp.zeros((n_features,), dtype), "b": jnp.zeros((), dtype)}


def svm_score(params: SVMParams, x: Array) -> Array:
    """D(x) = W.X + b  (eq. 6). x: (..., F) -> (...)."""
    return x @ params["w"] + params["b"]


def predict(params: SVMParams, x: Array) -> Array:
    """sign(W.X + b) > 0 -> person (eq. 7). Returns int32 {0, 1}."""
    return (svm_score(params, x) > 0).astype(jnp.int32)


def hinge_loss(params: SVMParams, x: Array, y_pm1: Array,
               lam: float, neg_weight: float = 1.0) -> Array:
    """lambda/2 ||w||^2 + weighted mean(max(0, 1 - y * D(x))), y in {-1,+1}.

    `neg_weight` re-weights the negative class -- used to counter the
    paper's 4202/2795 train imbalance (class-weighted C-SVM).
    """
    margins = y_pm1 * svm_score(params, x)
    w = jnp.where(y_pm1 < 0, neg_weight, 1.0)
    data = jnp.sum(w * jnp.maximum(0.0, 1.0 - margins)) / jnp.sum(w)
    reg = 0.5 * lam * jnp.sum(params["w"] * params["w"])
    return data + reg


@dataclasses.dataclass(frozen=True)
class SVMTrainConfig:
    steps: int = 2000
    batch: int = 256
    lam: float = 1e-4          # L2 strength (Pegasos lambda)
    seed: int = 0
    pegasos_lr: bool = True    # lr_t = 1/(lam * t); else constant 0.1
    neg_weight: float = 1.0    # class weight for negatives (imbalance fix)


@partial(jax.jit, static_argnames=("cfg",))
def train_svm(x: Array, y01: Array,
              cfg: SVMTrainConfig = SVMTrainConfig()) -> Tuple[SVMParams, Array]:
    """Train on features x (N, F), labels y01 (N,) in {0,1}.

    Returns (params, loss_curve). Pure-JAX scan so the whole training run
    is one compiled program (the "software training" half of the paper,
    minus Matlab).
    """
    n, f = x.shape
    y = (y01.astype(jnp.float32) * 2.0 - 1.0)
    params = init_svm(f)
    grad_fn = jax.grad(hinge_loss, argnums=0)

    def step(carry, t):
        params, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (cfg.batch,), 0, n)
        xb, yb = x[idx], y[idx]
        g = grad_fn(params, xb, yb, cfg.lam, cfg.neg_weight)
        if cfg.pegasos_lr:
            lr = 1.0 / (cfg.lam * (t.astype(jnp.float32) + 1.0))
            lr = jnp.minimum(lr, 1.0)   # clip the huge first steps
        else:
            lr = 0.1
        new = {"w": params["w"] - lr * g["w"], "b": params["b"] - lr * g["b"]}
        loss = hinge_loss(new, xb, yb, cfg.lam)
        return (new, key), loss

    (params, _), losses = jax.lax.scan(
        step, (params, jax.random.PRNGKey(cfg.seed)),
        jnp.arange(cfg.steps))
    return params, losses


def accuracy_table(params: SVMParams, x: Array, y01: Array) -> Dict[str, float]:
    """Reproduces the paper's Table I layout: per-class + total accuracy."""
    pred = predict(params, x)
    y01 = y01.astype(jnp.int32)
    pos = y01 == 1
    neg = y01 == 0
    tp = jnp.sum((pred == 1) & pos)
    tn = jnp.sum((pred == 0) & neg)
    n_pos = jnp.maximum(jnp.sum(pos), 1)
    n_neg = jnp.maximum(jnp.sum(neg), 1)
    return {
        "with_person_acc": float(tp / n_pos),
        "without_person_acc": float(tn / n_neg),
        "total_acc": float((tp + tn) / y01.shape[0]),
        "true_detection": int(tp + tn),
        "n": int(y01.shape[0]),
        "n_pos": int(jnp.sum(pos)),
        "n_neg": int(jnp.sum(neg)),
    }
