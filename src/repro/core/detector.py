"""Multi-scale sliding-window human detector -- device-resident end-to-end.

The paper's hardware detects a single fixed 130x66 window; multi-window /
multi-resolution detection is listed as "future development" (§VI). This
module is that future development, built TPU-natively on the staged HOG
pipeline (core/stages.py):

  * Block normalization (eq. 5) is *window-independent*, so the scene's
    normalized block grid is computed ONCE (dense layout, any backend:
    ref | kernel | fused) and shared by every window. A window's SVM
    score is a dot product between its 15x7 block patch and the weight
    tensor -- the whole score map is one valid-mode convolution that XLA
    lowers to MXU matmuls.
  * Multi-scale is ONE compiled program per frame-shape bucket: frames
    are padded up to a bucket shape, the image pyramid + dense scoring
    for every scale is unrolled inside a single jit, thresholding and
    top-k run device-side, and NMS is a vectorized matrix-IoU greedy
    pass (fori_loop over the fixed top-k, O(K) vector work per step --
    no O(N^2) host Python loop, no per-frame retrace).
  * Only box DECODE stays on host: top-k indices select rows of a
    static per-bucket box table (pure geometry, precomputed in numpy).

`detect()` keeps the original host-facing contract (list of dicts) with
one deliberate change: the device program considers at most
`max_detections` top-scoring candidates per frame (fixed K keeps the
shapes static); saturating that cap emits a RuntimeWarning.
`FrameDetector` is the reusable device-program handle the serving layer
uses (serve/engine.py full-frame requests).

The BATCHED path (`detect_batch`) vmaps the same per-bucket pyramid
program over a stacked (B, H, W) frame batch: one jit per
(true-shape, shape-bucket, B) tuple, per-frame top-k and NMS still
device-side, one host sync for the whole batch. The batch axis runs as a scanned map of
`batch_chunk`-wide vmapped chunks (chunk 1 = frame-at-a-time scan, the
fast layout on the CPU host; chunk >= B = one wide vmap for real
accelerators). Frames in a batch may differ in true size as long as
they share a padded bucket (the per-frame (h, w) mask rides along the
batch axis). This is the hot path the video/tracking layer
(core/video.py) and the serving microbatcher (serve/engine.py) sit on.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import HOGConfig, PAPER_HOG, grayscale
from repro.core.stages import dense_blocks
from repro.core.svm import SVMParams

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    hog: HOGConfig = PAPER_HOG
    scales: Tuple[float, ...] = (1.0, 0.8, 0.64)
    score_threshold: float = 0.0          # sign(D(x)) per eq. (7)
    nms_iou: float = 0.3
    max_detections: int = 256             # device top-k size (K)
    backend: str = "ref"                  # stage backend for dense HOG
    shape_bucket: int = 32                # frames pad up to multiples of this
    batch_chunk: int = 1                  # detect_batch vmap width: frames
    #   per vmapped chunk inside the scanned batch program. 1 = scan the
    #   batch frame-by-frame (best locality on the CPU host); >= B = one
    #   fully vectorized vmap step (wide accelerators)


def scene_blocks(gray: Array, cfg: HOGConfig,
                 backend: str = "ref") -> Array:
    """Whole-scene normalized block grid: (H, W) -> (BH, BW, 36).

    Thin view over the dense layout of the staged pipeline; `backend`
    selects ref (pure jnp) or the Pallas kernel/fused implementations.
    """
    return dense_blocks(gray, cfg, backend)


@partial(jax.jit, static_argnames=("cfg", "backend"))
def score_map(gray: Array, w: Array, b: Array,
              cfg: HOGConfig = PAPER_HOG, backend: str = "ref") -> Array:
    """Dense SVM score map at cell (8-px) stride. gray: (H, W) -> (PH, PW).

    score[i, j] = <blocks[i:i+15, j:j+7, :], W> + b  == valid conv.
    """
    blocks = scene_blocks(gray, cfg, backend)           # (BH, BW, 36)
    bh, bw = cfg.blocks_hw                              # 15, 7
    wk = w.reshape(bh, bw, cfg.block_dim).astype(blocks.dtype)
    out = jax.lax.conv_general_dilated(
        blocks[None],                                   # NHWC
        wk[..., None],                                  # HWIO (36 -> 1)
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    return out[0, :, :, 0] + b


# ------------------------------------------------------------------- NMS

def matrix_iou(a: Array, b: Array) -> Array:
    """Pairwise IoU. a: (N, 4), b: (M, 4) as (y0, x0, y1, x1) -> (N, M)."""
    y0 = jnp.maximum(a[:, None, 0], b[None, :, 0])
    x0 = jnp.maximum(a[:, None, 1], b[None, :, 1])
    y1 = jnp.minimum(a[:, None, 2], b[None, :, 2])
    x1 = jnp.minimum(a[:, None, 3], b[None, :, 3])
    inter = jnp.maximum(y1 - y0, 0.0) * jnp.maximum(x1 - x0, 0.0)
    area_a = (a[:, 2] - a[:, 0]) * (a[:, 3] - a[:, 1])
    area_b = (b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])
    return inter / jnp.maximum(area_a[:, None] + area_b[None, :] - inter,
                               1e-9)


def nms_keep(boxes: Array, scores: Array, iou_thr: float) -> Array:
    """Vectorized greedy NMS, device-resident.

    boxes (K, 4) must be sorted by descending score (lax.top_k order);
    entries with score == -inf are invalid and never kept. The IoU
    matrix is computed once; the greedy dependency runs as a fori_loop
    over the FIXED K with O(K) vector work per step, so the whole pass
    stays on device with a static shape -- exact same keep set as the
    host greedy reference (tests/test_stages_detector.py).
    """
    k = boxes.shape[0]
    iou = matrix_iou(boxes, boxes)
    valid = jnp.isfinite(scores)
    rank = jnp.arange(k)

    def body(i, keep):
        suppressed = jnp.any(keep & (iou[:, i] > iou_thr) & (rank < i))
        return keep.at[i].set(valid[i] & ~suppressed)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((k,), bool))


def _nms(boxes: np.ndarray, scores: np.ndarray, iou_thr: float) -> List[int]:
    """Greedy NMS on host -- the O(N^2) Python reference the vectorized
    `nms_keep` is validated against. boxes: (N, 4) as (y0, x0, y1, x1)."""
    order = np.argsort(-scores)
    keep: List[int] = []
    while order.size:
        i = order[0]
        keep.append(int(i))
        if order.size == 1:
            break
        rest = order[1:]
        yy0 = np.maximum(boxes[i, 0], boxes[rest, 0])
        xx0 = np.maximum(boxes[i, 1], boxes[rest, 1])
        yy1 = np.minimum(boxes[i, 2], boxes[rest, 2])
        xx1 = np.minimum(boxes[i, 3], boxes[rest, 3])
        inter = np.maximum(0, yy1 - yy0) * np.maximum(0, xx1 - xx0)
        a_i = (boxes[i, 2] - boxes[i, 0]) * (boxes[i, 3] - boxes[i, 1])
        a_r = (boxes[rest, 2] - boxes[rest, 0]) * (boxes[rest, 3] - boxes[rest, 1])
        iou = inter / np.maximum(a_i + a_r - inter, 1e-9)
        order = rest[iou <= iou_thr]
    return keep


# -------------------------------------------- per-bucket compiled program

def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b if b > 1 else a


def _frame_hw(shape) -> Tuple[int, int]:
    """True (h, w) of a frame shape; raises on anything that is not an
    (H, W) gray or (H, W, 3) RGB frame."""
    if len(shape) == 3 and shape[-1] == 3:
        return int(shape[0]), int(shape[1])
    if len(shape) == 2:
        return int(shape[0]), int(shape[1])
    raise ValueError(
        f"expected an (H, W) gray or (H, W, 3) RGB frame, got shape "
        f"{tuple(shape)}")


class DecodeTables:
    """Static host-side decode geometry of one compiled program: the
    flattened box/scale tables and the top-k size. Built once per
    FrameProgram; identity hash/eq on purpose so it can ride as the
    aux data of the api-layer Detections pytree."""

    __slots__ = ("boxes", "scales", "k")

    def __init__(self, boxes: np.ndarray, scales: np.ndarray, k: int):
        self.boxes = boxes             # (N, 4) window boxes, frame coords
        self.scales = scales           # (N,) nominal pyramid scale per row
        self.k = k                     # top-k size


@dataclasses.dataclass(frozen=True)
class FrameProgram:
    """One compiled multi-scale program + its static decode tables."""

    fn: "jax.stages.Wrapped"       # (gray_pad, w, b, hw) -> (scores, idx, keep)
    boxes: np.ndarray              # (N, 4) window boxes in frame coords
    scales: np.ndarray             # (N,) nominal pyramid scale per row
    n_positions: int               # N: total window positions, all scales
    k: int                         # top-k size
    per_scale: Tuple[Tuple[float, int, int], ...] = ()
    #                (scale, score-map PH, score-map PW) per pyramid level
    raw: "Callable" = None         # unjitted fn -- what detect_batch vmaps
    tables: "DecodeTables" = None  # the boxes/scales/k above, as one holder


@lru_cache(maxsize=64)
def _frame_program(ph: int, pw: int, cfg: DetectorConfig) -> FrameProgram:
    """Build the compiled program for padded frame shape (ph, pw).

    Everything shape-dependent is static here: the per-scale pyramid
    shapes, the flattened box table (pure geometry -> numpy, baked as a
    jit constant for the device-side gather), and K.
    """
    hcfg = cfg.hog
    specs: List[Tuple[int, int, float]] = []
    for s in cfg.scales:
        sh, sw = int(ph * s), int(pw * s)
        if sh >= hcfg.window_h and sw >= hcfg.window_w:
            specs.append((sh, sw, s))

    cell = hcfg.cell
    wbh, wbw = hcfg.blocks_hw                       # 15, 7 window blocks
    box_rows, scale_rows = [], []
    per_scale = []
    for sh, sw, s in specs:
        gh, gw = (sh - 2) // cell * cell, (sw - 2) // cell * cell
        sbh, sbw = gh // cell - hcfg.block + 1, gw // cell - hcfg.block + 1
        sph, spw = sbh - wbh + 1, sbw - wbw + 1     # score-map shape
        per_scale.append((s, sph, spw))
        # exact per-axis resize factor of the padded frame
        sy, sx = sh / ph, sw / pw
        ys, xs = np.mgrid[0:sph, 0:spw].astype(np.float64)
        y0, x0 = ys * cell / sy, xs * cell / sx
        boxes = np.stack([y0, x0, y0 + hcfg.window_h / sy,
                          x0 + hcfg.window_w / sx], axis=-1)
        box_rows.append(boxes.reshape(-1, 4).astype(np.float32))
        scale_rows.append(np.full(sph * spw, s, np.float32))

    if not box_rows:
        empty4 = np.zeros((0, 4), np.float32)
        empty1 = np.zeros((0,), np.float32)
        return FrameProgram(None, empty4, empty1, 0, 0, (),
                            tables=DecodeTables(empty4, empty1, 0))

    boxes_tab = np.concatenate(box_rows)
    scale_tab = np.concatenate(scale_rows)
    n = len(boxes_tab)
    k = min(cfg.max_detections, n)
    boxes_dev = jnp.asarray(boxes_tab)

    def fn(gray: Array, w: Array, b: Array, hw: Array):
        parts = []
        for sh, sw, _ in specs:
            g = gray if (sh, sw) == (ph, pw) else \
                jax.image.resize(gray, (sh, sw), "linear")
            parts.append(score_map(g, w, b, hcfg, cfg.backend).reshape(-1))
        scores = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        # windows must lie inside the TRUE (unpadded) frame and clear
        # the score threshold; both masks applied device-side
        inside = (boxes_dev[:, 2] <= hw[0] + 1e-4) \
            & (boxes_dev[:, 3] <= hw[1] + 1e-4)
        valid = inside & (scores > cfg.score_threshold)
        top, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
        keep = nms_keep(boxes_dev[idx], top, cfg.nms_iou)
        return top, idx, keep, jnp.sum(valid)

    return FrameProgram(jax.jit(fn), boxes_tab, scale_tab, n, k,
                        tuple(per_scale), fn,
                        tables=DecodeTables(boxes_tab, scale_tab, k))


@lru_cache(maxsize=64)
def _batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
              cfg: DetectorConfig) -> "jax.stages.Wrapped":
    """The per-bucket program vmapped over a stacked frame batch.

    One jit per (true-shape, shape-bucket, B) tuple: raw frames
    (B, h, w[, 3]) and the true (h, w) mask are batched, SVM params
    broadcast. Grayscale conversion and edge-pad to the bucket run
    INSIDE the program (uint8 stays on the wire; XLA fuses the luma
    into the gradient stage), so the host does zero per-frame prep
    dispatches. Keying on the true shape is the price of the fused
    prep: uniform batches of DIFFERENT true shapes in one bucket
    compile separate programs (bounded by the lru cache and, in
    practice, by the handful of camera geometries a deployment sees);
    mixed-shape batches take the pre-padded host path, which reuses
    the single (bucket, B) program. The batch axis is mapped in `cfg.batch_chunk`-wide
    vmapped chunks (lax.map): chunk 1 scans frame-by-frame, which keeps
    each frame's pyramid resident in cache and measures ~10-15% faster
    than sequential dispatch on the 2-core CPU host; chunk >= B is one
    fully vectorized vmap step, the layout for wide accelerators.
    Returns None when the bucket is too small for even one window (same
    as the single path).
    """
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        g = grayscale(frame) if frame.ndim == 3 else \
            frame.astype(jnp.float32)
        if (ph, pw) != (h, w):
            g = jnp.pad(g, ((0, ph - h), (0, pw - w)), mode="edge")
        return base.raw(g, wv, bv, hw)

    chunk = max(1, cfg.batch_chunk)
    if chunk >= batch:
        return jax.jit(jax.vmap(one, in_axes=(0, None, None, 0)))

    def fn(frames_b: Array, wv: Array, bv: Array, hw_b: Array):
        return jax.lax.map(lambda fh: one(fh[0], wv, bv, fh[1]),
                           (frames_b, hw_b),
                           batch_size=chunk if chunk > 1 else None)

    return jax.jit(fn)


class FrameDetector:
    """Reusable handle: SVM params + config -> per-frame detections.

    Compiles once per frame-shape bucket (shape_bucket rounding), then
    every call on a same-bucket frame reuses the device program with no
    retrace; only the final box decode touches host numpy.
    """

    def __init__(self, svm: SVMParams, cfg: DetectorConfig = DetectorConfig()):
        self.svm = svm
        self.cfg = cfg

    def program_for(self, h: int, w: int) -> Tuple[FrameProgram, int, int]:
        b = max(1, self.cfg.shape_bucket)
        return _frame_program(_round_up(h, b), _round_up(w, b),
                              self.cfg), _round_up(h, b), _round_up(w, b)

    @staticmethod
    def _to_gray(image: Array) -> Array:
        _frame_hw(np.shape(image))
        gray = jnp.asarray(image)
        if gray.ndim == 3:
            gray = grayscale(gray)
        return gray.astype(jnp.float32)

    def bucket_for(self, frame) -> Tuple[int, int]:
        """Padded-bucket shape a frame would be served under; raises
        ValueError on malformed shapes. The one validation + bucketing
        contract shared with the serving microbatcher."""
        h, w = _frame_hw(np.shape(frame))
        _, ph, pw = self.program_for(h, w)
        return ph, pw

    @staticmethod
    def _pad_to(gray: Array, ph: int, pw: int) -> Array:
        h, w = int(gray.shape[0]), int(gray.shape[1])
        if (ph, pw) == (h, w):
            return gray
        # edge-replicate so downscaling does not bleed zeros into
        # the last valid windows near the pad seam
        return jnp.pad(gray, ((0, ph - h), (0, pw - w)), mode="edge")

    def detect_raw(self, image: Array) -> "Detections":
        """One frame -> device-resident typed Detections (api layer).

        Nothing syncs to host here: the result wraps the compiled
        program's top-k/keep tensors plus the static decode tables, and
        decodes lazily on first host access (`.to_list()` et al.).
        """
        from repro.api.results import Detections
        gray = self._to_gray(image)
        h, w = int(gray.shape[0]), int(gray.shape[1])
        prog, ph, pw = self.program_for(h, w)
        if prog.fn is None:
            return Detections.empty(prog.tables)
        top, idx, keep, n_valid = prog.fn(self._pad_to(gray, ph, pw),
                                          self.svm["w"], self.svm["b"],
                                          jnp.asarray([h, w], jnp.float32))
        return Detections(top, idx, keep, n_valid, prog.tables)

    def __call__(self, image: Array) -> List[dict]:
        """Legacy per-frame contract (list of dicts). Thin shim over
        `detect_raw` -- prefer `repro.api.DetectionSession.detect`,
        which returns the typed result without the forced host sync."""
        return self.detect_raw(image).to_list()

    def detect_batch_raw(self, frames) -> "Detections":
        """Batched frame path: B frames -> one batched Detections.

        `frames` is a stacked (B, H, W[, 3]) array or a sequence of
        frames. All frames must land in the SAME padded shape bucket
        (equal shapes always do; the serving microbatcher groups by
        bucket before calling) -- mixed buckets raise ValueError. The
        compiled program is the single-frame pyramid program vmapped
        over the batch, jitted once per (bucket, B) pair; per-frame
        top-k + NMS run device-side and the host never syncs until the
        result is decoded.
        """
        from repro.api.results import Detections
        if isinstance(frames, (list, tuple)) and not frames:
            return Detections.empty_batch(
                DecodeTables(np.zeros((0, 4), np.float32),
                             np.zeros((0,), np.float32), 0), 0)
        uniform = not isinstance(frames, (list, tuple)) or \
            len({np.shape(f) for f in frames}) == 1
        if uniform:
            batch = np.stack([np.asarray(f) for f in frames]) \
                if isinstance(frames, (list, tuple)) else frames
            shape = tuple(np.shape(batch))
            if not isinstance(frames, (list, tuple)) \
                    and len(shape) == 3 and shape[-1] == 3:
                # a bare (H, W, 3) RGB frame would silently parse as H
                # gray frames of width 3 -- an ambiguity no caller wants
                raise ValueError(
                    f"shape {shape} looks like a single RGB frame; pass "
                    f"a list of frames or a stacked (B, H, W[, 3]) array")
            if not (len(shape) == 3
                    or (len(shape) == 4 and shape[-1] == 3)):
                raise ValueError(
                    f"expected (B, H, W[, 3]) stacked frames, got shape "
                    f"{shape}")
            n, h, w = int(shape[0]), int(shape[1]), int(shape[2])
            if n == 0:
                return Detections.empty_batch(
                    DecodeTables(np.zeros((0, 4), np.float32),
                                 np.zeros((0,), np.float32), 0), 0)
            hws = [(h, w)] * n
        else:
            # mixed true sizes: grayscale + pad per frame on host, then
            # hand the batched program a uniform pre-padded gray stack
            grays = [self._to_gray(f) for f in frames]
            n = len(grays)
            hws = [(int(g.shape[0]), int(g.shape[1])) for g in grays]
        buckets = {self.program_for(h, w)[1:] for h, w in hws}
        if len(buckets) != 1:
            raise ValueError(
                f"detect_batch needs one shape bucket per call, got "
                f"{sorted(buckets)}; group frames by bucket first")
        prog, ph, pw = self.program_for(*hws[0])
        if prog.fn is None:
            return Detections.empty_batch(prog.tables, n)
        if uniform:
            fn = _batch_fn(h, w, ph, pw, n, self.cfg)
            frames_b = jnp.asarray(batch)
        else:
            fn = _batch_fn(ph, pw, ph, pw, n, self.cfg)
            frames_b = jnp.stack([self._pad_to(g, ph, pw) for g in grays])
        hw_b = jnp.asarray(hws, jnp.float32)
        top, idx, keep, n_valid = fn(frames_b, self.svm["w"],
                                     self.svm["b"], hw_b)
        return Detections(top, idx, keep, n_valid, prog.tables)

    def detect_batch(self, frames) -> List[List[dict]]:
        """Legacy batched contract (B per-frame dict lists, one host
        sync). Thin shim over `detect_batch_raw`."""
        return self.detect_batch_raw(frames).to_list()


def detect(image_rgb: Array, svm: SVMParams,
           cfg: DetectorConfig = DetectorConfig()) -> List[dict]:
    """Multi-scale detection. Returns [{box:(y0,x0,y1,x1), score, scale}]
    sorted by descending score (top-k order).

    Deprecated shim: the unified entry point is
    `repro.api.DetectionSession.detect`, which reuses one session's
    compiled programs across calls and returns typed Detections
    (equivalence pinned by tests/test_api_session.py).
    """
    return FrameDetector(svm, cfg)(image_rgb)
