"""Multi-scale sliding-window human detector -- device-resident end-to-end.

The paper's hardware detects a single fixed 130x66 window; multi-window /
multi-resolution detection is listed as "future development" (§VI). This
module is that future development, built TPU-natively on the staged HOG
pipeline (core/stages.py):

  * Block normalization (eq. 5) is *window-independent*, so the scene's
    normalized block grid is computed ONCE (dense layout, any backend:
    ref | kernel | fused) and shared by every window. A window's SVM
    score is a dot product between its 15x7 block patch and the weight
    tensor -- the whole score map is one valid-mode convolution that XLA
    lowers to MXU matmuls.
  * Multi-scale is ONE compiled program per frame-shape bucket: frames
    are padded up to a bucket shape, the image pyramid + dense scoring
    for every scale is unrolled inside a single jit, thresholding and
    top-k run device-side, and NMS is a vectorized matrix-IoU greedy
    pass (fori_loop over the fixed top-k, O(K) vector work per step --
    no O(N^2) host Python loop, no per-frame retrace).
  * Only box DECODE stays on host: top-k indices select rows of a
    static per-bucket box table (pure geometry, precomputed in numpy).

`detect()` keeps the original host-facing contract (list of dicts) with
one deliberate change: the device program considers at most
`max_detections` top-scoring candidates per frame (fixed K keeps the
shapes static); saturating that cap emits a RuntimeWarning.
`FrameDetector` is the reusable device-program handle the serving layer
uses (serve/engine.py full-frame requests).

The BATCHED path (`detect_batch`) vmaps the same per-bucket pyramid
program over a stacked (B, H, W) frame batch: one jit per
(true-shape, shape-bucket, B) tuple, per-frame top-k and NMS still
device-side, one host sync for the whole batch. The batch axis runs as a scanned map of
`batch_chunk`-wide vmapped chunks (chunk 1 = frame-at-a-time scan, the
fast layout on the CPU host; chunk >= B = one wide vmap for real
accelerators). Frames in a batch may differ in true size as long as
they share a padded bucket (the per-frame (h, w) mask rides along the
batch axis). This is the hot path the video/tracking layer
(core/video.py) and the serving microbatcher (serve/engine.py) sit on.

The SHARDED path layers multi-device data parallelism on top of the
batched one: with `cfg.data_parallel != 1` the frame batch is laid over
the 'data' axis of a 1-D device mesh (launch/mesh.py:make_detection_mesh)
and the per-bucket program runs under shard_map -- each device executes
the same scan-vs-vmap schedule on its local B/n_devices sub-batch, with
pyramid, scoring, top-k and NMS all device-local (no cross-device
collectives, no host round-trips). Batches that do not divide the mesh
are padded with zero frames whose true-size mask is (0, 0), so every
window of a pad frame fails the inside-frame test and decodes to an
empty result; the pad rows are sliced off before the Detections is
built. Per-frame results are byte-identical to the single-device path
(tests/test_sharded.py pins this per backend/numerics mode).

The TILED path adds intra-frame parallelism on top of both: with
`cfg.frame_parallel != 1`, frames whose padded bucket clears
`frame_parallel_min_area` split ONE frame's pyramid work over the
'tile' axis of a ('data', 'tile') mesh -- by row-slab of each scale's
score grid (exact descriptor halo) or by whole scale-groups
(cfg.tile_mode). Each tile emits a local top-k; an exact union re-rank
(core/tiling.py:merge_topk) plus one nms_keep pass reproduce the
untiled result box-identically (tests/test_tiled.py), taking worst-case
single-frame latency from one chip to all of them (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import numerics as N, quant
from repro.core.hog import HOGConfig, PAPER_HOG, grayscale
from repro.core.stages import dense_blocks
from repro.core.svm import SVMParams

Array = jax.Array


@lru_cache(maxsize=1)
def _donate() -> bool:
    """Whether the per-bucket programs request frame-buffer donation.

    jax ignores donation on the CPU backend (with a warning), so
    donate_argnums is only requested where it can take effect. On TPU
    the frame/gray buffers of the per-bucket programs are donated: a 4K
    f32 frame batch is the largest allocation on the hot path and
    reusing it as the program's scratch removes the double-buffering
    high-water mark. Evaluated lazily (first detect call, cached) --
    `jax.default_backend()` initializes the backend, which must not
    happen at import time, before the user picks a platform."""
    return jax.default_backend() != "cpu"


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    hog: HOGConfig = PAPER_HOG
    scales: Tuple[float, ...] = (1.0, 0.8, 0.64)
    score_threshold: float = 0.0          # sign(D(x)) per eq. (7)
    nms_iou: float = 0.3
    max_detections: int = 0               # device top-k size (K).
    #   0 = AUTO: K = min(n, max(256, ceil(n / 256))) grows with the
    #   window count n, so UHD-sized grids don't silently saturate
    #   while every pre-UHD bucket keeps the historical K=256 (n stays
    #   < 65536 there). n > 0 pins K exactly (the legacy behavior).
    backend: str = "ref"                  # stage backend for dense HOG
    shape_bucket: int = 32                # frames pad up to multiples of this
    batch_chunk: int = 0                  # detect_batch vmap width: frames
    #   per vmapped chunk inside the scanned batch program. 0 = AUTOTUNE:
    #   probe scan-vs-vmap per (bucket, B) at first use (min-of-k on
    #   synthetic frames) and cache the winner -- see autotune_report().
    #   1 = scan the batch frame-by-frame (best locality on CPU hosts);
    #   >= B = one fully vectorized vmap step (wide accelerators).
    #   Under data_parallel != 1 the chunk applies to each device's
    #   LOCAL sub-batch.
    data_parallel: int = 1                # devices on the batch axis:
    #   1 = single-device (the pre-sharding path, bit-for-bit),
    #   0 = every visible device, n > 1 = exactly n devices (ValueError
    #   when the host has fewer). detect_batch pads B up to a multiple
    #   of the mesh size with masked-out zero frames and runs the
    #   per-bucket program under shard_map over the 'data' mesh axis
    #   (launch/mesh.py:make_detection_mesh) -- see DESIGN.md §10.
    frame_parallel: int = 1               # devices tiling ONE frame's
    #   pyramid (intra-frame parallelism): 1 = off, 0 = every device
    #   left over after the batch axis (device_count // data_parallel),
    #   n > 1 = exactly n tiles. Frames whose padded bucket area
    #   (ph * pw) >= frame_parallel_min_area route to the tiled path:
    #   per-tile local top-k under shard_map over the 'tile' mesh axis
    #   (launch/mesh.py:make_tiled_mesh), then an exact union re-rank +
    #   one NMS pass -- box-identical to the untiled program
    #   (core/tiling.py, DESIGN.md §11). Composes with data_parallel as
    #   a 2-D (data, tile) schedule for batches.
    tile_mode: str = "slab"               # intra-frame decomposition:
    #   "slab" = row-slabs of each scale's score grid (halo recompute,
    #   balanced rows), "scale" = whole pyramid scales greedily balanced
    #   over tiles by window count (no halo, coarser balance).
    frame_parallel_min_area: int = 0      # only frames with bucket area
    #   ph * pw >= this use the tiled path; 0 = every frame (when
    #   frame_parallel resolves > 1). The "uhd" preset sets 1280*720 so
    #   small frames keep the cheaper untiled program.
    pyramid_resize: str = "matmul"        # pyramid resize arithmetic:
    #   "matmul" = dense two-matmul form (the PR 1-5 default; O(src)
    #   per output pixel), "banded" = the SAME interpolation weights
    #   applied as <= ~4 fixed-order multiply-adds per output pixel
    #   (core/tiling.py:resize_banded; O(taps) -- the UHD-fast form,
    #   and per-element, hence exactly tiling-invariant). The two modes
    #   differ only in float accumulation order (final-ulp score
    #   deltas); each mode is self-consistent, and tiled == untiled
    #   bitwise WITHIN either mode.
    class_thresholds: Tuple[float, ...] = ()  # per-head score thresholds
    #   for MULTI-HEAD scoring (svm["w"] of shape (K, F), see
    #   score_blocks): entry k gates head k's windows. () = every head
    #   uses score_threshold. Length must equal K when the program is
    #   traced with stacked params; baked static (part of the program
    #   cache key) exactly like score_threshold.


def scene_blocks(gray: Array, cfg: HOGConfig,
                 backend: str = "ref") -> Array:
    """Whole-scene normalized block grid: (H, W) -> (BH, BW, 36).

    Thin view over the dense layout of the staged pipeline; `backend`
    selects ref (pure jnp) or the dense-grid Pallas kernel/fused
    implementations (kernels/dense_grad_hist.py et al.).
    """
    return dense_blocks(gray, cfg, backend)


def score_blocks(blocks: Array, w: Array, b: Array,
                 cfg: HOGConfig = PAPER_HOG, use_kernel: bool = False) -> Array:
    """Score the dense block grid: (BH, BW, 36) -> (PH, PW).

    score[i, j] = <blocks[i:i+15, j:j+7, :], W> + b. Instead of a
    15x7x36 conv (which XLA:CPU runs ~6x slower than the equivalent
    matmul), the window sum factors through the per-offset partial
    products: ONE (BH*BW, 36) @ (36, 105) matmul computes every block
    position's contribution to each of the 105 window offsets on the
    MXU, then 105 shifted adds collate the score map. bf16 block
    descriptors (the perf preset) feed the matmul directly with f32
    accumulation. `use_kernel` routes the matmul through the Pallas
    kernel (kernels/svm_matmul.py:score_matmul) -- the MXU-explicit
    path used by the kernel/fused backends.

    MULTI-HEAD: `w` of shape (K, F) with `b` of shape (K,) scores K
    stacked SVM heads in the SAME matmul, widened to (36, 105*K) --
    near-free on the MXU, since the reduction dim (36) and the M rows
    are unchanged. Returns (K, PH, PW). Per-column arithmetic is
    untouched by the widening: each output column is an independent
    36-element dot product (int8 mode is exact integer accumulation;
    float modes keep per-column accumulation order), so head k's plane
    is byte-identical to scoring head k alone (tests/test_multihead.py
    pins this per numerics mode).
    """
    bh, bw = cfg.blocks_hw                              # 15, 7
    BH, BW, bd = blocks.shape
    ph, pw = BH - bh + 1, BW - bw + 1
    flat = blocks.reshape(BH * BW, bd)
    if w.ndim == 2:                                     # stacked (K, F) heads
        return _score_blocks_multi(flat, w, b, cfg, use_kernel,
                                   BH, BW, ph, pw)
    if N.spec_for(cfg).quantized:
        # fixed mode: the incoming grid is dequantized int8 (exactly
        # q * scale, numerics.finish_blocks), so requantizing recovers
        # the codes EXACTLY -- q/127 * max has relative error ~2^-22,
        # far inside rint's 0.5 margin -- and the one array that flowed
        # through every stage/tile/shard seam stays the public contract.
        # int8 x int8 -> int32 is exact, so scores are byte-identical
        # under any blocking; the rank-1 f32 rescale is elementwise with
        # a fixed multiply order (quant.rescale_scores).
        q, s_rows = quant.quantize_blocks(flat)
        wt = w.reshape(bh * bw, bd).T.astype(jnp.float32)
        wq, s_cols = quant.quantize_weight_columns(wt)
        if use_kernel:
            from repro.kernels.svm_matmul import score_matmul_int8
            ci = score_matmul_int8(q, wq)
        else:
            ci = jax.lax.dot_general(
                q, wq, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        contrib = quant.rescale_scores(ci, s_rows, s_cols)
    else:
        wt = w.reshape(bh * bw, bd).T.astype(blocks.dtype)  # (36, 105)
        if use_kernel:
            from repro.kernels.svm_matmul import score_matmul
            contrib = score_matmul(flat, wt)
        else:
            contrib = jax.lax.dot_general(
                flat, wt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    contrib = contrib.reshape(BH, BW, bh * bw)
    out = jnp.zeros((ph, pw), jnp.float32)
    for di in range(bh):                                # static 15x7 unroll
        for dj in range(bw):
            out = out + contrib[di:di + ph, dj:dj + pw, di * bw + dj]
    return out + b


def _score_blocks_multi(flat: Array, w: Array, b: Array, cfg: HOGConfig,
                        use_kernel: bool, BH: int, BW: int,
                        ph: int, pw: int) -> Array:
    """K stacked heads through one widened matmul: (BH*BW, 36) @
    (36, 105*K) -> (K, PH, PW). Weight columns are laid out head-major
    ((k, offset) = k*105 + offset), so column k*105+o carries exactly
    the column head k's single-head matmul would have at offset o --
    the per-column int8 quantization scales, and with them the int8
    codes, match the per-head path code for code. The shifted-add
    collate runs the same static 15x7 unroll per head plane, in the
    same accumulation order as the single-head path."""
    bh, bw = cfg.blocks_hw
    bd = flat.shape[-1]
    K = w.shape[0]
    if N.spec_for(cfg).quantized:
        q, s_rows = quant.quantize_blocks(flat)
        # (K, bh*bw, bd) -> (bd, K*bh*bw), head-major columns
        wt = w.reshape(K * bh * bw, bd).T.astype(jnp.float32)
        wq, s_cols = quant.quantize_weight_columns(wt)
        if use_kernel:
            from repro.kernels.svm_matmul import score_matmul_int8
            ci = score_matmul_int8(q, wq)
        else:
            ci = jax.lax.dot_general(
                q, wq, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        contrib = quant.rescale_scores(ci, s_rows, s_cols)
    else:
        wt = w.reshape(K * bh * bw, bd).T.astype(flat.dtype)
        if use_kernel:
            from repro.kernels.svm_matmul import score_matmul
            contrib = score_matmul(flat, wt)
        else:
            contrib = jax.lax.dot_general(
                flat, wt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    contrib = contrib.reshape(BH, BW, K, bh * bw)
    out = jnp.zeros((K, ph, pw), jnp.float32)
    for di in range(bh):                                # static 15x7 unroll
        for dj in range(bw):
            out = out + jnp.moveaxis(
                contrib[di:di + ph, dj:dj + pw, :, di * bw + dj], 2, 0)
    return out + b[:, None, None]


@partial(jax.jit, static_argnames=("cfg", "backend"))
def score_map(gray: Array, w: Array, b: Array,
              cfg: HOGConfig = PAPER_HOG, backend: str = "ref") -> Array:
    """Dense SVM score map at cell (8-px) stride. gray: (H, W) -> (PH, PW)."""
    blocks = scene_blocks(gray, cfg, backend)           # (BH, BW, 36)
    return score_blocks(blocks, w, b, cfg, use_kernel=(backend != "ref"))


# ------------------------------------------------------------------- NMS

def matrix_iou(a: Array, b: Array) -> Array:
    """Pairwise IoU. a: (N, 4), b: (M, 4) as (y0, x0, y1, x1) -> (N, M)."""
    y0 = jnp.maximum(a[:, None, 0], b[None, :, 0])
    x0 = jnp.maximum(a[:, None, 1], b[None, :, 1])
    y1 = jnp.minimum(a[:, None, 2], b[None, :, 2])
    x1 = jnp.minimum(a[:, None, 3], b[None, :, 3])
    inter = jnp.maximum(y1 - y0, 0.0) * jnp.maximum(x1 - x0, 0.0)
    area_a = (a[:, 2] - a[:, 0]) * (a[:, 3] - a[:, 1])
    area_b = (b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])
    return inter / jnp.maximum(area_a[:, None] + area_b[None, :] - inter,
                               1e-9)


def nms_keep(boxes: Array, scores: Array, iou_thr: float) -> Array:
    """Vectorized greedy NMS, device-resident.

    boxes (K, 4) must be sorted by descending score (lax.top_k order);
    entries with score == -inf are invalid and never kept. The IoU
    matrix is computed once; the greedy dependency runs as a fori_loop
    over the FIXED K with O(K) vector work per step, so the whole pass
    stays on device with a static shape -- exact same keep set as the
    host greedy reference (tests/test_stages_detector.py).
    """
    k = boxes.shape[0]
    iou = matrix_iou(boxes, boxes)
    valid = jnp.isfinite(scores)
    rank = jnp.arange(k)

    def body(i, keep):
        suppressed = jnp.any(keep & (iou[:, i] > iou_thr) & (rank < i))
        return keep.at[i].set(valid[i] & ~suppressed)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((k,), bool))


def _nms(boxes: np.ndarray, scores: np.ndarray, iou_thr: float) -> List[int]:
    """Greedy NMS on host -- the O(N^2) Python reference the vectorized
    `nms_keep` is validated against. boxes: (N, 4) as (y0, x0, y1, x1)."""
    order = np.argsort(-scores)
    keep: List[int] = []
    while order.size:
        i = order[0]
        keep.append(int(i))
        if order.size == 1:
            break
        rest = order[1:]
        yy0 = np.maximum(boxes[i, 0], boxes[rest, 0])
        xx0 = np.maximum(boxes[i, 1], boxes[rest, 1])
        yy1 = np.minimum(boxes[i, 2], boxes[rest, 2])
        xx1 = np.minimum(boxes[i, 3], boxes[rest, 3])
        inter = np.maximum(0, yy1 - yy0) * np.maximum(0, xx1 - xx0)
        a_i = (boxes[i, 2] - boxes[i, 0]) * (boxes[i, 3] - boxes[i, 1])
        a_r = (boxes[rest, 2] - boxes[rest, 0]) * (boxes[rest, 3] - boxes[rest, 1])
        iou = inter / np.maximum(a_i + a_r - inter, 1e-9)
        order = rest[iou <= iou_thr]
    return keep


# -------------------------------------------- per-bucket compiled program

def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b if b > 1 else a


def _resolve_k(cfg: DetectorConfig, n: int) -> int:
    """Top-k size for a program with n window positions. Auto mode
    (max_detections == 0) scales K with the grid so big frames don't
    silently saturate: K = max(256, ceil(n / 256)) clamped to n --
    exactly 256 for every bucket below ~65k windows (the historical
    constant), ~953 at 4K's 244k windows. An explicit max_detections
    pins K (legacy / memory-bound deployments)."""
    if cfg.max_detections:
        return min(cfg.max_detections, n)
    return min(n, max(256, -(-n // 256)))


@lru_cache(maxsize=256)
def _resize_weights(src: int, dst: int) -> np.ndarray:
    """(dst, src) row-weight matrix reproducing jax.image.resize's
    "linear" kernel (incl. its anti-aliasing taps when downscaling),
    extracted exactly by resizing the identity. Lets the pyramid
    resize run as two small matmuls -- same arithmetic as the
    gather-based resize but in MXU/BLAS form, ~30% faster on the CPU
    host and one fused op per axis on TPU."""
    import jax.image
    # first use may be inside a jit trace (resize_banded builds its tap
    # tables lazily from program bodies); escape it so the identity
    # resize runs eagerly and converts to a concrete array
    with jax.ensure_compile_time_eval():
        eye = jnp.eye(src, dtype=jnp.float32)
        return np.asarray(jax.image.resize(eye, (dst, src), "linear"))


def _frame_hw(shape) -> Tuple[int, int]:
    """True (h, w) of a frame shape; raises on anything that is not an
    (H, W) gray or (H, W, 3) RGB frame."""
    if len(shape) == 3 and shape[-1] == 3:
        return int(shape[0]), int(shape[1])
    if len(shape) == 2:
        return int(shape[0]), int(shape[1])
    raise ValueError(
        f"expected an (H, W) gray or (H, W, 3) RGB frame, got shape "
        f"{tuple(shape)}")


class DecodeTables:
    """Static host-side decode geometry of one compiled program: the
    flattened box/scale tables and the top-k size. Built once per
    FrameProgram; identity hash/eq on purpose so it can ride as the
    aux data of the api-layer Detections pytree."""

    __slots__ = ("boxes", "scales", "k")

    def __init__(self, boxes: np.ndarray, scales: np.ndarray, k: int):
        self.boxes = boxes             # (N, 4) window boxes, frame coords
        self.scales = scales           # (N,) nominal pyramid scale per row
        self.k = k                     # top-k size


@dataclasses.dataclass(frozen=True)
class FrameProgram:
    """One compiled multi-scale program + its static decode tables."""

    fn: "jax.stages.Wrapped"       # (gray_pad, w, b, hw) -> (scores, idx, keep)
    boxes: np.ndarray              # (N, 4) window boxes in frame coords
    scales: np.ndarray             # (N,) nominal pyramid scale per row
    n_positions: int               # N: total window positions, all scales
    k: int                         # top-k size
    per_scale: Tuple[Tuple[float, int, int], ...] = ()
    #                (scale, score-map PH, score-map PW) per pyramid level
    raw: "Callable" = None         # unjitted fn -- what detect_batch vmaps
    tables: "DecodeTables" = None  # the boxes/scales/k above, as one holder


@lru_cache(maxsize=64)
def _frame_program(ph: int, pw: int, cfg: DetectorConfig) -> FrameProgram:
    """Build the compiled program for padded frame shape (ph, pw).

    Everything shape-dependent is static here: the per-scale pyramid
    shapes, the flattened box table (pure geometry -> numpy, baked as a
    jit constant for the device-side gather), and K.
    """
    hcfg = cfg.hog
    specs: List[Tuple[int, int, float]] = []
    for s in cfg.scales:
        sh, sw = int(ph * s), int(pw * s)
        if sh >= hcfg.window_h and sw >= hcfg.window_w:
            specs.append((sh, sw, s))

    cell = hcfg.cell
    wbh, wbw = hcfg.blocks_hw                       # 15, 7 window blocks
    box_rows, scale_rows = [], []
    per_scale = []
    for sh, sw, s in specs:
        gh, gw = (sh - 2) // cell * cell, (sw - 2) // cell * cell
        sbh, sbw = gh // cell - hcfg.block + 1, gw // cell - hcfg.block + 1
        sph, spw = sbh - wbh + 1, sbw - wbw + 1     # score-map shape
        per_scale.append((s, sph, spw))
        # exact per-axis resize factor of the padded frame
        sy, sx = sh / ph, sw / pw
        ys, xs = np.mgrid[0:sph, 0:spw].astype(np.float64)
        y0, x0 = ys * cell / sy, xs * cell / sx
        boxes = np.stack([y0, x0, y0 + hcfg.window_h / sy,
                          x0 + hcfg.window_w / sx], axis=-1)
        box_rows.append(boxes.reshape(-1, 4).astype(np.float32))
        scale_rows.append(np.full(sph * spw, s, np.float32))

    if not box_rows:
        empty4 = np.zeros((0, 4), np.float32)
        empty1 = np.zeros((0,), np.float32)
        return FrameProgram(None, empty4, empty1, 0, 0, (),
                            tables=DecodeTables(empty4, empty1, 0))

    boxes_tab = np.concatenate(box_rows)
    scale_tab = np.concatenate(scale_rows)
    n = len(boxes_tab)
    k = _resolve_k(cfg, n)
    boxes_dev = jnp.asarray(boxes_tab)

    if cfg.pyramid_resize not in ("matmul", "banded"):
        raise ValueError(
            f"DetectorConfig.pyramid_resize={cfg.pyramid_resize!r}: "
            f"expected 'matmul' or 'banded'")
    banded = cfg.pyramid_resize == "banded"
    # per-scale resize as two matmuls (exact jax.image.resize weights,
    # baked as jit constants); the full-res gray is shared, so the
    # grayscale conversion + pyramid schedule run once per frame and
    # every scale's resize->stages->score chain hangs off one buffer.
    # Under pyramid_resize="banded" the same weights apply in band form
    # instead (tiling.resize_banded builds its own tables).
    resize_w = {} if banded else \
        {(sh, sw): (jnp.asarray(_resize_weights(ph, sh)),
                    jnp.asarray(_resize_weights(pw, sw)))
         for sh, sw, _ in specs if (sh, sw) != (ph, pw)}

    def fn(gray: Array, w: Array, b: Array, hw: Array):
        from repro.core.tiling import resize_banded
        multi = w.ndim == 2            # stacked (K, F) heads, static
        parts = []
        for sh, sw, _ in specs:
            if (sh, sw) == (ph, pw):
                g = gray
            elif banded:
                g = resize_banded(gray, sh, sw)
            else:
                wy, wx = resize_w[(sh, sw)]
                g = (wy @ gray) @ wx.T
            sm = score_map(g, w, b, hcfg, cfg.backend)
            parts.append(sm.reshape(sm.shape[0], -1) if multi
                         else sm.reshape(-1))
        scores = parts[0] if len(parts) == 1 \
            else jnp.concatenate(parts, axis=-1)
        # windows must lie inside the TRUE (unpadded) frame and clear
        # the score threshold; both masks applied device-side
        inside = (boxes_dev[:, 2] <= hw[0] + 1e-4) \
            & (boxes_dev[:, 3] <= hw[1] + 1e-4)
        if multi:
            kh = int(w.shape[0])
            if cfg.class_thresholds and len(cfg.class_thresholds) != kh:
                raise ValueError(
                    f"class_thresholds has {len(cfg.class_thresholds)} "
                    f"entries but the stacked params carry {kh} heads")
            thr = jnp.asarray(cfg.class_thresholds
                              or (cfg.score_threshold,) * kh, jnp.float32)
            valid = inside[None, :] & (scores > thr[:, None])
            top, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
            keep = jax.vmap(nms_keep, in_axes=(0, 0, None))(
                boxes_dev[idx], top, cfg.nms_iou)
            return top, idx, keep, jnp.sum(valid, axis=-1)
        valid = inside & (scores > cfg.score_threshold)
        top, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
        keep = nms_keep(boxes_dev[idx], top, cfg.nms_iou)
        return top, idx, keep, jnp.sum(valid)

    return FrameProgram(jax.jit(fn), boxes_tab, scale_tab, n, k,
                        tuple(per_scale), fn,
                        tables=DecodeTables(boxes_tab, scale_tab, k))


def _prep_frame(frame: Array, h: int, w: int, ph: int, pw: int) -> Array:
    """In-program frame prep shared by the single and batched programs:
    grayscale (RGB input only) + edge-pad to the bucket. Runs INSIDE
    the jit so uint8 stays on the wire, XLA fuses the luma into the
    gradient stage, and the conversion happens once per frame -- every
    pyramid scale then resizes the one gray buffer."""
    g = grayscale(frame) if frame.ndim == 3 else frame.astype(jnp.float32)
    if (ph, pw) != (h, w):
        g = jnp.pad(g, ((0, ph - h), (0, pw - w)), mode="edge")
    return g


@lru_cache(maxsize=64)
def _single_fn(h: int, w: int, ph: int, pw: int,
               cfg: DetectorConfig) -> "jax.stages.Wrapped":
    """The per-frame program with grayscale + pad fused in: raw frame
    (h, w[, 3]) -> (top, idx, keep, n_valid). One jit per (true-shape,
    bucket) pair; the frame buffer is donated on accelerators (the
    program owns it -- detect_raw hands over a fresh buffer)."""
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None

    def fn(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    return jax.jit(fn, donate_argnums=(0,) if _donate() else ())


@lru_cache(maxsize=64)
def _batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
              cfg: DetectorConfig, donate: bool = False
              ) -> "jax.stages.Wrapped":
    """The per-bucket program vmapped over a stacked frame batch.

    One jit per (true-shape, shape-bucket, B) tuple: raw frames
    (B, h, w[, 3]) and the true (h, w) mask are batched, SVM params
    broadcast. Grayscale conversion and edge-pad to the bucket run
    INSIDE the program (uint8 stays on the wire; XLA fuses the luma
    into the gradient stage), so the host does zero per-frame prep
    dispatches. Keying on the true shape is the price of the fused
    prep: uniform batches of DIFFERENT true shapes in one bucket
    compile separate programs (bounded by the lru cache and, in
    practice, by the handful of camera geometries a deployment sees);
    mixed-shape batches take the pre-padded host path, which reuses
    the single (bucket, B) program. The batch axis is mapped in `cfg.batch_chunk`-wide
    vmapped chunks (lax.map): chunk 1 scans frame-by-frame (keeps each
    frame's pyramid cache-resident on CPU hosts), chunk >= B is one
    fully vectorized vmap step (wide accelerators); cfg.batch_chunk==0
    resolves the choice by measurement BEFORE this cache is consulted
    (_autotune_chunk). `donate` hands the frame-stack buffer to the
    program on accelerators; the autotune probe passes False so its
    reused probe buffers stay valid. Returns None when the bucket is
    too small for even one window (same as the single path).
    """
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    donate_kw = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(_chunked_schedule(one, max(1, cfg.batch_chunk), batch),
                   **donate_kw)


def _chunked_schedule(one: Callable, chunk: int, batch: int) -> Callable:
    """The scan-vs-vmap batch schedule shared by the single-device
    program and each device of the sharded one: chunk >= batch is one
    wide vmap, otherwise a lax.map scan of chunk-wide vmapped steps
    (chunk 1 = plain frame-by-frame scan). ONE definition on purpose:
    the sharded path's byte-identity with the single-device path rests
    on both running exactly this schedule."""
    if chunk >= batch:
        return jax.vmap(one, in_axes=(0, None, None, 0))

    def fn(frames_b: Array, wv: Array, bv: Array, hw_b: Array):
        return jax.lax.map(lambda fh: one(fh[0], wv, bv, fh[1]),
                           (frames_b, hw_b),
                           batch_size=chunk if chunk > 1 else None)

    return fn


# ------------------------------------------------- sharded batch program

@lru_cache(maxsize=8)
def _detection_mesh(dp: int):
    """The 1-D 'data' mesh sharded programs run over, built once per
    device count (Mesh construction touches jax device state, so it is
    deferred to first sharded call and cached)."""
    from repro.launch.mesh import make_detection_mesh
    return make_detection_mesh(dp)


def _resolve_dp(cfg: DetectorConfig) -> int:
    """Resolve cfg.data_parallel to a concrete device count.

    1 stays 1 without initializing the backend (the single-device path
    must not pay a device query); 0 means every visible device; an
    explicit n > jax.device_count() is a config error, reported with
    the same clear message as the mesh builders."""
    dp = cfg.data_parallel
    if dp == 1:
        return 1
    n = jax.device_count()
    if dp == 0:
        return n
    if not 1 <= dp <= n:
        raise ValueError(
            f"DetectorConfig.data_parallel={dp}: the host has {n} "
            f"visible device(s) (jax.devices()); use 0 (= all) or a "
            f"value in [1, {n}]")
    return dp


@lru_cache(maxsize=64)
def _sharded_batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
                      dp: int, cfg: DetectorConfig, donate: bool = False
                      ) -> "jax.stages.Wrapped":
    """The per-bucket program sharded over the 'data' mesh axis.

    `batch` is the PADDED global batch (a multiple of `dp`; the caller
    pads with zero frames masked out via hw = (0, 0)). Each device runs
    the same chunked scan-vs-vmap schedule `_batch_fn` would run, on
    its local batch/dp sub-batch -- shard_map with data-sharded frames
    and hw mask, replicated SVM params, and data-sharded outputs. No
    collective touches the hot path: frames are independent, so the
    program is embarrassingly parallel and per-frame results stay
    byte-identical to the single-device path. One jit per (true-shape,
    bucket, B, dp) tuple. Returns None when the bucket is too small for
    even one window (same as the single/batched paths).
    """
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None
    assert batch % dp == 0, (batch, dp)
    local = batch // dp
    mesh = _detection_mesh(dp)

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    local_fn = _chunked_schedule(one, max(1, cfg.batch_chunk), local)
    data = P("data")
    # check_vma=False: pallas_call (kernel/fused backends) has no
    # replication rule, and the program is embarrassingly parallel --
    # no collectives for the checker to validate anyway
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(data, P(), P(), data),
                   out_specs=(data, data, data, data),
                   check_vma=False)
    donate_kw = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(fn, **donate_kw)


# --------------------------------------------- intra-frame tiled program
# The frame-parallel path (DESIGN.md §11): one frame's pyramid work laid
# over the 'tile' axis of a (data, tile) mesh. Each tile runs a LOCAL
# program over the window positions it owns -- a row-slab of every
# scale's score grid (with an exact descriptor halo) or a whole
# scale-group -- and produces its local top-k; tiling.merge_topk then
# re-ranks the union exactly and ONE nms_keep pass over the merged list
# reproduces the untiled keep set, so results are box-identical to the
# untiled program per backend/numerics mode (tests/test_tiled.py).


def _resolve_fp(cfg: DetectorConfig, dp: Optional[int] = None) -> int:
    """Resolve cfg.frame_parallel to a concrete tile count. 1 stays 1
    without initializing the backend (the untiled path must not pay a
    device query); 0 means every device left over after the batch axis
    (device_count // data_parallel, at least 1); an explicit n must fit
    the host together with the data axis."""
    fp = cfg.frame_parallel
    if fp == 1:
        return 1
    if dp is None:
        dp = _resolve_dp(cfg)
    n = jax.device_count()
    if fp == 0:
        return max(1, n // dp)
    if fp < 1 or dp * fp > n:
        raise ValueError(
            f"DetectorConfig.frame_parallel={fp}: with data_parallel="
            f"{dp} the host's {n} visible device(s) allow at most "
            f"{max(1, n // dp)} tiles; use 0 (= all remaining) or a "
            f"value in [1, {max(1, n // dp)}]")
    return fp


@lru_cache(maxsize=8)
def _tile_mesh(dp: int, fp: int):
    """The 2-D ('data', 'tile') mesh tiled programs run over (deferred
    + cached like _detection_mesh)."""
    from repro.launch.mesh import make_tiled_mesh
    return make_tiled_mesh(dp, fp)


@lru_cache(maxsize=64)
def _tile_local_fn(ph: int, pw: int, fp: int,
                   cfg: DetectorConfig) -> Optional[Callable]:
    """One tile's local program: (gray_pad, w, b, hw) -> (top, idx,
    n_valid_local), where top/idx are the tile's LOCAL top-k over the
    global K (scores descending, -inf padded; idx = global flat window
    index, n for phantom rows) and the tile id comes from
    lax.axis_index('tile') -- one SPMD program for all tiles.

    tile_mode="slab": every scale is split into row-slabs of its score
    grid. A tile owning `slab` score rows computes hs = (slab + wbh +
    block - 2) * cell + 2 scaled-pixel rows starting at its cell-aligned
    offset d * slab * cell -- the (wbh + block - 2) cell-row descriptor
    halo plus the 2-px gradient border -- so every owned descriptor is
    built from exactly the pixels the untiled program uses. The resize
    tables (band or matmul row-weights) are zero-extended so the last
    tile's overhang computes exact zeros, and overhang score rows are
    masked to (-inf, idx=n) phantoms.

    tile_mode="scale": pyramid scales are greedily balanced over tiles
    by window count (tiling.scale_groups; groups may be empty) and each
    tile computes its scales FULL-frame with the exact expressions the
    untiled program uses, via one lax.switch on the tile id.

    Box-identity of the merged result rests on the tiling invariance of
    the per-tile arithmetic: banded resize is per-element; the matmul
    resize runs the full untiled product per tile and slices only
    RESULT rows (shape-dependent GEMM blocking makes anything less
    non-bitwise, see the inline note); the dense HOG
    stages are per-cell/per-block local; and local lists keep ascending
    global index among equal scores (see tiling.merge_topk).
    """
    from repro.core import tiling
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None
    hcfg = cfg.hog
    cell = hcfg.cell
    n, k = base.n_positions, base.k
    boxes_dev = jnp.asarray(base.boxes)
    thr = cfg.score_threshold
    banded = cfg.pyramid_resize == "banded"
    if cfg.tile_mode not in ("slab", "scale"):
        raise ValueError(
            f"DetectorConfig.tile_mode={cfg.tile_mode!r}: expected "
            f"'slab' or 'scale'")

    # per_scale is the untiled program's own geometry; rebuild each
    # scale's pixel shape and flat-index base from it so both paths
    # index the one box table identically
    specs = []
    off = 0
    for s, sph, spw in base.per_scale:
        sh, sw = int(ph * s), int(pw * s)
        specs.append((sh, sw, s, sph, spw, off))
        off += sph * spw
    assert off == n, (off, n)

    def _finish(parts_s, parts_i, nv):
        s_all = parts_s[0] if len(parts_s) == 1 else jnp.concatenate(parts_s)
        i_all = parts_i[0] if len(parts_i) == 1 else jnp.concatenate(parts_i)
        if s_all.shape[0] < k:
            padn = k - s_all.shape[0]
            s_all = jnp.concatenate(
                [s_all, jnp.full((padn,), -jnp.inf, s_all.dtype)])
            i_all = jnp.concatenate(
                [i_all, jnp.full((padn,), n, jnp.int32)])
        top, pos = jax.lax.top_k(s_all, k)
        return top, i_all[pos], nv

    if cfg.tile_mode == "slab":
        plans = []
        for sh, sw, s, sph, spw, base_i in specs:
            slab = tiling.slab_rows(sph, fp)
            hs = tiling.slab_pixel_rows(slab, hcfg)
            # resize tables must cover the LAST tile's slab window;
            # rows past the scaled image are zero-weight (exact zeros)
            L = max(sh, (fp - 1) * slab * cell + hs)
            p = dict(sph=sph, spw=spw, base=base_i, slab=slab, hs=hs)
            if (sh, sw) == (ph, pw):
                p["mode"] = "direct"
                p["L"] = L
            elif banded:
                lo_r, w_r = tiling.extend_band(
                    *tiling.band_weights(ph, sh), L)
                p.update(mode="banded", lo_r=jnp.asarray(lo_r),
                         w_r=jnp.asarray(w_r),
                         col=(tiling.band_weights(pw, sw)
                              if sw != pw else None))
            else:
                # full-shape weights: the tile runs the EXACT untiled
                # matmul and slices output rows after (see `local`)
                p.update(mode="matmul", sh=sh, L=L,
                         wy=jnp.asarray(_resize_weights(ph, sh)),
                         wx=(jnp.asarray(_resize_weights(pw, sw))
                             if sw != pw else None))
            plans.append(p)

        def local(gray: Array, wv: Array, bv: Array, hw: Array):
            d = jax.lax.axis_index("tile")
            parts_s, parts_i = [], []
            nv = jnp.zeros((), jnp.int32)
            for p in plans:
                slab, hs, spw = p["slab"], p["hs"], p["spw"]
                poff = d * (slab * cell)        # cell-aligned pixel base
                if p["mode"] == "direct":
                    g_ext = jnp.pad(gray, ((0, p["L"] - ph), (0, 0)))
                    gs = jax.lax.dynamic_slice(g_ext, (poff, 0), (hs, pw))
                elif p["mode"] == "banded":
                    lo_loc = jax.lax.dynamic_slice(p["lo_r"], (poff,), (hs,))
                    w_loc = jax.lax.dynamic_slice(
                        p["w_r"], (poff, 0), (hs, p["w_r"].shape[1]))
                    g_pad = jnp.pad(gray, ((0, p["w_r"].shape[1]), (0, 0)))
                    gs = tiling.band_rows(g_pad, lo_loc, w_loc)
                    if p["col"] is not None:
                        lo_c, w_c = p["col"]
                        gs = tiling.band_cols(
                            jnp.pad(gs, ((0, 0), (0, w_c.shape[1]))),
                            jnp.asarray(lo_c), jnp.asarray(w_c))
                else:
                    # matmul resize is NOT sliceable on its reduction
                    # OR output rows pre-hoc: XLA picks GEMM blocking
                    # (and with it the fp32 accumulation order) from
                    # the operand shapes, so a (hs, ph) slice of wy can
                    # produce different low bits than the same rows of
                    # the full product. Run the untiled expression
                    # verbatim and slice the RESULT -- data movement
                    # only, bitwise by construction. Tiling then buys
                    # no resize savings in this mode (the banded mode
                    # is the performance path); it stays for parity.
                    gs = p["wy"] @ gray
                    if p["wx"] is not None:
                        gs = gs @ p["wx"].T
                    gs = jnp.pad(gs, ((0, p["L"] - p["sh"]), (0, 0)))
                    gs = jax.lax.dynamic_slice(
                        gs, (poff, 0), (hs, gs.shape[1]))
                smap = score_map(gs, wv, bv, hcfg, cfg.backend)
                rows = d * slab + jnp.arange(slab, dtype=jnp.int32)
                idx = (p["base"] + rows[:, None] * spw
                       + jnp.arange(spw, dtype=jnp.int32)[None, :]
                       ).reshape(-1)
                owned = jnp.repeat(rows < p["sph"], spw)
                bx = boxes_dev[idx]             # gather clamps overhang
                inside = (bx[:, 2] <= hw[0] + 1e-4) \
                    & (bx[:, 3] <= hw[1] + 1e-4)
                valid = owned & inside & (smap.reshape(-1) > thr)
                parts_s.append(jnp.where(valid, smap.reshape(-1), -jnp.inf))
                parts_i.append(jnp.where(owned, idx, n))
                nv = nv + jnp.sum(valid)
            return _finish(parts_s, parts_i, nv)

        return local

    # tile_mode == "scale": whole scales per tile, one switch branch
    # per tile; every branch pads to the same candidate count
    groups = tiling.scale_groups(base.per_scale, fp)
    pmax = max([k] + [sum(sph * spw for _, sph, spw in
                          (base.per_scale[i] for i in g)) for g in groups])
    rw = {} if banded else \
        {(sh, sw): (jnp.asarray(_resize_weights(ph, sh)),
                    jnp.asarray(_resize_weights(pw, sw)))
         for sh, sw, _, _, _, _ in specs if (sh, sw) != (ph, pw)}

    def make_branch(group):
        gspecs = [specs[i] for i in group]

        def branch(gray: Array, wv: Array, bv: Array, hw: Array):
            parts_s = []
            parts_i = []
            nv = jnp.zeros((), jnp.int32)
            for sh, sw, s, sph, spw, base_i in gspecs:
                # exact same per-scale expressions as the untiled fn
                if (sh, sw) == (ph, pw):
                    g = gray
                elif banded:
                    g = tiling.resize_banded(gray, sh, sw)
                else:
                    wy, wx = rw[(sh, sw)]
                    g = (wy @ gray) @ wx.T
                flat = score_map(g, wv, bv, hcfg, cfg.backend).reshape(-1)
                bx = boxes_dev[base_i:base_i + sph * spw]
                inside = (bx[:, 2] <= hw[0] + 1e-4) \
                    & (bx[:, 3] <= hw[1] + 1e-4)
                valid = inside & (flat > thr)
                parts_s.append(jnp.where(valid, flat, -jnp.inf))
                parts_i.append(jnp.arange(base_i, base_i + sph * spw,
                                          dtype=jnp.int32))
                nv = nv + jnp.sum(valid)
            have = sum(sph * spw for _, _, _, sph, spw, _ in gspecs)
            if have < pmax:
                parts_s.append(jnp.full((pmax - have,), -jnp.inf))
                parts_i.append(jnp.full((pmax - have,), n, jnp.int32))
            return (parts_s[0] if len(parts_s) == 1
                    else jnp.concatenate(parts_s),
                    parts_i[0] if len(parts_i) == 1
                    else jnp.concatenate(parts_i),
                    nv)

        return branch

    branches = [make_branch(g) for g in groups]

    def local(gray: Array, wv: Array, bv: Array, hw: Array):
        d = jax.lax.axis_index("tile")
        s_all, i_all, nv = jax.lax.switch(d, branches, gray, wv, bv, hw)
        top, pos = jax.lax.top_k(s_all, k)
        return top, i_all[pos], nv

    return local


@lru_cache(maxsize=64)
def _tiled_single_fn(h: int, w: int, ph: int, pw: int, fp: int,
                     cfg: DetectorConfig) -> "jax.stages.Wrapped":
    """Single-frame tiled program: the per-tile local program under
    shard_map over the 'tile' axis (frame + SVM params replicated),
    stacked local top-k lists out, then ONE exact merge + NMS in the
    enclosing jit -- the merge runs once, not replicated per tile, which
    matters on hosts where forced devices share cores. Same signature
    and donation contract as _single_fn."""
    from repro.core.tiling import merge_topk
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None
    local = _tile_local_fn(ph, pw, fp, cfg)
    boxes_dev = jnp.asarray(base.boxes)
    mesh = _tile_mesh(1, fp)

    def tile_fn(gray: Array, wv: Array, bv: Array, hw: Array):
        t, i, v = local(gray, wv, bv, hw)
        return t[None], i[None], v[None]

    sm = shard_map(tile_fn, mesh=mesh,
                   in_specs=(P(), P(), P(), P()),
                   out_specs=(P("tile"), P("tile"), P("tile")),
                   check_vma=False)

    def fn(frame: Array, wv: Array, bv: Array, hw: Array):
        gray = _prep_frame(frame, h, w, ph, pw)
        tl, il, nl = sm(gray, wv, bv, hw)
        top, idx = merge_topk(tl, il, base.k)
        keep = nms_keep(boxes_dev[idx], top, cfg.nms_iou)
        return top, idx, keep, jnp.sum(nl)

    return jax.jit(fn, donate_argnums=(0,) if _donate() else ())


@lru_cache(maxsize=64)
def _tiled_batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
                    dp: int, fp: int, cfg: DetectorConfig,
                    donate: bool = False) -> "jax.stages.Wrapped":
    """Batched 2-D (data x tile) schedule: the frame batch is sharded
    over 'data' exactly as _sharded_batch_fn (zero-frame padding, same
    chunked scan-vs-vmap schedule per device column), and within each
    frame the pyramid runs tiled over 'tile'. The merge happens inside
    the shard_map per frame -- all_gather of the (k,) local lists plus a
    psum of the valid counts over 'tile' are the only collectives; NMS
    then runs on the merged list (replicated within a frame's tile row,
    sharded over 'data'). Per-frame results byte-identical to the
    untiled / tiled-single paths. One jit per (true-shape, bucket, B,
    dp, fp) tuple."""
    from repro.core.tiling import merge_topk
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None
    assert batch % dp == 0, (batch, dp)
    local_b = batch // dp
    local = _tile_local_fn(ph, pw, fp, cfg)
    boxes_dev = jnp.asarray(base.boxes)
    mesh = _tile_mesh(dp, fp)

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        gray = _prep_frame(frame, h, w, ph, pw)
        t, i, v = local(gray, wv, bv, hw)
        tl = jax.lax.all_gather(t, "tile")              # (fp, k)
        il = jax.lax.all_gather(i, "tile")
        nv = jax.lax.psum(v, "tile")
        top, idx = merge_topk(tl, il, base.k)
        keep = nms_keep(boxes_dev[idx], top, cfg.nms_iou)
        return top, idx, keep, nv

    local_fn = _chunked_schedule(one, max(1, cfg.batch_chunk), local_b)
    data = P("data")
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(data, P(), P(), data),
                   out_specs=(data, data, data, data),
                   check_vma=False)
    donate_kw = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(fn, **donate_kw)


# ------------------------------------------------- batch-chunk autotune
# The scan-vs-vmap layout choice used to be a hardcoded CPU/accelerator
# guess (batch_chunk=1 vs =B). It is now measured: the first
# detect_batch call on a new (true-shape, bucket, B) tuple probes each
# candidate schedule on synthetic frames (min-of-k wall time, donation
# off so the probe buffers survive), caches the winner for the process
# lifetime, and exposes the decisions through autotune_report() so the
# bench harness can record them in BENCH_detect.json.

_AUTOTUNE: dict = {}
_AUTOTUNE_PROBE_ITERS = 3


def _autotune_chunk(h: int, w: int, ph: int, pw: int, batch: int,
                    cfg: DetectorConfig, frame_shape: Tuple[int, ...],
                    frame_dtype, dp: int = 1, fp: int = 1,
                    heads: int = 0) -> int:
    import time

    from repro.core import autotune_cache
    layout = f"{'rgb' if len(frame_shape) == 4 else 'gray'}-{frame_dtype}"
    # `heads` rides at the END of the key so the k[7]/k[8] mesh indices
    # in _autotune_key_str stay valid for pre-existing entries; 0 = the
    # single-head (F,) parameter layout, K>0 = stacked (K, F) heads
    key = (h, w, ph, pw, batch, cfg, layout, dp, fp, heads)
    hit = _AUTOTUNE.get(key)
    if hit is not None:
        autotune_cache.note_memory_hit()
        return hit["chunk"]
    # under sharding the chunk schedules each device's LOCAL sub-batch
    local = batch // dp
    candidates = sorted({1, local} | ({4} if 1 < 4 < local else set()))
    if len(candidates) == 1:
        _AUTOTUNE[key] = {"chunk": candidates[0], "probe_ms": {}}
        return candidates[0]
    # a decision probed on an equivalent host may be on disk -- skip
    # the probe compiles entirely on warm starts (autotune_cache)
    dkey = autotune_cache.entry_key(_autotune_key_str(key), cfg)
    disk = autotune_cache.lookup(dkey)
    if disk is not None and disk["chunk"] in candidates:
        _AUTOTUNE[key] = {**disk, "source": "disk"}
        return disk["chunk"]
    # probe with the CALLER's frame layout (RGB uint8 vs gray f32, ...)
    # and the production donate flag, so the probe times -- and
    # pre-compiles -- the exact executable the real call will run,
    # grayscale conversion included. With donation active each probe
    # invocation hands over a fresh copy (the copy cost is symmetric
    # across candidates, so the scan-vs-vmap ranking is unaffected).
    frames = jnp.zeros(frame_shape, frame_dtype)
    donate = _donate()
    mk = (lambda: jnp.array(frames, copy=True)) if donate \
        else (lambda: frames)
    if heads:
        wv = jnp.zeros((heads, cfg.hog.n_features), jnp.float32)
        bv = jnp.zeros((heads,), jnp.float32)
    else:
        wv = jnp.zeros(cfg.hog.n_features, jnp.float32)
        bv = jnp.float32(0.0)
    hw_b = jnp.tile(jnp.asarray([h, w], jnp.float32), (batch, 1))
    probe_ms = {}
    for c in candidates:
        c_cfg = dataclasses.replace(cfg, batch_chunk=c)
        if fp > 1:
            fn = _tiled_batch_fn(h, w, ph, pw, batch, dp, fp, c_cfg, donate)
        elif dp > 1:
            fn = _sharded_batch_fn(h, w, ph, pw, batch, dp, c_cfg, donate)
        else:
            fn = _batch_fn(h, w, ph, pw, batch, c_cfg, donate)
        jax.block_until_ready(fn(mk(), wv, bv, hw_b))     # compile
        best = float("inf")
        for _ in range(_AUTOTUNE_PROBE_ITERS):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(mk(), wv, bv, hw_b))
            best = min(best, time.perf_counter() - t0)
        probe_ms[c] = best * 1e3
    chunk = min(probe_ms, key=probe_ms.get)
    _AUTOTUNE[key] = {"chunk": chunk, "probe_ms": probe_ms,
                      "source": "probe"}
    autotune_cache.store(dkey, chunk, probe_ms)
    return chunk


def _autotune_key_str(k: tuple) -> str:
    mesh = f"data:{k[7]}" + (f",tile:{k[8]}" if k[8] > 1 else "")
    heads = f" heads:{k[9]}" if len(k) > 9 and k[9] else ""
    return f"{k[0]}x{k[1]}->{k[2]}x{k[3]} B={k[4]} mesh={mesh}{heads} [{k[6]}]"


def autotune_report() -> dict:
    """Chosen detect_batch schedules, keyed by the probed geometry,
    mesh and frame layout: {"HxW->PHxPW B=n mesh=data:d [rgb-uint8]":
    {"chunk": c, "probe_ms": {candidate: ms}, "source": ...}}. Every
    key carries the mesh layout (data:1 = the unsharded path; a
    ",tile:f" suffix marks the 2-D frame-parallel schedule) so BENCH
    entries stay unambiguous about which device layout a schedule was
    probed on; "source" says whether the decision was probed live or
    restored from the disk cache (core/autotune_cache.py)."""
    return {_autotune_key_str(k): dict(v) for k, v in _AUTOTUNE.items()}


class FrameDetector:
    """Reusable handle: SVM params + config -> per-frame detections.

    Compiles once per frame-shape bucket (shape_bucket rounding), then
    every call on a same-bucket frame reuses the device program with no
    retrace; only the final box decode touches host numpy.
    """

    def __init__(self, svm: SVMParams, cfg: Optional[DetectorConfig] = None,
                 classes: Optional[Tuple[str, ...]] = None):
        # default built per instance (never a shared default-arg object)
        self.svm = svm
        self.cfg = DetectorConfig() if cfg is None else cfg
        # stacked (K, F) params score K heads in one widened matmul; the
        # optional class names ride into every Detections this handle
        # builds so decoded boxes carry class_id/label
        self.heads = int(np.shape(svm["w"])[0]) \
            if np.ndim(svm["w"]) == 2 else 0
        if classes is not None and self.heads \
                and len(classes) != self.heads:
            raise ValueError(
                f"{len(classes)} class names for {self.heads} heads")
        self.classes = tuple(classes) if classes is not None else (
            tuple(f"head{i}" for i in range(self.heads))
            if self.heads else None)

    def program_for(self, h: int, w: int) -> Tuple[FrameProgram, int, int]:
        b = max(1, self.cfg.shape_bucket)
        return _frame_program(_round_up(h, b), _round_up(w, b),
                              self.cfg), _round_up(h, b), _round_up(w, b)

    @property
    def data_devices(self) -> int:
        """Resolved device count of the batch ('data') axis: 1 on the
        single-device path, the mesh size under sharding. The serving
        microbatcher scales its coalescing target by this."""
        return _resolve_dp(self.cfg)

    @property
    def frame_devices(self) -> int:
        """Resolved device count of the intra-frame ('tile') axis: 1
        when frame parallelism is off. Whether a given frame actually
        runs tiled also depends on frame_parallel_min_area (see
        _tiled_for)."""
        return _resolve_fp(self.cfg)

    def _tiled_for(self, ph: int, pw: int, dp: int = 1) -> int:
        """Tile count a (ph, pw)-bucket frame runs under: the resolved
        'tile' axis when the bucket clears the area threshold, else 1
        (the untiled program). The threshold is on the PADDED bucket
        area -- that is the compute the program actually does, and it
        keeps routing deterministic per program."""
        fp = _resolve_fp(self.cfg, dp)
        if fp > 1 and ph * pw >= self.cfg.frame_parallel_min_area:
            if self.heads:
                raise ValueError(
                    "multi-head (stacked) params do not compose with "
                    "frame_parallel tiling yet; run the stacked heads "
                    "with frame_parallel=1 (the data axis still shards)")
            return fp
        return 1

    @staticmethod
    def _to_gray(image: Array) -> Array:
        _frame_hw(np.shape(image))
        gray = jnp.asarray(image)
        if gray.ndim == 3:
            gray = grayscale(gray)
        return gray.astype(jnp.float32)

    def bucket_for(self, frame) -> Tuple[int, int]:
        """Padded-bucket shape a frame would be served under; raises
        ValueError on malformed shapes. The one validation + bucketing
        contract shared with the serving microbatcher."""
        h, w = _frame_hw(np.shape(frame))
        _, ph, pw = self.program_for(h, w)
        return ph, pw

    @staticmethod
    def _pad_to(gray: Array, ph: int, pw: int) -> Array:
        h, w = int(gray.shape[0]), int(gray.shape[1])
        if (ph, pw) == (h, w):
            return gray
        # edge-replicate so downscaling does not bleed zeros into
        # the last valid windows near the pad seam
        return jnp.pad(gray, ((0, ph - h), (0, pw - w)), mode="edge")

    def detect_raw(self, image: Array) -> "Detections":
        """One frame -> device-resident typed Detections (api layer).

        Nothing syncs to host here: the result wraps the compiled
        program's top-k/keep tensors plus the static decode tables, and
        decodes lazily on first host access (`.to_list()` et al.).
        Grayscale + pad run inside the program (one dispatch per frame,
        keyed on the true shape like the batch path), and the frame
        buffer is donated on accelerators.
        """
        from repro.api.results import Detections
        _frame_hw(np.shape(image))
        frame = jnp.asarray(image)
        h, w = int(frame.shape[0]), int(frame.shape[1])
        prog, ph, pw = self.program_for(h, w)
        if prog.fn is None:
            return Detections.empty(prog.tables, self.classes)
        if _donate() and isinstance(image, jax.Array):
            # the program donates its frame argument; a caller-owned
            # device buffer must not be invalidated under them
            frame = jnp.array(frame, copy=True)
        fp = self._tiled_for(ph, pw)
        fn = (_tiled_single_fn(h, w, ph, pw, fp, self.cfg) if fp > 1
              else _single_fn(h, w, ph, pw, self.cfg))
        top, idx, keep, n_valid = fn(frame, self.svm["w"], self.svm["b"],
                                     jnp.asarray([h, w], jnp.float32))
        return Detections(top, idx, keep, n_valid, prog.tables,
                          classes=self.classes)

    def __call__(self, image: Array) -> List[dict]:
        """Legacy per-frame contract (list of dicts). Thin shim over
        `detect_raw` -- prefer `repro.api.DetectionSession.detect`,
        which returns the typed result without the forced host sync."""
        return self.detect_raw(image).to_list()

    def detect_batch_raw(self, frames) -> "Detections":
        """Batched frame path: B frames -> one batched Detections.

        `frames` is a stacked (B, H, W[, 3]) array or a sequence of
        frames. All frames must land in the SAME padded shape bucket
        (equal shapes always do; the serving microbatcher groups by
        bucket before calling) -- mixed buckets raise ValueError. The
        compiled program is the single-frame pyramid program vmapped
        over the batch, jitted once per (bucket, B) pair; per-frame
        top-k + NMS run device-side and the host never syncs until the
        result is decoded. With `cfg.data_parallel != 1` the batch is
        padded to a multiple of the data mesh size (masked zero frames,
        sliced off the result) and runs sharded, B/n_devices frames per
        device -- per-frame results byte-identical to data_parallel=1.
        """
        from repro.api.results import Detections
        if isinstance(frames, (list, tuple)) and not frames:
            return Detections.empty_batch(
                DecodeTables(np.zeros((0, 4), np.float32),
                             np.zeros((0,), np.float32), 0), 0,
                self.classes)
        uniform = not isinstance(frames, (list, tuple)) or \
            len({np.shape(f) for f in frames}) == 1
        if uniform:
            batch = np.stack([np.asarray(f) for f in frames]) \
                if isinstance(frames, (list, tuple)) else frames
            shape = tuple(np.shape(batch))
            if not isinstance(frames, (list, tuple)) \
                    and len(shape) == 3 and shape[-1] == 3:
                # a bare (H, W, 3) RGB frame would silently parse as H
                # gray frames of width 3 -- an ambiguity no caller wants
                raise ValueError(
                    f"shape {shape} looks like a single RGB frame; pass "
                    f"a list of frames or a stacked (B, H, W[, 3]) array")
            if not (len(shape) == 3
                    or (len(shape) == 4 and shape[-1] == 3)):
                raise ValueError(
                    f"expected (B, H, W[, 3]) stacked frames, got shape "
                    f"{shape}")
            n, h, w = int(shape[0]), int(shape[1]), int(shape[2])
            if n == 0:
                return Detections.empty_batch(
                    DecodeTables(np.zeros((0, 4), np.float32),
                                 np.zeros((0,), np.float32), 0), 0,
                    self.classes)
            hws = [(h, w)] * n
        else:
            # mixed true sizes: grayscale + pad per frame on host, then
            # hand the batched program a uniform pre-padded gray stack
            grays = [self._to_gray(f) for f in frames]
            n = len(grays)
            hws = [(int(g.shape[0]), int(g.shape[1])) for g in grays]
        buckets = {self.program_for(h, w)[1:] for h, w in hws}
        if len(buckets) != 1:
            raise ValueError(
                f"detect_batch needs one shape bucket per call, got "
                f"{sorted(buckets)}; group frames by bucket first")
        prog, ph, pw = self.program_for(*hws[0])
        if prog.fn is None:
            return Detections.empty_batch(prog.tables, n,
                                          self.classes)
        th, tw = (h, w) if uniform else (ph, pw)
        if uniform:
            frames_b = jnp.asarray(batch)
        else:
            frames_b = jnp.stack([self._pad_to(g, ph, pw) for g in grays])
        cfg = self.cfg
        dp = _resolve_dp(cfg)
        n_pad = _round_up(n, dp) if dp > 1 else n
        if n_pad != n:
            # pad the batch up to the mesh's data size with zero frames
            # whose true-size mask is (0, 0): every window fails the
            # inside-frame test, so pad rows decode to empty results
            # and are sliced off below before the Detections is built
            pad = jnp.zeros((n_pad - n,) + tuple(frames_b.shape[1:]),
                            frames_b.dtype)
            frames_b = jnp.concatenate([frames_b, pad])
            hws = list(hws) + [(0, 0)] * (n_pad - n)
        fp = self._tiled_for(ph, pw, dp)
        if cfg.batch_chunk == 0:         # autotune scan-vs-vmap (first use)
            chunk = _autotune_chunk(th, tw, ph, pw, n_pad, cfg,
                                    tuple(frames_b.shape), frames_b.dtype,
                                    dp, fp, self.heads)
            cfg = dataclasses.replace(cfg, batch_chunk=chunk)
        if fp > 1:
            fn = _tiled_batch_fn(th, tw, ph, pw, n_pad, dp, fp, cfg,
                                 _donate())
        elif dp > 1:
            fn = _sharded_batch_fn(th, tw, ph, pw, n_pad, dp, cfg, _donate())
        else:
            fn = _batch_fn(th, tw, ph, pw, n_pad, cfg, _donate())
        if _donate() and n_pad == n and isinstance(frames, jax.Array):
            # the batched program donates its frame stack; only copy
            # when the caller handed us their own device buffer (lists,
            # numpy stacks and the pad concatenate above all produced a
            # fresh one already)
            frames_b = jnp.array(frames_b, copy=True)
        hw_b = jnp.asarray(hws, jnp.float32)
        top, idx, keep, n_valid = fn(frames_b, self.svm["w"],
                                     self.svm["b"], hw_b)
        if n_pad != n:                   # drop the masked pad rows
            top, idx, keep, n_valid = (top[:n], idx[:n], keep[:n],
                                       n_valid[:n])
        return Detections(top, idx, keep, n_valid, prog.tables,
                          classes=self.classes)

    def detect_batch(self, frames) -> List[List[dict]]:
        """Legacy batched contract (B per-frame dict lists, one host
        sync). Thin shim over `detect_batch_raw`."""
        return self.detect_batch_raw(frames).to_list()


def detect(image_rgb: Array, svm: SVMParams,
           cfg: Optional[DetectorConfig] = None) -> List[dict]:
    """Multi-scale detection. Returns [{box:(y0,x0,y1,x1), score, scale}]
    sorted by descending score (top-k order).

    Deprecated shim: the unified entry point is
    `repro.api.DetectionSession.detect`, which reuses one session's
    compiled programs across calls and returns typed Detections
    (equivalence pinned by tests/test_api_session.py).
    """
    return FrameDetector(svm, cfg)(image_rgb)
