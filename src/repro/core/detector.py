"""Multi-scale sliding-window human detector -- device-resident end-to-end.

The paper's hardware detects a single fixed 130x66 window; multi-window /
multi-resolution detection is listed as "future development" (§VI). This
module is that future development, built TPU-natively on the staged HOG
pipeline (core/stages.py):

  * Block normalization (eq. 5) is *window-independent*, so the scene's
    normalized block grid is computed ONCE (dense layout, any backend:
    ref | kernel | fused) and shared by every window. A window's SVM
    score is a dot product between its 15x7 block patch and the weight
    tensor -- the whole score map is one valid-mode convolution that XLA
    lowers to MXU matmuls.
  * Multi-scale is ONE compiled program per frame-shape bucket: frames
    are padded up to a bucket shape, the image pyramid + dense scoring
    for every scale is unrolled inside a single jit, thresholding and
    top-k run device-side, and NMS is a vectorized matrix-IoU greedy
    pass (fori_loop over the fixed top-k, O(K) vector work per step --
    no O(N^2) host Python loop, no per-frame retrace).
  * Only box DECODE stays on host: top-k indices select rows of a
    static per-bucket box table (pure geometry, precomputed in numpy).

`detect()` keeps the original host-facing contract (list of dicts) with
one deliberate change: the device program considers at most
`max_detections` top-scoring candidates per frame (fixed K keeps the
shapes static); saturating that cap emits a RuntimeWarning.
`FrameDetector` is the reusable device-program handle the serving layer
uses (serve/engine.py full-frame requests).

The BATCHED path (`detect_batch`) vmaps the same per-bucket pyramid
program over a stacked (B, H, W) frame batch: one jit per
(true-shape, shape-bucket, B) tuple, per-frame top-k and NMS still
device-side, one host sync for the whole batch. The batch axis runs as a scanned map of
`batch_chunk`-wide vmapped chunks (chunk 1 = frame-at-a-time scan, the
fast layout on the CPU host; chunk >= B = one wide vmap for real
accelerators). Frames in a batch may differ in true size as long as
they share a padded bucket (the per-frame (h, w) mask rides along the
batch axis). This is the hot path the video/tracking layer
(core/video.py) and the serving microbatcher (serve/engine.py) sit on.

The SHARDED path layers multi-device data parallelism on top of the
batched one: with `cfg.data_parallel != 1` the frame batch is laid over
the 'data' axis of a 1-D device mesh (launch/mesh.py:make_detection_mesh)
and the per-bucket program runs under shard_map -- each device executes
the same scan-vs-vmap schedule on its local B/n_devices sub-batch, with
pyramid, scoring, top-k and NMS all device-local (no cross-device
collectives, no host round-trips). Batches that do not divide the mesh
are padded with zero frames whose true-size mask is (0, 0), so every
window of a pad frame fails the inside-frame test and decodes to an
empty result; the pad rows are sliced off before the Detections is
built. Per-frame results are byte-identical to the single-device path
(tests/test_sharded.py pins this per backend/numerics mode).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.hog import HOGConfig, PAPER_HOG, grayscale
from repro.core.stages import dense_blocks
from repro.core.svm import SVMParams

Array = jax.Array


@lru_cache(maxsize=1)
def _donate() -> bool:
    """Whether the per-bucket programs request frame-buffer donation.

    jax ignores donation on the CPU backend (with a warning), so
    donate_argnums is only requested where it can take effect. On TPU
    the frame/gray buffers of the per-bucket programs are donated: a 4K
    f32 frame batch is the largest allocation on the hot path and
    reusing it as the program's scratch removes the double-buffering
    high-water mark. Evaluated lazily (first detect call, cached) --
    `jax.default_backend()` initializes the backend, which must not
    happen at import time, before the user picks a platform."""
    return jax.default_backend() != "cpu"


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    hog: HOGConfig = PAPER_HOG
    scales: Tuple[float, ...] = (1.0, 0.8, 0.64)
    score_threshold: float = 0.0          # sign(D(x)) per eq. (7)
    nms_iou: float = 0.3
    max_detections: int = 256             # device top-k size (K)
    backend: str = "ref"                  # stage backend for dense HOG
    shape_bucket: int = 32                # frames pad up to multiples of this
    batch_chunk: int = 0                  # detect_batch vmap width: frames
    #   per vmapped chunk inside the scanned batch program. 0 = AUTOTUNE:
    #   probe scan-vs-vmap per (bucket, B) at first use (min-of-k on
    #   synthetic frames) and cache the winner -- see autotune_report().
    #   1 = scan the batch frame-by-frame (best locality on CPU hosts);
    #   >= B = one fully vectorized vmap step (wide accelerators).
    #   Under data_parallel != 1 the chunk applies to each device's
    #   LOCAL sub-batch.
    data_parallel: int = 1                # devices on the batch axis:
    #   1 = single-device (the pre-sharding path, bit-for-bit),
    #   0 = every visible device, n > 1 = exactly n devices (ValueError
    #   when the host has fewer). detect_batch pads B up to a multiple
    #   of the mesh size with masked-out zero frames and runs the
    #   per-bucket program under shard_map over the 'data' mesh axis
    #   (launch/mesh.py:make_detection_mesh) -- see DESIGN.md §10.


def scene_blocks(gray: Array, cfg: HOGConfig,
                 backend: str = "ref") -> Array:
    """Whole-scene normalized block grid: (H, W) -> (BH, BW, 36).

    Thin view over the dense layout of the staged pipeline; `backend`
    selects ref (pure jnp) or the dense-grid Pallas kernel/fused
    implementations (kernels/dense_grad_hist.py et al.).
    """
    return dense_blocks(gray, cfg, backend)


def score_blocks(blocks: Array, w: Array, b: Array,
                 cfg: HOGConfig = PAPER_HOG, use_kernel: bool = False) -> Array:
    """Score the dense block grid: (BH, BW, 36) -> (PH, PW).

    score[i, j] = <blocks[i:i+15, j:j+7, :], W> + b. Instead of a
    15x7x36 conv (which XLA:CPU runs ~6x slower than the equivalent
    matmul), the window sum factors through the per-offset partial
    products: ONE (BH*BW, 36) @ (36, 105) matmul computes every block
    position's contribution to each of the 105 window offsets on the
    MXU, then 105 shifted adds collate the score map. bf16 block
    descriptors (the perf preset) feed the matmul directly with f32
    accumulation. `use_kernel` routes the matmul through the Pallas
    kernel (kernels/svm_matmul.py:score_matmul) -- the MXU-explicit
    path used by the kernel/fused backends.
    """
    bh, bw = cfg.blocks_hw                              # 15, 7
    BH, BW, bd = blocks.shape
    ph, pw = BH - bh + 1, BW - bw + 1
    flat = blocks.reshape(BH * BW, bd)
    wt = w.reshape(bh * bw, bd).T.astype(blocks.dtype)  # (36, 105)
    if use_kernel:
        from repro.kernels.svm_matmul import score_matmul
        contrib = score_matmul(flat, wt)
    else:
        contrib = jax.lax.dot_general(
            flat, wt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    contrib = contrib.reshape(BH, BW, bh * bw)
    out = jnp.zeros((ph, pw), jnp.float32)
    for di in range(bh):                                # static 15x7 unroll
        for dj in range(bw):
            out = out + contrib[di:di + ph, dj:dj + pw, di * bw + dj]
    return out + b


@partial(jax.jit, static_argnames=("cfg", "backend"))
def score_map(gray: Array, w: Array, b: Array,
              cfg: HOGConfig = PAPER_HOG, backend: str = "ref") -> Array:
    """Dense SVM score map at cell (8-px) stride. gray: (H, W) -> (PH, PW)."""
    blocks = scene_blocks(gray, cfg, backend)           # (BH, BW, 36)
    return score_blocks(blocks, w, b, cfg, use_kernel=(backend != "ref"))


# ------------------------------------------------------------------- NMS

def matrix_iou(a: Array, b: Array) -> Array:
    """Pairwise IoU. a: (N, 4), b: (M, 4) as (y0, x0, y1, x1) -> (N, M)."""
    y0 = jnp.maximum(a[:, None, 0], b[None, :, 0])
    x0 = jnp.maximum(a[:, None, 1], b[None, :, 1])
    y1 = jnp.minimum(a[:, None, 2], b[None, :, 2])
    x1 = jnp.minimum(a[:, None, 3], b[None, :, 3])
    inter = jnp.maximum(y1 - y0, 0.0) * jnp.maximum(x1 - x0, 0.0)
    area_a = (a[:, 2] - a[:, 0]) * (a[:, 3] - a[:, 1])
    area_b = (b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])
    return inter / jnp.maximum(area_a[:, None] + area_b[None, :] - inter,
                               1e-9)


def nms_keep(boxes: Array, scores: Array, iou_thr: float) -> Array:
    """Vectorized greedy NMS, device-resident.

    boxes (K, 4) must be sorted by descending score (lax.top_k order);
    entries with score == -inf are invalid and never kept. The IoU
    matrix is computed once; the greedy dependency runs as a fori_loop
    over the FIXED K with O(K) vector work per step, so the whole pass
    stays on device with a static shape -- exact same keep set as the
    host greedy reference (tests/test_stages_detector.py).
    """
    k = boxes.shape[0]
    iou = matrix_iou(boxes, boxes)
    valid = jnp.isfinite(scores)
    rank = jnp.arange(k)

    def body(i, keep):
        suppressed = jnp.any(keep & (iou[:, i] > iou_thr) & (rank < i))
        return keep.at[i].set(valid[i] & ~suppressed)

    return jax.lax.fori_loop(0, k, body, jnp.zeros((k,), bool))


def _nms(boxes: np.ndarray, scores: np.ndarray, iou_thr: float) -> List[int]:
    """Greedy NMS on host -- the O(N^2) Python reference the vectorized
    `nms_keep` is validated against. boxes: (N, 4) as (y0, x0, y1, x1)."""
    order = np.argsort(-scores)
    keep: List[int] = []
    while order.size:
        i = order[0]
        keep.append(int(i))
        if order.size == 1:
            break
        rest = order[1:]
        yy0 = np.maximum(boxes[i, 0], boxes[rest, 0])
        xx0 = np.maximum(boxes[i, 1], boxes[rest, 1])
        yy1 = np.minimum(boxes[i, 2], boxes[rest, 2])
        xx1 = np.minimum(boxes[i, 3], boxes[rest, 3])
        inter = np.maximum(0, yy1 - yy0) * np.maximum(0, xx1 - xx0)
        a_i = (boxes[i, 2] - boxes[i, 0]) * (boxes[i, 3] - boxes[i, 1])
        a_r = (boxes[rest, 2] - boxes[rest, 0]) * (boxes[rest, 3] - boxes[rest, 1])
        iou = inter / np.maximum(a_i + a_r - inter, 1e-9)
        order = rest[iou <= iou_thr]
    return keep


# -------------------------------------------- per-bucket compiled program

def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b if b > 1 else a


@lru_cache(maxsize=256)
def _resize_weights(src: int, dst: int) -> np.ndarray:
    """(dst, src) row-weight matrix reproducing jax.image.resize's
    "linear" kernel (incl. its anti-aliasing taps when downscaling),
    extracted exactly by resizing the identity. Lets the pyramid
    resize run as two small matmuls -- same arithmetic as the
    gather-based resize but in MXU/BLAS form, ~30% faster on the CPU
    host and one fused op per axis on TPU."""
    import jax.image
    eye = jnp.eye(src, dtype=jnp.float32)
    return np.asarray(jax.image.resize(eye, (dst, src), "linear"))


def _frame_hw(shape) -> Tuple[int, int]:
    """True (h, w) of a frame shape; raises on anything that is not an
    (H, W) gray or (H, W, 3) RGB frame."""
    if len(shape) == 3 and shape[-1] == 3:
        return int(shape[0]), int(shape[1])
    if len(shape) == 2:
        return int(shape[0]), int(shape[1])
    raise ValueError(
        f"expected an (H, W) gray or (H, W, 3) RGB frame, got shape "
        f"{tuple(shape)}")


class DecodeTables:
    """Static host-side decode geometry of one compiled program: the
    flattened box/scale tables and the top-k size. Built once per
    FrameProgram; identity hash/eq on purpose so it can ride as the
    aux data of the api-layer Detections pytree."""

    __slots__ = ("boxes", "scales", "k")

    def __init__(self, boxes: np.ndarray, scales: np.ndarray, k: int):
        self.boxes = boxes             # (N, 4) window boxes, frame coords
        self.scales = scales           # (N,) nominal pyramid scale per row
        self.k = k                     # top-k size


@dataclasses.dataclass(frozen=True)
class FrameProgram:
    """One compiled multi-scale program + its static decode tables."""

    fn: "jax.stages.Wrapped"       # (gray_pad, w, b, hw) -> (scores, idx, keep)
    boxes: np.ndarray              # (N, 4) window boxes in frame coords
    scales: np.ndarray             # (N,) nominal pyramid scale per row
    n_positions: int               # N: total window positions, all scales
    k: int                         # top-k size
    per_scale: Tuple[Tuple[float, int, int], ...] = ()
    #                (scale, score-map PH, score-map PW) per pyramid level
    raw: "Callable" = None         # unjitted fn -- what detect_batch vmaps
    tables: "DecodeTables" = None  # the boxes/scales/k above, as one holder


@lru_cache(maxsize=64)
def _frame_program(ph: int, pw: int, cfg: DetectorConfig) -> FrameProgram:
    """Build the compiled program for padded frame shape (ph, pw).

    Everything shape-dependent is static here: the per-scale pyramid
    shapes, the flattened box table (pure geometry -> numpy, baked as a
    jit constant for the device-side gather), and K.
    """
    hcfg = cfg.hog
    specs: List[Tuple[int, int, float]] = []
    for s in cfg.scales:
        sh, sw = int(ph * s), int(pw * s)
        if sh >= hcfg.window_h and sw >= hcfg.window_w:
            specs.append((sh, sw, s))

    cell = hcfg.cell
    wbh, wbw = hcfg.blocks_hw                       # 15, 7 window blocks
    box_rows, scale_rows = [], []
    per_scale = []
    for sh, sw, s in specs:
        gh, gw = (sh - 2) // cell * cell, (sw - 2) // cell * cell
        sbh, sbw = gh // cell - hcfg.block + 1, gw // cell - hcfg.block + 1
        sph, spw = sbh - wbh + 1, sbw - wbw + 1     # score-map shape
        per_scale.append((s, sph, spw))
        # exact per-axis resize factor of the padded frame
        sy, sx = sh / ph, sw / pw
        ys, xs = np.mgrid[0:sph, 0:spw].astype(np.float64)
        y0, x0 = ys * cell / sy, xs * cell / sx
        boxes = np.stack([y0, x0, y0 + hcfg.window_h / sy,
                          x0 + hcfg.window_w / sx], axis=-1)
        box_rows.append(boxes.reshape(-1, 4).astype(np.float32))
        scale_rows.append(np.full(sph * spw, s, np.float32))

    if not box_rows:
        empty4 = np.zeros((0, 4), np.float32)
        empty1 = np.zeros((0,), np.float32)
        return FrameProgram(None, empty4, empty1, 0, 0, (),
                            tables=DecodeTables(empty4, empty1, 0))

    boxes_tab = np.concatenate(box_rows)
    scale_tab = np.concatenate(scale_rows)
    n = len(boxes_tab)
    k = min(cfg.max_detections, n)
    boxes_dev = jnp.asarray(boxes_tab)

    # per-scale resize as two matmuls (exact jax.image.resize weights,
    # baked as jit constants); the full-res gray is shared, so the
    # grayscale conversion + pyramid schedule run once per frame and
    # every scale's resize->stages->score chain hangs off one buffer
    resize_w = {(sh, sw): (jnp.asarray(_resize_weights(ph, sh)),
                           jnp.asarray(_resize_weights(pw, sw)))
                for sh, sw, _ in specs if (sh, sw) != (ph, pw)}

    def fn(gray: Array, w: Array, b: Array, hw: Array):
        parts = []
        for sh, sw, _ in specs:
            if (sh, sw) == (ph, pw):
                g = gray
            else:
                wy, wx = resize_w[(sh, sw)]
                g = (wy @ gray) @ wx.T
            parts.append(score_map(g, w, b, hcfg, cfg.backend).reshape(-1))
        scores = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        # windows must lie inside the TRUE (unpadded) frame and clear
        # the score threshold; both masks applied device-side
        inside = (boxes_dev[:, 2] <= hw[0] + 1e-4) \
            & (boxes_dev[:, 3] <= hw[1] + 1e-4)
        valid = inside & (scores > cfg.score_threshold)
        top, idx = jax.lax.top_k(jnp.where(valid, scores, -jnp.inf), k)
        keep = nms_keep(boxes_dev[idx], top, cfg.nms_iou)
        return top, idx, keep, jnp.sum(valid)

    return FrameProgram(jax.jit(fn), boxes_tab, scale_tab, n, k,
                        tuple(per_scale), fn,
                        tables=DecodeTables(boxes_tab, scale_tab, k))


def _prep_frame(frame: Array, h: int, w: int, ph: int, pw: int) -> Array:
    """In-program frame prep shared by the single and batched programs:
    grayscale (RGB input only) + edge-pad to the bucket. Runs INSIDE
    the jit so uint8 stays on the wire, XLA fuses the luma into the
    gradient stage, and the conversion happens once per frame -- every
    pyramid scale then resizes the one gray buffer."""
    g = grayscale(frame) if frame.ndim == 3 else frame.astype(jnp.float32)
    if (ph, pw) != (h, w):
        g = jnp.pad(g, ((0, ph - h), (0, pw - w)), mode="edge")
    return g


@lru_cache(maxsize=64)
def _single_fn(h: int, w: int, ph: int, pw: int,
               cfg: DetectorConfig) -> "jax.stages.Wrapped":
    """The per-frame program with grayscale + pad fused in: raw frame
    (h, w[, 3]) -> (top, idx, keep, n_valid). One jit per (true-shape,
    bucket) pair; the frame buffer is donated on accelerators (the
    program owns it -- detect_raw hands over a fresh buffer)."""
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None

    def fn(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    return jax.jit(fn, donate_argnums=(0,) if _donate() else ())


@lru_cache(maxsize=64)
def _batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
              cfg: DetectorConfig, donate: bool = False
              ) -> "jax.stages.Wrapped":
    """The per-bucket program vmapped over a stacked frame batch.

    One jit per (true-shape, shape-bucket, B) tuple: raw frames
    (B, h, w[, 3]) and the true (h, w) mask are batched, SVM params
    broadcast. Grayscale conversion and edge-pad to the bucket run
    INSIDE the program (uint8 stays on the wire; XLA fuses the luma
    into the gradient stage), so the host does zero per-frame prep
    dispatches. Keying on the true shape is the price of the fused
    prep: uniform batches of DIFFERENT true shapes in one bucket
    compile separate programs (bounded by the lru cache and, in
    practice, by the handful of camera geometries a deployment sees);
    mixed-shape batches take the pre-padded host path, which reuses
    the single (bucket, B) program. The batch axis is mapped in `cfg.batch_chunk`-wide
    vmapped chunks (lax.map): chunk 1 scans frame-by-frame (keeps each
    frame's pyramid cache-resident on CPU hosts), chunk >= B is one
    fully vectorized vmap step (wide accelerators); cfg.batch_chunk==0
    resolves the choice by measurement BEFORE this cache is consulted
    (_autotune_chunk). `donate` hands the frame-stack buffer to the
    program on accelerators; the autotune probe passes False so its
    reused probe buffers stay valid. Returns None when the bucket is
    too small for even one window (same as the single path).
    """
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    donate_kw = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(_chunked_schedule(one, max(1, cfg.batch_chunk), batch),
                   **donate_kw)


def _chunked_schedule(one: Callable, chunk: int, batch: int) -> Callable:
    """The scan-vs-vmap batch schedule shared by the single-device
    program and each device of the sharded one: chunk >= batch is one
    wide vmap, otherwise a lax.map scan of chunk-wide vmapped steps
    (chunk 1 = plain frame-by-frame scan). ONE definition on purpose:
    the sharded path's byte-identity with the single-device path rests
    on both running exactly this schedule."""
    if chunk >= batch:
        return jax.vmap(one, in_axes=(0, None, None, 0))

    def fn(frames_b: Array, wv: Array, bv: Array, hw_b: Array):
        return jax.lax.map(lambda fh: one(fh[0], wv, bv, fh[1]),
                           (frames_b, hw_b),
                           batch_size=chunk if chunk > 1 else None)

    return fn


# ------------------------------------------------- sharded batch program

@lru_cache(maxsize=8)
def _detection_mesh(dp: int):
    """The 1-D 'data' mesh sharded programs run over, built once per
    device count (Mesh construction touches jax device state, so it is
    deferred to first sharded call and cached)."""
    from repro.launch.mesh import make_detection_mesh
    return make_detection_mesh(dp)


def _resolve_dp(cfg: DetectorConfig) -> int:
    """Resolve cfg.data_parallel to a concrete device count.

    1 stays 1 without initializing the backend (the single-device path
    must not pay a device query); 0 means every visible device; an
    explicit n > jax.device_count() is a config error, reported with
    the same clear message as the mesh builders."""
    dp = cfg.data_parallel
    if dp == 1:
        return 1
    n = jax.device_count()
    if dp == 0:
        return n
    if not 1 <= dp <= n:
        raise ValueError(
            f"DetectorConfig.data_parallel={dp}: the host has {n} "
            f"visible device(s) (jax.devices()); use 0 (= all) or a "
            f"value in [1, {n}]")
    return dp


@lru_cache(maxsize=64)
def _sharded_batch_fn(h: int, w: int, ph: int, pw: int, batch: int,
                      dp: int, cfg: DetectorConfig, donate: bool = False
                      ) -> "jax.stages.Wrapped":
    """The per-bucket program sharded over the 'data' mesh axis.

    `batch` is the PADDED global batch (a multiple of `dp`; the caller
    pads with zero frames masked out via hw = (0, 0)). Each device runs
    the same chunked scan-vs-vmap schedule `_batch_fn` would run, on
    its local batch/dp sub-batch -- shard_map with data-sharded frames
    and hw mask, replicated SVM params, and data-sharded outputs. No
    collective touches the hot path: frames are independent, so the
    program is embarrassingly parallel and per-frame results stay
    byte-identical to the single-device path. One jit per (true-shape,
    bucket, B, dp) tuple. Returns None when the bucket is too small for
    even one window (same as the single/batched paths).
    """
    base = _frame_program(ph, pw, cfg)
    if base.raw is None:
        return None
    assert batch % dp == 0, (batch, dp)
    local = batch // dp
    mesh = _detection_mesh(dp)

    def one(frame: Array, wv: Array, bv: Array, hw: Array):
        return base.raw(_prep_frame(frame, h, w, ph, pw), wv, bv, hw)

    local_fn = _chunked_schedule(one, max(1, cfg.batch_chunk), local)
    data = P("data")
    # check_vma=False: pallas_call (kernel/fused backends) has no
    # replication rule, and the program is embarrassingly parallel --
    # no collectives for the checker to validate anyway
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(data, P(), P(), data),
                   out_specs=(data, data, data, data),
                   check_vma=False)
    donate_kw = dict(donate_argnums=(0,)) if donate else {}
    return jax.jit(fn, **donate_kw)


# ------------------------------------------------- batch-chunk autotune
# The scan-vs-vmap layout choice used to be a hardcoded CPU/accelerator
# guess (batch_chunk=1 vs =B). It is now measured: the first
# detect_batch call on a new (true-shape, bucket, B) tuple probes each
# candidate schedule on synthetic frames (min-of-k wall time, donation
# off so the probe buffers survive), caches the winner for the process
# lifetime, and exposes the decisions through autotune_report() so the
# bench harness can record them in BENCH_detect.json.

_AUTOTUNE: dict = {}
_AUTOTUNE_PROBE_ITERS = 3


def _autotune_chunk(h: int, w: int, ph: int, pw: int, batch: int,
                    cfg: DetectorConfig, frame_shape: Tuple[int, ...],
                    frame_dtype, dp: int = 1) -> int:
    import time
    layout = f"{'rgb' if len(frame_shape) == 4 else 'gray'}-{frame_dtype}"
    key = (h, w, ph, pw, batch, cfg, layout, dp)
    hit = _AUTOTUNE.get(key)
    if hit is not None:
        return hit["chunk"]
    # under sharding the chunk schedules each device's LOCAL sub-batch
    local = batch // dp
    candidates = sorted({1, local} | ({4} if 1 < 4 < local else set()))
    if len(candidates) == 1:
        _AUTOTUNE[key] = {"chunk": candidates[0], "probe_ms": {}}
        return candidates[0]
    # probe with the CALLER's frame layout (RGB uint8 vs gray f32, ...)
    # and the production donate flag, so the probe times -- and
    # pre-compiles -- the exact executable the real call will run,
    # grayscale conversion included. With donation active each probe
    # invocation hands over a fresh copy (the copy cost is symmetric
    # across candidates, so the scan-vs-vmap ranking is unaffected).
    frames = jnp.zeros(frame_shape, frame_dtype)
    donate = _donate()
    mk = (lambda: jnp.array(frames, copy=True)) if donate \
        else (lambda: frames)
    wv = jnp.zeros(cfg.hog.n_features, jnp.float32)
    bv = jnp.float32(0.0)
    hw_b = jnp.tile(jnp.asarray([h, w], jnp.float32), (batch, 1))
    probe_ms = {}
    for c in candidates:
        c_cfg = dataclasses.replace(cfg, batch_chunk=c)
        fn = (_sharded_batch_fn(h, w, ph, pw, batch, dp, c_cfg, donate)
              if dp > 1 else
              _batch_fn(h, w, ph, pw, batch, c_cfg, donate))
        jax.block_until_ready(fn(mk(), wv, bv, hw_b))     # compile
        best = float("inf")
        for _ in range(_AUTOTUNE_PROBE_ITERS):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(mk(), wv, bv, hw_b))
            best = min(best, time.perf_counter() - t0)
        probe_ms[c] = best * 1e3
    chunk = min(probe_ms, key=probe_ms.get)
    _AUTOTUNE[key] = {"chunk": chunk, "probe_ms": probe_ms}
    return chunk


def autotune_report() -> dict:
    """Chosen detect_batch schedules, keyed by the probed geometry,
    mesh and frame layout: {"HxW->PHxPW B=n mesh=data:d [rgb-uint8]":
    {"chunk": c, "probe_ms": {candidate: ms}}}. Every key carries the
    mesh dimension (data:1 = the unsharded path) so BENCH entries stay
    unambiguous about which device layout a schedule was probed on."""
    return {f"{k[0]}x{k[1]}->{k[2]}x{k[3]} B={k[4]} mesh=data:{k[7]} "
            f"[{k[6]}]": dict(v)
            for k, v in _AUTOTUNE.items()}


class FrameDetector:
    """Reusable handle: SVM params + config -> per-frame detections.

    Compiles once per frame-shape bucket (shape_bucket rounding), then
    every call on a same-bucket frame reuses the device program with no
    retrace; only the final box decode touches host numpy.
    """

    def __init__(self, svm: SVMParams, cfg: Optional[DetectorConfig] = None):
        # default built per instance (never a shared default-arg object)
        self.svm = svm
        self.cfg = DetectorConfig() if cfg is None else cfg

    def program_for(self, h: int, w: int) -> Tuple[FrameProgram, int, int]:
        b = max(1, self.cfg.shape_bucket)
        return _frame_program(_round_up(h, b), _round_up(w, b),
                              self.cfg), _round_up(h, b), _round_up(w, b)

    @property
    def data_devices(self) -> int:
        """Resolved device count of the batch ('data') axis: 1 on the
        single-device path, the mesh size under sharding. The serving
        microbatcher scales its coalescing target by this."""
        return _resolve_dp(self.cfg)

    @staticmethod
    def _to_gray(image: Array) -> Array:
        _frame_hw(np.shape(image))
        gray = jnp.asarray(image)
        if gray.ndim == 3:
            gray = grayscale(gray)
        return gray.astype(jnp.float32)

    def bucket_for(self, frame) -> Tuple[int, int]:
        """Padded-bucket shape a frame would be served under; raises
        ValueError on malformed shapes. The one validation + bucketing
        contract shared with the serving microbatcher."""
        h, w = _frame_hw(np.shape(frame))
        _, ph, pw = self.program_for(h, w)
        return ph, pw

    @staticmethod
    def _pad_to(gray: Array, ph: int, pw: int) -> Array:
        h, w = int(gray.shape[0]), int(gray.shape[1])
        if (ph, pw) == (h, w):
            return gray
        # edge-replicate so downscaling does not bleed zeros into
        # the last valid windows near the pad seam
        return jnp.pad(gray, ((0, ph - h), (0, pw - w)), mode="edge")

    def detect_raw(self, image: Array) -> "Detections":
        """One frame -> device-resident typed Detections (api layer).

        Nothing syncs to host here: the result wraps the compiled
        program's top-k/keep tensors plus the static decode tables, and
        decodes lazily on first host access (`.to_list()` et al.).
        Grayscale + pad run inside the program (one dispatch per frame,
        keyed on the true shape like the batch path), and the frame
        buffer is donated on accelerators.
        """
        from repro.api.results import Detections
        _frame_hw(np.shape(image))
        frame = jnp.asarray(image)
        h, w = int(frame.shape[0]), int(frame.shape[1])
        prog, ph, pw = self.program_for(h, w)
        if prog.fn is None:
            return Detections.empty(prog.tables)
        if _donate() and isinstance(image, jax.Array):
            # the program donates its frame argument; a caller-owned
            # device buffer must not be invalidated under them
            frame = jnp.array(frame, copy=True)
        fn = _single_fn(h, w, ph, pw, self.cfg)
        top, idx, keep, n_valid = fn(frame, self.svm["w"], self.svm["b"],
                                     jnp.asarray([h, w], jnp.float32))
        return Detections(top, idx, keep, n_valid, prog.tables)

    def __call__(self, image: Array) -> List[dict]:
        """Legacy per-frame contract (list of dicts). Thin shim over
        `detect_raw` -- prefer `repro.api.DetectionSession.detect`,
        which returns the typed result without the forced host sync."""
        return self.detect_raw(image).to_list()

    def detect_batch_raw(self, frames) -> "Detections":
        """Batched frame path: B frames -> one batched Detections.

        `frames` is a stacked (B, H, W[, 3]) array or a sequence of
        frames. All frames must land in the SAME padded shape bucket
        (equal shapes always do; the serving microbatcher groups by
        bucket before calling) -- mixed buckets raise ValueError. The
        compiled program is the single-frame pyramid program vmapped
        over the batch, jitted once per (bucket, B) pair; per-frame
        top-k + NMS run device-side and the host never syncs until the
        result is decoded. With `cfg.data_parallel != 1` the batch is
        padded to a multiple of the data mesh size (masked zero frames,
        sliced off the result) and runs sharded, B/n_devices frames per
        device -- per-frame results byte-identical to data_parallel=1.
        """
        from repro.api.results import Detections
        if isinstance(frames, (list, tuple)) and not frames:
            return Detections.empty_batch(
                DecodeTables(np.zeros((0, 4), np.float32),
                             np.zeros((0,), np.float32), 0), 0)
        uniform = not isinstance(frames, (list, tuple)) or \
            len({np.shape(f) for f in frames}) == 1
        if uniform:
            batch = np.stack([np.asarray(f) for f in frames]) \
                if isinstance(frames, (list, tuple)) else frames
            shape = tuple(np.shape(batch))
            if not isinstance(frames, (list, tuple)) \
                    and len(shape) == 3 and shape[-1] == 3:
                # a bare (H, W, 3) RGB frame would silently parse as H
                # gray frames of width 3 -- an ambiguity no caller wants
                raise ValueError(
                    f"shape {shape} looks like a single RGB frame; pass "
                    f"a list of frames or a stacked (B, H, W[, 3]) array")
            if not (len(shape) == 3
                    or (len(shape) == 4 and shape[-1] == 3)):
                raise ValueError(
                    f"expected (B, H, W[, 3]) stacked frames, got shape "
                    f"{shape}")
            n, h, w = int(shape[0]), int(shape[1]), int(shape[2])
            if n == 0:
                return Detections.empty_batch(
                    DecodeTables(np.zeros((0, 4), np.float32),
                                 np.zeros((0,), np.float32), 0), 0)
            hws = [(h, w)] * n
        else:
            # mixed true sizes: grayscale + pad per frame on host, then
            # hand the batched program a uniform pre-padded gray stack
            grays = [self._to_gray(f) for f in frames]
            n = len(grays)
            hws = [(int(g.shape[0]), int(g.shape[1])) for g in grays]
        buckets = {self.program_for(h, w)[1:] for h, w in hws}
        if len(buckets) != 1:
            raise ValueError(
                f"detect_batch needs one shape bucket per call, got "
                f"{sorted(buckets)}; group frames by bucket first")
        prog, ph, pw = self.program_for(*hws[0])
        if prog.fn is None:
            return Detections.empty_batch(prog.tables, n)
        th, tw = (h, w) if uniform else (ph, pw)
        if uniform:
            frames_b = jnp.asarray(batch)
        else:
            frames_b = jnp.stack([self._pad_to(g, ph, pw) for g in grays])
        cfg = self.cfg
        dp = _resolve_dp(cfg)
        n_pad = _round_up(n, dp) if dp > 1 else n
        if n_pad != n:
            # pad the batch up to the mesh's data size with zero frames
            # whose true-size mask is (0, 0): every window fails the
            # inside-frame test, so pad rows decode to empty results
            # and are sliced off below before the Detections is built
            pad = jnp.zeros((n_pad - n,) + tuple(frames_b.shape[1:]),
                            frames_b.dtype)
            frames_b = jnp.concatenate([frames_b, pad])
            hws = list(hws) + [(0, 0)] * (n_pad - n)
        if cfg.batch_chunk == 0:         # autotune scan-vs-vmap (first use)
            chunk = _autotune_chunk(th, tw, ph, pw, n_pad, cfg,
                                    tuple(frames_b.shape), frames_b.dtype,
                                    dp)
            cfg = dataclasses.replace(cfg, batch_chunk=chunk)
        fn = (_sharded_batch_fn(th, tw, ph, pw, n_pad, dp, cfg, _donate())
              if dp > 1 else
              _batch_fn(th, tw, ph, pw, n_pad, cfg, _donate()))
        if _donate() and n_pad == n and isinstance(frames, jax.Array):
            # the batched program donates its frame stack; only copy
            # when the caller handed us their own device buffer (lists,
            # numpy stacks and the pad concatenate above all produced a
            # fresh one already)
            frames_b = jnp.array(frames_b, copy=True)
        hw_b = jnp.asarray(hws, jnp.float32)
        top, idx, keep, n_valid = fn(frames_b, self.svm["w"],
                                     self.svm["b"], hw_b)
        if n_pad != n:                   # drop the masked pad rows
            top, idx, keep, n_valid = (top[:n], idx[:n], keep[:n],
                                       n_valid[:n])
        return Detections(top, idx, keep, n_valid, prog.tables)

    def detect_batch(self, frames) -> List[List[dict]]:
        """Legacy batched contract (B per-frame dict lists, one host
        sync). Thin shim over `detect_batch_raw`."""
        return self.detect_batch_raw(frames).to_list()


def detect(image_rgb: Array, svm: SVMParams,
           cfg: Optional[DetectorConfig] = None) -> List[dict]:
    """Multi-scale detection. Returns [{box:(y0,x0,y1,x1), score, scale}]
    sorted by descending score (top-k order).

    Deprecated shim: the unified entry point is
    `repro.api.DetectionSession.detect`, which reuses one session's
    compiled programs across calls and returns typed Detections
    (equivalence pinned by tests/test_api_session.py).
    """
    return FrameDetector(svm, cfg)(image_rgb)
