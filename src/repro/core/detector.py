"""Multi-scale sliding-window human detector.

The paper's hardware detects a single fixed 130x66 window; multi-window /
multi-resolution detection is listed as "future development". This module
is that future development, built TPU-natively:

  * The paper's block normalization (eq. 5) is *window-independent* (each
    2x2-cell block normalizes by its own energy), so the scene's normalized
    block grid can be computed ONCE and shared by every window.
  * A window's SVM score is then a dot product between its 15x7 block
    patch and the weight tensor -- i.e. the whole score map is a single
    valid-mode convolution, which XLA lowers to MXU matmuls:
        scores = conv2d(blocks_(BH,BW,36), W_(15,7,36)) + b
    One conv scores every window position at 8-px stride simultaneously,
    amortizing HOG across overlapping windows (the classical dense-HOG
    trick; a large win over the paper's per-window recompute -- quantified
    in benchmarks/bench_timing.py).
  * Multi-scale: image pyramid via jax.image.resize, per-scale score maps,
    box extraction + NMS on host.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import (HOGConfig, PAPER_HOG, block_normalize,
                            cell_histograms, gradients, grayscale, _MAG_BIN)
from repro.core.svm import SVMParams

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    hog: HOGConfig = PAPER_HOG
    scales: Tuple[float, ...] = (1.0, 0.8, 0.64)
    score_threshold: float = 0.0          # sign(D(x)) per eq. (7)
    nms_iou: float = 0.3


def scene_blocks(gray: Array, cfg: HOGConfig) -> Array:
    """Whole-scene normalized block grid: (H, W) -> (BH, BW, 36)."""
    fx, fy = gradients(gray.astype(jnp.float32))
    mag, b = _MAG_BIN[cfg.mode](fx, fy, cfg.bins)
    # trim so the gradient field tiles into whole cells
    gh = (mag.shape[-2] // cfg.cell) * cfg.cell
    gw = (mag.shape[-1] // cfg.cell) * cfg.cell
    mag, b = mag[..., :gh, :gw], b[..., :gh, :gw]
    scene_cfg = dataclasses.replace(cfg, window_h=gh + 2, window_w=gw + 2)
    hist = cell_histograms(mag, b, scene_cfg)
    return block_normalize(hist, scene_cfg)


@partial(jax.jit, static_argnames=("cfg",))
def score_map(gray: Array, w: Array, b: Array,
              cfg: HOGConfig = PAPER_HOG) -> Array:
    """Dense SVM score map at 8-px stride. gray: (H, W) -> (PH, PW).

    score[i, j] = <blocks[i:i+15, j:j+7, :], W> + b  == valid conv.
    """
    blocks = scene_blocks(gray, cfg)                    # (BH, BW, 36)
    bh, bw = cfg.blocks_hw                              # 15, 7
    wk = w.reshape(bh, bw, cfg.block_dim)               # (15, 7, 36)
    out = jax.lax.conv_general_dilated(
        blocks[None],                                   # NHWC
        wk[..., None],                                  # HWIO (36 -> 1)
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0, :, :, 0] + b


def _nms(boxes: np.ndarray, scores: np.ndarray, iou_thr: float) -> List[int]:
    """Greedy NMS on host. boxes: (N, 4) as (y0, x0, y1, x1)."""
    order = np.argsort(-scores)
    keep: List[int] = []
    while order.size:
        i = order[0]
        keep.append(int(i))
        if order.size == 1:
            break
        rest = order[1:]
        yy0 = np.maximum(boxes[i, 0], boxes[rest, 0])
        xx0 = np.maximum(boxes[i, 1], boxes[rest, 1])
        yy1 = np.minimum(boxes[i, 2], boxes[rest, 2])
        xx1 = np.minimum(boxes[i, 3], boxes[rest, 3])
        inter = np.maximum(0, yy1 - yy0) * np.maximum(0, xx1 - xx0)
        a_i = (boxes[i, 2] - boxes[i, 0]) * (boxes[i, 3] - boxes[i, 1])
        a_r = (boxes[rest, 2] - boxes[rest, 0]) * (boxes[rest, 3] - boxes[rest, 1])
        iou = inter / np.maximum(a_i + a_r - inter, 1e-9)
        order = rest[iou <= iou_thr]
    return keep


def detect(image_rgb: Array, svm: SVMParams,
           cfg: DetectorConfig = DetectorConfig()) -> List[dict]:
    """Multi-scale detection. Returns [{box:(y0,x0,y1,x1), score, scale}]."""
    gray = grayscale(jnp.asarray(image_rgb))
    hh, ww = gray.shape
    hcfg = cfg.hog
    all_boxes, all_scores, all_scales = [], [], []
    for s in cfg.scales:
        sh, sw = int(hh * s), int(ww * s)
        if sh < hcfg.window_h or sw < hcfg.window_w:
            continue
        g = jax.image.resize(gray, (sh, sw), "linear")
        sm = np.asarray(score_map(g, svm["w"], svm["b"], hcfg))
        ys, xs = np.where(sm > cfg.score_threshold)
        for y, x in zip(ys, xs):
            y0, x0 = y * hcfg.cell / s, x * hcfg.cell / s
            all_boxes.append((y0, x0, y0 + hcfg.window_h / s,
                              x0 + hcfg.window_w / s))
            all_scores.append(sm[y, x])
            all_scales.append(s)
    if not all_boxes:
        return []
    boxes = np.asarray(all_boxes)
    scores = np.asarray(all_scores)
    keep = _nms(boxes, scores, cfg.nms_iou)
    return [{"box": tuple(float(v) for v in boxes[i]),
             "score": float(scores[i]), "scale": float(all_scales[i])}
            for i in keep]
