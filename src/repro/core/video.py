"""Video-stream detection: frame-to-frame tracking over the batched
detector.

The paper's §VI "future development" is a camera -> detection stream;
Gajjar et al. (arXiv:1709.00726) pair the per-frame detector with a
tracker so identities persist across frames and single-frame score
noise is smoothed out. This module is that layer, host-side on top of
the device-resident detection programs (core/detector.py):

  * `Tracker` -- greedy IoU association between constant-velocity
    track predictions and the current frame's detections, gated on
    `class_id` when detections carry one (multi-head results,
    DESIGN.md §13): a pedestrian track can never be stolen by an
    overlapping vehicle detection, and ids are allocated per class.
    Matched
    tracks update their box, an EMA-smoothed score, and an EMA-smoothed
    velocity; unmatched detections open new tracks; unmatched tracks
    coast on their prediction for up to `max_misses` frames before
    retiring. Pure numpy -- association is O(tracks x dets) on a few
    dozen boxes, not worth a device round-trip.
  * `VideoDetector` -- FrameDetector + Tracker. `step()` serves a live
    stream one frame at a time; `process_clip()` pushes a recorded clip
    through `detect_batch` in `batch_size` chunks (one device dispatch
    per chunk) and associates frames in order.

Detections gain a stable integer `track_id` plus the smoothed score;
`hits`/`misses` let callers gate on track confirmation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.detector import DetectorConfig, FrameDetector
from repro.core.svm import SVMParams


def iou_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise IoU on host. a: (N, 4), b: (M, 4) as (y0, x0, y1, x1).

    Numpy twin of detector.matrix_iou (same eps clamp) for the
    association step, which never touches the device.
    """
    a = np.asarray(a, np.float64).reshape(-1, 4)
    b = np.asarray(b, np.float64).reshape(-1, 4)
    y0 = np.maximum(a[:, None, 0], b[None, :, 0])
    x0 = np.maximum(a[:, None, 1], b[None, :, 1])
    y1 = np.minimum(a[:, None, 2], b[None, :, 2])
    x1 = np.minimum(a[:, None, 3], b[None, :, 3])
    inter = np.maximum(y1 - y0, 0.0) * np.maximum(x1 - x0, 0.0)
    area_a = (a[:, 2] - a[:, 0]) * (a[:, 3] - a[:, 1])
    area_b = (b[:, 2] - b[:, 0]) * (b[:, 3] - b[:, 1])
    return inter / np.maximum(area_a[:, None] + area_b[None, :] - inter,
                              1e-9)


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    iou_match: float = 0.3       # min IoU for a track<->detection match
    max_misses: int = 2          # coasting frames before a track retires
    min_hits: int = 1            # matches before a track is "confirmed"
    score_alpha: float = 0.6     # EMA weight of the NEW score
    velocity_alpha: float = 0.7  # EMA weight of the NEW center velocity
    emit_coasting: bool = False  # also report unmatched-but-alive tracks


@dataclasses.dataclass
class Track:
    track_id: int
    box: np.ndarray              # (4,) float64 (y0, x0, y1, x1)
    velocity: np.ndarray         # (2,) float64 center (dy, dx) per frame
    score: float                 # EMA-smoothed SVM score
    scale: float                 # pyramid scale of the last matched det
    hits: int = 1                # total matched frames
    misses: int = 0              # consecutive unmatched frames
    class_id: Optional[int] = None   # detection head this track follows
    label: Optional[str] = None      # head name (multi-class results)

    @property
    def predicted(self) -> np.ndarray:
        """Constant-velocity prediction of the box for the next frame."""
        return self.box + np.concatenate([self.velocity, self.velocity])


class Tracker:
    """IoU-greedy multi-object tracker over per-frame detections."""

    def __init__(self, cfg: Optional[TrackerConfig] = None):
        # the default config is constructed PER INSTANCE: a
        # `cfg=TrackerConfig()` default argument would be one shared
        # object across every Tracker in the process (and TrackerConfig
        # is kept frozen so thresholds cannot be mutated out from under
        # a running tracker either way)
        self.cfg = TrackerConfig() if cfg is None else cfg
        self.tracks: List[Track] = []
        self._next_id = 0

    def update(self, detections: Sequence[Dict]) -> List[Dict]:
        """Associate one frame's detections; returns them with track ids.

        `detections` is the FrameDetector output (score-sorted dicts
        with box/score/scale, plus class_id/label on multi-head
        results). Greedy matching takes the globally highest-IoU
        (track, detection) pair first, so a detection can never steal a
        track from a better-overlapping detection; pairs whose class
        ids differ are masked out of the IoU matrix up front, so
        association and id allocation are per class.
        """
        cfg = self.cfg
        dets = list(detections)
        matched_t: set = set()
        matched_d: set = set()
        if self.tracks and dets:
            pred = np.stack([t.predicted for t in self.tracks])
            dbox = np.asarray([d["box"] for d in dets], np.float64)
            iou = iou_np(pred, dbox)
            # class gate: a track only matches detections of ITS class
            # (None matches None -- the single-head path is unchanged)
            tcls = np.asarray([-1 if t.class_id is None else t.class_id
                               for t in self.tracks])
            dcls = np.asarray([-1 if d.get("class_id") is None
                               else d["class_id"] for d in dets])
            iou[tcls[:, None] != dcls[None, :]] = -1.0
            while True:
                ti, di = np.unravel_index(np.argmax(iou), iou.shape)
                if iou[ti, di] < cfg.iou_match:
                    break
                self._match(self.tracks[ti], dets[di])
                matched_t.add(int(ti))
                matched_d.add(int(di))
                iou[ti, :] = -1.0
                iou[:, di] = -1.0

        survivors: List[Track] = []
        for ti, t in enumerate(self.tracks):
            if ti not in matched_t:
                t.misses += 1
                if t.misses > cfg.max_misses:
                    continue                      # retire
                t.box = t.predicted               # coast on the prediction
            survivors.append(t)
        for di, d in enumerate(dets):             # unmatched dets open tracks
            if di not in matched_d:
                survivors.append(
                    Track(self._next_id, np.asarray(d["box"], np.float64),
                          np.zeros(2), float(d["score"]),
                          float(d.get("scale", 1.0)),
                          class_id=d.get("class_id"),
                          label=d.get("label")))
                self._next_id += 1
        self.tracks = survivors

        out = [{"box": tuple(float(v) for v in t.box),
                "score": t.score, "scale": t.scale,
                "track_id": t.track_id, "hits": t.hits,
                "misses": t.misses,
                **({"class_id": t.class_id, "label": t.label}
                   if t.class_id is not None else {})}
               for t in self.tracks
               if t.hits >= cfg.min_hits
               and (t.misses == 0 or cfg.emit_coasting)]
        out.sort(key=lambda d: -d["score"])
        return out

    def _match(self, t: Track, det: Dict) -> None:
        new_box = np.asarray(det["box"], np.float64)
        a = self.cfg.velocity_alpha
        new_v = _center(new_box) - _center(t.box)
        t.velocity = a * new_v + (1.0 - a) * t.velocity
        t.box = new_box
        s = self.cfg.score_alpha
        t.score = s * float(det["score"]) + (1.0 - s) * t.score
        t.scale = float(det.get("scale", t.scale))
        t.hits += 1
        t.misses = 0


def _center(box: np.ndarray) -> np.ndarray:
    return np.asarray([(box[0] + box[2]) * 0.5, (box[1] + box[3]) * 0.5])


class VideoDetector:
    """Tracked detection stream: the camera->detection stream of §VI.

    Deprecated shim over `repro.api.DetectionSession` (which owns the
    compiled programs and the typed Detections results): `step(frame)`
    serves a live stream; `process_clip(frames)` routes a recorded clip
    through `session.stream` (batched device path, `batch_size` frames
    per dispatch, association in frame order). Equivalence with the
    session path is pinned by tests/test_api_session.py.
    """

    def __init__(self, svm: SVMParams,
                 cfg: Optional[DetectorConfig] = None,
                 tracker: Optional[TrackerConfig] = None):
        # deferred import: repro.api sits on top of this module
        from repro.api.config import PipelineConfig
        from repro.api.session import DetectionSession
        cfg = DetectorConfig() if cfg is None else cfg
        tracker = TrackerConfig() if tracker is None else tracker
        self.session = DetectionSession(
            svm, PipelineConfig(hog=cfg.hog, detector=cfg, tracker=tracker))
        self.tracker = Tracker(tracker)

    @property
    def detector(self) -> FrameDetector:
        """The session's device-program handle (legacy attribute)."""
        return self.session.detector

    def step(self, frame) -> List[Dict]:
        return self.tracker.update(self.session.detect(frame).to_list())

    def process_clip(self, frames, batch_size: int = 8) -> List[List[Dict]]:
        """(T, H, W[, 3]) stacked clip or list of frames -> per-frame
        tracked detections."""
        return [d.to_list()
                for d in self.session.stream(frames, batch_size=batch_size,
                                             tracker=self.tracker)]
