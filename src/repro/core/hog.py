"""HOG feature extraction — the paper's feature pipeline, in JAX.

Faithful to Nguyen et al. (2022):
  * fixed 130x66 detection window (H=130, W=66); the 1-pixel border is
    reserved for central differences, so the active region is 128x64,
  * central-difference gradients (eqs. 1-2),
  * magnitude/orientation via CORDIC (eqs. 3-4) -- `mode="cordic"`,
  * 8x8-pixel cells, 9 orientation bins (unsigned, 0..180 deg),
    HARD bin assignment weighted by magnitude (the paper's hardware
    simplification -- no trilinear interpolation),
  * 2x2-cell blocks at 1-cell stride -> 15x7 blocks, L2 normalization
    (eq. 5) with Newton-Raphson rsqrt in hardware mode,
  * descriptor = 15*7*36 = 3780 features.

Modes (all validated against each other in tests):
  * "ref"    -- jnp.arctan2 / jnp.sqrt / jax.lax.rsqrt (software oracle),
  * "cordic" -- faithful 15-iteration CORDIC + Newton-Raphson rsqrt,
  * "sector" -- TPU-native: orientation bin via 8 tangent-boundary
    cross-multiplication comparisons (no trig, no division), hardware
    rsqrt. This is the beyond-paper numerics path (see DESIGN.md §2).

This module is pure jnp and doubles as the oracle for kernels/*.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import numerics as N
from repro.core.cordic import cordic_mag_angle, cordic_mag_bin_fixed

Array = jax.Array

# ITU-R BT.601 luma weights -- what Matlab's rgb2gray uses; the paper's
# grayscale stage is Matlab-side, so we match Matlab.
_LUMA = (0.2989, 0.5870, 0.1140)


@dataclasses.dataclass(frozen=True)
class HOGConfig:
    """Geometry of the paper's detection window."""

    window_h: int = 130          # full window, incl. 1px gradient border
    window_w: int = 66
    cell: int = 8                # 8x8 px cells
    block: int = 2               # 2x2 cells per block
    bins: int = 9                # 9 unsigned orientation bins (20 deg each)
    eps: float = 1e-2            # eq. (5) epsilon
    mode: str = "ref"            # "ref" | "cordic" | "sector"
    feat_dtype: str = "f32"      # "f32" | "bf16" descriptor width (§Perf)
    numerics: str = "float"      # "float" | "fixed" (int8 datapath, §12)

    def __post_init__(self):
        if self.numerics not in ("float", "fixed"):
            raise ValueError(
                f"numerics must be 'float' or 'fixed', got {self.numerics!r}")
        if self.numerics == "fixed" and self.feat_dtype != "f32":
            # fixed descriptors are int8-on-a-grid carried as f32; a bf16
            # recast would round them OFF the grid and break the exact
            # requantization the scoring path relies on
            raise ValueError(
                "numerics='fixed' requires feat_dtype='f32' "
                f"(got {self.feat_dtype!r})")

    @property
    def active_h(self) -> int:   # 128
        return (self.window_h - 2) // self.cell * self.cell

    @property
    def active_w(self) -> int:   # 64
        return (self.window_w - 2) // self.cell * self.cell

    @property
    def cells_hw(self) -> Tuple[int, int]:      # (16, 8)
        return self.active_h // self.cell, self.active_w // self.cell

    @property
    def blocks_hw(self) -> Tuple[int, int]:     # (15, 7)
        ch, cw = self.cells_hw
        return ch - self.block + 1, cw - self.block + 1

    @property
    def block_dim(self) -> int:                 # 36
        return self.block * self.block * self.bins

    @property
    def n_features(self) -> int:                # 3780
        bh, bw = self.blocks_hw
        return bh * bw * self.block_dim


PAPER_HOG = HOGConfig()
assert PAPER_HOG.n_features == 3780, PAPER_HOG.n_features


# ---------------------------------------------------------------------------
# stage 2: color standardization
# ---------------------------------------------------------------------------

def grayscale(rgb: Array) -> Array:
    """RGB (..., 3) uint8/float -> float32 gray in [0, 255] (8-bit range)."""
    rgb = rgb.astype(jnp.float32)
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    return _LUMA[0] * r + _LUMA[1] * g + _LUMA[2] * b


# ---------------------------------------------------------------------------
# stage 3: gradients (eqs. 1-2) -- central differences on the interior
# ---------------------------------------------------------------------------

def gradients(gray: Array) -> Tuple[Array, Array]:
    """Central differences. gray: (..., H, W) -> fx, fy on (..., H-2, W-2).

    Paper eq. (1): f_x(x,y) = f(x+1,y) - f(x-1,y)   (horizontal / along W)
    Paper eq. (2): f_y(x,y) = f(x,y+1) - f(x,y-1)   (vertical   / along H)
    """
    fx = gray[..., 1:-1, 2:] - gray[..., 1:-1, :-2]
    fy = gray[..., 2:, 1:-1] - gray[..., :-2, 1:-1]
    return fx, fy


# ---------------------------------------------------------------------------
# magnitude + orientation bin (eqs. 3-4), three numerics modes
# ---------------------------------------------------------------------------

_BOUNDARY_DEG = [20.0 * (k + 1) for k in range(8)]         # 20..160
_COS_B = jnp.asarray([math.cos(math.radians(b)) for b in _BOUNDARY_DEG])
_SIN_B = jnp.asarray([math.sin(math.radians(b)) for b in _BOUNDARY_DEG])


def mag_bin_ref(fx: Array, fy: Array, bins: int = 9) -> Tuple[Array, Array]:
    """Oracle: sqrt + arctan2, unsigned angle folded to [0, 180)."""
    mag = jnp.sqrt(fx * fx + fy * fy)
    theta = jnp.degrees(jnp.arctan2(fy, fx))               # (-180, 180]
    theta = jnp.mod(theta, 180.0)                          # [0, 180)
    b = jnp.clip(jnp.floor(theta / (180.0 / bins)), 0, bins - 1)
    return mag, b.astype(jnp.int32)


def mag_bin_cordic(fx: Array, fy: Array, bins: int = 9,
                   iters: int = 15) -> Tuple[Array, Array]:
    """Faithful mode: the paper's CORDIC (15 LUT angles, Fig. 7-8)."""
    mag, theta_deg = cordic_mag_angle(fx, fy, iters=iters)
    theta = jnp.mod(theta_deg, 180.0)
    b = jnp.clip(jnp.floor(theta / (180.0 / bins)), 0, bins - 1)
    return mag, b.astype(jnp.int32)


def mag_bin_sector(fx: Array, fy: Array, bins: int = 9) -> Tuple[Array, Array]:
    """TPU-native: bin via cross-multiplication against tan boundaries.

    Fold direction to the upper half-plane (unsigned gradient), then
    theta >= b_k  <=>  fy*cos(b_k) - fx*sin(b_k) >= 0  for b_k in (0,180).
    bin = number of boundaries passed. Multiplies + compares only.
    """
    assert bins == 9, "sector table is built for 9 bins"
    mag = jnp.sqrt(fx * fx + fy * fy)
    # fold to [0, 180): (fx, fy) and (-fx, -fy) share an unsigned angle
    flip = fy < 0
    ux = jnp.where(flip, -fx, fx)
    uy = jnp.where(flip, -fy, fy)
    # fy == 0, fx < 0 => theta == 180 which folds to bin 0; handle by
    # treating that point as theta=0 (mag-weighted vote identical).
    on_axis = (uy == 0) & (ux < 0)
    ux = jnp.where(on_axis, -ux, ux)
    crossed = (uy[..., None] * _COS_B - ux[..., None] * _SIN_B) >= 0.0
    b = jnp.sum(crossed, axis=-1).astype(jnp.int32)
    return mag, b


def mag_bin_ref_fast(fx: Array, fy: Array, bins: int = 9) -> Tuple[Array, Array]:
    """Hot-path form of `mag_bin_ref`: same sqrt magnitude, bins via the
    sector cross-multiplication tests instead of arctan2.

    The two are the same fp32 predicate reordered (theta >= b_k  <=>
    fy*cos(b_k) - fx*sin(b_k) >= 0; see test_modes_agree_on_bins), so
    bins only differ on pixels whose angle lands within float rounding
    of a 20-degree boundary -- measured 2 in 4M random normal gradients,
    none on uint8-derived frames. The transcendental-free form is ~10x
    faster on the CPU host and pure VPU mul/cmp on TPU, which is why
    the staged pipeline's "ref" backend (core/stages.py) routes its
    mag/bin stage here; `mag_bin_ref` stays the arctan2 oracle the
    tests pin numerics against.
    """
    if bins != 9:                 # sector table is built for 9 bins
        return mag_bin_ref(fx, fy, bins)
    return mag_bin_sector(fx, fy, bins)


def mag_bin_fixed(fx: Array, fy: Array, bins: int = 9) -> Tuple[Array, Array]:
    """Fixed-point mode: integer shift-add CORDIC (core/cordic.py).

    Returns int32 magnitudes in half-gray-level units (quant.MAG_SCALE);
    downstream cell histograms accumulate them in integers and store
    int16 (numerics.store_hist).
    """
    return cordic_mag_bin_fixed(fx, fy, bins=bins)


_MAG_BIN = {"ref": mag_bin_ref, "cordic": mag_bin_cordic,
            "sector": mag_bin_sector, "fixed": mag_bin_fixed}

#: what the staged pipeline dispatches on: identical to _MAG_BIN except
#: "ref" takes the transcendental-free fast path (bit-identical bins on
#: non-boundary pixels, same sqrt magnitude).
_MAG_BIN_FAST = dict(_MAG_BIN, ref=mag_bin_ref_fast)


# ---------------------------------------------------------------------------
# stage 4: cell histograms -- one-hot matmul binning (MXU-friendly)
# ---------------------------------------------------------------------------

def cell_histograms(mag: Array, bin_idx: Array, cfg: HOGConfig) -> Array:
    """(..., Ha, Wa) mag/bin -> (..., ch, cw, bins) histograms.

    Hard assignment: hist[c, b] = sum of magnitudes of pixels in cell c
    whose orientation bin is b -- a dense select-and-reduce over the
    static bin count, the same formulation the Pallas cell_hist kernel
    uses (the scatter "hist[bin] += mag" would serialize on TPU).
    """
    ch, cw = cfg.cells_hw
    c = cfg.cell
    lead = mag.shape[:-2]
    m = mag.reshape(lead + (ch, c, cw, c))
    bi = bin_idx.reshape(lead + (ch, c, cw, c))
    # select-and-reduce per bin: the formulation the Pallas cell_hist
    # kernel uses, and ~3x faster than the one-hot einsum on the CPU
    # host (no materialized (..., H, W, bins) one-hot tensor -- the
    # select fuses into the tree reduction)
    outs = [jnp.sum(jnp.where(bi == k, m, jnp.zeros_like(m)), axis=(-3, -1))
            for k in range(cfg.bins)]
    # fixed chain: int32 accumulate above, int16 store (64 px * 361 max
    # magnitude = 23104 < 2^15 per cell); float chains pass through
    return N.store_hist(jnp.stack(outs, axis=-1))


# ---------------------------------------------------------------------------
# stage 5-6: block normalization (eq. 5) + descriptor collation
# ---------------------------------------------------------------------------

#: back-compat alias -- the canonical NR rsqrt lives in core/numerics.py
#: so every backend shares one implementation (the PR 6 identity-trap fix)
_nr_rsqrt = N.nr_rsqrt


def block_normalize(hist: Array, cfg: HOGConfig, use_nr: bool = False,
                    norm: str | None = None) -> Array:
    """(..., ch, cw, bins) -> (..., bh, bw, block_dim) L2-normalized blocks.

    eq. (5): v_i / sqrt(||v||^2 + eps^2) over each 36-dim block vector.
    The tail (rsqrt flavor + optional int8 quantize) is
    numerics.finish_blocks, shared with every Pallas block-norm kernel;
    `norm` overrides the legacy use_nr flag when given.
    """
    bh, bw = cfg.blocks_hw
    b = cfg.block
    # gather the 2x2 cell neighborhoods: (..., bh, bw, b, b, bins)
    parts = [hist[..., i:i + bh, j:j + bw, :]
             for i in range(b) for j in range(b)]
    v = jnp.stack(parts, axis=-2)                    # (..., bh, bw, b*b, bins)
    v = v.reshape(v.shape[:-2] + (cfg.block_dim,))   # (..., bh, bw, 36)
    if norm is None:
        norm = "nr" if use_nr else "rsqrt"
    out = N.finish_blocks(v, cfg.eps, norm)
    if cfg.feat_dtype == "bf16":
        out = out.astype(jnp.bfloat16)   # §Perf: halves descriptor traffic
    return out


def collate(blocks: Array, cfg: HOGConfig) -> Array:
    """(..., bh, bw, 36) -> (..., 3780) descriptor."""
    return blocks.reshape(blocks.shape[:-3] + (cfg.n_features,))


# ---------------------------------------------------------------------------
# end-to-end extractor -- a thin view over the staged pipeline
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def hog_descriptor(window: Array, cfg: HOGConfig = PAPER_HOG) -> Array:
    """Full HOG chain: (..., H, W, 3) RGB or (..., H, W) gray -> (..., 3780).

    Crops the active region so any window >= (cfg.window_h, cfg.window_w)
    top-left-anchored works; the paper's window is exactly 130x66.
    Smaller windows raise ValueError (at trace time).

    The chain itself lives in core/stages.py (window layout, "ref"
    backend); kernels/ops.py and detector.py instantiate the same stage
    list with the Pallas backends / dense layout.
    """
    from repro.core.stages import window_descriptor
    return window_descriptor(window, cfg, backend="ref")


def hog_descriptor_batch(windows: Array, cfg: HOGConfig = PAPER_HOG) -> Array:
    """Alias with batch-first contract: (B, H, W[, 3]) -> (B, 3780)."""
    return hog_descriptor(windows, cfg)
