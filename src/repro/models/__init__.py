from repro.models.configs import ModelConfig
from repro.models.model import (init_params, forward, loss_fn, prefill,
                                decode_step, init_cache)
from repro.models.moe import ShardingCtx
