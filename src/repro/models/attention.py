"""Attention: GQA, qk-norm, RoPE / M-RoPE, sliding window, meta-token sinks,
cross-attention, and KV-cache decode. Pure einsum formulations (GSPMD-
friendly: the compiler shards heads / sequence / batch per sharding rules).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, rmsnorm

Array = jax.Array
Params = Dict[str, Array]

NEG_INF = -1e9  # bf16-safe mask value (bf16 min normal ~ -3.4e38, but
                # -1e9 survives fp32 softmax subtraction cleanly)


def _project_qkv(x: Array, p: Params, cfg: ModelConfig,
                 positions: Array) -> Tuple[Array, Array, Array]:
    B, S, D = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x,
                   p["wq"].reshape(D, cfg.n_heads, hd))
    k = jnp.einsum("bsd,dhk->bshk", x,
                   p["wk"].reshape(D, cfg.n_kv_heads, hd))
    v = jnp.einsum("bsd,dhk->bshk", x,
                   p["wv"].reshape(D, cfg.n_kv_heads, hd))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def make_mask(q_pos: Array, k_pos: Array, *, causal: bool,
              window: int = 0, n_meta: int = 0) -> Array:
    """Boolean mask (..., Sq, Sk): True = attend.

    `window` > 0 restricts to the last `window` keys; the first `n_meta`
    keys (hymba meta tokens) stay always-visible (attention sinks).
    """
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        m &= dk <= dq
    if window > 0:
        in_window = dk > dq - window
        is_meta = dk < n_meta
        m &= in_window | is_meta
    return m


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array],
          cfg: ModelConfig, ctx=None) -> Array:
    """Grouped scaled-dot-product attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H = K * rep.
    Softmax in fp32 (the paper's lesson on fp32 datapaths applies here).

    Sharding (ctx != None): context-parallel -- queries stay sharded on
    Sq over the tp axis, K/V are all-gathered over the sequence (GQA K/V
    are small), scores are Sq-sharded. Pinning these is essential: left
    alone, GSPMD pads kv-heads to the tp size and replicates batch.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    if ctx is not None and Sq > 1:
        q = ctx.act_q(q)
        k = ctx.act_kv_gathered(k)
        v = ctx.act_kv_gathered(v)
    q = q.reshape(B, Sq, K, rep, hd)
    if (ctx is not None and getattr(ctx, "flash_vjp", False)
            and Sq > 1 and mask is not None):
        out = sdpa_flash(q, k, v, mask, hd ** -0.5)
        out = out.reshape(B, Sq, H, hd)
        if ctx is not None and Sq > 1:
            out = ctx.act_q(out)
        return out
    if ctx is not None and ctx.bf16_scores:
        # §Perf "flash-width" path: every materialized S x S tensor is
        # bf16; softmax statistics stay fp32 INSIDE fusions (the sub/exp
        # chain fuses, so only the bf16 results cross HBM) -- the XLA
        # analogue of keeping fp32 only in a flash kernel's registers.
        scores = jnp.einsum("bqkrh,bskh->bkrqs", q, k,
                            preferred_element_type=jnp.bfloat16)
        if ctx is not None and Sq > 1:
            scores = ctx.act_scores(scores)
        scores = scores * jnp.bfloat16(hd ** -0.5)
        if mask is not None:
            scores = jnp.where(mask[:, None, None, :, :], scores,
                               jnp.bfloat16(-3e4))
        # bf16 max is exact (order-preserving); exp/sum accumulate fp32
        # inside fusions/reductions -- no f32 S x S copy crosses HBM
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp((scores - m).astype(jnp.float32)).astype(jnp.bfloat16)
        l = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
        w = (p / l.astype(jnp.bfloat16)).astype(v.dtype)
        if ctx is not None and Sq > 1:
            w = ctx.act_scores(w)
    else:
        scores = jnp.einsum("bqkrh,bskh->bkrqs", q, k).astype(jnp.float32)
        if ctx is not None and Sq > 1:
            scores = ctx.act_scores(scores)
        scores *= hd ** -0.5
        if mask is not None:
            scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        if ctx is not None and Sq > 1:
            w = ctx.act_scores(w)
    out = jnp.einsum("bkrqs,bskh->bqkrh", w, v)
    out = out.reshape(B, Sq, H, hd)
    if ctx is not None and Sq > 1:
        out = ctx.act_q(out)
    return out


def attention(x: Array, p: Params, cfg: ModelConfig, positions: Array,
              *, window: int = 0, n_meta: int = 0,
              causal: bool = True, ctx=None) -> Array:
    """Full-sequence attention (training / prefill without cache)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    mask = make_mask(pos1d, pos1d, causal=causal, window=window,
                     n_meta=n_meta)
    out = _sdpa(q, k, v, mask, cfg, ctx)
    return jnp.einsum("bshk,hkd->bsd", out,
                      p["wo"].reshape(cfg.n_heads, cfg.hd, D))


def attention_decode(x: Array, p: Params, cfg: ModelConfig,
                     cache: Dict[str, Array], positions: Array,
                     *, window: int = 0, n_meta: int = 0
                     ) -> Tuple[Array, Dict[str, Array]]:
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache: {"k","v": (B, Smax, K, hd), "idx": ()} -- `idx`
    is the current length (same for the whole batch; continuous-batching
    engines pass per-slot lengths via positions).
    """
    B, _, D = x.shape
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    idx = cache["idx"]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    Smax = k.shape[1]
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    k_pos = jnp.arange(Smax)[None, :]                   # (1, Smax)
    q_pos = pos1d[:, -1:]                               # (B, 1)
    mask = make_mask(q_pos, k_pos, causal=True, window=window,
                     n_meta=n_meta)
    out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out,
                   p["wo"].reshape(cfg.n_heads, cfg.hd, D))
    return y, {"k": k, "v": v, "idx": idx + 1}


def cross_attention(x: Array, enc: Array, p: Params,
                    cfg: ModelConfig) -> Array:
    """Decoder cross-attention over encoder states (whisper). No RoPE."""
    B, S, D = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(D, cfg.n_heads, hd))
    k = jnp.einsum("bsd,dhk->bshk", enc,
                   p["wk"].reshape(D, cfg.n_kv_heads, hd))
    v = jnp.einsum("bsd,dhk->bshk", enc,
                   p["wv"].reshape(D, cfg.n_kv_heads, hd))
    out = _sdpa(q, k, v, None, cfg)
    return jnp.einsum("bshk,hkd->bsd", out,
                      p["wo"].reshape(cfg.n_heads, hd, D))


def _sdpa_lse(q: Array, k: Array, v: Array, mask: Optional[Array],
              bf16: bool, ctx=None) -> Tuple[Array, Array]:
    """SDPA returning (normalized out (B,Sq,H,hd), lse (B,Sq,H)) for
    split-softmax merging (flash-style partial attention).

    bf16 mode keeps every materialized (Sq, Sk) tensor at 2 bytes; the
    max is taken in bf16 (exact: max is order-preserving under rounding)
    and exp/sum accumulate in f32 INSIDE fusions/reductions so no f32
    copy of the score tensor ever crosses HBM.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    q5 = q.reshape(B, Sq, K, rep, hd)
    pt = jnp.bfloat16 if bf16 else jnp.float32
    scores = jnp.einsum("bqkrh,bskh->bkrqs", q5, k,
                        preferred_element_type=pt)
    if ctx is not None:
        scores = ctx.constrain(scores, ctx.dp_axes, None, None,
                               ctx.tp_axis, None)
    scores = scores * jnp.asarray(hd ** -0.5, pt)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores,
                           jnp.asarray(-3e4, pt))
    m = jnp.max(scores, axis=-1).astype(jnp.float32)          # (B,K,rep,Sq)
    p = jnp.exp((scores - m[..., None].astype(pt)).astype(jnp.float32)
                ).astype(v.dtype)
    l = jnp.sum(p, axis=-1, dtype=jnp.float32)                # (B,K,rep,Sq)
    out = jnp.einsum("bkrqs,bskh->bqkrh", p, v).reshape(B, Sq, H, hd)
    out = out / jnp.moveaxis(jnp.maximum(l, 1e-30).reshape(B, H, Sq), 1,
                             2)[..., None].astype(out.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))                  # (B,K,rep,Sq)
    lse = jnp.moveaxis(lse.reshape(B, H, Sq), 1, 2)           # (B,Sq,H)
    return out, lse


def banded_attention(x: Array, p: Params, cfg: ModelConfig,
                     positions: Array, *, window: int, n_meta: int = 0,
                     ctx=None) -> Array:
    """§Perf: block-banded sliding-window attention.

    The baseline computes full S x S scores then masks -- O(S^2) HBM
    traffic and FLOPs even though each query sees only `window` keys
    (+ meta-token sinks). Here the sequence is reshaped into blocks of
    size bq == window; block i attends to the key band [block i-1 ;
    block i] (covers every in-window key), and separately to the meta
    prefix; the two partial softmaxes merge by log-sum-exp. Traffic and
    FLOPs drop from O(S^2) to O(S * (2*window + n_meta)) -- ~16x for
    hymba prefill_32k. Fully vectorized (the block axis is the sequence
    axis, so sequence sharding is preserved). Numerically equivalent to
    the masked baseline (tests/test_banded.py).
    """
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    out = banded_core(q, k, v, pos1d, cfg, window=window, n_meta=n_meta,
                      ctx=ctx)
    return jnp.einsum("bshk,hkd->bsd", out,
                      p["wo"].reshape(cfg.n_heads, cfg.hd, D))


def banded_core(q: Array, k: Array, v: Array, pos1d: Array,
                cfg: ModelConfig, *, window: int, n_meta: int = 0,
                ctx=None) -> Array:
    """Banded attention on projected q/k/v -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    if ctx is not None:
        # gather the sequence axis BEFORE folding into blocks: the
        # (B, S) -> (B*nblk, bq) reshape across a sharded S triggers
        # XLA's "involuntary full rematerialization" (replicate+repart).
        # After this, the fold is device-local; the folded constraints
        # below re-introduce 2D parallelism (rows over dp, bq over tp).
        q = ctx.constrain(q, ctx.dp_axes, None, None, None)
        k = ctx.constrain(k, ctx.dp_axes, None, None, None)
        v = ctx.constrain(v, ctx.dp_axes, None, None, None)
    bq = window
    nblk = -(-S // bq)
    Sp = nblk * bq
    if Sp != S:
        pad4 = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        pos1d = jnp.pad(pos1d, ((0, 0), (0, Sp - S)),
                        constant_values=2 ** 30)
    def blocks(t):  # (B, Sp, ...) -> (B*nblk, bq, ...)
        return t.reshape((B * nblk, bq) + t.shape[2:])

    def bands(t):   # (B, Sp, ...) -> (B*nblk, 2bq, ...): [prev; cur]
        tb = t.reshape((B, nblk, bq) + t.shape[2:])
        prev = jnp.pad(tb, ((0, 0), (1, 0)) + ((0, 0),) * (tb.ndim - 2)
                       )[:, :-1]
        band = jnp.concatenate([prev, tb], axis=2)
        return band.reshape((B * nblk, 2 * bq) + t.shape[2:])

    qb, kb, vb = blocks(q), bands(k), bands(v)
    if ctx is not None:
        # folded (B*nblk) rows shard over dp; query positions over tp.
        # Without these pins GSPMD replicates the folded tensors
        # (observed: 139 GiB/device on hymba train_4k).
        qb = ctx.constrain(qb, ctx.dp_axes, ctx.tp_axis, None, None)
        kb = ctx.constrain(kb, ctx.dp_axes, None, None, None)
        vb = ctx.constrain(vb, ctx.dp_axes, None, None, None)
    qp = blocks(pos1d[..., None])[..., 0]
    kp = bands(pos1d[..., None])[..., 0]
    # block 0's zero-padded "previous" band must never be attended
    first_pad = (jnp.arange(B * nblk) % nblk == 0)[:, None] \
        & (jnp.arange(2 * bq) < bq)[None, :]
    kp = jnp.where(first_pad, 2 ** 30, kp)
    mask = make_mask(qp, kp, causal=True, window=window)
    if n_meta:
        mask &= (kp >= n_meta)[:, None, :]   # meta: separate pass below
    bf16 = bool(ctx is not None and ctx.bf16_scores)
    out_b, lse_b = _sdpa_lse(qb, kb, vb, mask, bf16, ctx)
    out_b = out_b.reshape(B, Sp, H, hd)[:, :S]
    lse_b = lse_b.reshape(B, Sp, H)[:, :S]

    if n_meta:
        # meta keys are always visible through the window (sinks), but
        # causality still applies for the meta tokens' own queries
        mask_m = (jnp.arange(n_meta)[None, None, :]
                  <= pos1d[:, :S, None])
        out_m, lse_m = _sdpa_lse(q[:, :S], k[:, :n_meta], v[:, :n_meta],
                                 mask_m, bf16)
        mx = jnp.maximum(lse_b, lse_m)
        wb = jnp.exp(lse_b - mx)
        wm = jnp.exp(lse_m - mx)
        den = wb + wm
        out = (out_b * (wb / den)[..., None].astype(out_b.dtype)
               + out_m * (wm / den)[..., None].astype(out_m.dtype))
    else:
        out = out_b
    return out


# ---------------------------------------------------------------------
# §Perf: flash-style custom VJP -- save the LSE, recompute attention
# weights in backward as ONE fused exp((s - lse)) pass instead of
# autodiff re-running the full mask/max/exp/sum/div chain. Cuts the
# number of materialized S x S tensors in backward roughly in half
# (the HBM-bound term for every train cell). Numerics: standard flash
# backward (dV = w^T dO; dS = w*(dW - rowsum(dW*w)); exact, not approx).
# ---------------------------------------------------------------------

from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(4,))
def sdpa_flash(q5, k, v, mask, scale):
    """q5: (B,Sq,K,rep,hd); k,v: (B,Sk,K,hd); mask (B,Sq,Sk) bool."""
    out, _ = _flash_fwd_impl(q5, k, v, mask, scale)
    return out


def _flash_fwd_impl(q5, k, v, mask, scale):
    s = jnp.einsum("bqkrh,bskh->bkrqs", q5, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]).astype(v.dtype)
    l = jnp.sum(p, axis=-1, dtype=jnp.float32)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = jnp.einsum("bkrqs,bskh->bqkrh", p, v)
    out = out / jnp.moveaxis(
        jnp.maximum(l, 1e-30).reshape(l.shape[0], -1, l.shape[-1]), 1, 2
    ).reshape(out.shape[:2] + out.shape[2:4] + (1,)).astype(out.dtype)
    return out, lse


def _flash_fwd(q5, k, v, mask, scale):
    out, lse = _flash_fwd_impl(q5, k, v, mask, scale)
    return out, (q5, k, v, mask, lse)


def _flash_bwd(scale, res, dout):
    q5, k, v, mask, lse = res
    # recompute weights from the saved LSE: one dot + one fused exp
    s = jnp.einsum("bqkrh,bskh->bkrqs", q5, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    w = jnp.exp(s - lse[..., None]).astype(v.dtype)       # (B,K,rep,Sq,Sk)
    dv = jnp.einsum("bkrqs,bqkrh->bskh", w, dout)
    dw = jnp.einsum("bqkrh,bskh->bkrqs", dout, v)
    delta = jnp.sum(dw.astype(jnp.float32) * w.astype(jnp.float32),
                    axis=-1)                              # (B,K,rep,Sq)
    ds = (w.astype(jnp.float32)
          * (dw.astype(jnp.float32) - delta[..., None])
          * scale).astype(q5.dtype)
    dq5 = jnp.einsum("bkrqs,bskh->bqkrh", ds, k)
    dk = jnp.einsum("bkrqs,bqkrh->bskh", ds, q5)
    import numpy as _np
    dmask = _np.zeros(mask.shape, jax.dtypes.float0)
    return dq5, dk, dv, dmask


sdpa_flash.defvjp(_flash_fwd, _flash_bwd)


def attention_decode_windowed(x: Array, p: Params, cfg: ModelConfig,
                              cache: Dict[str, Array], positions: Array,
                              *, window: int, n_meta: int = 0
                              ) -> Tuple[Array, Dict[str, Array]]:
    """§Perf: sliding-window decode that READS only the live window.

    The baseline decode scores the query against the FULL cache and
    masks (524k-wide reads for long_500k even though only `window` keys
    are visible). Here the cache update is unchanged (the cache stays
    dense so global layers / later resizing work), but attention slices
    just [idx-window+1 .. idx] plus the meta prefix: score width drops
    from Smax to window + n_meta (512x for hymba at 500k).
    Mathematically identical to the masked baseline.
    """
    B, _, D = x.shape
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    idx = cache["idx"]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    Smax = k.shape[1]
    start = jnp.clip(idx - window + 1, 0, Smax - window)
    k_win = jax.lax.dynamic_slice_in_dim(k, start, window, axis=1)
    v_win = jax.lax.dynamic_slice_in_dim(v, start, window, axis=1)
    kp_win = (start + jnp.arange(window))[None, :]          # (1, W)
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    q_pos = pos1d[:, -1:]
    mask_win = make_mask(q_pos, kp_win, causal=True, window=window)
    # exclude meta positions from the window slice (handled separately)
    if n_meta:
        mask_win &= (kp_win >= n_meta)[:, None, :]
        k_m, v_m = k[:, :n_meta], v[:, :n_meta]
        kk = jnp.concatenate([k_m, k_win], axis=1)
        vv = jnp.concatenate([v_m, v_win], axis=1)
        mask = jnp.concatenate(
            [jnp.ones((B, 1, n_meta), bool), mask_win], axis=2)
    else:
        kk, vv, mask = k_win, v_win, mask_win
    out = _sdpa(q, kk, vv, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out,
                   p["wo"].reshape(cfg.n_heads, cfg.hd, D))
    return y, {"k": k, "v": v, "idx": idx + 1}
