"""Unified causal LM / enc-dec model: init, forward, prefill, decode.

One parameter pytree with layer leaves stacked on a leading L axis;
`jax.lax.scan` over layers (+ per-layer remat) keeps the HLO one-body-
per-stack, which is what makes 80 full-size dry-run compiles tractable
and keeps activation memory at one (B, S, D) residual per layer.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (attention, attention_decode,
                                    banded_attention, banded_core,
                                    cross_attention, make_mask, _sdpa,
                                    _project_qkv)
from repro.models.configs import ModelConfig
from repro.models.layers import mlp, norm, rmsnorm, sinusoidal_positions
from repro.models.moe import ShardingCtx, moe_ffn
from repro.models.ssm import ssd_decode, ssd_forward

Array = jax.Array
Params = Dict[str, Any]


# =====================================================================
# init
# =====================================================================

def _norm_p(key, L, D, cfg, zero_bias=True):
    p = {"scale": jnp.ones((L, D) if L else (D,), cfg.dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((L, D) if L else (D,), cfg.dtype)
    return p


def _dense(key, shape, cfg, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(cfg.dtype)


def _attn_p(key, L, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    lead = (L,) if L else ()
    p = {"wq": _dense(ks[0], lead + (D, H * hd), cfg),
         "wk": _dense(ks[1], lead + (D, K * hd), cfg),
         "wv": _dense(ks[2], lead + (D, K * hd), cfg),
         "wo": _dense(ks[3], lead + (H * hd, D), cfg)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(lead + (hd,), cfg.dtype)
        p["k_norm"] = jnp.ones(lead + (hd,), cfg.dtype)
    return p


def _mlp_p(key, L, cfg: ModelConfig, d_ff=None) -> Params:
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    lead = (L,) if L else ()
    if cfg.mlp == "swiglu":
        return {"w_gate": _dense(ks[0], lead + (D, F), cfg),
                "w_up": _dense(ks[1], lead + (D, F), cfg),
                "w_down": _dense(ks[2], lead + (F, D), cfg)}
    return {"w_up": _dense(ks[0], lead + (D, F), cfg),
            "w_down": _dense(ks[1], lead + (F, D), cfg)}


def _moe_p(key, L, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (L,) if L else ()
    p = {"router": _dense(ks[0], lead + (D, E), cfg, scale=0.02),
         "w_gate": _dense(ks[1], lead + (E, D, F), cfg),
         "w_up": _dense(ks[2], lead + (E, D, F), cfg),
         "w_down": _dense(ks[3], lead + (E, F, D), cfg)}
    if cfg.shared_expert:
        p["shared"] = _mlp_p(ks[4], L, cfg)
    return p


def _ssm_p(key, L, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    D, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    proj_out = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + H
    lead = (L,) if L else ()
    dt = jnp.exp(jax.random.uniform(ks[2], lead + (H,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_proj": _dense(ks[0], lead + (D, proj_out), cfg),
        "conv_w": _dense(ks[1], lead + (cfg.conv_dim, cfg.ssm_conv), cfg,
                         scale=cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros(lead + (cfg.conv_dim,), cfg.dtype),
        "A_log": jnp.zeros(lead + (H,), jnp.float32)
                 + jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D_skip": jnp.ones(lead + (H,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),   # inv softplus
        "norm_scale": jnp.ones(lead + (di,), cfg.dtype),
        "out_proj": _dense(ks[3], lead + (di, D), cfg),
    }


def _layer_stack_p(key, L: int, cfg: ModelConfig, *, cross: bool = False,
                   causal_stack: bool = True) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": _norm_p(ks[0], L, cfg.d_model, cfg),
                 "ln2": _norm_p(ks[1], L, cfg.d_model, cfg)}
    if cfg.has_attention:
        p["attn"] = _attn_p(ks[2], L, cfg)
    if cfg.has_ssm and causal_stack:
        p["ssm"] = _ssm_p(ks[3], L, cfg)
        if cfg.family == "hybrid":
            p["bn_attn"] = _norm_p(ks[4], L, cfg.d_model, cfg)
            p["bn_ssm"] = _norm_p(ks[5], L, cfg.d_model, cfg)
    if cfg.is_moe:
        p["moe"] = _moe_p(ks[6], L, cfg)
    elif cfg.family != "ssm":
        p["mlp"] = _mlp_p(ks[6], L, cfg)
    if cross:
        p["xattn"] = _attn_p(ks[7], L, cfg)
        p["ln_x"] = _norm_p(ks[7], L, cfg.d_model, cfg)
    return p


def init_params(cfg: ModelConfig, key: Array) -> Params:
    ks = jax.random.split(key, 8)
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    p: Params = {
        "embed": _dense(ks[0], (V, D), cfg, scale=0.02),
        "final_norm": _norm_p(ks[1], 0, D, cfg),
        "layers": _layer_stack_p(ks[2], L, cfg,
                                 cross=bool(cfg.encoder_layers)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense(ks[3], (D, V), cfg, scale=0.02)
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, family="dense", n_experts=0)
        p["enc_layers"] = _layer_stack_p(ks[4], cfg.encoder_layers, enc_cfg)
        p["enc_norm"] = _norm_p(ks[5], 0, D, cfg)
    if cfg.meta_tokens:
        p["meta"] = _dense(ks[6], (cfg.meta_tokens, D), cfg, scale=0.02)
    return p


# =====================================================================
# blocks
# =====================================================================

def _is_global(layer_idx: Array, cfg: ModelConfig) -> Array:
    """Per-layer flag: full attention (vs sliding window)."""
    if not cfg.sliding_window:
        return jnp.asarray(True)
    if not cfg.global_attn_layers:
        return jnp.asarray(False)
    g = jnp.asarray(cfg.global_attn_layers)
    return jnp.any(layer_idx == g)


def _mixer(x, lp, cfg: ModelConfig, positions, layer_idx, ctx,
           enc=None, static_window=None):
    """Token mixer for one layer: attention / SSM / hybrid-parallel.

    static_window: None (baseline: compute full+windowed, runtime-select)
    or 'window'/'global' when the layer stack is segmented statically
    (§Perf banded profile -- avoids the dual computation entirely).
    """
    outs = []
    if cfg.has_attention:
        if static_window == "window":
            a = banded_attention(x, lp["attn"], cfg, positions,
                                 window=cfg.sliding_window,
                                 n_meta=cfg.meta_tokens, ctx=ctx)
            if cfg.family == "hybrid":
                a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
            outs.append(a)
        elif static_window == "global":
            a = attention(x, lp["attn"], cfg, positions,
                          n_meta=cfg.meta_tokens, ctx=ctx)
            if cfg.family == "hybrid":
                a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
            outs.append(a)
        # window size must be static for mask building: build both, select
        elif cfg.sliding_window:
            a_full = attention(x, lp["attn"], cfg, positions,
                               window=0, n_meta=cfg.meta_tokens, ctx=ctx)
            a_win = attention(x, lp["attn"], cfg, positions,
                              window=cfg.sliding_window,
                              n_meta=cfg.meta_tokens, ctx=ctx)
            a = jnp.where(_is_global(layer_idx, cfg), a_full, a_win)
            if cfg.family == "hybrid":
                a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
            outs.append(a)
        else:
            a = attention(x, lp["attn"], cfg, positions,
                          n_meta=cfg.meta_tokens, ctx=ctx)
            if cfg.family == "hybrid":
                a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
            outs.append(a)
    if cfg.has_ssm:
        s, _ = ssd_forward(x, lp["ssm"], cfg)
        if cfg.family == "hybrid":
            s = norm(s, lp["bn_ssm"], cfg.norm, cfg.norm_eps)
        outs.append(s)
    if len(outs) == 2:
        return 0.5 * (outs[0] + outs[1])
    return outs[0]


def _ffn(x, lp, cfg: ModelConfig, ctx):
    if cfg.is_moe:
        return moe_ffn(x, lp["moe"], cfg, ctx)
    if cfg.family == "ssm":
        return jnp.zeros_like(x)          # mamba2: no separate FFN
    return mlp(x, lp["mlp"], cfg.mlp)


def _decoder_layer(x, lp, cfg, positions, layer_idx, ctx, enc=None,
                   static_window=None):
    if ctx is not None:
        x = ctx.act3(x)
    h = norm(x, lp["ln1"], cfg.norm, cfg.norm_eps)
    x = x + _mixer(h, lp, cfg, positions, layer_idx, ctx,
                   static_window=static_window)
    if enc is not None:
        h = norm(x, lp["ln_x"], cfg.norm, cfg.norm_eps)
        x = x + cross_attention(h, enc, lp["xattn"], cfg)
    if cfg.family != "ssm":
        h = norm(x, lp["ln2"], cfg.norm, cfg.norm_eps)
        x = x + _ffn(h, lp, cfg, ctx)
    return x


def layer_segments(cfg: ModelConfig):
    """Consecutive same-attention-kind layer runs, for static banding."""
    segs = []
    for l in range(cfg.n_layers):
        kind = ("global" if (not cfg.sliding_window
                             or l in cfg.global_attn_layers) else "window")
        if segs and segs[-1][2] == kind:
            segs[-1] = (segs[-1][0], l + 1, kind)
        else:
            segs.append((l, l + 1, kind))
    return segs


def _scan_layers(x, layers_p, cfg: ModelConfig, positions, ctx,
                 enc=None, n_layers: Optional[int] = None,
                 remat: bool = True):
    L = n_layers or cfg.n_layers
    banded = (ctx is not None and ctx.banded and cfg.sliding_window
              and cfg.has_attention)

    def make_body(static_window):
        def body(carry, inp):
            lp, idx = inp
            y = _decoder_layer(carry, lp, cfg, positions, idx, ctx, enc,
                               static_window=static_window)
            return y, None
        return jax.checkpoint(body, policy=None) if remat else body

    if not banded:
        x, _ = jax.lax.scan(make_body(None), x,
                            (layers_p, jnp.arange(L)))
        return x
    # §Perf: segment the stack so each scan has a STATIC window kind
    for a, b, kind in layer_segments(cfg):
        seg_p = jax.tree.map(lambda t: t[a:b], layers_p)
        x, _ = jax.lax.scan(make_body(kind), x,
                            (seg_p, jnp.arange(a, b)))
    return x


# =====================================================================
# full model
# =====================================================================

def embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"][tokens].astype(cfg.dtype) * (cfg.d_model ** 0.5)


def logits_from_hidden(params, x, cfg: ModelConfig, ctx=None):
    x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.dtype))
    if ctx is not None and logits.shape[1] > 1:
        logits = ctx.act_logits(logits)
    return logits


def encode(params, enc_input: Array, cfg: ModelConfig, ctx=None) -> Array:
    """Whisper encoder: (B, T_enc, D) stub frame embeddings -> states."""
    B, T, D = enc_input.shape
    x = enc_input.astype(cfg.dtype) + sinusoidal_positions(T, D).astype(cfg.dtype)
    enc_cfg = dataclasses.replace(cfg, family="dense", n_experts=0,
                                  meta_tokens=0)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    # non-causal: reuse the decoder layer with causal off via full mask
    def body(carry, inp):
        lp, idx = inp
        h = norm(carry, lp["ln1"], cfg.norm, cfg.norm_eps)
        a = attention(h, lp["attn"], enc_cfg, pos, causal=False, ctx=ctx)
        y = carry + a
        h = norm(y, lp["ln2"], cfg.norm, cfg.norm_eps)
        return y + mlp(h, lp["mlp"], cfg.mlp), None
    fn = jax.checkpoint(body)
    x, _ = jax.lax.scan(fn, x, (params["enc_layers"],
                                jnp.arange(cfg.encoder_layers)))
    return norm(x, params["enc_norm"], cfg.norm, cfg.norm_eps)


def forward(params: Params, batch: Dict[str, Array], cfg: ModelConfig,
            ctx: Optional[ShardingCtx] = None) -> Array:
    """Training/eval forward -> logits (B, S, V).

    batch: tokens (B, S) [+ positions (B,S) or (B,S,3) for mrope]
           [+ enc_input (B, T_enc, D) for encdec]
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    if cfg.encoder_layers and not cfg.mrope:
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)[None]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (B,) + params["meta"].shape)
        x = jnp.concatenate([meta.astype(cfg.dtype), x], axis=1)
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(cfg.meta_tokens)[None], (B, cfg.meta_tokens)),
             positions + cfg.meta_tokens], axis=1)
    enc = None
    if cfg.encoder_layers:
        enc = encode(params, batch["enc_input"], cfg, ctx)
    if ctx is not None:
        x = ctx.act3(x)
    x = _scan_layers(x, params["layers"], cfg, positions, ctx, enc)
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens:]
    return logits_from_hidden(params, x, cfg, ctx)


def loss_fn(params: Params, batch: Dict[str, Array], cfg: ModelConfig,
            ctx: Optional[ShardingCtx] = None) -> Array:
    """Next-token cross-entropy (labels = batch['labels'], -100 ignored)."""
    logits = forward(params, batch, cfg, ctx).astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    labels_c = jnp.where(valid, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


# =====================================================================
# serving: prefill + decode
# =====================================================================

def init_cache(cfg: ModelConfig, B: int, max_len: int) -> Params:
    """KV (+SSM) cache pytree, layer-stacked."""
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    cache: Params = {"idx": jnp.zeros((), jnp.int32)}
    S = max_len + cfg.meta_tokens
    if cfg.has_attention:
        cache["k"] = jnp.zeros((L, B, S, K, hd), cfg.dtype)
        cache["v"] = jnp.zeros((L, B, S, K, hd), cfg.dtype)
    if cfg.has_ssm:
        cache["state"] = jnp.zeros((L, B, cfg.ssm_heads, cfg.ssm_state,
                                    cfg.ssm_headdim), jnp.float32)
        cache["conv"] = jnp.zeros((L, B, cfg.ssm_conv - 1, cfg.conv_dim),
                                  jnp.float32)
    return cache


def _decode_layer(x, lp, cfg, cache_l, positions, layer_idx, ctx, enc=None,
                  static_window=None):
    new_cache = {}
    h = norm(x, lp["ln1"], cfg.norm, cfg.norm_eps)
    outs = []
    if cfg.has_attention:
        c = {"k": cache_l["k"], "v": cache_l["v"], "idx": cache_l["idx"]}
        if static_window == "window":
            from repro.models.attention import attention_decode_windowed
            a, cnew = attention_decode_windowed(
                h, lp["attn"], cfg, c, positions,
                window=cfg.sliding_window, n_meta=cfg.meta_tokens)
        elif static_window == "global":
            a, cnew = attention_decode(h, lp["attn"], cfg, c, positions,
                                       n_meta=cfg.meta_tokens)
        elif cfg.sliding_window:
            a_full, cf = attention_decode(h, lp["attn"], cfg, c, positions,
                                          window=0, n_meta=cfg.meta_tokens)
            a_win, _ = attention_decode(h, lp["attn"], cfg, c, positions,
                                        window=cfg.sliding_window,
                                        n_meta=cfg.meta_tokens)
            a = jnp.where(_is_global(layer_idx, cfg), a_full, a_win)
            cnew = cf
        else:
            a, cnew = attention_decode(h, lp["attn"], cfg, c, positions,
                                       n_meta=cfg.meta_tokens)
        if cfg.family == "hybrid":
            a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
        outs.append(a)
        new_cache["k"], new_cache["v"] = cnew["k"], cnew["v"]
    if cfg.has_ssm:
        s, snew = ssd_decode(h, lp["ssm"], cfg,
                             {"state": cache_l["state"],
                              "conv": cache_l["conv"]})
        if cfg.family == "hybrid":
            s = norm(s, lp["bn_ssm"], cfg.norm, cfg.norm_eps)
        outs.append(s)
        new_cache["state"], new_cache["conv"] = snew["state"], snew["conv"]
    x = x + (0.5 * (outs[0] + outs[1]) if len(outs) == 2 else outs[0])
    if enc is not None:
        h = norm(x, lp["ln_x"], cfg.norm, cfg.norm_eps)
        x = x + cross_attention(h, enc, lp["xattn"], cfg)
    if cfg.family != "ssm":
        h = norm(x, lp["ln2"], cfg.norm, cfg.norm_eps)
        x = x + _ffn(h, lp, cfg, ctx)
    return x, new_cache


def decode_step(params: Params, token: Array, cache: Params,
                cfg: ModelConfig, ctx: Optional[ShardingCtx] = None,
                enc: Optional[Array] = None
                ) -> Tuple[Array, Params]:
    """One decode step. token: (B, 1) -> (logits (B, 1, V), new cache)."""
    B = token.shape[0]
    if enc is not None:
        enc = enc.astype(cfg.dtype)   # raw f32 enc states would promote
    x = embed_tokens(params, token, cfg)
    idx = cache["idx"]
    if cfg.encoder_layers:
        pe = sinusoidal_positions(32768 + 8, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, idx, 1)[None].astype(cfg.dtype)
    if cfg.mrope:
        positions = jnp.broadcast_to(idx[None, None, None],
                                     (B, 1, 3)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(idx[None, None], (B, 1)).astype(jnp.int32)

    def body(carry, inp, static_window=None):
        lp, cache_l, li = inp
        y, new_c = _decode_layer(carry, lp, cfg,
                                 dict(cache_l, idx=idx), positions, li,
                                 ctx, enc, static_window=static_window)
        return y, new_c

    layer_caches = {k: v for k, v in cache.items() if k != "idx"}
    # NOTE (§Perf, refuted iteration): segmenting the DECODE scan slices
    # the layer caches per segment, which XLA lowers as full-cache
    # copies EVERY step (decode_32k: 0.073 s -> 0.899 s). The windowed
    # read (attention_decode_windowed, bit-identical logits) only pays
    # off with segment-structured cache STORAGE -- future work, gated
    # behind ctx.windowed_decode (no profile sets it).
    banded = (ctx is not None and getattr(ctx, "windowed_decode", False)
              and cfg.sliding_window and cfg.has_attention)
    if not banded:
        x, new_layer_caches = jax.lax.scan(
            body, x, (params["layers"], layer_caches,
                      jnp.arange(cfg.n_layers)))
    else:
        # §Perf: static segmentation -- windowed layers read only the
        # live window of the cache (attention_decode_windowed)
        parts = []
        for a, b, kind in layer_segments(cfg):
            seg_p = jax.tree.map(lambda t: t[a:b], params["layers"])
            seg_c = jax.tree.map(lambda t: t[a:b], layer_caches)
            x, seg_new = jax.lax.scan(
                partial(body, static_window=kind), x,
                (seg_p, seg_c, jnp.arange(a, b)))
            parts.append(seg_new)
        new_layer_caches = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    logits = logits_from_hidden(params, x, cfg, ctx)
    new_cache = dict(new_layer_caches, idx=idx + 1)
    return logits, new_cache


def prefill(params: Params, batch: Dict[str, Array], cfg: ModelConfig,
            max_len: int, ctx: Optional[ShardingCtx] = None
            ) -> Tuple[Array, Params]:
    """Prefill: run the full prompt, build the cache, return last logits.

    Implemented as forward + cache construction inside one scan so the
    cache fills in a single pass (no per-token loop).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    if cfg.encoder_layers and not cfg.mrope:
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)[None]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (B,) + params["meta"].shape)
        x = jnp.concatenate([meta.astype(cfg.dtype), x], axis=1)
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(cfg.meta_tokens)[None],
                              (B, cfg.meta_tokens)),
             positions + cfg.meta_tokens], axis=1)
    enc = encode(params, batch["enc_input"], cfg, ctx) if cfg.encoder_layers else None
    Sm = x.shape[1]
    cache = init_cache(cfg, B, max_len)

    def body(carry, inp, static_window=None):
        lp, li = inp
        if ctx is not None:
            carry = ctx.act3(carry)
        h = norm(carry, lp["ln1"], cfg.norm, cfg.norm_eps)
        new_c = {}
        outs = []
        if cfg.has_attention:
            q, k, v = _project_qkv(h, lp["attn"], cfg, positions)
            pos1d = positions if positions.ndim == 2 else positions[..., 0]
            if static_window == "window":
                from repro.models.attention import banded_core
                a = banded_core(q, k, v, pos1d, cfg,
                                window=cfg.sliding_window,
                                n_meta=cfg.meta_tokens, ctx=ctx)
            elif static_window == "global":
                m = make_mask(pos1d, pos1d, causal=True,
                              n_meta=cfg.meta_tokens)
                a = _sdpa(q, k, v, m, cfg, ctx)
            elif cfg.sliding_window:
                m_full = make_mask(pos1d, pos1d, causal=True, window=0,
                                   n_meta=cfg.meta_tokens)
                m_win = make_mask(pos1d, pos1d, causal=True,
                                  window=cfg.sliding_window,
                                  n_meta=cfg.meta_tokens)
                a_f = _sdpa(q, k, v, m_full, cfg, ctx)
                a_w = _sdpa(q, k, v, m_win, cfg, ctx)
                a = jnp.where(_is_global(li, cfg), a_f, a_w)
            else:
                m = make_mask(pos1d, pos1d, causal=True,
                              n_meta=cfg.meta_tokens)
                a = _sdpa(q, k, v, m, cfg, ctx)
            a = jnp.einsum("bshk,hkd->bsd", a,
                           lp["attn"]["wo"].reshape(cfg.n_heads, cfg.hd,
                                                    cfg.d_model))
            if cfg.family == "hybrid":
                a = norm(a, lp["bn_attn"], cfg.norm, cfg.norm_eps)
            outs.append(a)
            Smax = max_len + cfg.meta_tokens
            pad = Smax - Sm
            new_c["k"] = jnp.pad(k.astype(cfg.dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_c["v"] = jnp.pad(v.astype(cfg.dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        if cfg.has_ssm:
            s, snew = ssd_forward(h, lp["ssm"], cfg)
            if cfg.family == "hybrid":
                s = norm(s, lp["bn_ssm"], cfg.norm, cfg.norm_eps)
            outs.append(s)
            new_c["state"], new_c["conv"] = snew["state"], snew["conv"]
        y = carry + (0.5 * (outs[0] + outs[1]) if len(outs) == 2 else outs[0])
        if enc is not None:
            h2 = norm(y, lp["ln_x"], cfg.norm, cfg.norm_eps)
            y = y + cross_attention(h2, enc, lp["xattn"], cfg)
        if cfg.family != "ssm":
            h3 = norm(y, lp["ln2"], cfg.norm, cfg.norm_eps)
            y = y + _ffn(h3, lp, cfg, ctx)
        return y, new_c

    banded = (ctx is not None and ctx.banded and cfg.sliding_window
              and cfg.has_attention)
    if not banded:
        fn = jax.checkpoint(body)
        x, layer_caches = jax.lax.scan(fn, x, (params["layers"],
                                               jnp.arange(cfg.n_layers)))
    else:
        # §Perf: segment the stack so windowed layers run the banded
        # kernel with a STATIC window (see _scan_layers)
        cache_parts = []
        for a, b, kind in layer_segments(cfg):
            seg_p = jax.tree.map(lambda t: t[a:b], params["layers"])
            fn = jax.checkpoint(partial(body, static_window=kind))
            x, seg_caches = jax.lax.scan(fn, x, (seg_p,
                                                 jnp.arange(a, b)))
            cache_parts.append(seg_caches)
        layer_caches = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *cache_parts)
    if cfg.meta_tokens:
        x_last = x[:, -1:]
    else:
        x_last = x[:, -1:]
    logits = logits_from_hidden(params, x_last, cfg, ctx)
    cache = dict(layer_caches, idx=jnp.asarray(Sm, jnp.int32))
    return logits, cache
