"""Unified model configuration covering every assigned architecture family.

One dataclass; families select features:
  dense   -- GQA transformer (internlm2, phi3, qwen3, command-r)
  moe     -- + mixture-of-experts FFN (llama4-scout, olmoe)
  ssm     -- attention-free Mamba-2 SSD stack (mamba2-130m)
  hybrid  -- parallel attention + SSM heads per block (hymba)
  encdec  -- encoder-decoder with cross-attention (whisper; audio frontend
             is a ShapeDtypeStruct stub per the assignment)
  vlm     -- decoder with M-RoPE positions (qwen2-vl; vision frontend stub)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    mlp: str = "swiglu"             # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope_theta: float = 1e6
    mrope: bool = False             # M-RoPE (t/h/w sections, qwen2-vl)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False     # llama4-style always-on shared expert
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0              # d_state (N)
    ssm_expand: int = 2
    ssm_headdim: int = 64           # P
    ssm_groups: int = 1             # G (B/C groups)
    ssm_conv: int = 4               # causal conv width
    ssm_chunk: int = 256            # SSD chunk length

    # --- attention variants ---
    sliding_window: int = 0         # 0 = full; hymba uses 1024
    global_attn_layers: Tuple[int, ...] = ()   # layers that stay full-attn
    meta_tokens: int = 0            # hymba learnable prefix tokens

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_ctx: int = 0            # 1500 audio frames after conv stub

    dtype: Any = jnp.bfloat16

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:     # H_ssm = d_inner / P
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:      # conv runs over [x, B, C]
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid w/ sliding attn)."""
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.sliding_window > 0)

    # ---- parameter counting (for 6ND roofline cross-check) ----
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab
        nrm = 2 * D if self.norm == "layernorm" else D  # scale (+ bias)
        n = V * D                                   # embed
        if not self.tie_embeddings:
            n += D * V                              # lm_head
        n += nrm                                    # final norm

        def attn_params() -> int:
            hd = self.hd
            p = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
                + self.n_heads * hd * D
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_ffn() -> int:
            return 3 * D * F if self.mlp == "swiglu" else 2 * D * F

        def ssm_params() -> int:
            di, G, N, H = (self.d_inner, self.ssm_groups, self.ssm_state,
                           self.ssm_heads)
            p = D * (2 * di + 2 * G * N + H)        # in_proj [z,x,B,C,dt]
            p += self.conv_dim * (self.ssm_conv + 1)  # conv w + bias
            p += 3 * H + di                         # A_log, D, dt_bias, norm
            p += di * D                             # out_proj
            return p

        per_layer = 2 * nrm                         # ln1, ln2
        if self.has_attention:
            per_layer += attn_params()
        if self.has_ssm:
            per_layer += ssm_params()
            if self.family == "hybrid":
                per_layer += 2 * nrm                # branch norms
        if self.family in ("dense", "encdec", "vlm", "hybrid"):
            per_layer += dense_ffn()
        if self.is_moe:
            e = (self.top_k if active_only else self.n_experts)
            per_layer += D * self.n_experts         # router (always dense)
            per_layer += e * 3 * D * F
            if self.shared_expert:
                per_layer += 3 * D * F
        n += self.n_layers * per_layer
        if self.encoder_layers:
            enc_per = 2 * nrm + attn_params() + dense_ffn()
            n += self.encoder_layers * enc_per + nrm   # + enc final norm
            n += self.n_layers * (attn_params() + nrm)  # dec cross-attn + ln_x
        if self.meta_tokens:
            n += self.meta_tokens * D
        return n
