"""Primitive layers: norms, MLPs, RoPE / M-RoPE. Pure functions on pytrees."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Array]


def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x: Array, p: Params, kind: str, eps: float) -> Array:
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


def swiglu(x: Array, p: Params) -> Array:
    """SwiGLU MLP: silu(x W_gate) * (x W_up) W_down."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", g * u, p["w_down"])


def gelu_mlp(x: Array, p: Params) -> Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["w_up"]))
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def mlp(x: Array, p: Params, kind: str) -> Array:
    return swiglu(x, p) if kind == "swiglu" else gelu_mlp(x, p)


# ---------------------------------------------------------------- RoPE

def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x (same dtype)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """M-RoPE (qwen2-vl): positions (B, S, 3) = (t, h, w) indices.

    The hd/2 frequency slots are split into three contiguous sections;
    each section rotates by its own position stream. For pure text
    (t == h == w) this reduces exactly to standard RoPE.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=hd // 2)     # (hd/2,)
    pos = positions.astype(jnp.float32)[..., sec_id]     # (B, S, hd/2)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
