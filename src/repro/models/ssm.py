"""Mamba-2 SSD (state-space duality) mixer -- arXiv:2405.21060.

Chunked "block-decomposition" algorithm for training/prefill (intra-chunk
quadratic term + inter-chunk state recurrence), single-step recurrence for
decode. Selective-scan numerics run in fp32 (exp of decay cumsums),
matmul-heavy terms stay in the model dtype for the MXU.

Shapes (per layer): d_inner = expand*D, P = headdim, H = d_inner/P heads,
N = d_state, G = n_groups (B/C shared across H/G heads).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig
from repro.models.layers import rmsnorm

Array = jax.Array
Params = Dict[str, Array]


def _causal_conv(xBC: Array, w: Array, b: Array, k: int) -> Array:
    """Depthwise causal conv, width k, via k shifted adds (k is 4)."""
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    S = xBC.shape[1]
    for j in range(k):
        out = out + pad[:, j:j + S, :].astype(jnp.float32) * w[:, j]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def ssd_forward(x: Array, p: Params, cfg: ModelConfig
                ) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence SSD. x: (B, S, D) -> (y: (B, S, D), final ssm cache)."""
    B, S0, D = x.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    Q = min(cfg.ssm_chunk, S0)
    # pad to a chunk multiple; padded steps get dt == 0 (identity state
    # update, zero output contribution) so the recurrence is unaffected
    S = -(-S0 // Q) * Q
    if S != S0:
        x = jnp.pad(x, ((0, 0), (0, S - S0), (0, 0)))
    valid = (jnp.arange(S) < S0)[None, :, None]          # (1, S, 1)
    nc = S // Q

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + cfg.conv_dim]
    dt_raw = zxbcdt[..., di + cfg.conv_dim:]
    conv_tail = xBC[:, S0 - (cfg.ssm_conv - 1):S0, :]    # decode carry (raw)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"], cfg.ssm_conv)
    xs = xBC[..., :di]
    Bm = xBC[..., di:di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = dt * valid                                                  # mask pad
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                      # (H,)
    dA = dt * A                                                       # (B,S,H)

    xh = xs.reshape(B, S, H, P)
    rep = H // G                              # heads per B/C group
    # chunked views
    dAc = dA.reshape(B, nc, Q, H)
    dtc = dt.reshape(B, nc, Q, H)
    xc = xh.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, G, N)
    Cc = Cm.reshape(B, nc, Q, G, N)

    cum = jnp.cumsum(dAc, axis=2)                                     # (B,nc,Q,H)
    # ---- intra-chunk (quadratic, attention-like) ----
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                           # (B,nc,G,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])    # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], decay, 0.0)            # q>=k
    M = M * dtc[:, :, None, :, :]                                     # * dt[k]
    # scores per head: CB group-broadcast to heads
    CBh = jnp.repeat(CB, rep, axis=2)                                 # (B,nc,H,Q,K)
    Mh = jnp.moveaxis(M, -1, 2)                                       # (B,nc,H,Q,K)
    W = CBh * Mh
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", W.astype(x.dtype), xc)

    # ---- chunk states ----
    # S_c = sum_k exp(cum[last]-cum[k]) * dt[k] * B[k] (x) x[k]
    seg = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                      # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3)                                  # (B,nc,Q,H,N)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                        seg, Bh.astype(jnp.float32), xc.astype(jnp.float32))

    # ---- inter-chunk recurrence over nc ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])                           # (B,nc,H)

    def step(carry, inp):
        st_in = carry                                                 # (B,H,N,P)
        dec, s_new = inp                                              # (B,H),(B,H,N,P)
        st_out = st_in * dec[..., None, None] + s_new
        return st_out, st_in                                          # emit ENTERING state

    init = jnp.zeros((B, H, N, P), jnp.float32)
    final_state, entering = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)                           # (B,nc,H,N,P)

    Ch = jnp.repeat(Cc, rep, axis=3)                                  # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqh,bcqhn,bchnp->bcqhp",
                         jnp.exp(cum), Ch.astype(jnp.float32), entering)

    y = (y_intra.astype(jnp.float32) + y_inter).reshape(B, S, H, P)
    y = y + p["D_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)[:, :S0]
    z = z[:, :S0]
    # gated RMSNorm + out projection
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    cache = {"state": final_state,
             "conv": conv_tail.astype(jnp.float32)}
    return out, cache


def ssd_decode(x: Array, p: Params, cfg: ModelConfig,
               cache: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    """One-token recurrent step. x: (B, 1, D), cache from ssd_forward."""
    B, _, D = x.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    k = cfg.ssm_conv
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]          # (B,E)
    z = zxbcdt[:, :di]
    xBC_new = zxbcdt[:, di:di + cfg.conv_dim]
    dt_raw = zxbcdt[:, di + cfg.conv_dim:]

    conv_buf = jnp.concatenate(
        [cache["conv"], xBC_new[:, None, :].astype(jnp.float32)], axis=1)  # (B,k,C)
    xBC = jnp.einsum("bkc,ck->bc", conv_buf, p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(xBC + p["conv_b"]).astype(x.dtype)

    xs = xBC[:, :di].reshape(B, H, P)
    Bm = xBC[:, di:di + G * N].reshape(B, G, N)
    Cm = xBC[:, di + G * N:].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                                   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A)                                              # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, Bh.astype(jnp.float32),
                     xs.astype(jnp.float32))
    state = cache["state"] * dec[..., None, None] + upd                # (B,H,N,P)

    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + p["D_skip"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32))[:, None].astype(x.dtype),
                p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_conv = conv_buf[:, 1:]                                         # (B,k-1,C)
    return out, {"state": state, "conv": new_conv}
