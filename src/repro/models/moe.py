"""Mixture-of-Experts FFN with expert parallelism.

Three execution paths, one routing algorithm (top-k, per-shard capacity,
token dropping -- the GShard/Switch discipline):

  * local          -- no mesh: capacity-buffer routing on one device
                      (smoke tests, small-scale training).
  * EP + all-to-all -- shard_map over the mesh; tokens sharded over
                      (dp axes x ep axis), experts sharded over the EP
                      axis. Dispatch/combine are `lax.all_to_all`s, the
                      canonical large-scale MoE pattern. Used when the
                      flattened token count divides the EP axis (train /
                      prefill).
  * EP + replicate -- decode: the token batch is tiny (B tokens), so
                      tokens are replicated across the EP axis, each
                      shard computes only its local experts, and a psum
                      combines. Avoids degenerate 1-token all-to-alls.

The routing scatter/gather is LOCAL in all paths (per-device buffers),
so GSPMD never sees a distributed scatter -- only dense einsums and
explicit collectives. FLOPs stay honest at ~top_k x FFN (+ capacity
slack), which the roofline reads off the compiled HLO.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.configs import ModelConfig
from repro.models.layers import swiglu

Array = jax.Array
Params = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """How the model is laid out on the mesh (see sharding/rules.py).

    The constrain helpers pin ACTIVATION shardings inside the model --
    without them GSPMD is free to pick catastrophic layouts for the GQA
    attention einsums (observed: batch replicated + kv-heads padded
    8->16, turning 2.7 GiB/device score tensors into 80 GiB/device).
    """
    mesh: object                     # jax.sharding.Mesh
    dp_axes: Tuple[str, ...]         # batch axes, e.g. ('pod', 'data')
    tp_axis: str = "model"           # tensor/expert-parallel axis
    seq_sharded: bool = True         # shard sequence over tp_axis too
    bf16_scores: bool = False        # §Perf: half-width score tensors
    banded: bool = False             # §Perf: banded sliding-window attn
    flash_vjp: bool = False          # §Perf: LSE-saving attention VJP

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def seq_axis(self):
        return self.tp_axis if self.seq_sharded else None

    def constrain(self, x: Array, *axes) -> Array:
        """with_sharding_constraint, dropping non-divisible axes."""
        from jax.sharding import NamedSharding
        spec = []
        for i, a in enumerate(axes):
            if a is None:
                spec.append(None)
                continue
            t = list(a) if isinstance(a, tuple) else [a]
            def size(ax_list):
                s = 1
                for n in ax_list:
                    s *= self.mesh.shape[n]
                return s
            while t and x.shape[i] % size(t) != 0:
                t.pop()
            spec.append(tuple(t) if len(t) > 1 else (t[0] if t else None))
        sh = NamedSharding(self.mesh, P(*spec))
        return jax.lax.with_sharding_constraint(x, sh)

    # canonical activation layouts -------------------------------------
    def act3(self, x: Array) -> Array:          # (B, S, D) residual
        return self.constrain(x, self.dp_axes, self.seq_axis, None)

    def act_q(self, x: Array) -> Array:         # (B, S, H, hd)
        return self.constrain(x, self.dp_axes, self.seq_axis, None, None)

    def act_kv_gathered(self, x: Array) -> Array:   # (B, S, K, hd) full-S
        return self.constrain(x, self.dp_axes, None, None, None)

    def act_scores(self, x: Array) -> Array:    # (B, K, rep, Sq, Sk)
        return self.constrain(x, self.dp_axes, None, None, self.seq_axis,
                              None)

    def act_logits(self, x: Array) -> Array:    # (B, S, V)
        return self.constrain(x, self.dp_axes, self.seq_axis, None)


def _route(x_flat: Array, gates: Array, cfg: ModelConfig,
           capacity: int) -> Tuple[Array, Array, Array, Array]:
    """Top-k routing into per-expert capacity buffers (local).

    x_flat: (T, D), gates: (T, E) fp32 probabilities.
    Returns (buf (E, C, D), tok_ids (T*k,), slot (T*k,), weight (T*k,)).
    Slot == C means dropped.
    """
    T, D = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    w, e_idx = jax.lax.top_k(gates, k)                   # (T, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)  # renormalize
    e_flat = e_idx.reshape(-1)                           # (T*k,)
    w_flat = w.reshape(-1).astype(x_flat.dtype)
    tok_ids = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*k, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot          # position in expert
    slot = jnp.sum(ranks * onehot, axis=1)               # (T*k,)
    keep = slot < capacity
    slot_c = jnp.where(keep, slot, capacity - 1)
    contrib = jnp.where(keep[:, None], x_flat[tok_ids], 0)
    buf = jnp.zeros((E, capacity, D), x_flat.dtype)
    buf = buf.at[e_flat, slot_c].add(contrib)
    slot_out = jnp.where(keep, slot, capacity)           # C == dropped
    return buf, tok_ids, slot_out, w_flat


def _expert_ffn(buf: Array, wg: Array, wu: Array, wd: Array) -> Array:
    """(E, C, D) x per-expert SwiGLU -> (E, C, D)."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", g * u, wd)


def _combine(out_buf: Array, tok_ids: Array, e_flat_slots: Tuple[Array, Array],
             w_flat: Array, T: int) -> Array:
    """Gather expert outputs back to token order, weighted-sum top-k."""
    e_flat, slot = e_flat_slots
    E, C1, D = out_buf.shape          # C1 == capacity (+ pad row handled below)
    padded = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, D), out_buf.dtype)], axis=1)
    vals = padded[e_flat, slot]                           # (T*k, D); C==drop->0
    y = jnp.zeros((T, D), out_buf.dtype)
    return y.at[tok_ids].add(vals * w_flat[:, None])


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, int(c))


def _moe_local(x: Array, p: Params, cfg: ModelConfig) -> Array:
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32), -1)
    C = _capacity(T, cfg)
    w, e_idx = jax.lax.top_k(gates, cfg.top_k)
    buf, tok_ids, slot, w_flat = _route(xf, gates, cfg, C)
    out_buf = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    e_flat = e_idx.reshape(-1)
    y = _combine(out_buf, tok_ids, (e_flat, slot), w_flat, T)
    return y.reshape(B, S, D)


def _moe_ep_a2a(x: Array, p: Params, cfg: ModelConfig,
                ctx: ShardingCtx) -> Array:
    """Tokens sharded over (dp x ep); dispatch via all_to_all."""
    ep = ctx.ep_size
    E_l = cfg.n_experts // ep
    ax = ctx.tp_axis

    def body(xl, router, wg, wu, wd):
        # xl: (B_l, S_l, D); wg/wu/wd: (E_l, D, F)
        Bl, Sl, D = xl.shape
        T_l = Bl * Sl
        xf = xl.reshape(T_l, D)
        gates = jax.nn.softmax(
            jnp.einsum("td,de->te", xf, router).astype(jnp.float32), -1)
        C = _capacity(T_l, cfg)
        w, e_idx = jax.lax.top_k(gates, cfg.top_k)
        buf, tok_ids, slot, w_flat = _route(xf, gates, cfg, C)
        # (E, C, D) -> (ep, E_l, C, D) -> exchange -> same shape,
        # first axis now indexes SOURCE shard
        send = buf.reshape(ep, E_l, C, D)
        recv = jax.lax.all_to_all(send, ax, split_axis=0, concat_axis=0,
                                  tiled=False)
        work = jnp.swapaxes(recv, 0, 1).reshape(E_l, ep * C, D)
        out = _expert_ffn(work, wg, wu, wd)
        back = jnp.swapaxes(out.reshape(E_l, ep, C, D), 0, 1)
        ret = jax.lax.all_to_all(back, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
        out_buf = ret.reshape(cfg.n_experts, C, D)
        e_flat = e_idx.reshape(-1)
        y = _combine(out_buf, tok_ids, (e_flat, slot), w_flat, T_l)
        return y.reshape(Bl, Sl, D)

    dp = ctx.dp_axes
    seq = ax if ctx.seq_sharded else None
    x_spec = P(dp, seq, None)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(x_spec, P(None, None), P(ax, None, None),
                  P(ax, None, None), P(ax, None, None)),
        out_specs=x_spec, check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_ep_replicated(x: Array, p: Params, cfg: ModelConfig,
                       ctx: ShardingCtx) -> Array:
    """Decode path: tokens replicated over EP axis, psum combine."""
    ep = ctx.ep_size
    E_l = cfg.n_experts // ep
    ax = ctx.tp_axis

    def body(xl, router, wg, wu, wd):
        Bl, Sl, D = xl.shape
        T_l = Bl * Sl
        xf = xl.reshape(T_l, D)
        gates = jax.nn.softmax(
            jnp.einsum("td,de->te", xf, router).astype(jnp.float32), -1)
        C = _capacity(T_l, cfg)
        w, e_idx = jax.lax.top_k(gates, cfg.top_k)
        buf, tok_ids, slot, w_flat = _route(xf, gates, cfg, C)
        shard = jax.lax.axis_index(ax)
        local = jax.lax.dynamic_slice_in_dim(buf, shard * E_l, E_l, axis=0)
        out_local = _expert_ffn(local, wg, wu, wd)
        # scatter local outputs back into the full (E, C, D) frame
        out_buf = jnp.zeros_like(buf)
        out_buf = jax.lax.dynamic_update_slice_in_dim(
            out_buf, out_local, shard * E_l, axis=0)
        e_flat = e_idx.reshape(-1)
        y = _combine(out_buf, tok_ids, (e_flat, slot), w_flat, T_l)
        y = jax.lax.psum(y, ax)
        return y.reshape(Bl, Sl, D)

    dp = ctx.dp_axes
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), P(None, None), P(ax, None, None),
                  P(ax, None, None), P(ax, None, None)),
        out_specs=P(dp, None, None), check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_ffn(x: Array, p: Params, cfg: ModelConfig,
            ctx: Optional[ShardingCtx] = None) -> Array:
    """MoE FFN with optional llama4-style shared expert."""
    if ctx is None:
        y = _moe_local(x, p, cfg)
    else:
        B, S, _ = x.shape
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        ep = ctx.ep_size
        a2a_ok = (ctx.seq_sharded and B % dp_size == 0 and S % ep == 0
                  and cfg.n_experts % ep == 0)
        if a2a_ok:
            y = _moe_ep_a2a(x, p, cfg, ctx)
        elif B % dp_size == 0 and cfg.n_experts % ep == 0:
            y = _moe_ep_replicated(x, p, cfg, ctx)
        else:
            y = _moe_local(x, p, cfg)
    if cfg.shared_expert:
        y = y + swiglu(x, p["shared"])
    return y
