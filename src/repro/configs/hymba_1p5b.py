"""hymba-1.5b [hybrid] -- 32L d=1600 25H (kv 5) d_ff=5504 vocab=32001,
parallel attention + Mamba heads per block, ssm_state=16, sliding-window
attention (1024) with 3 full-attention layers {first, mid, last}, and 128
learnable meta tokens (attention sinks). [arXiv:2411.13676; hf]
"""
import dataclasses
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64, ssm_state=16, ssm_expand=2, ssm_headdim=64,
    ssm_groups=1, ssm_conv=4, sliding_window=1024,
    global_attn_layers=(0, 15, 31), meta_tokens=128, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, ssm_state=8, ssm_headdim=16, ssm_chunk=16,
    sliding_window=16, global_attn_layers=(0,), meta_tokens=8)
