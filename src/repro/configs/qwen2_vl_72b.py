"""qwen2-vl-72b [vlm] -- 80L d=8192 64H (kv 8) d_ff=29568 vocab=152064,
M-RoPE + dynamic resolution. The vision frontend (ViT patch encoder) is a
STUB per the assignment: input_specs() provides token ids plus (B, S, 3)
M-RoPE (t, h, w) position streams; image patches arrive as precomputed
embeddings merged upstream. [arXiv:2409.12191; hf]
"""
import dataclasses
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, mrope_sections=(2, 3, 3))
