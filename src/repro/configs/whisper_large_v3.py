"""whisper-large-v3 [audio] -- enc-dec, 32+32L d=1280 20H (kv 20)
d_ff=5120 vocab=51866. Conv/audio frontend is a STUB: input_specs()
provides precomputed (B, 1500, 1280) frame embeddings per the assignment.
[arXiv:2212.04356; unverified]
"""
import dataclasses
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, mlp="gelu", norm="layernorm",
    encoder_layers=32, encoder_ctx=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, encoder_layers=2, encoder_ctx=32)
