"""The paper's own workload config: the HOG+SVM detection co-processor.

Geometry and numerics per Nguyen et al. (2022); PERF variant carries the
beyond-paper §Perf settings. Used by launch/dryrun.py (--arch
hog_svm_coproc), benchmarks/bench_accuracy.py and bench_timing.py.
"""
import dataclasses

from repro.core.hog import HOGConfig
from repro.core.svm import SVMTrainConfig
from repro.data.synth_pedestrian import PedestrianDataConfig

# faithful: fp32 datapath, CORDIC magnitude/angle, NR rsqrt
FAITHFUL = HOGConfig(mode="cordic")

# TPU-native default: sector-compare binning, hardware rsqrt
CONFIG = HOGConfig(mode="sector")

# §Perf: bf16 descriptors + bf16 SVM weights (fp32 accumulation)
PERF = dataclasses.replace(CONFIG, feat_dtype="bf16")

# the paper's actual datapath: integer CORDIC gradients, int16 cell
# histograms, int8 block descriptors, int8 scoring matmul (DESIGN.md §12)
QUANT = HOGConfig(mode="cordic", numerics="fixed")

TRAIN = SVMTrainConfig(steps=4000, neg_weight=6.0)
DATA = PedestrianDataConfig()          # paper split: 4202/2795, 160/134
BATCH_PER_POD = 16384                  # dry-run serving batch (256 chips)
