"""command-r-35b [dense] -- 40L d=8192 64H (kv 8) d_ff=22528 vocab=256000,
GQA, no-bias (all projections bias-free, as everywhere in this repo).
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
import dataclasses
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab=256000, rope_theta=1e4, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512)
