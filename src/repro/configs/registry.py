"""Architecture registry: --arch <id> -> config, smoke config, input specs.

Also defines the assigned input-shape set and the skip rules:
  * decode shapes lower `serve_step` (one token + KV cache), not train_step
  * long_500k requires sub-quadratic attention -> SSM/hybrid only
  * hog_svm_coproc is the paper's own workload (batched window detection)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.configs import ModelConfig

ARCH_IDS = (
    "llama4-scout-17b-a16e",
    "olmoe-1b-7b",
    "whisper-large-v3",
    "internlm2-20b",
    "phi3-medium-14b",
    "qwen3-14b",
    "command-r-35b",
    "qwen2-vl-72b",
    "mamba2-130m",
    "hymba-1.5b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "p")
            for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)
SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch == "hog_svm_coproc":
        raise ValueError("hog_svm_coproc is handled by repro.core, "
                         "see launch/dryrun.py")
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). Encodes the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attn): 500k decode needs sub-quadratic attention"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                smoke: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B = 4 if smoke else shape.global_batch
    S = 32 if smoke else shape.seq_len
    i32 = jnp.int32
    f = jax.ShapeDtypeStruct

    def tok(b, s):
        return f((b, s), i32)

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = tok(B, S)
        specs["labels"] = tok(B, S)
        if cfg.mrope:
            specs["positions"] = f((B, S, 3), i32)
        if cfg.encoder_layers:
            specs["enc_input"] = f((B, cfg.encoder_ctx, cfg.d_model),
                                   jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = tok(B, S)
        if cfg.mrope:
            specs["positions"] = f((B, S, 3), i32)
        if cfg.encoder_layers:
            specs["enc_input"] = f((B, cfg.encoder_ctx, cfg.d_model),
                                   jnp.float32)
    else:  # decode: one new token against a cache of length seq_len
        specs["token"] = tok(B, 1)
        if cfg.encoder_layers:
            specs["enc_states"] = f((B, cfg.encoder_ctx, cfg.d_model),
                                    jnp.float32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec,
                smoke: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct for the decode-shape KV/SSM cache."""
    from repro.models.model import init_cache
    B = 4 if smoke else shape.global_batch
    S = 64 if smoke else shape.seq_len
    return jax.eval_shape(lambda: init_cache(cfg, B, S))
