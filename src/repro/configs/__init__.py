from repro.configs.registry import (ARCH_IDS, SHAPES, SHAPE_BY_NAME,
                                    ShapeSpec, get_config, input_specs,
                                    cache_specs, shape_applicable)
