"""mamba2-130m [ssm] -- 24L d=768, attention-free, vocab=50280,
SSD (state-space duality), d_state=128, expand=2, headdim=64.
[arXiv:2405.21060; unverified]
"""
import dataclasses
from repro.models.configs import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_groups=1, ssm_conv=4, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=512, ssm_state=16,
    ssm_headdim=16, ssm_chunk=16)
