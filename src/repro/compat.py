"""Cross-version JAX compatibility shims.

The repo targets the `jax.shard_map` public API (jax >= 0.5, `check_vma`
kwarg). On the pinned container jax (0.4.x) that symbol lives at
`jax.experimental.shard_map.shard_map` and the kwarg is `check_rep`.
Import `shard_map` from here everywhere so call sites stay on the new
spelling.
"""
from __future__ import annotations

import functools

try:                                    # jax >= 0.5
    from jax import shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

__all__ = ["shard_map"]
