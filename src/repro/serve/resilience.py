"""Resilience primitives for the serving stack (DESIGN.md §14).

A real-time stream is only as good as its worst frame: the service
cannot block on a dead worker, burn compute on a request whose caller
already gave up, or let one slow batch snowball into a backlog of
doomed work. This module holds the four host-side mechanisms the
engine composes -- all plain Python, deterministic, and unit-testable
without a device:

  * `RetryPolicy`    -- capped exponential backoff with seeded jitter;
                        drives both in-flight request retries and the
                        supervisor's restart pacing.
  * `CircuitBreaker` -- closed -> open after N CONSECUTIVE worker
                        failures (fail-fast admission), half-open probe
                        after a cooldown, closed again on success. The
                        clock is injectable so tests never sleep.
  * `RollingLatency` -- fixed-window latency ring with p50/p99; feeds
                        the stats() telemetry and the ladder.
  * `DegradationLadder` -- hysteresis state machine over quality rungs
                        (full -> cascade -> coarse, or full -> reduced):
                        degrade one rung when rolling p99 or queue depth
                        crosses the overload line, climb back one rung
                        only after `recover_dwell` consecutive healthy
                        observations below the (lower) recovery line.

`ResilienceConfig` is the JSON-round-trippable knob block nested into
`api.config.ServiceConfig`; every default is inert (no deadline, ladder
off) so an unconfigured service behaves exactly like the pre-resilience
engine, with supervision and transient-retry always on.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import time
from typing import Optional, Sequence, Tuple

import numpy as np


# -------------------------------------------------------------- policies

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    `delay_ms(attempt)` for attempt = 1, 2, ... doubles from
    `backoff_base_ms` up to `backoff_cap_ms`; `jitter` subtracts up to
    that fraction of the delay, drawn from the caller's seeded rng so a
    chaos run replays byte-identically."""

    max_attempts: int = 3          # total tries per request (1 = never retry)
    backoff_base_ms: float = 5.0
    backoff_cap_ms: float = 200.0
    jitter: float = 0.5            # fraction of the delay jittered away
    seed: int = 0                  # seeds the service's backoff rng

    def delay_ms(self, attempt: int,
                 rng: Optional[random.Random] = None) -> float:
        base = min(float(self.backoff_cap_ms),
                   float(self.backoff_base_ms) * (2 ** max(0, attempt - 1)))
        if self.jitter <= 0.0:
            return base
        r = (rng if rng is not None
             else random.Random(self.seed * 1000003 + attempt)).random()
        return base * (1.0 - float(self.jitter) * r)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Serving resilience knobs (engine defaults are inert).

    deadline_ms        per-request compute budget; expired requests are
                       shed BEFORE compute with a DeadlineExceeded
                       payload (0 = no deadline)
    retry              in-flight retry + restart backoff policy
    breaker_failures   consecutive worker failures that trip the
                       circuit breaker to fail-fast admission
    breaker_reset_s    open -> half-open probe cooldown
    degrade_p99_ms     rolling-p99 latency that drops the service one
                       ladder rung (0 = ladder disabled)
    recover_p99_ms     p99 below which an observation counts as healthy
                       (0 = degrade_p99_ms / 2) -- the hysteresis band
    degrade_depth      pending-queue depth that also triggers a
                       degrade (0 = depth trigger off)
    recover_dwell      consecutive healthy batches required per upward
                       rung
    latency_window     rolling window size (requests) for p50/p99
    """

    deadline_ms: float = 0.0
    retry: RetryPolicy = RetryPolicy()
    breaker_failures: int = 5
    breaker_reset_s: float = 5.0
    degrade_p99_ms: float = 0.0
    recover_p99_ms: float = 0.0
    degrade_depth: int = 0
    recover_dwell: int = 3
    latency_window: int = 64


# -------------------------------------------------------- circuit breaker

class CircuitBreaker:
    """closed -> open after `max_failures` CONSECUTIVE failures;
    open -> half_open once `reset_after_s` elapses (one probe worker);
    half_open -> closed on the first success, -> open again on failure.

    `admit()` is the submission gate (False = fail fast), `probe_due()`
    is the supervisor's respawn gate (transitions open -> half_open).
    The clock is injectable for deterministic tests."""

    def __init__(self, max_failures: int = 5, reset_after_s: float = 5.0,
                 clock=time.monotonic):
        self.max_failures = max(1, int(max_failures))
        self.reset_after_s = float(reset_after_s)
        self._clock = clock
        self.state = "closed"
        self.consecutive = 0
        self.opened_at: Optional[float] = None

    def record_failure(self) -> None:
        self.consecutive += 1
        if self.consecutive >= self.max_failures:
            self.state = "open"
            self.opened_at = self._clock()

    def record_success(self) -> None:
        self.consecutive = 0
        self.state = "closed"
        self.opened_at = None

    def _cooled(self) -> bool:
        return (self.opened_at is not None
                and self._clock() - self.opened_at >= self.reset_after_s)

    def admit(self) -> bool:
        """May new work enter? False only while open and still cooling
        (a cooled-but-unprobed breaker admits: the probe is due)."""
        return self.state != "open" or self._cooled()

    def probe_due(self) -> bool:
        """Supervisor gate: True when a probe worker should run. An
        open breaker whose cooldown elapsed transitions to half_open."""
        if self.state == "open" and self._cooled():
            self.state = "half_open"
        return self.state != "open"

    def snapshot(self) -> dict:
        return {"state": self.state, "consecutive": self.consecutive}


# ------------------------------------------------------- rolling latency

class RollingLatency:
    """Fixed-size rolling window of per-request latencies (ms)."""

    def __init__(self, window: int = 64):
        self._buf: "collections.deque[float]" = \
            collections.deque(maxlen=max(1, int(window)))

    def add(self, ms: float) -> None:
        self._buf.append(float(ms))

    def __len__(self) -> int:
        return len(self._buf)

    def percentile(self, p: float) -> float:
        if not self._buf:
            return 0.0
        return float(np.percentile(np.asarray(self._buf), p))

    def snapshot(self) -> dict:
        return {"p50": round(self.percentile(50), 3),
                "p99": round(self.percentile(99), 3),
                "window": len(self._buf)}


# ---------------------------------------------------- degradation ladder

class DegradationLadder:
    """Hysteresis state machine over quality rungs.

    `rungs[0]` is the full pipeline; each later rung is cheaper and
    lower quality. `observe(p99_ms, depth, n_samples)` runs once per
    served batch: overload (p99 >= degrade_p99_ms with a full enough
    window, OR depth >= degrade_depth) drops ONE rung immediately;
    recovery requires `recover_dwell` CONSECUTIVE healthy observations
    (p99 <= recover_p99_ms AND depth <= degrade_depth / 2) per upward
    rung -- the hysteresis band that stops flapping. With both triggers
    at 0 the ladder is inert and `rung` stays `rungs[0]`."""

    def __init__(self, rungs: Sequence[str],
                 degrade_p99_ms: float = 0.0,
                 recover_p99_ms: float = 0.0,
                 degrade_depth: int = 0,
                 recover_dwell: int = 3,
                 min_samples: int = 4):
        if not rungs:
            raise ValueError("ladder needs at least one rung")
        self.rungs: Tuple[str, ...] = tuple(rungs)
        self.degrade_p99_ms = float(degrade_p99_ms)
        self.recover_p99_ms = (float(recover_p99_ms) if recover_p99_ms > 0
                               else self.degrade_p99_ms / 2.0)
        self.degrade_depth = int(degrade_depth)
        self.recover_dwell = max(1, int(recover_dwell))
        self.min_samples = max(1, int(min_samples))
        self.level = 0
        self.transitions = 0
        self._healthy = 0

    @property
    def enabled(self) -> bool:
        return (self.degrade_p99_ms > 0 or self.degrade_depth > 0) \
            and len(self.rungs) > 1

    @property
    def rung(self) -> str:
        return self.rungs[self.level]

    def observe(self, p99_ms: float, depth: int, n_samples: int) -> str:
        if not self.enabled:
            return self.rung
        overload = ((self.degrade_p99_ms > 0
                     and n_samples >= self.min_samples
                     and p99_ms >= self.degrade_p99_ms)
                    or (self.degrade_depth > 0
                        and depth >= self.degrade_depth))
        healthy = ((self.degrade_p99_ms <= 0
                    or p99_ms <= self.recover_p99_ms)
                   and (self.degrade_depth <= 0
                        or depth <= self.degrade_depth // 2))
        if overload:
            self._healthy = 0
            if self.level < len(self.rungs) - 1:
                self.level += 1
                self.transitions += 1
        elif healthy and self.level > 0:
            self._healthy += 1
            if self._healthy >= self.recover_dwell:
                self.level -= 1
                self.transitions += 1
                self._healthy = 0
        else:
            self._healthy = 0
        return self.rung

    def snapshot(self) -> dict:
        return {"rung": self.rung, "level": self.level,
                "rungs": list(self.rungs),
                "transitions": self.transitions}
