"""Serving engines.

`DetectionService` -- the paper's co-processor as a batched service:
window requests (RGB windows) are queued, padded to the compiled batch
size, classified in one TPU step, results returned per request. This is
the Fig. 6 datapath plus the batching/queueing layer an FPGA front-end
would implement in NIOS/ARM (the paper's "future development" §VI).

The canonical way to build one is `repro.api.DetectionSession.serve()`,
which wires the service from a single PipelineConfig and shares the
session's compiled detection programs (`frame_detector=` injection).

Full-FRAME requests (`submit_frame` / `detect_frames`) route through the
device-resident multi-scale detector (core/detector.py:FrameDetector):
pyramid, dense HOG, thresholding, top-k and NMS all run in one compiled
program per frame-shape bucket, with per-frame latency/box stats -- the
"camera -> detection block" stream the paper sketches in §VI.

Frame requests MICROBATCH: requests whose frames land in the same shape
bucket coalesce (up to `frame_batch * n_devices` -- the detector's data
mesh multiplies the per-dispatch target -- waiting at most `max_wait_ms`
for stragglers) into one batched device step
(`FrameDetector.detect_batch`); requests for other buckets are set
aside and served in arrival order on the next rounds. The bounded frame
queue is the backpressure valve: `submit_frame` raises
`ServiceOverloaded` instead of queueing unbounded work, and a malformed
frame is answered with an error result without poisoning the batch it
arrived in. Futures can never hang: an unexpected worker exception
drains the pending backlog with an error payload carrying the traceback
(`worker_error` keeps it for inspection), and `stop()` with a backlog
answers every accepted-but-unserved request with an error instead of
leaving submitters blocked in `fut.get()`.

`generate` -- LM serving: prefill + greedy/temperature decode loop with
the layer-stacked KV cache. Used by examples and the serve benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
import traceback
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detector import DetectorConfig, FrameDetector
from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.pipeline import classify_windows
from repro.core.svm import SVMParams
from repro.models.configs import ModelConfig
from repro.models.model import decode_step, prefill

Array = jax.Array


# ------------------------------------------------------------- detection

@dataclasses.dataclass
class DetectionRequest:
    window: np.ndarray                  # (130, 66, 3) uint8
    future: "queue.Queue"


@dataclasses.dataclass
class FrameRequest:
    frame: np.ndarray                   # (H, W, 3) uint8 or (H, W) gray
    future: "queue.Queue"


class ServiceOverloaded(RuntimeError):
    """Raised by submit_frame when the bounded frame queue is full --
    the caller must shed load or retry later (backpressure)."""


class DetectionService:
    """Micro-batching co-processor front-end (thread-based).

    Two request classes share the worker thread:
      * windows -- classified in padded micro-batches (one jit'd step),
      * frames  -- full multi-scale detection via the device-resident
        FrameDetector (one compiled program per frame-shape bucket).
    """

    def __init__(self, svm: SVMParams, batch_size: int = 64,
                 cfg: HOGConfig = PAPER_HOG, path: str = "ref",
                 max_wait_ms: float = 2.0,
                 detector: Optional[DetectorConfig] = None,
                 frame_batch: int = 8,
                 max_pending_frames: int = 256,
                 frame_detector: Optional[FrameDetector] = None):
        self.svm = svm
        self.batch = batch_size
        self.cfg = cfg
        self.path = path
        self.max_wait = max_wait_ms / 1e3
        self.frame_batch = max(1, frame_batch)
        self.max_pending_frames = max_pending_frames
        self.q: "queue.Queue[DetectionRequest]" = queue.Queue()
        self.frame_q: "queue.Queue[FrameRequest]" = \
            queue.Queue(maxsize=max_pending_frames)
        # same-arrival-order parking spot for requests whose shape
        # bucket did not match the batch being formed
        self._frame_backlog: "collections.deque[FrameRequest]" = \
            collections.deque()
        # accepted-but-unanswered frame requests, wherever they sit
        # (queue, backlog, or the worker's hands) -- the number the
        # backpressure valve actually bounds
        self._pending_frames = 0
        self._pending_lock = threading.Lock()
        self._work = threading.Event()
        self._stop = False
        self._fn = jax.jit(partial(classify_windows, cfg=cfg, path=path))
        # an injected handle (DetectionSession.serve) shares the
        # session's compiled programs; otherwise build our own
        self._detector = frame_detector if frame_detector is not None \
            else FrameDetector(svm, detector if detector is not None
                               else DetectorConfig(hog=cfg, backend=path))
        # the detector's data mesh multiplies the per-dispatch frame
        # target: one batched step can feed frame_batch frames to each
        # of the detector's devices
        self.devices = max(1, getattr(self._detector, "data_devices", 1))
        self.frame_target = self.frame_batch * self.devices
        self.worker_error: Optional[str] = None
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"batches": 0, "requests": 0, "occupancy": 0.0,
                      "frames": 0, "frame_ms": 0.0, "frame_boxes": 0,
                      "frame_batches": 0, "frame_occupancy": 0.0,
                      "frame_rejects": 0, "frames_saturated": 0,
                      # kept-box counts per head label on multi-class
                      # sessions ({} until a labelled detection lands)
                      "class_boxes": {},
                      "devices": self.devices,
                      "tile_devices": max(
                          1, getattr(self._detector, "frame_devices", 1)),
                      "device_frames": [0] * self.devices,
                      "per_device_occupancy": [0.0] * self.devices}

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        """Stop the worker; a backlog is answered with errors, never
        left hanging in `fut.get()`."""
        self._stop = True
        self._work.set()                  # wake an idle worker at once
        if self._thread.ident is not None:
            self._thread.join(timeout=5)
        # requests still pending (worker never started, died, or the
        # join timed out mid-batch) would otherwise hang their clients
        self._drain_pending("DetectionService stopped with a backlog")

    def _drain_pending(self, msg: str) -> int:
        """Answer every queued/parked request with an error payload;
        returns how many were drained. Called on stop() and after an
        unexpected worker exception -- the no-hanging-futures rule."""
        n = 0
        while True:
            try:
                # popleft-or-IndexError IS the emptiness check: stop()
                # and the worker's exit drain can run concurrently, so
                # a check-then-pop would race (deque ops are atomic)
                req = self._frame_backlog.popleft()
            except IndexError:
                try:
                    req = self.frame_q.get_nowait()
                except queue.Empty:
                    break
            self._answer_frame(req, {"detections": [], "ms": 0.0,
                                     "error": msg})
            n += 1
        while True:
            try:
                r = self.q.get_nowait()
            except queue.Empty:
                break
            r.future.put({"score": float("nan"), "human": -1,
                          "error": msg})
            n += 1
        return n

    # ------------------------------------------------------- window path
    def submit(self, window: np.ndarray) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self.q.put(DetectionRequest(window, fut))
        self._work.set()
        return fut

    def detect(self, windows: List[np.ndarray],
               timeout: float = 30.0) -> List[Dict[str, float]]:
        futs = [self.submit(w) for w in windows]
        return [f.get(timeout=timeout) for f in futs]

    # -------------------------------------------------------- frame path
    def submit_frame(self, frame: np.ndarray) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        # the bound counts every accepted-but-unanswered request --
        # queued, parked in the bucket backlog, or in the worker's
        # hands -- so shuffling between holding areas cannot grow total
        # pending work past max_pending_frames
        with self._pending_lock:
            if self._pending_frames >= self.max_pending_frames:
                self.stats["frame_rejects"] += 1
                raise ServiceOverloaded(
                    f"{self.max_pending_frames} frames pending; "
                    f"shed load or retry")
            self._pending_frames += 1
        try:
            self.frame_q.put_nowait(FrameRequest(frame, fut))
        except queue.Full:                    # maxsize == the same bound,
            with self._pending_lock:          # so only a relic race path
                self._pending_frames -= 1
            self.stats["frame_rejects"] += 1
            raise ServiceOverloaded(
                f"frame queue full ({self.frame_q.maxsize} pending); "
                f"shed load or retry") from None
        self._work.set()
        return fut

    def _answer_frame(self, req: FrameRequest, payload: Dict) -> None:
        """Resolve a frame request's future and release its pending
        slot -- the ONLY way frame futures are answered."""
        with self._pending_lock:
            self._pending_frames -= 1
        req.future.put(payload)

    def detect_frames(self, frames: List[np.ndarray],
                      timeout: float = 120.0) -> List[Dict[str, Any]]:
        """Full-frame requests: each result is {detections, ms,
        saturated} (saturated = the frame's threshold candidates
        overflowed the program's top-k, see api/results.py); a
        request that raised -- or was shed by backpressure -- carries
        an extra "error" key instead of hanging or aborting the rest
        of the submission (the worker survives bad inputs). Callers
        that want the hard ServiceOverloaded signal use submit_frame
        directly."""
        futs: List[Any] = []
        for f in frames:
            try:
                futs.append(self.submit_frame(f))
            except ServiceOverloaded as e:
                futs.append({"detections": [], "ms": 0.0,
                             "error": f"ServiceOverloaded: {e}"})
        return [f if isinstance(f, dict) else f.get(timeout=timeout)
                for f in futs]

    # ------------------------------------------------------------ worker
    def _loop(self):
        try:
            while not self._stop:
                try:
                    served = self._serve_frame_batch()
                    served = self._serve_window_batch() or served
                except Exception:
                    # a bug escaping the per-request containment used to
                    # kill the worker silently and leave every submitter
                    # blocked in fut.get() forever; instead: keep the
                    # traceback, fail the pending backlog, keep serving
                    self.worker_error = traceback.format_exc()
                    served = self._drain_pending(
                        "DetectionService worker error (see "
                        ".worker_error):\n" + self.worker_error) > 0
                if not served:
                    # idle: block on the wake event (no busy-poll). Clear
                    # first, then re-check the queues so a submit racing
                    # the clear re-sets the event and the wait returns at
                    # once.
                    self._work.clear()
                    if self.q.empty() and self.frame_q.empty() \
                            and not self._frame_backlog:
                        self._work.wait(timeout=0.1)
        finally:
            # worker exiting (stop() or a fatal error): nobody will ever
            # answer what is still queued -- fail it now, don't hang
            self._drain_pending(
                "DetectionService worker exited"
                + (f"; worker_error:\n{self.worker_error}"
                   if self.worker_error else ""))

    def _next_frame_req(self) -> Optional[FrameRequest]:
        if self._frame_backlog:
            return self._frame_backlog.popleft()
        try:
            return self.frame_q.get_nowait()
        except queue.Empty:
            return None

    def _serve_frame_batch(self) -> bool:
        """Coalesce same-bucket frame requests into one batched step.

        The first request pins the shape bucket; further requests are
        drained from the backlog/queue until `frame_target` frames
        (`frame_batch` per device of the detector's data mesh) are
        gathered or `max_wait` expires. Mismatched buckets park in the
        backlog (served, in order, on later rounds); malformed frames
        are answered with an error result immediately and never join
        the batch.
        """
        req = self._next_frame_req()
        if req is None:
            return False
        try:
            bucket = self._detector.bucket_for(req.frame)
        except Exception as e:
            self._answer_frame(req, {"detections": [], "ms": 0.0,
                                     "error": f"{type(e).__name__}: {e}"})
            return True
        group: List[FrameRequest] = [req]
        parked: List[FrameRequest] = []
        deadline = time.monotonic() + self.max_wait
        while len(group) < self.frame_target:
            nxt = None
            if self._frame_backlog:
                nxt = self._frame_backlog.popleft()
            else:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    break
                try:
                    nxt = self.frame_q.get(timeout=wait)
                except queue.Empty:
                    break
            try:
                b = self._detector.bucket_for(nxt.frame)
            except Exception as e:
                self._answer_frame(nxt, {"detections": [], "ms": 0.0,
                                         "error": f"{type(e).__name__}: "
                                                  f"{e}"})
                continue
            if b == bucket:
                group.append(nxt)
            else:
                parked.append(nxt)
        self._frame_backlog.extend(parked)

        t0 = time.perf_counter()
        try:
            if len(group) == 1:
                results = [self._detector.detect_raw(group[0].frame)]
            else:
                batch = self._detector.detect_batch_raw(
                    [r.frame for r in group])
                results = [batch.frame(i) for i in range(len(group))]
            # decode inside the timed region so per-frame ms keeps the
            # legacy meaning (device step + host decode)
            dets_per = [(res.to_list(), bool(np.any(res.saturated)))
                        for res in results]
        except Exception:
            # batch failed as a whole: fall back to per-frame so one
            # poisonous frame cannot fail its innocent batch-mates
            dets_per = []
            for r in group:
                try:
                    res = self._detector.detect_raw(r.frame)
                    dets_per.append((res.to_list(),
                                     bool(np.any(res.saturated))))
                except Exception as e:
                    dets_per.append(e)
        ms = (time.perf_counter() - t0) * 1e3 / len(group)
        self.stats["frame_batches"] += 1
        self._account_device_frames(len(group))
        for r, dets in zip(group, dets_per):
            if isinstance(dets, Exception):
                self._answer_frame(
                    r, {"detections": [], "ms": 0.0,
                        "error": f"{type(dets).__name__}: {dets}"})
                continue
            dets, saturated = dets
            self.stats["frames"] += 1
            self.stats["frames_saturated"] += int(saturated)
            self.stats["frame_boxes"] += len(dets)
            for d in dets:                       # per-class serve stats
                if "label" in d:
                    cb = self.stats["class_boxes"]
                    cb[d["label"]] = cb.get(d["label"], 0) + 1
            self.stats["frame_ms"] += (ms - self.stats["frame_ms"]) \
                / self.stats["frames"]
            self._answer_frame(r, {"detections": dets, "ms": ms,
                                   "saturated": saturated})
        self.stats["frame_occupancy"] = (
            self.stats["frames"]
            / (self.stats["frame_batches"] * self.frame_target))
        self.stats["per_device_occupancy"] = [
            df / (self.stats["frame_batches"] * self.frame_batch)
            for df in self.stats["device_frames"]]
        return True

    def _account_device_frames(self, g: int) -> None:
        """Attribute one dispatched group of g frames to the devices
        that ran it: the sharded batch program pads g up to the mesh
        size and lays contiguous rows per device, a single-frame
        dispatch runs on device 0. Feeds per_device_occupancy."""
        df = self.stats["device_frames"]
        if g == 1 or self.devices == 1:
            df[0] += g
            return
        local = -(-g // self.devices)      # rows per device, post-pad
        for i in range(self.devices):
            df[i] += min(local, max(0, g - i * local))

    def _serve_window_batch(self) -> bool:
        reqs: List[DetectionRequest] = []
        try:
            reqs.append(self.q.get_nowait())
        except queue.Empty:
            return False
        t0 = time.monotonic()
        while (len(reqs) < self.batch
               and time.monotonic() - t0 < self.max_wait):
            try:
                reqs.append(self.q.get_nowait())
            except queue.Empty:
                time.sleep(0.0005)
        n = len(reqs)
        pad = self.batch - n
        try:
            wins = np.stack([r.window for r in reqs]
                            + [np.zeros_like(reqs[0].window)] * pad)
            out = self._fn(self.svm, jnp.asarray(wins))
            score = np.asarray(out["score"])
            human = np.asarray(out["human"])
        except Exception as e:   # contain: fail the batch, keep serving
            for r in reqs:
                r.future.put({"score": float("nan"), "human": -1,
                              "error": f"{type(e).__name__}: {e}"})
            return True
        for i, r in enumerate(reqs):
            r.future.put({"score": float(score[i]),
                          "human": int(human[i])})
        self.stats["batches"] += 1
        self.stats["requests"] += n
        self.stats["occupancy"] = (self.stats["requests"]
                                   / (self.stats["batches"] * self.batch))
        return True


# -------------------------------------------------------------------- LM

def generate(params: Any, cfg: ModelConfig, prompt: Array,
             max_new_tokens: int = 32, temperature: float = 0.0,
             key: Optional[Array] = None, ctx=None,
             enc_input: Optional[Array] = None) -> Array:
    """Greedy/temperature decoding. prompt: (B, S) -> (B, S + new)."""
    B, S = prompt.shape
    batch = {"tokens": prompt}
    if cfg.encoder_layers:
        batch["enc_input"] = enc_input
    logits, cache = prefill(params, batch, cfg,
                            max_len=S + max_new_tokens, ctx=ctx)
    enc = None
    if cfg.encoder_layers:
        from repro.models.model import encode
        enc = encode(params, enc_input, cfg, ctx)

    step_fn = jax.jit(partial(decode_step, cfg=cfg, ctx=ctx))
    toks = [prompt]
    cur = _sample(logits[:, -1], temperature, key)
    for t in range(max_new_tokens):
        toks.append(cur)
        if t == max_new_tokens - 1:
            break
        logits, cache = (step_fn(params, cur, cache, enc=enc)
                         if enc is not None else
                         step_fn(params, cur, cache))
        if key is not None:
            key, _ = jax.random.split(key)
        cur = _sample(logits[:, -1], temperature, key)
    return jnp.concatenate(toks, axis=1)


def _sample(logits: Array, temperature: float,
            key: Optional[Array]) -> Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
