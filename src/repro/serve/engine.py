"""Serving engines.

`DetectionService` -- the paper's co-processor as a batched service:
window requests (RGB windows) are queued, padded to the compiled batch
size, classified in one TPU step, results returned per request. This is
the Fig. 6 datapath plus the batching/queueing layer an FPGA front-end
would implement in NIOS/ARM (the paper's "future development" §VI).

The canonical way to build one is `repro.api.DetectionSession.serve()`,
which wires the service from a single PipelineConfig and shares the
session's compiled detection programs (`frame_detector=` injection).

Full-FRAME requests (`submit_frame` / `detect_frames`) route through the
device-resident multi-scale detector (core/detector.py:FrameDetector):
pyramid, dense HOG, thresholding, top-k and NMS all run in one compiled
program per frame-shape bucket, with per-frame latency/box stats -- the
"camera -> detection block" stream the paper sketches in §VI.

Frame requests MICROBATCH: requests whose frames land in the same shape
bucket coalesce (up to `frame_batch * n_devices` -- the detector's data
mesh multiplies the per-dispatch target -- waiting at most `max_wait_ms`
for stragglers) into one batched device step
(`FrameDetector.detect_batch`); requests for other buckets are set
aside and served in arrival order on the next rounds. The bounded frame
queue is the backpressure valve: `submit_frame` raises
`ServiceOverloaded` instead of queueing unbounded work, and a malformed
frame is answered with an error result without poisoning the batch it
arrived in.

RESILIENCE (DESIGN.md §14). Four mechanisms compose on top of the
microbatcher, all configured by `ResilienceConfig` (inert defaults):

  * Deadlines: `submit_frame(frame, deadline_ms=...)` (or the config
    default) gives each request a compute budget; expired requests are
    shed BEFORE compute with a `DeadlineExceeded` payload, so one slow
    batch cannot cascade into a backlog of doomed work.
  * Supervised worker: the detect thread runs under a supervisor that
    respawns it on ANY escape -- including BaseException-grade thread
    death -- with the session's compiled-program caches intact (they
    are process-wide lru caches in core/detector.py). In-flight
    requests are retried with capped exponential backoff + jitter when
    the failure looks transient, or failed fast with the original
    traceback when it is deterministic (`faults.DETERMINISTIC_TYPES`).
    A circuit breaker trips to fail-fast admission (`CircuitOpen`)
    after N consecutive failures, half-opens after a cooldown, and
    closes on the first healthy batch.
  * Degradation ladder: rolling p99 latency / queue depth drive a
    hysteresis ladder full -> cascade -> coarse (when a cascade handle
    is wired) or full -> reduced pyramid scales (otherwise); every
    response carries `degraded_mode` and `stats` tracks the rung.
  * Fault injection: `faults=FaultInjector(...)` (serve/faults.py)
    drives all of the above deterministically in the chaos suite;
    `faults=None` (default) is a no-op.

Futures can never hang: every accepted request is answered exactly once
(result, DeadlineExceeded, or a traceback-carrying error) -- on batch
errors, worker death, breaker trips, and `stop()` with a backlog alike.

`generate` -- LM serving: prefill + greedy/temperature decode loop with
the layer-stacked KV cache. Used by examples and the serve benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import random
import threading
import time
import traceback
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import platform
from repro.core.cascade import reduced_detector
from repro.core.detector import DetectorConfig, FrameDetector
from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.pipeline import classify_windows
from repro.core.svm import SVMParams
from repro.models.configs import ModelConfig
from repro.models.model import decode_step, prefill
from repro.obs.metrics import Emitter, MetricsConfig, make_sink
from repro.serve.faults import DETERMINISTIC_TYPES, FaultInjector
from repro.serve.resilience import (CircuitBreaker, DegradationLadder,
                                    ResilienceConfig, RollingLatency)

Array = jax.Array


# ------------------------------------------------------------- detection

@dataclasses.dataclass
class DetectionRequest:
    window: np.ndarray                  # (130, 66, 3) uint8
    future: "queue.Queue"


@dataclasses.dataclass
class FrameRequest:
    frame: np.ndarray                   # (H, W, 3) uint8 or (H, W) gray
    future: "queue.Queue"
    deadline: Optional[float] = None    # absolute time.monotonic() budget
    t_submit: float = 0.0               # for sojourn-latency telemetry
    attempts: int = 0                   # serve attempts consumed so far
    answered: bool = False              # exactly-once answer guard


class ServiceOverloaded(RuntimeError):
    """Raised by submit_frame when the bounded frame queue is full --
    the caller must shed load or retry later (backpressure)."""


class CircuitOpen(ServiceOverloaded):
    """Raised by submit/submit_frame while the circuit breaker is open:
    N consecutive worker failures tripped admission to fail-fast; the
    breaker half-opens after `breaker_reset_s` (see .worker_error)."""


class ServiceStopped(RuntimeError):
    """Raised by submit/submit_frame after stop(): the worker is gone,
    so enqueueing would park the request forever."""


class DetectionService:
    """Micro-batching co-processor front-end (thread-based).

    Two request classes share the supervised worker thread:
      * windows -- classified in padded micro-batches (one jit'd step),
      * frames  -- full multi-scale detection via the device-resident
        FrameDetector (one compiled program per frame-shape bucket).
    """

    def __init__(self, svm: SVMParams, batch_size: int = 64,
                 cfg: HOGConfig = PAPER_HOG, path: str = "ref",
                 max_wait_ms: float = 2.0,
                 detector: Optional[DetectorConfig] = None,
                 frame_batch: int = 8,
                 max_pending_frames: int = 256,
                 frame_detector: Optional[FrameDetector] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 faults: Optional[FaultInjector] = None,
                 cascade: Optional[Any] = None,
                 metrics: Optional[MetricsConfig] = None):
        self.svm = svm
        self.batch = batch_size
        self.cfg = cfg
        self.path = path
        self.max_wait = max_wait_ms / 1e3
        self.frame_batch = max(1, frame_batch)
        self.max_pending_frames = max_pending_frames
        self.q: "queue.Queue[DetectionRequest]" = queue.Queue()
        self.frame_q: "queue.Queue[FrameRequest]" = \
            queue.Queue(maxsize=max_pending_frames)
        # same-arrival-order parking spot for requests whose shape
        # bucket did not match the batch being formed
        self._frame_backlog: "collections.deque[FrameRequest]" = \
            collections.deque()
        # accepted-but-unanswered frame requests, wherever they sit
        # (queue, backlog, or the worker's hands) -- the number the
        # backpressure valve actually bounds
        self._pending_frames = 0
        self._pending_lock = threading.Lock()
        self._work = threading.Event()
        self._stop = False
        self._stopped = False
        self._fn = jax.jit(partial(classify_windows, cfg=cfg, path=path))
        # an injected handle (DetectionSession.serve) shares the
        # session's compiled programs; otherwise build our own
        self._detector = frame_detector if frame_detector is not None \
            else FrameDetector(svm, detector if detector is not None
                               else DetectorConfig(hog=cfg, backend=path))
        # the detector's data mesh multiplies the per-dispatch frame
        # target: one batched step can feed frame_batch frames to each
        # of the detector's devices
        self.devices = max(1, getattr(self._detector, "data_devices", 1))
        self.frame_target = self.frame_batch * self.devices

        # ----------------------------------------------- resilience seam
        self.res = resilience if resilience is not None \
            else ResilienceConfig()
        self.faults = faults
        self._retry = self.res.retry
        self._backoff_rng = random.Random(self._retry.seed)
        self._breaker = CircuitBreaker(self.res.breaker_failures,
                                       self.res.breaker_reset_s)
        self._latency = RollingLatency(self.res.latency_window)
        # ladder rungs from what this deployment can fall back to: a
        # wired CascadeDetector opens the cascade -> coarse rungs, else
        # the reduced-pyramid detector (same head, first scale only)
        self._cascade = cascade
        if cascade is not None:
            rungs = ("full", "cascade", "coarse")
            self._reduced = None
        else:
            rungs = ("full", "reduced")
            self._reduced = reduced_detector(self._detector)
        self._ladder = DegradationLadder(
            rungs, degrade_p99_ms=self.res.degrade_p99_ms,
            recover_p99_ms=self.res.recover_p99_ms,
            degrade_depth=self.res.degrade_depth,
            recover_dwell=self.res.recover_dwell)
        # requests in the worker's hands (popped but unanswered): the
        # supervisor retries/fails these on worker death, stop() sweeps
        # them so a wedged worker cannot hang its clients
        self._inflight: List[FrameRequest] = []
        self._inflight_windows: List[DetectionRequest] = []

        # ------------------------------------------ metrics export (§15)
        # structured events out of process (obs/metrics.py): the
        # supervisor loop and the batch path emit through one Emitter
        # (rank-0 guarded, never raising into the serve loop); disabled
        # config -> NullSink -> every emit is a cheap no-op
        self.metrics = metrics if metrics is not None else MetricsConfig()
        sink, self._metrics_ring = make_sink(self.metrics)
        self._emit = Emitter(sink, rank0_only=self.metrics.rank0_only)

        self.worker_error: Optional[str] = None
        self._thread: Optional[threading.Thread] = None
        self._supervisor = threading.Thread(
            target=self._supervise, daemon=True, name="repro-supervisor")
        self.stats = {"batches": 0, "requests": 0, "occupancy": 0.0,
                      "frames": 0, "frame_ms": 0.0, "frame_boxes": 0,
                      "frame_batches": 0, "frame_occupancy": 0.0,
                      "frame_rejects": 0, "frames_saturated": 0,
                      # kept-box counts per head label on multi-class
                      # sessions ({} until a labelled detection lands)
                      "class_boxes": {},
                      "devices": self.devices,
                      "tile_devices": max(
                          1, getattr(self._detector, "frame_devices", 1)),
                      "device_frames": [0] * self.devices,
                      "per_device_occupancy": [0.0] * self.devices,
                      # -------------------- resilience telemetry (§14)
                      "frame_answers": 0,       # every resolved future
                      "frame_errors": 0,        # error-payload answers
                      "deadline_shed": 0,       # shed before compute
                      "retries": 0,             # in-flight re-queues
                      "restarts": 0,            # supervised respawns
                      "worker_failures": 0,     # escapes from the loop
                      "frames_degraded": 0,     # served below "full"
                      "latency_ms": self._latency.snapshot(),
                      "breaker": self._breaker.snapshot(),
                      "degraded_mode": self._ladder.rung,
                      "ladder": self._ladder.snapshot(),
                      # -------------------- environment + export (§15)
                      "platform": platform.describe(),
                      "metrics": {"enabled": self._emit.active,
                                  "emitted": 0, "dropped": 0}}

    def _metrics_stats(self) -> None:
        self.stats["metrics"] = {
            "enabled": self._emit.active,
            "emitted": self._emit._seq,
            "dropped": self._emit.dropped,
            **({"recent": self._metrics_ring.counts()}
               if self._metrics_ring is not None else {})}

    def start(self):
        self._supervisor.start()
        self._emit.emit(
            "service_start",
            rungs=list(self._ladder.rungs),
            frame_batch=self.frame_batch, devices=self.devices,
            frame_target=self.frame_target,
            max_pending_frames=self.max_pending_frames,
            deadline_ms=self.res.deadline_ms,
            platform=self.stats["platform"])
        return self

    def stop(self):
        """Stop the supervisor + worker; a backlog is answered with
        errors, never left hanging in `fut.get()`. Returns within the
        join timeouts even when a worker is wedged mid-batch: the
        final drain sweeps queued, parked, AND in-flight requests
        (answers are exactly-once, so a late worker answer is a no-op).
        """
        self._stopped = True
        self._stop = True
        self._work.set()                  # wake an idle worker at once
        for t in (self._thread, self._supervisor):
            if t is not None and t.ident is not None \
                    and t is not threading.current_thread():
                t.join(timeout=5)
        # requests still pending (worker never started, died, or the
        # join timed out mid-batch) would otherwise hang their clients
        self._drain_pending("DetectionService stopped with a backlog")
        self._emit.emit(
            "service_stop",
            frames=self.stats["frames"], batches=self.stats["frame_batches"],
            answers=self.stats["frame_answers"],
            errors=self.stats["frame_errors"],
            deadline_shed=self.stats["deadline_shed"],
            retries=self.stats["retries"], restarts=self.stats["restarts"],
            worker_failures=self.stats["worker_failures"],
            frames_degraded=self.stats["frames_degraded"],
            latency_ms=self.stats["latency_ms"],
            ladder=self.stats["ladder"], breaker=self.stats["breaker"])
        self._metrics_stats()
        self._emit.close()

    def _drain_pending(self, msg: str) -> int:
        """Answer every queued/parked/in-flight request with an error
        payload; returns how many were drained. Called on stop(), on
        breaker-open admission draining, and when the supervisor exits
        -- the no-hanging-futures rule."""
        n = 0
        while True:
            try:
                # popleft-or-IndexError IS the emptiness check: stop()
                # and the worker's exit drain can run concurrently, so
                # a check-then-pop would race (deque ops are atomic)
                req = self._frame_backlog.popleft()
            except IndexError:
                try:
                    req = self.frame_q.get_nowait()
                except queue.Empty:
                    break
            if self._answer_frame(req, {"detections": [], "ms": 0.0,
                                        "error": msg}):
                n += 1
        # in-flight sweep: answered-flag answers make this idempotent
        # against a worker that resolves the same request late
        for req in list(self._inflight):
            if self._answer_frame(req, {"detections": [], "ms": 0.0,
                                        "error": msg}):
                n += 1
        for r in list(self._inflight_windows):
            try:
                r.future.put_nowait({"score": float("nan"), "human": -1,
                                     "error": msg})
                n += 1
            except queue.Full:
                pass
        while True:
            try:
                r = self.q.get_nowait()
            except queue.Empty:
                break
            r.future.put({"score": float("nan"), "human": -1,
                          "error": msg})
            n += 1
        return n

    # ------------------------------------------------------- window path
    def submit(self, window: np.ndarray) -> "queue.Queue":
        self._check_admission()
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self.q.put(DetectionRequest(window, fut))
        if self._stopped:
            # stop() may have drained between the admission check and
            # this enqueue: answer the straggler ourselves
            self._drain_pending("DetectionService stopped with a backlog")
        self._work.set()
        return fut

    def detect(self, windows: List[np.ndarray],
               timeout: float = 30.0) -> List[Dict[str, float]]:
        futs = [self.submit(w) for w in windows]
        return [f.get(timeout=timeout) for f in futs]

    # -------------------------------------------------------- frame path
    def _check_admission(self) -> None:
        if self._stopped:
            raise ServiceStopped(
                "DetectionService.stop() was called; a request "
                "submitted now could never be served")
        if not self._breaker.admit():
            raise CircuitOpen(
                f"circuit open after {self._breaker.consecutive} "
                f"consecutive worker failures; admission fails fast "
                f"for {self.res.breaker_reset_s:.1f}s (see .worker_error)")

    def submit_frame(self, frame: np.ndarray,
                     deadline_ms: Optional[float] = None) -> "queue.Queue":
        """Enqueue one frame. `deadline_ms` caps the request's time in
        the system (default: config's `resilience.deadline_ms`; 0 or
        None = no deadline): a request still unserved when its budget
        expires is shed BEFORE compute and answered with a
        `DeadlineExceeded` payload. Raises `ServiceStopped` after
        stop(), `CircuitOpen` while the breaker fails fast, and
        `ServiceOverloaded` when the pending bound is hit."""
        self._check_admission()
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        dl = deadline_ms if deadline_ms is not None \
            else (self.res.deadline_ms or None)
        now = time.monotonic()
        req = FrameRequest(frame, fut, t_submit=now,
                           deadline=None if not dl else now + dl / 1e3)
        # the bound counts every accepted-but-unanswered request --
        # queued, parked in the bucket backlog, or in the worker's
        # hands -- so shuffling between holding areas cannot grow total
        # pending work past max_pending_frames
        with self._pending_lock:
            if self._pending_frames >= self.max_pending_frames:
                self.stats["frame_rejects"] += 1
                raise ServiceOverloaded(
                    f"{self.max_pending_frames} frames pending; "
                    f"shed load or retry")
            self._pending_frames += 1
        try:
            self.frame_q.put_nowait(req)
        except queue.Full:                    # maxsize == the same bound,
            with self._pending_lock:          # so only a relic race path
                self._pending_frames -= 1
            self.stats["frame_rejects"] += 1
            raise ServiceOverloaded(
                f"frame queue full ({self.frame_q.maxsize} pending); "
                f"shed load or retry") from None
        if self._stopped:
            # stop() may have drained between the admission check and
            # this enqueue: answer the straggler ourselves
            self._drain_pending("DetectionService stopped with a backlog")
        self._work.set()
        return fut

    def _answer_frame(self, req: FrameRequest, payload: Dict) -> bool:
        """Resolve a frame request's future and release its pending
        slot -- the ONLY way frame futures are answered, and EXACTLY
        once per request (the answered flag makes concurrent answer
        attempts -- worker vs drain -- race-free)."""
        with self._pending_lock:
            if req.answered:
                return False
            req.answered = True
            self._pending_frames -= 1
        self.stats["frame_answers"] += 1
        if "error" in payload:
            self.stats["frame_errors"] += 1
        try:
            req.future.put_nowait(payload)
        except queue.Full:          # pragma: no cover -- maxsize-1 relic
            pass
        return True

    def detect_frames(self, frames: List[np.ndarray],
                      timeout: float = 120.0,
                      deadline_ms: Optional[float] = None
                      ) -> List[Dict[str, Any]]:
        """Full-frame requests: each result is {detections, ms,
        saturated, degraded_mode} (saturated = the frame's threshold
        candidates overflowed the program's top-k, see api/results.py;
        degraded_mode = the ladder rung that served it); a request
        that raised -- was shed by backpressure, fail-fast admission,
        or its deadline -- carries an extra "error" key instead of
        hanging or aborting the rest of the submission (the worker
        survives bad inputs). Callers that want the hard
        ServiceOverloaded / CircuitOpen signal use submit_frame
        directly."""
        futs: List[Any] = []
        for f in frames:
            try:
                futs.append(self.submit_frame(f, deadline_ms=deadline_ms))
            except ServiceOverloaded as e:
                futs.append({"detections": [], "ms": 0.0,
                             "error": f"{type(e).__name__}: {e}"})
        return [f if isinstance(f, dict) else f.get(timeout=timeout)
                for f in futs]

    # -------------------------------------------------------- supervisor
    def _supervise(self):
        """Worker lifecycle: spawn -> join -> classify the exit.

        A clean exit means stop(); anything else is a worker death the
        supervisor absorbs: restart accounting, breaker bookkeeping
        (done at failure time by `_on_worker_failure`), capped
        exponential backoff + jitter before the respawn. While the
        breaker is open, admission fails fast and anything already
        queued is drained instead of parking until the half-open probe.
        """
        try:
            while not self._stop:
                if not self._breaker.probe_due():
                    # open: answer queued work now, poll for the probe
                    self._drain_pending(
                        f"circuit open ({self._breaker.consecutive} "
                        f"consecutive worker failures); see .worker_error")
                    time.sleep(0.01)
                    continue
                t = threading.Thread(target=self._worker_main,
                                     daemon=True,
                                     name="repro-detect-worker")
                self._thread = t
                t.start()
                t.join()
                if self._stop:
                    break
                # unexpected worker exit: supervised restart. Compiled
                # programs survive (process-wide lru caches), so the
                # respawn costs a thread, not a recompile.
                self.stats["restarts"] += 1
                self._emit.emit("restart",
                                restarts=self.stats["restarts"],
                                breaker=self._breaker.snapshot())
                delay_s = self._retry.delay_ms(
                    max(1, self._breaker.consecutive),
                    self._backoff_rng) / 1e3
                end = time.monotonic() + delay_s
                while not self._stop and time.monotonic() < end:
                    time.sleep(min(0.005, delay_s))
        finally:
            # supervisor exiting: nobody will ever answer what is still
            # queued -- fail it now, don't hang
            self._drain_pending(
                "DetectionService worker exited with a backlog"
                + (f"; worker_error:\n{self.worker_error}"
                   if self.worker_error else ""))

    def _worker_main(self):
        """One worker incarnation. No blanket per-round containment:
        per-request/per-batch errors are contained inside the serve
        methods; anything that escapes -- including BaseException-grade
        thread kills -- routes through `_on_worker_failure` and exits
        the incarnation for the supervisor to respawn."""
        try:
            while not self._stop:
                served = self._serve_frame_batch()
                served = self._serve_window_batch() or served
                if not served:
                    # idle: block on the wake event (no busy-poll).
                    # Clear first, then re-check the queues so a submit
                    # racing the clear re-sets the event and the wait
                    # returns at once.
                    self._work.clear()
                    if self.q.empty() and self.frame_q.empty() \
                            and not self._frame_backlog:
                        self._work.wait(timeout=0.05)
        except BaseException as exc:   # noqa: B036 -- supervised seam
            self._on_worker_failure(exc)

    def _on_worker_failure(self, exc: BaseException) -> None:
        """Classify a worker death and settle its in-flight requests:
        deterministic failures (and requests out of retry budget) fail
        fast with the original traceback; transient ones re-queue at
        the FRONT of the backlog, order preserved, for the respawned
        worker."""
        tb = "".join(traceback.format_exception(
            type(exc), exc, exc.__traceback__))
        self.worker_error = tb
        self.stats["worker_failures"] += 1
        deterministic = isinstance(exc, DETERMINISTIC_TYPES)
        inflight, self._inflight = self._inflight, []
        windows, self._inflight_windows = self._inflight_windows, []
        requeue: List[FrameRequest] = []
        for r in inflight:
            if r.answered:
                continue
            r.attempts += 1
            if deterministic or r.attempts >= self._retry.max_attempts:
                kind = ("deterministic failure" if deterministic else
                        f"failed after {r.attempts} attempts")
                self._answer_frame(r, {
                    "detections": [], "ms": 0.0,
                    "degraded_mode": self._ladder.rung,
                    "error": f"worker {kind}:\n{tb}"})
            else:
                self.stats["retries"] += 1
                requeue.append(r)
        for r in reversed(requeue):
            self._frame_backlog.appendleft(r)
        for r in windows:
            try:
                r.future.put_nowait({"score": float("nan"), "human": -1,
                                     "error": f"worker failure:\n{tb}"})
            except queue.Full:
                pass
        self._breaker.record_failure()
        self.stats["breaker"] = self._breaker.snapshot()
        self._emit.emit("worker_failure",
                        error=f"{type(exc).__name__}: {exc}",
                        deterministic=deterministic,
                        requeued=len(requeue),
                        failed_fast=len(inflight) - len(requeue),
                        breaker=self.stats["breaker"])
        self._work.set()             # the next incarnation has work

    # ------------------------------------------------------------ worker
    def _next_frame_req(self) -> Optional[FrameRequest]:
        if self._frame_backlog:
            return self._frame_backlog.popleft()
        try:
            return self.frame_q.get_nowait()
        except queue.Empty:
            return None

    def _shed_expired(self, req: FrameRequest,
                      now: Optional[float] = None) -> bool:
        """Deadline gate: answer an over-budget request with the
        DeadlineExceeded payload BEFORE any compute is spent on it."""
        if req.deadline is None:
            return False
        if (time.monotonic() if now is None else now) <= req.deadline:
            return False
        self.stats["deadline_shed"] += 1
        self._answer_frame(req, {
            "detections": [], "ms": 0.0, "deadline_exceeded": True,
            "degraded_mode": self._ladder.rung,
            "error": "DeadlineExceeded: request budget expired before "
                     "compute"})
        with self._pending_lock:
            depth = self._pending_frames
        self._emit.emit("deadline_shed",
                        shed_total=self.stats["deadline_shed"],
                        queue_depth=depth, rung=self._ladder.rung)
        return True

    def _degraded_result(self, rung: str, frame: np.ndarray
                         ) -> Tuple[List[dict], bool]:
        """Serve one frame on a non-full ladder rung (core/cascade.py
        degraded entry points). Returns (detections, saturated)."""
        if rung == "cascade":
            return self._cascade.detect(frame), False
        if rung == "coarse":
            return self._cascade.detect_degraded(frame, "coarse"), False
        res = self._reduced.detect_raw(frame)
        return res.to_list(), bool(np.any(res.saturated))

    def _serve_frame_batch(self) -> bool:
        """Coalesce same-bucket frame requests into one batched step.

        The first request pins the shape bucket; further requests are
        drained from the backlog/queue until `frame_target` frames
        (`frame_batch` per device of the detector's data mesh) are
        gathered or `max_wait` expires. Mismatched buckets park in the
        backlog (served, in order, on later rounds); malformed frames
        are answered with an error result immediately and never join
        the batch; requests whose deadline expired are shed before
        compute. The fault hook and the batch dispatch run OUTSIDE the
        per-batch containment on purpose: an escape there is a worker
        failure the supervisor handles (retry / fail-fast / restart).
        """
        req = None
        while req is None:
            req = self._next_frame_req()
            if req is None:
                return False
            if self._shed_expired(req):
                req = None
        try:
            bucket = self._detector.bucket_for(req.frame)
        except Exception as e:
            self._answer_frame(req, {"detections": [], "ms": 0.0,
                                     "error": f"{type(e).__name__}: {e}"})
            return True
        group: List[FrameRequest] = [req]
        parked: List[FrameRequest] = []
        deadline = time.monotonic() + self.max_wait
        while len(group) < self.frame_target:
            nxt = None
            if self._frame_backlog:
                nxt = self._frame_backlog.popleft()
            else:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    break
                try:
                    nxt = self.frame_q.get(timeout=wait)
                except queue.Empty:
                    break
            if self._shed_expired(nxt):
                continue
            try:
                b = self._detector.bucket_for(nxt.frame)
            except Exception as e:
                self._answer_frame(nxt, {"detections": [], "ms": 0.0,
                                         "error": f"{type(e).__name__}: "
                                                  f"{e}"})
                continue
            if b == bucket:
                group.append(nxt)
            else:
                parked.append(nxt)
        self._frame_backlog.extend(parked)

        # last shed pass: the straggler wait may have burned the budget
        now = time.monotonic()
        group = [r for r in group if not self._shed_expired(r, now)]
        if not group:
            return True

        rung = self._ladder.rung
        self._inflight = group
        if self.faults is not None:
            # chaos seam: may sleep (latency spike) or raise (injected
            # worker failure / device loss / thread kill)
            self.faults.before_batch(len(group))

        t_dispatch = time.monotonic()
        t0 = time.perf_counter()
        if rung == "full":
            try:
                if len(group) == 1:
                    results = [self._detector.detect_raw(group[0].frame)]
                else:
                    batch = self._detector.detect_batch_raw(
                        [r.frame for r in group])
                    results = [batch.frame(i) for i in range(len(group))]
                # decode inside the timed region so per-frame ms keeps
                # the legacy meaning (device step + host decode)
                dets_per = [(res.to_list(), bool(np.any(res.saturated)))
                            for res in results]
            except Exception:
                # batch failed as a whole: fall back to per-frame so one
                # poisonous frame cannot fail its innocent batch-mates
                dets_per = []
                for r in group:
                    try:
                        res = self._detector.detect_raw(r.frame)
                        dets_per.append((res.to_list(),
                                         bool(np.any(res.saturated))))
                    except Exception as e:
                        dets_per.append(e)
        else:
            # degraded rung: per-frame through the cheap entry point
            dets_per = []
            for r in group:
                try:
                    dets_per.append(self._degraded_result(rung, r.frame))
                except Exception as e:
                    dets_per.append(e)
        ms = (time.perf_counter() - t0) * 1e3 / len(group)
        self.stats["frame_batches"] += 1
        self._account_device_frames(len(group))
        now = time.monotonic()
        for r, dets in zip(group, dets_per):
            if isinstance(dets, Exception):
                self._answer_frame(
                    r, {"detections": [], "ms": 0.0,
                        "degraded_mode": rung,
                        "error": f"{type(dets).__name__}: {dets}"})
                continue
            dets, saturated = dets
            self.stats["frames"] += 1
            if rung != "full":
                self.stats["frames_degraded"] += 1
            self.stats["frames_saturated"] += int(saturated)
            self.stats["frame_boxes"] += len(dets)
            for d in dets:                       # per-class serve stats
                if "label" in d:
                    cb = self.stats["class_boxes"]
                    cb[d["label"]] = cb.get(d["label"], 0) + 1
            self.stats["frame_ms"] += (ms - self.stats["frame_ms"]) \
                / self.stats["frames"]
            self._latency.add((now - r.t_submit) * 1e3)
            self._answer_frame(r, {"detections": dets, "ms": ms,
                                   "saturated": saturated,
                                   "degraded_mode": rung})
        self._inflight = []
        self.stats["frame_occupancy"] = (
            self.stats["frames"]
            / (self.stats["frame_batches"] * self.frame_target))
        self.stats["per_device_occupancy"] = [
            df / (self.stats["frame_batches"] * self.frame_batch)
            for df in self.stats["device_frames"]]
        # ------------------------------------------- ladder + telemetry
        p99 = self._latency.percentile(99)
        with self._pending_lock:
            depth = self._pending_frames
        self._ladder.observe(p99, depth, len(self._latency))
        self.stats["latency_ms"] = self._latency.snapshot()
        self.stats["degraded_mode"] = self._ladder.rung
        self.stats["ladder"] = self._ladder.snapshot()
        self._breaker.record_success()
        self.stats["breaker"] = self._breaker.snapshot()
        # ------------------------------------------- metrics export (§15)
        if self._emit.active:
            devices_used = 1 if len(group) == 1 \
                else min(self.devices, len(group))
            self._emit.emit(
                "batch", n=len(group), ms_per_frame=round(ms, 3),
                queue_depth=depth, rung=rung,
                latency_ms=self.stats["latency_ms"],
                devices_used=devices_used, devices_total=self.devices,
                occupancy=round(len(group) / self.frame_target, 4))
            if self._ladder.rung != rung:
                self._emit.emit(
                    "rung_transition", rung_from=rung,
                    rung_to=self._ladder.rung, p99_ms=round(p99, 3),
                    queue_depth=depth,
                    direction="degrade" if self._rung_level(
                        self._ladder.rung) > self._rung_level(rung)
                    else "recover")
            if self.metrics.stage_timing:
                queue_ms = [(t_dispatch - r.t_submit) * 1e3 for r in group]
                self._emit.emit(
                    "stage_timing", n=len(group),
                    queue_ms_mean=round(sum(queue_ms) / len(queue_ms), 3),
                    queue_ms_max=round(max(queue_ms), 3),
                    compute_ms_per_frame=round(ms, 3))
            self._metrics_stats()
        return True

    def _rung_level(self, rung: str) -> int:
        """Index of a rung in the ladder (higher = more degraded)."""
        try:
            return self._ladder.rungs.index(rung)
        except (AttributeError, ValueError):
            return 0

    def _account_device_frames(self, g: int) -> None:
        """Attribute one dispatched group of g frames to the devices
        that ran it: the sharded batch program pads g up to the mesh
        size and lays contiguous rows per device, a single-frame
        dispatch runs on device 0. Feeds per_device_occupancy."""
        df = self.stats["device_frames"]
        if g == 1 or self.devices == 1:
            df[0] += g
            return
        local = -(-g // self.devices)      # rows per device, post-pad
        for i in range(self.devices):
            df[i] += min(local, max(0, g - i * local))

    def _serve_window_batch(self) -> bool:
        reqs: List[DetectionRequest] = []
        try:
            reqs.append(self.q.get_nowait())
        except queue.Empty:
            return False
        t0 = time.monotonic()
        while (len(reqs) < self.batch
               and time.monotonic() - t0 < self.max_wait):
            try:
                reqs.append(self.q.get_nowait())
            except queue.Empty:
                time.sleep(0.0005)
        self._inflight_windows = reqs
        n = len(reqs)
        pad = self.batch - n
        try:
            wins = np.stack([r.window for r in reqs]
                            + [np.zeros_like(reqs[0].window)] * pad)
            out = self._fn(self.svm, jnp.asarray(wins))
            score = np.asarray(out["score"])
            human = np.asarray(out["human"])
        except Exception as e:   # contain: fail the batch, keep serving
            for r in reqs:
                r.future.put({"score": float("nan"), "human": -1,
                              "error": f"{type(e).__name__}: {e}"})
            self._inflight_windows = []
            return True
        for i, r in enumerate(reqs):
            r.future.put({"score": float(score[i]),
                          "human": int(human[i])})
        self._inflight_windows = []
        self.stats["batches"] += 1
        self.stats["requests"] += n
        self.stats["occupancy"] = (self.stats["requests"]
                                   / (self.stats["batches"] * self.batch))
        self._breaker.record_success()
        self.stats["breaker"] = self._breaker.snapshot()
        return True


# -------------------------------------------------------------------- LM

def generate(params: Any, cfg: ModelConfig, prompt: Array,
             max_new_tokens: int = 32, temperature: float = 0.0,
             key: Optional[Array] = None, ctx=None,
             enc_input: Optional[Array] = None) -> Array:
    """Greedy/temperature decoding. prompt: (B, S) -> (B, S + new)."""
    B, S = prompt.shape
    batch = {"tokens": prompt}
    if cfg.encoder_layers:
        batch["enc_input"] = enc_input
    logits, cache = prefill(params, batch, cfg,
                            max_len=S + max_new_tokens, ctx=ctx)
    enc = None
    if cfg.encoder_layers:
        from repro.models.model import encode
        enc = encode(params, enc_input, cfg, ctx)

    step_fn = jax.jit(partial(decode_step, cfg=cfg, ctx=ctx))
    toks = [prompt]
    cur = _sample(logits[:, -1], temperature, key)
    for t in range(max_new_tokens):
        toks.append(cur)
        if t == max_new_tokens - 1:
            break
        logits, cache = (step_fn(params, cur, cache, enc=enc)
                         if enc is not None else
                         step_fn(params, cur, cache))
        if key is not None:
            key, _ = jax.random.split(key)
        cur = _sample(logits[:, -1], temperature, key)
    return jnp.concatenate(toks, axis=1)


def _sample(logits: Array, temperature: float,
            key: Optional[Array]) -> Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
