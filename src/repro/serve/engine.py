"""Serving engines.

`DetectionService` -- the paper's co-processor as a batched service:
requests (RGB windows) are queued, padded to the compiled batch size,
classified in one TPU step, results returned per request. This is the
Fig. 6 datapath plus the batching/queueing layer an FPGA front-end
would implement in NIOS/ARM (the paper's "future development" §VI).

`generate` -- LM serving: prefill + greedy/temperature decode loop with
the layer-stacked KV cache. Used by examples and the serve benchmarks.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hog import HOGConfig, PAPER_HOG
from repro.core.pipeline import classify_windows
from repro.core.svm import SVMParams
from repro.models.configs import ModelConfig
from repro.models.model import decode_step, prefill

Array = jax.Array


# ------------------------------------------------------------- detection

@dataclasses.dataclass
class DetectionRequest:
    window: np.ndarray                  # (130, 66, 3) uint8
    future: "queue.Queue"


class DetectionService:
    """Micro-batching co-processor front-end (thread-based)."""

    def __init__(self, svm: SVMParams, batch_size: int = 64,
                 cfg: HOGConfig = PAPER_HOG, path: str = "ref",
                 max_wait_ms: float = 2.0):
        self.svm = svm
        self.batch = batch_size
        self.cfg = cfg
        self.path = path
        self.max_wait = max_wait_ms / 1e3
        self.q: "queue.Queue[DetectionRequest]" = queue.Queue()
        self._stop = False
        self._fn = jax.jit(partial(classify_windows, cfg=cfg, path=path))
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"batches": 0, "requests": 0, "occupancy": 0.0}

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop = True
        self._thread.join(timeout=5)

    def submit(self, window: np.ndarray) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self.q.put(DetectionRequest(window, fut))
        return fut

    def detect(self, windows: List[np.ndarray],
               timeout: float = 30.0) -> List[Dict[str, float]]:
        futs = [self.submit(w) for w in windows]
        return [f.get(timeout=timeout) for f in futs]

    def _loop(self):
        while not self._stop:
            reqs: List[DetectionRequest] = []
            try:
                reqs.append(self.q.get(timeout=0.1))
            except queue.Empty:
                continue
            t0 = time.time()
            while (len(reqs) < self.batch
                   and time.time() - t0 < self.max_wait):
                try:
                    reqs.append(self.q.get_nowait())
                except queue.Empty:
                    time.sleep(0.0005)
            n = len(reqs)
            pad = self.batch - n
            wins = np.stack([r.window for r in reqs]
                            + [np.zeros_like(reqs[0].window)] * pad)
            out = self._fn(self.svm, jnp.asarray(wins))
            score = np.asarray(out["score"])
            human = np.asarray(out["human"])
            for i, r in enumerate(reqs):
                r.future.put({"score": float(score[i]),
                              "human": int(human[i])})
            self.stats["batches"] += 1
            self.stats["requests"] += n
            self.stats["occupancy"] = (self.stats["requests"]
                                       / (self.stats["batches"] * self.batch))


# -------------------------------------------------------------------- LM

def generate(params: Any, cfg: ModelConfig, prompt: Array,
             max_new_tokens: int = 32, temperature: float = 0.0,
             key: Optional[Array] = None, ctx=None,
             enc_input: Optional[Array] = None) -> Array:
    """Greedy/temperature decoding. prompt: (B, S) -> (B, S + new)."""
    B, S = prompt.shape
    batch = {"tokens": prompt}
    if cfg.encoder_layers:
        batch["enc_input"] = enc_input
    logits, cache = prefill(params, batch, cfg,
                            max_len=S + max_new_tokens, ctx=ctx)
    enc = None
    if cfg.encoder_layers:
        from repro.models.model import encode
        enc = encode(params, enc_input, cfg, ctx)

    step_fn = jax.jit(partial(decode_step, cfg=cfg, ctx=ctx))
    toks = [prompt]
    cur = _sample(logits[:, -1], temperature, key)
    for t in range(max_new_tokens):
        toks.append(cur)
        if t == max_new_tokens - 1:
            break
        logits, cache = (step_fn(params, cur, cache, enc=enc)
                         if enc is not None else
                         step_fn(params, cur, cache))
        if key is not None:
            key, _ = jax.random.split(key)
        cur = _sample(logits[:, -1], temperature, key)
    return jnp.concatenate(toks, axis=1)


def _sample(logits: Array, temperature: float,
            key: Optional[Array]) -> Array:
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
