"""Deterministic fault injection for the serving stack (DESIGN.md §14).

Chaos you cannot replay is chaos you cannot debug: every fault here
fires either at an explicit batch index or from a SEEDED Bernoulli
draw, so a failing chaos run reproduces byte-identically from its seed.
The engine calls `FaultInjector.before_batch(...)` at exactly one
point -- after a frame micro-batch is formed and deadline-shed, before
any compute -- and passes `faults=None` (the default) to compile the
hook out entirely in production.

Fault taxonomy (what the supervisor does with each):

  * `TransientFault` / `SimulatedDeviceLoss` -- retryable: in-flight
    requests are re-queued (capped exponential backoff + jitter,
    `RetryPolicy`) and the worker restarts.
  * `DeterministicFault` -- NOT retryable: in-flight requests fail
    fast with the original traceback; retrying a deterministic bug
    only burns the latency budget of a doomed request.
  * `WorkerKilled` -- subclasses BaseException so it sails past every
    `except Exception` containment layer, exactly like a real thread
    death; the supervisor must respawn the worker from scratch.
  * latency faults -- `time.sleep` before compute: the p99 spike that
    drives the degradation ladder in tests and benchmarks.

`DETERMINISTIC_TYPES` is the engine's classification table for
UNINJECTED exceptions too: a ValueError escaping the worker is a bug
that will recur on retry, so it fails fast; anything else is assumed
transient and retried within the policy's attempt budget.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import List, Optional, Tuple

import numpy as np


# ------------------------------------------------------------ fault types

class FaultError(RuntimeError):
    """Base class of injected serving faults."""


class TransientFault(FaultError):
    """Injected failure that would succeed on retry (network blip,
    spurious XLA error): the supervisor retries in-flight requests."""


class DeterministicFault(FaultError):
    """Injected failure that recurs on every retry (poisoned input,
    code bug): in-flight requests fail fast with the traceback."""


class SimulatedDeviceLoss(TransientFault):
    """The accelerator disappeared mid-batch; retryable -- the restarted
    worker re-dispatches onto the (recovered or remaining) devices."""


class WorkerKilled(BaseException):
    """Simulated hard thread death. Deliberately NOT an Exception: it
    escapes every `except Exception` containment exactly like a killed
    thread, so only the supervisor's BaseException net catches it."""


#: exception classes the supervisor treats as deterministic (fail the
#: in-flight request fast, with traceback, instead of retrying)
DETERMINISTIC_TYPES: Tuple[type, ...] = (
    DeterministicFault, ValueError, TypeError, KeyError, IndexError,
    AttributeError, AssertionError, ZeroDivisionError)

_KINDS = ("exception", "latency", "device_loss", "kill_worker")


# ------------------------------------------------------------ fault plans

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind         "exception" | "latency" | "device_loss" | "kill_worker"
    at_batches   explicit frame-batch indices (0-based dispatch order)
    prob         seeded per-batch Bernoulli (alternative to at_batches)
    max_fires    cap on total firings (0 = unlimited)
    latency_ms   sleep before compute (kind="latency")
    transient    kind="exception": TransientFault vs DeterministicFault
    """

    kind: str
    at_batches: Tuple[int, ...] = ()
    prob: float = 0.0
    max_fires: int = 0
    latency_ms: float = 0.0
    transient: bool = True
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_KINDS}")


class FaultInjector:
    """Seeded, replayable fault schedule. `FaultInjector()` (no specs)
    is the no-op default; the engine also accepts `faults=None`.

    `fired` logs every firing as (batch_index, kind) for test
    assertions; `batches` counts dispatched frame batches."""

    def __init__(self, specs: Tuple[FaultSpec, ...] = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._fires = [0] * len(self.specs)
        self.fired: List[Tuple[int, str]] = []
        self.batches = 0
        self._lock = threading.Lock()

    def before_batch(self, group_size: int) -> None:
        """Engine hook: called once per formed frame micro-batch,
        before compute. Applies latency faults in spec order, then
        raises the first firing failure fault."""
        with self._lock:
            i = self.batches
            self.batches += 1
            firing = []
            for k, s in enumerate(self.specs):
                if s.max_fires and self._fires[k] >= s.max_fires:
                    continue
                hit = i in s.at_batches or (
                    s.prob > 0.0 and self._rng.random() < s.prob)
                if not hit:
                    continue
                self._fires[k] += 1
                self.fired.append((i, s.kind))
                firing.append(s)
        boom: Optional[BaseException] = None
        for s in firing:
            if s.kind == "latency":
                time.sleep(s.latency_ms / 1e3)
            elif boom is None:
                msg = f"{s.message} (batch {i})"
                if s.kind == "kill_worker":
                    boom = WorkerKilled(msg)
                elif s.kind == "device_loss":
                    boom = SimulatedDeviceLoss(msg)
                else:
                    boom = (TransientFault(msg) if s.transient
                            else DeterministicFault(msg))
        if boom is not None:
            raise boom


# ----------------------------------------------------------- frame chaos

def malformed_frame(rng: np.random.Generator) -> np.ndarray:
    """A deterministically-garbage 'frame' (wrong rank/size/dtype) for
    client-side chaos: the service must answer it with an error payload
    without poisoning its batch-mates."""
    kind = int(rng.integers(0, 4))
    if kind == 0:
        return np.zeros((int(rng.integers(1, 9)),), np.uint8)   # rank 1
    if kind == 1:
        return np.zeros((0, 0, 3), np.uint8)                    # empty
    if kind == 2:
        return np.zeros((3, int(rng.integers(1, 5)),
                         int(rng.integers(1, 5)), 3), np.uint8)  # rank 4
    return np.zeros((2, 2), np.float64)                          # tiny


def chaos_specs(seed: int = 0) -> Tuple[FaultSpec, ...]:
    """The standard chaos-smoke scenario (CI lane `chaos-smoke` and
    `launch.serve --detect --chaos`): one worker kill, one transient
    device loss, and a burst of latency spikes, all at fixed batch
    indices so the run replays exactly."""
    del seed  # fixed schedule; the seed knob is for prob-based plans
    return (
        FaultSpec("kill_worker", at_batches=(1,), max_fires=1,
                  message="chaos: worker thread killed"),
        FaultSpec("device_loss", at_batches=(3,), max_fires=1,
                  message="chaos: device lost"),
        FaultSpec("latency", at_batches=(5, 6, 7), latency_ms=60.0,
                  message="chaos: latency spike"),
    )
