from repro import platform
platform.force_host_devices(512)
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Only the dry-run sees 512 host devices;
# force_host_devices MERGES into XLA_FLAGS, so operator-set flags (and
# an operator-set device count) survive instead of being clobbered.

import argparse          # noqa: E402
import os                # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import hlo_parse, roofline  # noqa: E402
from repro.configs import (ARCH_IDS, SHAPE_BY_NAME, SHAPES, get_config,
                           input_specs, shape_applicable)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.configs import ModelConfig  # noqa: E402
from repro.models.model import (decode_step, init_cache, prefill)  # noqa: E402
from repro.sharding.rules import (PROFILES, batch_specs, cache_specs_tree,
                                  dp_axes, fit_tree, make_ctx,
                                  param_specs)  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402
from repro.train.train_step import (init_train_state, jit_train_step,
                                    state_shardings)  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               profile_name: str = "baseline", smoke: bool = False):
    """Build + lower + compile one (arch x shape x mesh) cell.

    Returns (compiled, lowered, cfg, n_chips)."""
    profile = PROFILES[profile_name]
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    if arch == "hog_svm_coproc":
        return _lower_hog(mesh, shape, smoke, profile_name), mesh

    cfg = get_config(arch, smoke=smoke)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(reason)

    from repro.models.model import init_params
    specs = input_specs(cfg, shape, smoke=smoke)
    b_specs = {k: v for k, v in
               batch_specs(cfg, mesh, shape.kind, profile).items()
               if k in specs}
    b_specs = fit_tree(b_specs, specs, mesh)
    b_sh = {k: NamedSharding(mesh, b_specs[k]) for k in specs}

    def fitted_param_sh(params_shape):
        ps = fit_tree(param_specs(params_shape, cfg), params_shape, mesh)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), ps,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        state_shape = jax.eval_shape(
            partial(init_train_state, cfg), jax.random.PRNGKey(0))
        jitted = jit_train_step(cfg, OptConfig(), mesh, state_shape,
                                specs, profile=profile)
        lowered = jitted.lower(state_shape, specs)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(partial(init_params, cfg),
                                      jax.random.PRNGKey(0))
        p_sh = fitted_param_sh(params_shape)
        ctx = make_ctx(mesh, profile=profile)
        fn = partial(prefill, cfg=cfg, max_len=shape.seq_len, ctx=ctx)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params_shape, specs)
    else:  # decode
        params_shape = jax.eval_shape(partial(init_params, cfg),
                                      jax.random.PRNGKey(0))
        p_sh = fitted_param_sh(params_shape)
        B = 4 if smoke else shape.global_batch
        S = 64 if smoke else shape.seq_len
        cache_shape = jax.eval_shape(partial(init_cache, cfg, B, S))
        c_specs = fit_tree(cache_specs_tree(cfg, mesh, profile),
                           cache_shape, mesh)
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                            is_leaf=lambda x: isinstance(x, P))
        import dataclasses as _dc
        ctx = _dc.replace(make_ctx(mesh, profile=profile),
                          seq_sharded=False)
        enc_sh = None
        if cfg.encoder_layers:
            enc_sh = NamedSharding(mesh, P(dp_axes(mesh), None, None))

            def fn(params, token, cache, enc_states):
                return decode_step(params, token, cache, cfg, ctx,
                                   enc=enc_states)
            jitted = jax.jit(fn, in_shardings=(
                p_sh, b_sh["token"], c_sh, enc_sh), donate_argnums=(2,))
            lowered = jitted.lower(params_shape, specs["token"],
                                   cache_shape, specs["enc_states"])
        else:
            def fn(params, token, cache):
                return decode_step(params, token, cache, cfg, ctx)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh["token"], c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_shape, specs["token"],
                                   cache_shape)
    return (lowered, cfg), mesh


def _lower_hog(mesh, shape, smoke, profile_name="baseline"):
    """The paper's co-processor at pod scale: batched window detection,
    data-parallel over every non-model axis."""
    import dataclasses as _dc
    from repro.core.hog import PAPER_HOG
    from repro.core.pipeline import classify_windows
    hog_cfg = (PAPER_HOG if profile_name == "baseline"
               else _dc.replace(PAPER_HOG, feat_dtype="bf16"))
    B = 64 if smoke else 16384 * (mesh.size // 256)
    dp = dp_axes(mesh)
    w_sh = {"w": NamedSharding(mesh, P(None)),
            "b": NamedSharding(mesh, P())}
    x_sh = NamedSharding(mesh, P(dp, None, None, None))
    params = {"w": jax.ShapeDtypeStruct((3780,), jnp.float32),
              "b": jax.ShapeDtypeStruct((), jnp.float32)}
    wins = jax.ShapeDtypeStruct((B, 130, 66, 3), jnp.uint8)
    fn = partial(classify_windows, cfg=hog_cfg, path="ref")
    jitted = jax.jit(fn, in_shardings=(w_sh, x_sh))
    lowered = jitted.lower(params, wins)

    class _Cfg:  # roofline hooks for the non-LM workload
        name = "hog_svm_coproc"
        n_layers = 1

        @staticmethod
        def param_count(active_only=False):
            return 3781
    return (lowered, _Cfg)


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "baseline", smoke: bool = False) -> dict:
    t0 = time.time()
    shape = SHAPE_BY_NAME[shape_name]
    (lowered, cfg), mesh = lower_cell(arch, shape_name, multi_pod,
                                      profile, smoke)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    agg = hlo_parse.aggregate(hlo, layer_hint=cfg.n_layers)
    n_chips = mesh.size
    mf = (roofline.model_flops(cfg, shape, n_chips)
          if arch != "hog_svm_coproc" else 0.0)
    rl = roofline.Roofline(
        name=f"{arch}/{shape_name}/{'multi' if multi_pod else 'single'}",
        flops_dev=agg["flops"], mem_bytes_dev=agg["mem_bytes"],
        coll_bytes_dev=agg["coll_bytes"], model_flops_dev=mf,
        cost_flops=float(cost.get("flops", 0.0)),
        cost_bytes=float(cost.get("bytes accessed", 0.0)))
    row = rl.row()
    row.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "profile": profile, "smoke": smoke,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes),
        },
        "coll_detail": {k.split("/", 1)[1]: v for k, v in agg.items()
                        if k.startswith("coll/")},
        "cost_flops_raw": float(cost.get("flops", 0.0)),
        "status": "ok",
    })
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id or 'all' (default: all + hog_svm_coproc)")
    ap.add_argument("--shape", default=None,
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--profile", default="baseline",
                    choices=list(PROFILES.keys()))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch and args.arch != "all"
             else list(ARCH_IDS) + ["hog_svm_coproc"])
    shapes = ([args.shape] if args.shape and args.shape != "all"
              else [s.name for s in SHAPES])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape_name in shapes:
            if arch == "hog_svm_coproc" and shape_name != "train_4k":
                continue   # coproc has one canonical detection shape
            for mp in meshes:
                key = (f"{arch}|{shape_name}|{'multi' if mp else 'single'}"
                       f"|{args.profile}")
                if args.resume and key in results and \
                        results[key].get("status") in ("ok", "skip"):
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key} ...", flush=True)
                try:
                    row = run_cell(arch, shape_name, mp, args.profile,
                                   args.smoke)
                    print(f"  ok: compile={row['compile_s']}s "
                          f"bottleneck={row['bottleneck']} "
                          f"step={row['step_time_s']:.4f}s "
                          f"peak={row['mem']['peak_bytes']/2**30:.2f}GiB",
                          flush=True)
                except SkipCell as e:
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "profile": args.profile,
                           "status": "skip", "reason": str(e)}
                    print(f"  skip: {e}", flush=True)
                except Exception as e:
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "profile": args.profile,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"  ERROR: {e!r}", flush=True)
                results[key] = row
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                jax.clear_caches()   # keep host RSS flat across 80 cells
                import gc
                gc.collect()
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skip")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
