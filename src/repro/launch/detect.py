"""Detection launcher: train (or load) an SVM and run the device-resident
multi-scale detector on synthetic scenes -- the paper's system as a CLI.

Usage: PYTHONPATH=src python -m repro.launch.detect
           [--scenes 3] [--fast] [--backend ref|kernel|fused]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DetectorConfig, train_svm
from repro.core.detector import FrameDetector
from repro.core.hog import PAPER_HOG, hog_descriptor
from repro.core.svm import SVMTrainConfig
from repro.data.synth_pedestrian import (PedestrianDataConfig, make_scene,
                                         make_windows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", type=int, default=2)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "kernel", "fused"],
                    help="stage backend for the dense HOG pass")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    cfg = PedestrianDataConfig()
    n_pos, n_neg = (500, 350) if args.fast else (1500, 1000)
    print(f"training SVM on {n_pos}+{n_neg} windows ...")
    x, y = make_windows(n_pos, n_neg, cfg, rng)
    feats = hog_descriptor(jnp.asarray(x), PAPER_HOG)
    svm, _ = train_svm(feats, jnp.asarray(y),
                       SVMTrainConfig(steps=2500, neg_weight=6.0))

    detector = FrameDetector(svm, DetectorConfig(score_threshold=0.5,
                                                 backend=args.backend))
    hits = 0
    for i in range(args.scenes):
        scene, truth = make_scene(rng, 320, 240, n_people=2)
        t0 = time.perf_counter()
        dets = detector(scene)
        ms = (time.perf_counter() - t0) * 1e3
        tag = "compile+run" if i == 0 else "steady"
        print(f"scene {i}: {len(truth)} people, {len(dets)} detections "
              f"({ms:.1f} ms {tag})")
        for d in dets[:4]:
            y0, x0, y1, x1 = d["box"]
            print(f"   ({y0:5.0f},{x0:5.0f})-({y1:5.0f},{x1:5.0f}) "
                  f"score={d['score']:.2f}")
        for (ty, tx, th, tw) in truth:
            ok = any(abs(d["box"][0] - ty) < 32 and abs(d["box"][1] - tx) < 32
                     for d in dets)
            hits += ok
    print(f"recall over scenes: {hits}/{2*args.scenes}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
