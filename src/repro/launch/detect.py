"""Detection launcher: one DetectionSession (repro.api) end to end --
train or load an SVM, run the device-resident multi-scale detector on
synthetic scenes, report recall and top-k saturation.

Repeated runs skip the SVM train: `--save DIR` checkpoints the params
after training (checkpoint/manager.py atomic layout), `--load DIR`
restores them (falling back to training, then saving if --save was also
given -- so `--load D --save D` is "train once, reuse forever").

Usage: PYTHONPATH=src python -m repro.launch.detect
           [--scenes 3] [--fast] [--backend ref|kernel|fused]
           [--preset paper|faithful|perf|default]
           [--save DIR] [--load DIR]
"""
from __future__ import annotations

from repro import platform  # applies REPRO_* before jax initializes

import argparse
import sys
import time

import numpy as np

from repro.api import DetectionSession, PipelineConfig, presets
from repro.core.detector import DetectorConfig
from repro.core.svm import SVMTrainConfig
from repro.data.synth_pedestrian import make_scene


def build_config(args) -> PipelineConfig:
    import dataclasses
    if args.preset:
        # keep the preset's detector (backend, batch_chunk, ...);
        # --backend, when given explicitly, overrides it
        base = presets(args.preset)
        det = dataclasses.replace(
            base.detector, score_threshold=0.5,
            backend=args.backend or base.detector.backend)
        return base.replace(detector=det)
    return PipelineConfig(
        detector=DetectorConfig(score_threshold=0.5,
                                backend=args.backend or "ref"),
        train=SVMTrainConfig(steps=2500, neg_weight=6.0))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", type=int, default=2)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["ref", "kernel", "fused"],
                    help="stage backend for the dense HOG pass "
                         "(default: the preset's backend, else ref)")
    ap.add_argument("--preset", default=None, choices=list(presets()),
                    help="PipelineConfig preset (numerics + train "
                         "schedule); default keeps the ref datapath")
    ap.add_argument("--save", metavar="DIR", default=None,
                    help="checkpoint the trained SVM params here")
    ap.add_argument("--load", metavar="DIR", default=None,
                    help="restore SVM params instead of training "
                         "(falls back to training if DIR is empty)")
    args = ap.parse_args(argv)

    cfg = build_config(args)
    n_pos, n_neg = (500, 350) if args.fast else (1500, 1000)

    # one rng stream for training windows AND evaluation scenes (the
    # seed CLI's contract: scenes are drawn from the post-train state);
    # REPRO_SEED overrides for replaying a lane, default 0 as before
    rng = np.random.default_rng(platform.default_seed())
    session = None
    if args.load:
        try:
            session = DetectionSession.load(args.load, cfg)
            print(f"loaded SVM params from {args.load} "
                  f"(skipping the {cfg.train.steps}-step train)")
            # advance the stream by the skipped window draws so the
            # scenes below are identical to a train-path run
            from repro.data.synth_pedestrian import (PedestrianDataConfig,
                                                     make_windows)
            make_windows(n_pos, n_neg, PedestrianDataConfig(), rng)
        except FileNotFoundError:
            print(f"no checkpoint under {args.load}; training")
    if session is None:
        print(f"training SVM on {n_pos}+{n_neg} windows "
              f"({cfg.train.steps} steps) ...")
        session = DetectionSession.train(cfg, n_pos=n_pos, n_neg=n_neg,
                                         rng=rng)
        if args.save:
            session.save(args.save)
            print(f"saved SVM params to {args.save}")

    hits = 0
    for i in range(args.scenes):
        scene, truth = make_scene(rng, 320, 240, n_people=2)
        t0 = time.perf_counter()
        result = session.detect(scene)
        dets = result.to_list()
        ms = (time.perf_counter() - t0) * 1e3
        tag = "compile+run" if i == 0 else "steady"
        sat = " [top-k saturated]" if result.saturated else ""
        print(f"scene {i}: {len(truth)} people, {len(dets)} detections "
              f"({ms:.1f} ms {tag}){sat}")
        for d in dets[:4]:
            y0, x0, y1, x1 = d["box"]
            print(f"   ({y0:5.0f},{x0:5.0f})-({y1:5.0f},{x1:5.0f}) "
                  f"score={d['score']:.2f}")
        for (ty, tx, th, tw) in truth:
            ok = any(abs(d["box"][0] - ty) < 32 and abs(d["box"][1] - tx) < 32
                     for d in dets)
            hits += ok
    print(f"recall over scenes: {hits}/{2*args.scenes}")
    stats = session.cache_stats()
    print(f"compiled programs: {stats['frame_programs']['size']} "
          f"(hits {stats['frame_programs']['hits']})")
    plat = stats["platform"]
    print(f"platform: {plat['backend']} x{plat['device_count']} "
          f"x64={plat['x64']} jax={plat['jax_version']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
