"""Training launcher: --arch <id> [--smoke] on the host mesh, with
checkpoint/restart fault tolerance, preemption handling (SIGTERM ->
final checkpoint -> clean exit), straggler detection (slow-step log),
and optional DDP + int8 gradient compression.

At pod scale the same step functions are compiled by launch/dryrun.py
onto the production meshes; this driver is the single-host harness.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 100 --ckpt /tmp/ck [--ddp --compress]
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data.lm_data import LMDataConfig, batches
from repro.train.optimizer import OptConfig
from repro.train.train_step import (init_ddp_state, init_train_state,
                                    make_ddp_train_step, make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ddp", action="store_true",
                    help="shard_map DDP over host devices")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression (with --ddp)")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.2f}M "
          f"devices={len(jax.devices())}")
    opt = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)

    if args.ddp:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        state = init_ddp_state(cfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_ddp_train_step(cfg, opt, mesh,
                                              compress=args.compress))
        mesh_ctx = jax.set_mesh(mesh)
        mesh_ctx.__enter__()
    else:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(cfg, opt))  # no donation: m/v
        # share XLA zero constants on host; donating would alias twice

    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state = mgr.restore(start, target)
        print(f"resumed from step {start}")

    stop = {"now": False}

    def _sigterm(signum, frame):   # preemption: checkpoint + exit
        print("SIGTERM: writing final checkpoint", flush=True)
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    data = batches(LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                batch=args.batch))
    step_times = []
    for step in range(start, args.steps):
        b = next(data)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if cfg.mrope:
            B, S = batch["tokens"].shape
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
        if cfg.encoder_layers:
            batch["enc_input"] = jnp.zeros(
                (args.batch, cfg.encoder_ctx, cfg.d_model), jnp.float32)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        step_times.append(dt)
        med = float(np.median(step_times[-20:]))
        if len(step_times) > 5 and dt > args.straggler_factor * med:
            print(f"[straggler] step {step}: {dt:.2f}s vs median "
                  f"{med:.2f}s -- at pod scale this triggers re-slicing",
                  flush=True)
        if (step + 1) % 10 == 0:
            print(f"step {step+1:4d} loss {loss:.3f} "
                  f"({args.batch*args.seq/dt:,.0f} tok/s)", flush=True)
        if mgr is not None and ((step + 1) % args.ckpt_every == 0
                                or stop["now"]):
            mgr.save_async(step + 1, state)
        if stop["now"]:
            mgr and mgr.wait()
            return 0
    mgr and mgr.wait()
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
