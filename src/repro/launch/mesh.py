"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state -- required because the dry-run forces 512
host devices via XLA_FLAGS before any jax init, while tests/benches must
see a single CPU device.

`make_detection_mesh` is the detection-side default: the sharded
detect_batch path (core/detector.py) lays its frame batch over the
1-D 'data' axis of this mesh, one B/n_devices sub-batch per chip.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axes: ('pod',) 'data', 'model' -- see DESIGN.md §5. The 'pod' axis
    carries only gradient all-reduces / pipeline hops (slow inter-pod
    links); 'data' is FSDP + batch; 'model' is TP/EP/SP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if not 1 <= model <= n:
        # without this guard, model > n makes data = n // model == 0 and
        # the reshape below dies with an opaque numpy size-mismatch error
        raise ValueError(
            f"make_host_mesh(model={model}): the host has {n} visible "
            f"device(s) (jax.devices()); 'model' must be in [1, {n}]")
    data = n // model
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def make_detection_mesh(data_parallel: int = 0) -> Mesh:
    """1-D 'data' mesh for sharded detection -- the detection default.

    `data_parallel=0` takes every visible device (the host-mesh data
    axis with model=1); `n > 0` takes exactly the first n devices and
    raises a clear ValueError when the host has fewer. The sharded
    detect_batch program (core/detector.py:_sharded_batch_fn) shards
    its frame batch over this mesh's 'data' axis.
    """
    n = len(jax.devices())
    data = n if data_parallel == 0 else int(data_parallel)
    if not 1 <= data <= n:
        raise ValueError(
            f"make_detection_mesh(data_parallel={data_parallel}): the "
            f"host has {n} visible device(s) (jax.devices()); "
            f"data_parallel must be 0 (= all) or in [1, {n}]")
    return Mesh(np.asarray(jax.devices()[:data]), ("data",))


def make_tiled_mesh(data_parallel: int = 1, frame_parallel: int = 0) -> Mesh:
    """2-D ('data', 'tile') mesh for intra-frame tiled detection.

    The frame batch is sharded over 'data' (as in make_detection_mesh)
    and each frame's pyramid work is split over 'tile' -- the tiled
    detect programs (core/detector.py:_tiled_single_fn /
    _tiled_batch_fn) run their per-tile local top-k under shard_map on
    this mesh. `frame_parallel=0` takes every device left over after
    the data axis; single-frame tiled latency uses data_parallel=1 with
    'tile' spanning the host (DESIGN.md §11).
    """
    n = len(jax.devices())
    dp = n if data_parallel == 0 else int(data_parallel)
    if dp < 1 or dp > n:
        raise ValueError(
            f"make_tiled_mesh(data_parallel={data_parallel}): the host "
            f"has {n} visible device(s) (jax.devices()); data_parallel "
            f"must be 0 (= all) or in [1, {n}]")
    fp = (n // dp) if frame_parallel == 0 else int(frame_parallel)
    if fp < 1 or dp * fp > n:
        raise ValueError(
            f"make_tiled_mesh(data_parallel={data_parallel}, "
            f"frame_parallel={frame_parallel}): with {n} visible "
            f"device(s) and data_parallel={dp}, frame_parallel must be "
            f"0 (= all remaining) or in [1, {n // dp}]")
    devs = np.asarray(jax.devices()[: dp * fp]).reshape(dp, fp)
    return Mesh(devs, ("data", "tile"))
