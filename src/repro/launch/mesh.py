"""Production mesh builders. Functions (not module constants) so importing
never touches jax device state -- required because the dry-run forces 512
host devices via XLA_FLAGS before any jax init, while tests/benches must
see a single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axes: ('pod',) 'data', 'model' -- see DESIGN.md §5. The 'pod' axis
    carries only gradient all-reduces / pipeline hops (slow inter-pod
    links); 'data' is FSDP + batch; 'model' is TP/EP/SP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = n // model
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))
